"""Kernel microbenchmarks: Pallas (interpret) vs reference paths, plus the
analytic VMEM/roofline accounting for the fused kernel on TPU v5e.

Interpret-mode wall times are NOT TPU times — the derived metrics carry
the structural numbers that transfer: bytes streamed per output tile,
VMEM working set, and arithmetic intensity of the fused kernel vs the
dequant-then-matmul baseline.

Emits ``BENCH_kernels.json`` at the repo root (schema: benchmarks/common.py)
so every perf PR is measured against its predecessors, and mirrors the
legacy ``name,us_per_call,derived`` CSV to stdout.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BenchSuite, timeit
from repro.core import formats, qlinear
from repro.kernels import autotune
from repro.kernels.itq3_matvec import MATVEC_MAX_M

BLOCK = 256


# Per 256-weight block the kernel streams 96 bytes of packed planes PLUS
# the dequant metadata: one f32 scale + one f32 zero-point (the wrappers
# upcast the stored f16 before the pallas_call), 8 bytes. Counting codes
# only overstated arithmetic intensity by ~8%.
SCALE_ZP_BYTES = 8
PACKED_BYTES = 96  # plane2 (64) + plane1 (32)


def kernel_accounting(m, n, k, tm, tn, bpw=3.125):
    kb = k // BLOCK
    # per output tile (tm x tn): packed weights + scale planes stream once
    # per k-block
    wbytes = tn * kb * (PACKED_BYTES + SCALE_ZP_BYTES)
    xbytes = tm * k * 2  # bf16 activations
    obytes = tm * tn * 4
    flops = 2 * m * n * k + 2 * n * k * BLOCK  # matmul + in-kernel rotation
    vmem = (tm * BLOCK * 4 + tn * (64 + 32 + 8) + BLOCK * BLOCK * 4
            + tm * tn * 4 + tn * BLOCK * 4)
    # ceil-div: ragged shapes still stream a full tile per partial tile
    # (floor-div undercounted, or zeroed the traffic outright for m < tm)
    m_tiles = -(-m // tm)
    n_tiles = -(-n // tn)
    ai = flops / (wbytes * m_tiles + xbytes * n_tiles + obytes)
    return wbytes, vmem, ai


def streamed_mb(n, k) -> float:
    """Total HBM bytes for one full pass over a quantized (K, N) operand:
    packed codes at 3.125 bits/weight + the per-block scale/zp planes."""
    blocks = n * (k // BLOCK)
    return (blocks * (PACKED_BYTES + SCALE_ZP_BYTES)) / 1e6


def add_int8_records(suite: BenchSuite, *, smoke: bool = False) -> None:
    """W3A8 integer-path records (``kernel/int8_*``): the rotation-domain
    int8 contraction vs the float dequant-then-matmul baseline, measured in
    the SAME regime (both jitted XLA on this host — the ref int8 path
    carries the integer MACs in f32, bit-identical to the kernels' int32
    accumulators, see ``TernaryFormat.contract_int8``). Bytes accounting:
    packed ternary weights + scale/zp planes + 1-byte int8 activations,
    against the dequant baseline's full bf16 weight + f32 activation
    stream."""
    rng = np.random.default_rng(0)
    shapes = ([("matvec", 8, 512, 512)] if smoke else
              [("matvec", 8, 2048, 2048),      # decode-width (MMVQ class)
               ("tiled", 256, 2048, 2048),     # batch decode / small prefill
               ("prefill", 512, 2048, 2048)])  # chunked-prefill width
    iters = 1 if smoke else 2
    for label, m, n, k in shapes:
        w = jnp.asarray(rng.normal(size=(k, n)) * 0.02, jnp.float32)
        x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
        qt = formats.quantize(w, "itq3_s")
        dequant = jax.jit(functools.partial(qlinear.qmatmul, mode="dequant",
                                            compute_dtype=jnp.float32))
        us_dq = timeit(dequant, x, qt, iters=iters)
        int8 = jax.jit(functools.partial(qlinear.qmatmul, mode="activations",
                                         backend="ref", act_quant=True,
                                         compute_dtype=jnp.float32))
        us_i8 = timeit(int8, x, qt, iters=iters)
        int8_mb = streamed_mb(n, k) + (m * k * 1 + m * 4 + m * n * 4) / 1e6
        dq_mb = (2 * k * n + 4 * m * k + 4 * m * n) / 1e6
        suite.add(f"kernel/int8_{label}_m{m}", us_i8,
                  dequant_us=round(us_dq, 2),
                  speedup_vs_dequant=round(us_dq / us_i8, 2),
                  bytes_streamed_total_mb=round(int8_mb, 2),
                  dequant_bytes_streamed_mb=round(dq_mb, 2),
                  bytes_ratio_vs_dequant=round(int8_mb / dq_mb, 3),
                  act_bytes_per_elt=1,
                  note="jit XLA walltime for both paths (host-comparative)")


def main(smoke: bool = False) -> None:
    suite = BenchSuite("kernels", smoke=smoke)
    rng = np.random.default_rng(0)
    shapes = [(8, 512, 512)] if smoke else [(8, 2048, 2048), (256, 2048, 2048)]
    iters = 1 if smoke else 2
    for (m, n, k) in shapes:
        w = jnp.asarray(rng.normal(size=(k, n)) * 0.02, jnp.float32)
        x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
        qt = formats.quantize(w, "itq3_s")
        tm, tn = autotune.get_tiles(m, n, k, "itq3_s", interpret=True)
        tm = min(tm, m)
        kernel_name = "matvec" if m <= MATVEC_MAX_M else "tiled"

        ref = jax.jit(functools.partial(qlinear.qmatmul, mode="dequant",
                                        compute_dtype=jnp.float32))
        us_ref = timeit(ref, x, qt, iters=iters)
        wb, vmem, ai = kernel_accounting(m, n, k, tm, tn)
        suite.add(f"kernel/ref_dequant_m{m}", us_ref,
                  streams_full_bf16_weights_mb=round(2 * k * n / 1e6, 1))
        us_k = timeit(functools.partial(qlinear.qmatmul, mode="weights",
                                        backend="pallas", interpret=True),
                      x, qt, iters=1)
        suite.add(f"kernel/fused_weights_m{m}", us_k,
                  kernel=kernel_name, tm=tm, tn=tn,
                  bytes_streamed_packed_mb=round(k * n * 3.125 / 8 / 1e6, 2),
                  bytes_streamed_total_mb=round(streamed_mb(n, k), 2),
                  vmem_tile_kb=round(vmem / 1024),
                  arith_intensity_flops_per_byte=round(ai, 1),
                  note="interpret-mode walltime")
        us_a = timeit(functools.partial(qlinear.qmatmul, mode="activations",
                                        backend="pallas", interpret=True),
                      x, qt, iters=1)
        suite.add(f"kernel/fused_activations_m{m}", us_a,
                  kernel=kernel_name,
                  rotations_per_matmul=k // BLOCK,
                  weight_side_rotations=n * k // BLOCK // BLOCK)
        if m > MATVEC_MAX_M:
            # hoisted-vs-flat: the weight-tile reuse win at prefill widths
            from repro.kernels.itq3_matmul import itq3_matmul_pallas
            args = (x, qt.data["plane2"], qt.data["plane1"],
                    qt.data["scales"], qt.data["zps"])
            for hoist in (True, False):
                fn = functools.partial(itq3_matmul_pallas, tm=128, tn=tn,
                                       interpret=True, hoist=hoist)
                us_h = timeit(fn, *args, iters=1)
                suite.add(f"kernel/tiled_m{m}_hoist_{hoist}", us_h,
                          tile_expansions=(n // tn) * (k // BLOCK)
                          * (1 if hoist else -(-m // 128)))
    add_int8_records(suite, smoke=smoke)
    from benchmarks.attn_bench import add_kernel_records, add_prefill_records
    add_kernel_records(suite, smoke=smoke)
    add_prefill_records(suite, smoke=smoke)
    suite.write()


if __name__ == "__main__":
    main()
