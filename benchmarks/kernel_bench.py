"""Kernel microbenchmarks: Pallas (interpret) vs reference paths, plus the
analytic VMEM/roofline accounting for the fused kernel on TPU v5e.

Interpret-mode wall times are NOT TPU times — the derived column carries
the structural numbers that transfer: bytes streamed per output tile,
VMEM working set, and arithmetic intensity of the fused kernel vs the
dequant-then-matmul baseline.

CSV: name,us_per_call,derived
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core import formats, qlinear

BLOCK = 256


def kernel_accounting(m, n, k, tm, tn, bpw=3.125):
    kb = k // BLOCK
    # per output tile (tm x tn): packed weights stream once per k-block
    wbytes = tn * kb * (96 + 4)  # planes + scales/zps
    xbytes = tm * k * 2  # bf16 activations
    obytes = tm * tn * 4
    flops = 2 * m * n * k + 2 * n * k * BLOCK  # matmul + in-kernel rotation
    vmem = (tm * BLOCK * 4 + tn * (64 + 32 + 8) + BLOCK * BLOCK * 4
            + tm * tn * 4 + tn * BLOCK * 4)
    ai = flops / (wbytes * (m // tm) + xbytes * (n // tn) + obytes)
    return wbytes, vmem, ai


def main() -> None:
    rng = np.random.default_rng(0)
    for (m, n, k) in [(8, 2048, 2048), (256, 2048, 2048)]:
        w = jnp.asarray(rng.normal(size=(k, n)) * 0.02, jnp.float32)
        x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
        qt = formats.quantize(w, "itq3_s")

        ref = jax.jit(functools.partial(qlinear.qmatmul, mode="dequant",
                                        compute_dtype=jnp.float32))
        us_ref = timeit(ref, x, qt, iters=2)
        wb, vmem, ai = kernel_accounting(m, n, k, min(m, 256), 256)
        emit(f"kernel/ref_dequant_m{m}", us_ref,
             f"streams_full_bf16_weights={2*k*n/1e6:.1f}MB")
        us_k = timeit(functools.partial(qlinear.qmatmul, mode="weights",
                                        backend="pallas", interpret=True,
                                        tm=min(m, 256), tn=256), x, qt, iters=1)
        emit(f"kernel/fused_weights_m{m}", us_k,
             f"streams_packed={k*n*3.125/8/1e6:.1f}MB vmem_tile={vmem/1024:.0f}KB "
             f"arith_intensity={ai:.1f}flops/B (interpret-mode walltime)")
        us_a = timeit(functools.partial(qlinear.qmatmul, mode="activations",
                                        backend="pallas", interpret=True,
                                        tm=min(m, 256), tn=256), x, qt, iters=1)
        emit(f"kernel/fused_activations_m{m}", us_a,
             f"rotations_per_matmul={k//BLOCK} (vs {n*k//BLOCK//BLOCK} weight-side)")


if __name__ == "__main__":
    main()
