"""Paper §3.1 (Theorem 1 / Corollary 1): distribution smoothing by FWHT.

Measures, on heavy-tailed weights: excess kurtosis before/after rotation
(-> ~0, Gaussian), the l_inf/sigma reduction factor (Cor. 1 predicts
~sqrt(2 log n) ~ 3.3 at n=256 for the rotated side), and the optimal-scale
fit quality (post-rotation empirical MSE at alpha* vs the Gaussian oracle).

CSV: name,us_per_call,derived
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core import grids
from repro.core.fwht import fwht
import jax
import jax.numpy as jnp


def main() -> None:
    rng = np.random.default_rng(0)
    for dist, sample in [
        ("student_t4", lambda: rng.standard_t(df=4, size=(4096, 256))),
        ("laplace", lambda: rng.laplace(size=(4096, 256))),
        ("outlier_cols", lambda: rng.normal(size=(4096, 256))
            + 20.0 * (rng.random((4096, 256)) < 0.002) * rng.normal(size=(4096, 256))),
    ]:
        w = np.asarray(sample(), np.float32)
        wr = np.asarray(fwht(jnp.asarray(w)))

        def stats(a):
            s = a.std(axis=-1, keepdims=True)
            kurt = np.mean(((a - a.mean(-1, keepdims=True)) / s) ** 4) - 3.0
            linf = np.mean(np.abs(a).max(-1) / s[:, 0])
            return kurt, linf

        k0, l0 = stats(w)
        k1, l1 = stats(wr)
        us = timeit(jax.jit(fwht), jnp.asarray(w))
        emit(f"theory/{dist}", us,
             f"kurtosis {k0:+.2f}->{k1:+.2f} linf/sigma {l0:.2f}->{l1:.2f} "
             f"(gauss kurt=0, E[linf/sigma]~3.3)")

        # post-rotation MSE at the three scale rules vs Gaussian oracle
        sig = wr.std(-1, keepdims=True)
        for rule, c in grids.SCALE_RULES.items():
            q = np.clip(np.round(wr / (c * sig)), -1, 1) * (c * sig)
            emp = np.mean((wr - q) ** 2 / sig ** 2)
            oracle = float(grids.ternary_mse(c))
            emit(f"theory/{dist}_mse_{rule}", 0.0,
                 f"empirical={emp:.4f} gaussian_oracle={oracle:.4f}")


if __name__ == "__main__":
    main()
