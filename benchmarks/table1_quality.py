"""Paper Table 1 proxy: quality vs format at matched bit-widths.

Trains one reduced model, quantizes it into every format, and reports the
eval-loss delta vs the fp baseline (the PPL-gap analogue). The paper's
claims to reproduce:

  * ITQ3_S closes a large fraction of the 3-bit gap vs the no-rotation
    IQ3_S baseline (paper: 57% of delta-PPL),
  * ITQ3_S beats the QuIP#-style random-rotation variant slightly,
  * the ladder fp16 < q8_0 < q4_0 < itq3 family ordering holds.

Beyond-paper rows: the Lloyd-corrected scale rule and the 5-level itq3_x
escape grid at identical storage cost.

CSV: name,us_per_call(=quantization time),derived(=eval-loss delta and ppl ratio)
"""
from __future__ import annotations

import math
import time

import jax

from benchmarks.common import emit, eval_loss, trained_model
from repro.configs.base import mixed_precision_recipe
from repro.serve.quantized import QuantPolicy, quantize_params, quantized_bytes

FORMATS = [
    ("bf16", "paper"), ("q8_0", "paper"), ("q4_0", "paper"),
    ("iq3_s", "paper"), ("quip3", "paper"),
    ("itq3_s", "paper"), ("itq3_s_sub", "paper"),
    ("itq3_s", "lloyd"), ("itq3_x", "lloyd"),
]


def main() -> None:
    cfg, params, corpus = trained_model()
    base = eval_loss(cfg, params, corpus)
    emit("table1/fp32_baseline", 0.0, f"eval_loss={base:.4f} dppl=1.0")

    rows = {}
    for fmt, rule in FORMATS:
        t0 = time.time()
        q = quantize_params(params, fmt, rule=rule)
        jax.block_until_ready(jax.tree.leaves(q)[0])
        qt_us = (time.time() - t0) * 1e6
        loss = eval_loss(cfg, q, corpus)
        delta = loss - base
        rows[(fmt, rule)] = delta
        emit(f"table1/{fmt}[{rule}]", qt_us,
             f"eval_loss={loss:.4f} delta={delta:+.4f} "
             f"ppl_ratio={math.exp(delta):.4f} bytes={quantized_bytes(q)}")

    # beyond-paper row: the default mixed-precision QuantPolicy (head 8-bit,
    # MLP sub-block scales, rest itq3_s) — the quality/bytes middle ground
    # policy-level control buys (TernaryLLM/Tequila-style).
    t0 = time.time()
    q = quantize_params(params, QuantPolicy.from_dict(mixed_precision_recipe(cfg)))
    jax.block_until_ready(jax.tree.leaves(q)[0])
    qt_us = (time.time() - t0) * 1e6
    loss = eval_loss(cfg, q, corpus)
    emit("table1/policy_mixed", qt_us,
         f"eval_loss={loss:.4f} delta={loss-base:+.4f} "
         f"bytes={quantized_bytes(q)}")

    # the paper's headline: fraction of the 3-bit gap closed by rotation
    gap_iq3 = rows[("iq3_s", "paper")]
    gap_itq3 = rows[("itq3_s", "paper")]
    if gap_iq3 > 0:
        closed = 100.0 * (1.0 - gap_itq3 / gap_iq3)
        emit("table1/rotation_gap_closed", 0.0,
             f"pct={closed:.1f} (paper claims 57% on LLaMA-3 8B)")


if __name__ == "__main__":
    main()
