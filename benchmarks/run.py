"""Benchmark harness entry point: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only table1,...]

Emits ``name,us_per_call,derived`` CSV rows (stdout). The quality tables
train/cache a small model on first run (see benchmarks/common.py).
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

MODULES = [
    ("table1", "benchmarks.table1_quality"),
    ("table2", "benchmarks.table2_throughput"),
    ("table3", "benchmarks.table3_blocksize"),
    ("theory", "benchmarks.theory_smoothing"),
    ("kernel", "benchmarks.kernel_bench"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: "
                         + ",".join(name for name, _ in MODULES))
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failed = []
    for name, modname in MODULES:
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            mod = __import__(modname, fromlist=["main"])
            mod.main()
            print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception:
            failed.append(name)
            print(f"# {name} FAILED:\n{traceback.format_exc()}", file=sys.stderr)
    if failed:
        sys.exit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
