"""Benchmark harness entry point: one module per paper table/figure, plus
the perf-trajectory suites.

    PYTHONPATH=src python -m benchmarks.run [--only table1,...] [--smoke]

Emits ``name,us_per_call,derived`` CSV rows (stdout); the ``kernel`` and
``serve`` suites additionally write machine-readable ``BENCH_kernels.json``
and ``BENCH_serve.json`` at the repo root — the perf record every future
PR is measured against (ROADMAP.md bench-trajectory convention).

``--smoke`` runs only the JSON-emitting suites at reduced sizes — the CI
bench job (fast, validates schema, uploads artifacts). Smoke output lands
in ``BENCH_*.smoke.json`` so a quick post-run smoke can never overwrite
the committed full-size trajectory; CI fails if a committed BENCH_*.json
ever carries ``smoke: true`` records.
"""
from __future__ import annotations

import argparse
import inspect
import sys
import time
import traceback

MODULES = [
    ("table1", "benchmarks.table1_quality"),
    ("table2", "benchmarks.table2_throughput"),
    ("table3", "benchmarks.table3_blocksize"),
    ("theory", "benchmarks.theory_smoothing"),
    ("kernel", "benchmarks.kernel_bench"),
    ("serve", "benchmarks.serve_bench"),
    # decode-attention records are embedded in the kernel/serve suites
    # above (benchmarks/attn_bench.py); running the module here too would
    # measure everything twice. `python -m benchmarks.attn_bench` runs it
    # standalone (CSV only, JSON trajectory untouched).
]
SMOKE_MODULES = ("kernel", "serve")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: "
                         + ",".join(name for name, _ in MODULES))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced-size run of the BENCH_*.json suites only")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    if args.smoke and only is None:
        only = set(SMOKE_MODULES)

    print("name,us_per_call,derived")
    failed = []
    for name, modname in MODULES:
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            mod = __import__(modname, fromlist=["main"])
            if "smoke" in inspect.signature(mod.main).parameters:
                mod.main(smoke=args.smoke)
            else:
                mod.main()
            print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception:
            failed.append(name)
            print(f"# {name} FAILED:\n{traceback.format_exc()}", file=sys.stderr)
    if failed:
        sys.exit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
