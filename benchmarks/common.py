"""Shared benchmark utilities: a small trained model (cached across
benchmarks), timing helpers, CSV emission.

All quality benchmarks run on a reduced-config model trained on the
synthetic corpus — the CPU-feasible stand-in for the paper's LLaMA-3 +
WikiText-2 setup. What must reproduce is the *ordering and relative gaps*
between formats (Table 1) and block sizes (Table 3), not absolute PPL.
"""
from __future__ import annotations

import json
import os
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.configs.base import get_config, reduced
from repro.data.pipeline import SyntheticCorpus
from repro.models import lm
from repro.models.layers import Runtime
from repro.train import loop as tl

CACHE_DIR = os.environ.get("BENCH_CACHE", "/tmp/repro_bench_cache")
RT = Runtime(compute_dtype=jnp.float32)
TRAIN_STEPS = int(os.environ.get("BENCH_TRAIN_STEPS", "300"))

BENCH_SCHEMA = "repro.bench.v1"

# Records whose name starts with a prefix below must carry the listed
# metric keys — the CI bench-smoke job validates the request-lifecycle
# serving records (scheduler TTFT/queue-wait, cache-donation no-copy)
# through the same schema gate as everything else.
REQUIRED_METRICS_BY_PREFIX = {
    "kernel/int8_": ("dequant_us", "speedup_vs_dequant",
                     "bytes_streamed_total_mb", "bytes_ratio_vs_dequant"),
    "serve/sched_": ("policy", "ttft_ms", "queue_wait_ms", "tok_s", "tokens"),
    "serve/cache_donation": ("donated", "bytes_moved", "decode_steps"),
    "serve/tp": ("tok_s", "cache_bytes_per_device"),
    "serve/faults_": ("quarantined", "deadline_expired", "rejected", "shed",
                      "preempted", "resumed", "tok_s", "tokens"),
    "serve/paged_": ("tok_s", "pool_utilization", "max_concurrent"),
    "serve/spec_": ("tok_s", "acceptance_rate", "tokens_per_step"),
    "serve/calibration": ("wall_ms",),
}

# Serving-SLO metrics the regression gate watches on serve/sched_* records,
# with the direction that counts as WORSE.
SLO_METRIC_SENSE = {
    "ttft_ms": "lower",        # lower is better
    "queue_wait_ms": "lower",
    "tok_s": "higher",         # higher is better
}

# Machine-speed calibration: the serve suite stamps a ``serve/calibration``
# record holding the wall time of this fixed jitted workload on the machine
# that produced the trajectory. The SLO gate re-times the same workload and
# widens its tolerance by the speed ratio when the checking machine is
# SLOWER than the recording machine — absolute wall-clock SLOs only
# transfer between machines after normalization.
CALIBRATION_RECORD = "serve/calibration"


def calibration_wall_ms(iters: int = 5) -> float:
    """Median wall ms of a fixed jitted workload — the machine-speed probe
    behind ``serve/calibration``. Deliberately tiny (a few matmul+reduce
    steps on a (256, 256) operand) so stamping it costs nothing next to
    the serve suite itself."""
    x = jnp.asarray(np.random.default_rng(0).normal(size=(256, 256)),
                    jnp.float32)

    @jax.jit
    def probe(a):
        for _ in range(8):
            a = jnp.tanh(a @ a.T) / 16.0
        return a.sum()

    jax.block_until_ready(probe(x))  # compile outside the timed region
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(probe(x))
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e3)


def calibration_ratio(committed_records, fresh_records) -> float:
    """fresh/committed machine slowdown from the two ``serve/calibration``
    stamps; 1.0 when either side lacks one (gate falls back to the raw
    tolerance)."""
    def wall(records):
        for r in records:
            if r.get("name") == CALIBRATION_RECORD:
                w = r.get("metrics", {}).get("wall_ms")
                if isinstance(w, (int, float)) and w > 0:
                    return float(w)
        return None

    was, now = wall(committed_records), wall(fresh_records)
    if was is None or now is None:
        return 1.0
    return now / was


def slo_regressions(committed_records, fresh_records, *, max_ratio: float,
                    prefix: str = "serve/sched_",
                    require_all: bool = False) -> list[str]:
    """Compare a fresh run's ``serve/sched_*`` SLO metrics against the
    committed trajectory. Returns a list of human-readable violations —
    empty means the gate passes. A metric regresses when it is worse by
    more than ``max_ratio``x (TTFT/queue-wait up, tok/s down); only
    records present in BOTH sets are compared unless ``require_all``,
    which also flags committed records the fresh run dropped (a silently
    deleted policy is itself a regression)."""
    old = {r["name"]: r.get("metrics", {}) for r in committed_records
           if r["name"].startswith(prefix)}
    new = {r["name"]: r.get("metrics", {}) for r in fresh_records
           if r["name"].startswith(prefix)}
    problems = []
    if require_all:
        for name in sorted(set(old) - set(new)):
            problems.append(f"{name}: present in committed trajectory but "
                            f"missing from the fresh run")
    for name in sorted(set(old) & set(new)):
        for metric, sense in SLO_METRIC_SENSE.items():
            was, now = old[name].get(metric), new[name].get(metric)
            if not isinstance(was, (int, float)) or not isinstance(
                    now, (int, float)) or was <= 0 or now <= 0:
                continue
            ratio = (now / was) if sense == "lower" else (was / now)
            if ratio > max_ratio:
                worse = "rose" if sense == "lower" else "fell"
                problems.append(
                    f"{name}: {metric} {worse} {was:.2f} -> {now:.2f} "
                    f"({ratio:.2f}x worse > {max_ratio:.2f}x tolerance)")
    return problems


def assert_no_slo_regression(committed_path, fresh_records, *,
                             max_ratio: float | None = None,
                             require_all: bool = False) -> None:
    """The serving-SLO gate: raise if a fresh run's scheduler records
    regress beyond tolerance against the COMMITTED ``BENCH_serve.json``.
    Tolerance defaults to ``SERVE_SLO_MAX_RATIO`` (env, default 2.0 —
    generous because CI machines differ; the gate exists to catch
    order-of-magnitude lifecycle regressions, not wall-clock noise). When
    both sides carry a ``serve/calibration`` stamp the tolerance is
    additionally widened by the measured machine slowdown — see
    :func:`calibration_ratio`."""
    if max_ratio is None:
        max_ratio = float(os.environ.get("SERVE_SLO_MAX_RATIO", "2.0"))
    committed = load_and_validate(committed_path, forbid_smoke=True)
    # machine-aware widening: a checker that is N x slower than the machine
    # that recorded the trajectory gets N x more wall-clock headroom (a
    # FASTER checker keeps the raw tolerance — speed never hides a
    # regression, it only stops a slow machine from faking one)
    cal = calibration_ratio(committed["records"], fresh_records)
    effective = max_ratio * max(1.0, cal)
    problems = slo_regressions(committed["records"], fresh_records,
                               max_ratio=effective, require_all=require_all)
    if problems:
        raise AssertionError(
            "serving SLO regression vs committed trajectory "
            f"({committed_path}):\n  " + "\n  ".join(problems)
            + f"\n(effective tolerance {effective:.2f}x = {max_ratio:.2f}x "
              f"base * {max(1.0, cal):.2f}x machine calibration; raise "
              "SERVE_SLO_MAX_RATIO to override a known machine mismatch)")


def repo_root() -> Path:
    return Path(__file__).resolve().parents[1]


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.2f},{derived}")


class BenchSuite:
    """Machine-readable bench emission: collects records and writes the
    repo-root ``BENCH_<suite>.json`` that tracks the perf trajectory across
    PRs (see ROADMAP.md). Also mirrors each record to the legacy CSV.

    Smoke runs land in ``BENCH_<suite>.smoke.json`` instead: a quick
    ``--smoke`` pass after the full regeneration must never overwrite the
    committed full-size trajectory (a documented pitfall — smoke-sized
    records silently destroyed the record set)."""

    def __init__(self, suite: str, *, smoke: bool = False):
        self.suite = suite
        self.smoke = smoke
        self.records: list[dict] = []

    def add(self, name: str, us_per_call: float | None = None, **metrics):
        rec: dict = {"name": name, "metrics": metrics}
        if us_per_call is not None:
            rec["us_per_call"] = round(float(us_per_call), 2)
        self.records.append(rec)
        derived = " ".join(f"{k}={v}" for k, v in metrics.items())
        emit(name, us_per_call if us_per_call is not None else float("nan"),
             derived)
        return rec

    def write(self, path: str | Path | None = None) -> Path:
        if path is None:
            stem = (f"BENCH_{self.suite}.smoke.json" if self.smoke
                    else f"BENCH_{self.suite}.json")
            path = repo_root() / stem
        path = Path(path)
        doc = {
            "schema": BENCH_SCHEMA,
            "suite": self.suite,
            "smoke": self.smoke,
            "device": jax.default_backend(),
            "device_kind": jax.devices()[0].device_kind,
            "jax_version": jax.__version__,
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
            "records": self.records,
        }
        validate_bench_doc(doc)
        with open(path, "w") as f:
            json.dump(doc, f, indent=1)
            f.write("\n")
        return path


def validate_bench_doc(doc: dict) -> None:
    """Schema check for BENCH_*.json (raises ValueError). Used by the CI
    bench-smoke job so a malformed trajectory file fails the build."""
    if doc.get("schema") != BENCH_SCHEMA:
        raise ValueError(f"bad schema tag: {doc.get('schema')!r}")
    for field in ("suite", "device", "records"):
        if field not in doc:
            raise ValueError(f"missing field {field!r}")
    if not isinstance(doc["records"], list) or not doc["records"]:
        raise ValueError("records must be a non-empty list")
    for rec in doc["records"]:
        if not isinstance(rec.get("name"), str):
            raise ValueError(f"record without name: {rec!r}")
        if "us_per_call" in rec and not isinstance(
                rec["us_per_call"], (int, float)):
            raise ValueError(f"non-numeric us_per_call in {rec['name']}")
        if not isinstance(rec.get("metrics", {}), dict):
            raise ValueError(f"metrics must be a dict in {rec['name']}")
        for prefix, required in REQUIRED_METRICS_BY_PREFIX.items():
            if rec["name"].startswith(prefix):
                missing = [k for k in required if k not in rec["metrics"]]
                if missing:
                    raise ValueError(
                        f"record {rec['name']} missing metrics {missing}")


def load_and_validate(path: str | Path, *, forbid_smoke: bool = False) -> dict:
    """Load + schema-check a BENCH_*.json. ``forbid_smoke=True`` is the CI
    gate for the COMMITTED trajectory files: a smoke-sized record set there
    means a post-run smoke overwrote the full regeneration."""
    with open(path) as f:
        doc = json.load(f)
    validate_bench_doc(doc)
    if forbid_smoke and doc.get("smoke"):
        raise ValueError(
            f"{path} contains smoke-sized records (smoke=true): the "
            f"committed trajectory must come from a full run — regenerate "
            f"with `python -m benchmarks.run --only kernel,serve`")
    return doc


def timeit(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time per call in microseconds (CPU; comparative only)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


def trained_model(arch: str = "smollm-135m", steps: int = TRAIN_STEPS):
    """Train (or load cached) reduced model on the synthetic corpus."""
    cfg = reduced(get_config(arch))
    corpus = SyntheticCorpus(cfg.vocab_size, seed=3)
    cdir = os.path.join(CACHE_DIR, f"{arch}_{steps}")
    state = tl.init_train_state(jax.random.PRNGKey(0), cfg)
    if ckpt.latest_step(cdir) == steps:
        state, _ = ckpt.restore(cdir, state)
        return cfg, state.params, corpus
    step = jax.jit(tl.make_train_step(cfg, RT, warmup=10, total_steps=steps,
                                      lr_peak=3e-3))
    for s in range(steps):
        b = corpus.batch(s, 16, 64)
        state, m = step(state, {k: jnp.asarray(v) for k, v in b.items()})
    ckpt.save(cdir, steps, state)
    return cfg, state.params, corpus


def eval_loss(cfg, params, corpus, n: int = 6) -> float:
    tot = 0.0
    for b in corpus.eval_batches(n, 8, 64):
        loss, _ = lm.forward_xent(params, jnp.asarray(b["tokens"]),
                                  jnp.asarray(b["labels"]), RT, cfg)
        tot += float(loss)
    return tot / n
