"""Shared benchmark utilities: a small trained model (cached across
benchmarks), timing helpers, CSV emission.

All quality benchmarks run on a reduced-config model trained on the
synthetic corpus — the CPU-feasible stand-in for the paper's LLaMA-3 +
WikiText-2 setup. What must reproduce is the *ordering and relative gaps*
between formats (Table 1) and block sizes (Table 3), not absolute PPL.
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.configs.base import get_config, reduced
from repro.data.pipeline import SyntheticCorpus
from repro.models import lm
from repro.models.layers import Runtime
from repro.train import loop as tl

CACHE_DIR = os.environ.get("BENCH_CACHE", "/tmp/repro_bench_cache")
RT = Runtime(compute_dtype=jnp.float32)
TRAIN_STEPS = int(os.environ.get("BENCH_TRAIN_STEPS", "300"))


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.2f},{derived}")


def timeit(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time per call in microseconds (CPU; comparative only)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


def trained_model(arch: str = "smollm-135m", steps: int = TRAIN_STEPS):
    """Train (or load cached) reduced model on the synthetic corpus."""
    cfg = reduced(get_config(arch))
    corpus = SyntheticCorpus(cfg.vocab_size, seed=3)
    cdir = os.path.join(CACHE_DIR, f"{arch}_{steps}")
    state = tl.init_train_state(jax.random.PRNGKey(0), cfg)
    if ckpt.latest_step(cdir) == steps:
        state, _ = ckpt.restore(cdir, state)
        return cfg, state.params, corpus
    step = jax.jit(tl.make_train_step(cfg, RT, warmup=10, total_steps=steps,
                                      lr_peak=3e-3))
    for s in range(steps):
        b = corpus.batch(s, 16, 64)
        state, m = step(state, {k: jnp.asarray(v) for k, v in b.items()})
    ckpt.save(cdir, steps, state)
    return cfg, state.params, corpus


def eval_loss(cfg, params, corpus, n: int = 6) -> float:
    tot = 0.0
    for b in corpus.eval_batches(n, 8, 64):
        loss, _ = lm.forward_xent(params, jnp.asarray(b["tokens"]),
                                  jnp.asarray(b["labels"]), RT, cfg)
        tot += float(loss)
    return tot / n
