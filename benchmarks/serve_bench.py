"""Serve-path benchmark: the decode hot loop, measured end to end.

Runs the continuous-batching engine on a reduced model (random init — this
measures plumbing, not quality) in both sampling modes:

  * ``host``   — the pre-overhaul decode discipline: logits shipped out of
    the jitted step, one host argmax (= one device->host sync) per active
    slot per step.
  * ``device`` — the overhauled path: sampling inside the jitted decode,
    one (slots,) token-vector transfer per step.

and records tok/s, wall seconds, host syncs per decoded token, and the
derived speedup. Greedy decoding makes the two modes token-identical, which
is asserted — a perf number for a wrong answer is worthless.

Each engine is run once untimed (jit warmup) and then timed on a fresh
request batch; engines are reused across batches so compile time never
lands in the measurement.

Request-lifecycle records (PR 4):

  * ``serve/cache_donation`` — asserts the jitted decode's donated cache
    buffers actually engaged (``cache_bytes_moved == 0``): a regression
    back to per-step functional cache copies fails the bench.
  * ``serve/sched_{fifo,priority,sjf}`` — streams a saturating queue
    through ``ServeEngine.generate`` under each admission policy and
    records mean queue wait, mean TTFT, and end-to-end tok/s.

Resilience records (PR 7):

  * ``serve/robust_overhead`` — the same fifo workload with deadlines,
    a bounded queue, and the watchdog armed: the fault-free cost of the
    resilience layer (token output asserted identical).
  * ``serve/faults_chaos`` — a seeded compound failure scenario (KV-scale
    poison, clock-skip deadline expiry, stalled step, queue overflow,
    priority preemption); asserts every resilience counter moved.
  * The **serving-SLO gate**: before overwriting the committed
    trajectory, a full run is compared against it and fails on
    ``serve/sched_*`` TTFT / queue-wait / tok_s regressions beyond
    ``SERVE_SLO_MAX_RATIO`` (benchmarks/common.py).

Speculative-decoding records (PR 10):

  * ``serve/spec_baseline`` / ``serve/spec_selfdraft`` — the
    propose/verify/commit pipeline on an acceptance-friendly self-draft
    pair (target layers >= 1 are exact no-ops): decode tok/s speedup with
    acceptance rate and mean committed tokens/step, greedy token parity
    asserted.
  * ``serve/calibration`` — wall time of a fixed jitted probe on the
    machine that produced the trajectory; the SLO gate widens its
    tolerance by the measured slowdown when a different machine checks.

Emits ``BENCH_serve.json`` at the repo root (schema: benchmarks/common.py;
the scheduler/donation/fault records carry required metric keys the CI
bench-smoke job validates). Smoke mode writes ``BENCH_serve.smoke.json``
instead — a post-run smoke must never clobber the committed full-size
trajectory.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (
    BenchSuite, CALIBRATION_RECORD, assert_no_slo_regression,
    calibration_wall_ms, repo_root,
)
from repro.configs.base import get_config, reduced
from repro.models import lm
from repro.models.layers import Runtime
from repro.serve.engine import Request, ServeEngine
from repro.serve.quantized import quantize_params

RT = Runtime(compute_dtype=jnp.float32)


def _requests(n: int, vocab: int, max_new: int, seed: int) -> list[Request]:
    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt=rng.integers(0, vocab, size=6 + i % 5),
                    max_new=max_new) for i in range(n)]


def _run_mode(params, cfg, *, sample_on_host: bool, slots: int,
              n_requests: int, max_new: int, max_len: int, repeats: int = 3):
    eng = ServeEngine(params, cfg, slots=slots, max_len=max_len, rt=RT,
                      sample_on_host=sample_on_host)
    eng.run(_requests(n_requests, cfg.vocab_size, max_new, seed=1))  # warmup
    walls, out, tokens = [], None, 0
    syncs0, toks0 = eng.host_syncs, eng.tokens_decoded
    for _ in range(repeats):  # median over repeats: CPU walltime is noisy
        reqs = _requests(n_requests, cfg.vocab_size, max_new, seed=2)
        t0 = time.perf_counter()
        done = eng.run(reqs)
        walls.append(time.perf_counter() - t0)
        tokens = sum(len(r.out) for r in done)
        cur = [r.out for r in done]
        assert out is None or out == cur, "engine run is not deterministic"
        out = cur
    wall = float(np.median(walls))
    return {
        "wall_s": wall,
        "tokens": tokens,
        "tok_s": tokens / wall,
        "host_syncs": (eng.host_syncs - syncs0) // repeats,
        "syncs_per_token": (eng.host_syncs - syncs0) / max(
            eng.tokens_decoded - toks0, 1),
        "out": out,
        "engine_stats": eng.stats(),
    }


def _run_scheduler(params, cfg, *, policy: str, slots: int, n_requests: int,
                   max_new: int, max_len: int, eng_kw: dict | None = None,
                   deadline_ms: float | None = None):
    """Submit a full queue up front and stream via ``generate()``: measures
    the lifecycle numbers admission policy actually moves — queue wait and
    TTFT — plus end-to-end tok/s. Prompt lengths and priorities are spread
    so fifo/priority/sjf produce genuinely different admission orders.
    ``eng_kw``/``deadline_ms`` arm the resilience layer (the
    ``serve/robust_overhead`` record measures its fault-free cost)."""
    eng = ServeEngine(params, cfg, slots=slots, max_len=max_len, rt=RT,
                      scheduler=policy, **(eng_kw or {}))
    rng = np.random.default_rng(5)

    def make():
        return [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size,
                                            size=4 + (i * 7) % 13),
                        max_new=max_new, priority=i % 3,
                        deadline_ms=deadline_ms)
                for i in range(n_requests)]

    for _ in eng.generate(make()):  # warmup: compile every wave shape
        pass
    reqs = make()
    t0 = time.perf_counter()
    n_events = sum(1 for _ in eng.generate(reqs))
    wall = time.perf_counter() - t0
    tokens = sum(len(r.out) for r in reqs)
    assert n_events == tokens, "one StreamEvent per emitted token"
    ttft = float(np.mean([r.t_first - r.t_submit for r in reqs]))
    queue_wait = float(np.mean([r.t_admit - r.t_submit for r in reqs]))
    return {
        "policy": policy,
        "wall_s": wall,
        "tokens": tokens,
        "tok_s": tokens / wall,
        "ttft_ms": 1e3 * ttft,
        "queue_wait_ms": 1e3 * queue_wait,
    }


def add_fault_records(suite: BenchSuite, params, cfg, *, smoke: bool) -> None:
    """``serve/faults_chaos``: drive the engine through a seeded compound
    failure scenario — KV-scale poisoning, deadline expiry via clock skip,
    a stalled step, queue overflow, and priority preemption — and record
    how every resilience path fired. The record asserts each counter
    actually moved: a resilience path that silently stopped firing is a
    regression even when throughput looks fine."""
    from repro.serve.faults import Fault, FaultClock, FaultPlan, burst

    rtq = Runtime(compute_dtype=jnp.float32, kv_quant=True)
    slots = 4
    n_low, n_high = (4, 3) if smoke else (6, 4)
    max_new = 8 if smoke else 16
    clk = FaultClock()
    eng = ServeEngine(params, cfg, slots=slots, max_len=64, rt=rtq,
                      scheduler="priority", clock=clk, max_queue=slots,
                      shed_policy="shed_lowest", watchdog_timeout_s=0.5)
    # warmup compiles the wave shapes WITHOUT arming faults
    for _ in eng.generate(burst(slots, cfg.vocab_size, seed=8,
                                max_new=max_new)):
        pass
    s0 = eng.decode_steps
    eng.faults = FaultPlan([
        Fault("kv_nan", step=s0 + 2, slot=0),
        Fault("clock_skip", step=s0 + 6, dt=1.0),
        Fault("stall", step=s0 + 6, dt=2.0),
    ], clock=clk)
    counters0 = {k: getattr(eng, k) for k in (
        "quarantined", "deadline_expired", "requests_rejected",
        "requests_shed", "preemptions", "resumes", "stalled_steps")}
    toks0 = eng.tokens_decoded
    # low-priority work first (deadline-carrying), then a queue-filling
    # second wave, then a high-priority burst mid-stream: forces
    # shed_lowest overflow AND should_preempt eviction in one run
    lows = burst(slots, cfg.vocab_size, seed=9, max_new=max_new,
                 rid0=100, priority=0, deadline_ms=400.0)
    lows_q = burst(n_low, cfg.vocab_size, seed=9, max_new=max_new,
                   rid0=150, priority=0, deadline_ms=400.0)
    highs = burst(n_high, cfg.vocab_size, seed=10, max_new=max_new,
                  rid0=200, priority=2)
    t0 = time.perf_counter()
    it = eng.generate(lows)
    for _ in range(slots + 2):  # lows are live, mid-decode
        next(it)
    for r in lows_q + highs:
        eng.submit_request(r)
    for _ in it:
        pass
    wall = time.perf_counter() - t0
    reqs = lows + lows_q + highs
    assert all(r.done for r in reqs), "chaos run left unfinished requests"
    delta = {k: getattr(eng, k) - counters0[k] for k in counters0}
    for k in ("quarantined", "deadline_expired", "stalled_steps"):
        assert delta[k] >= 1, f"chaos scenario never exercised {k}"
    assert delta["requests_rejected"] + delta["requests_shed"] >= 1, \
        "chaos burst never overflowed max_queue"
    assert delta["preemptions"] >= 1, "priority burst never preempted"
    tokens = eng.tokens_decoded - toks0
    suite.add("serve/faults_chaos",
              us_per_call=1e6 * wall / max(tokens, 1),
              tok_s=round(tokens / wall, 2),
              tokens=tokens,
              requests=len(reqs),
              quarantined=delta["quarantined"],
              deadline_expired=delta["deadline_expired"],
              rejected=delta["requests_rejected"],
              shed=delta["requests_shed"],
              preempted=delta["preemptions"],
              resumed=delta["resumes"],
              stalled_steps=delta["stalled_steps"],
              all_terminal=True)


def add_paged_records(suite: BenchSuite, params, cfg, *, smoke: bool) -> None:
    """``serve/paged_*``: dense reservation vs the paged block pool at EQUAL
    cache bytes on a heterogeneous-length burst. The dense engine caps
    concurrency at ``slots`` because every slot reserves ``max_len``
    positions; the paged engine only holds blocks for live tokens, so the
    same bytes serve >= 2x the concurrent requests (the acceptance bar this
    record asserts). Token streams are checked identical request-by-request
    — paging must change capacity, never content."""
    rtq = Runtime(compute_dtype=jnp.float32, kv_quant=True)
    max_len, block_size = 64, 16
    dense_slots = 4
    # equal token capacity: dense reserves 4 x 64 = 256 positions; the pool
    # gets 256 / 16 = 16 usable blocks (+ the reserved null block)
    num_blocks = dense_slots * max_len // block_size + 1
    paged_slots = 16
    n = 12 if smoke else 24

    def reqs():
        # per-request tokens (plen + max_new) <= 15: one block each, so the
        # pool can host paged_slots concurrent requests without thrashing
        rng = np.random.default_rng(11)
        return [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size,
                                            size=3 + i % 7).astype(np.int32),
                        max_new=6) for i in range(n)]

    def bench(paged: bool):
        kw = dict(paged=True, num_blocks=num_blocks,
                  block_size=block_size) if paged else {}
        eng = ServeEngine(params, cfg, slots=paged_slots if paged
                          else dense_slots, max_len=max_len, rt=rtq, **kw)
        eng.run(reqs())  # warmup: compile every wave shape
        eng.max_concurrent = 0
        peak_util = 0.0
        batch = reqs()
        t0 = time.perf_counter()
        for _ in eng.generate(batch):
            if paged:
                peak_util = max(peak_util, eng.pool.utilization())
        wall = time.perf_counter() - t0
        st = eng.stats()
        return {"wall_s": wall,
                "tokens": sum(len(r.out) for r in batch),
                "out": {r.rid: list(r.out) for r in batch},
                "max_concurrent": st["max_concurrent"],
                "cache_bytes": st["cache_bytes"],
                "pool_utilization": round(peak_util, 4) if paged else 1.0,
                "stats": st}

    dense = bench(paged=False)
    paged = bench(paged=True)
    assert paged["out"] == dense["out"], \
        "paged engine token streams diverged from dense"
    assert paged["max_concurrent"] >= 2 * dense["max_concurrent"], (
        f"paged concurrency {paged['max_concurrent']} is not >= 2x dense "
        f"{dense['max_concurrent']} at equal cache bytes")
    for name, r, extra in (
            ("serve/paged_dense_baseline", dense,
             dict(slots=dense_slots)),
            ("serve/paged_pool", paged,
             dict(slots=paged_slots, block_size=block_size,
                  pool_blocks=num_blocks - 1,
                  blocks_swapped=paged["stats"]["blocks_swapped"],
                  prefix_hits=paged["stats"]["prefix_hits"],
                  concurrency_vs_dense=round(
                      paged["max_concurrent"]
                      / max(dense["max_concurrent"], 1), 2)))):
        suite.add(name,
                  us_per_call=1e6 * r["wall_s"] / max(r["tokens"], 1),
                  tok_s=round(r["tokens"] / r["wall_s"], 2),
                  wall_s=round(r["wall_s"], 3),
                  tokens=r["tokens"],
                  requests=n,
                  max_concurrent=r["max_concurrent"],
                  pool_utilization=r["pool_utilization"],
                  cache_bytes=r["cache_bytes"],
                  tokens_match=True,
                  **extra)


def add_spec_records(suite: BenchSuite, cfg, *, smoke: bool) -> None:
    """``serve/spec_*``: speculative decoding on an acceptance-friendly
    pair. The target's layers >= 1 get ZERO residual projections (wo/down)
    — each is an exact passthrough, so the 1-layer self-draft computes the
    target's logits and greedy acceptance sits at ~100%. That is the
    honest upper-bound workload for the propose/verify/commit pipeline:
    it isolates the pipeline's speedup (draft steps are cheap, one batched
    verify replaces K+1 decode ticks) from draft quality, which is a
    model-training question, not a serving one. Token parity with the
    non-speculative engine is asserted — a speedup that changes greedy
    output is a bug, not a result."""
    from repro.serve import spec as spec_mod

    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    layers = {k: dict(v) if isinstance(v, dict) else v
              for k, v in params["layers"].items()}
    layers["attn"]["wo"] = layers["attn"]["wo"].at[1:].set(0.0)
    layers["mlp"]["down"] = layers["mlp"]["down"].at[1:].set(0.0)
    params = dict(params, layers=layers)
    qparams = quantize_params(params, "itq3_s")
    draft, dcfg = spec_mod.draft_from_params(qparams, cfg, 1)

    rtq = Runtime(compute_dtype=jnp.float32, kv_quant=True)
    slots = 4
    # long decodes: the speedup under measurement is the DECODE pipeline's;
    # admission prefill (identical work on both sides) must not dilute it
    n, max_new, max_len, k = ((4, 12, 64, 4) if smoke
                              else (8, 96, 128, 8))

    def reqs():
        rng = np.random.default_rng(17)
        return [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size,
                                            size=4 + i % 5).astype(np.int32),
                        max_new=max_new) for i in range(n)]

    def bench(spec_on: bool):
        kw = dict(draft_params=draft, draft_cfg=dcfg,
                  num_draft_tokens=k) if spec_on else {}
        eng = ServeEngine(qparams, cfg, slots=slots, max_len=max_len,
                          rt=rtq, **kw)
        eng.run(reqs())  # warmup: compile every wave shape
        batch = reqs()
        t0 = time.perf_counter()
        eng.run(batch)
        wall = time.perf_counter() - t0
        st = eng.stats()
        return {"wall_s": wall,
                "tokens": sum(len(r.out) for r in batch),
                "out": {r.rid: list(r.out) for r in batch},
                "stats": st}

    base = bench(spec_on=False)
    spec_r = bench(spec_on=True)
    assert spec_r["out"] == base["out"], \
        "greedy speculative streams diverged from the non-speculative engine"
    st = spec_r["stats"]
    speedup = (base["wall_s"] / spec_r["wall_s"])
    assert st["acceptance_rate"] >= 0.9, (
        f"no-op-tail self-draft should verify ~always, got "
        f"{st['acceptance_rate']:.1%}")
    if not smoke:  # smoke batches are too small for stable wall-clock
        assert speedup >= 1.5, (
            f"speculative decode speedup {speedup:.2f}x < 1.5x on the "
            f"acceptance-friendly workload")
    suite.add("serve/spec_baseline",
              us_per_call=1e6 * base["wall_s"] / max(base["tokens"], 1),
              tok_s=round(base["tokens"] / base["wall_s"], 2),
              wall_s=round(base["wall_s"], 3),
              tokens=base["tokens"],
              acceptance_rate=0.0,
              tokens_per_step=1.0,
              slots=slots)
    suite.add("serve/spec_selfdraft",
              us_per_call=1e6 * spec_r["wall_s"] / max(spec_r["tokens"], 1),
              tok_s=round(spec_r["tokens"] / spec_r["wall_s"], 2),
              wall_s=round(spec_r["wall_s"], 3),
              tokens=spec_r["tokens"],
              acceptance_rate=round(st["acceptance_rate"], 4),
              tokens_per_step=round(st["tokens_per_step"], 3),
              speedup_vs_baseline=round(speedup, 3),
              draft_layers=1,
              num_draft_tokens=k,
              spec_steps=st["spec_steps"],
              tokens_match=True,
              slots=slots)


_TP_SCRIPT = textwrap.dedent("""
    import json, time
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import get_config, reduced
    from repro.models import lm
    from repro.models.layers import Runtime
    from repro.serve.engine import Request, ServeEngine
    from repro.serve.quantized import quantize_params
    from repro.launch.mesh import make_host_mesh

    smoke = {smoke}
    cfg = reduced(get_config("qwen1.5-0.5b"))  # kv=4: head-sharded cache
    params = quantize_params(lm.init_params(jax.random.PRNGKey(0), cfg),
                             "itq3_s")
    rt = Runtime(compute_dtype=jnp.float32, kv_quant=True)
    n_requests, max_new = (4, 8) if smoke else (8, 24)

    def reqs(seed):
        rng = np.random.default_rng(seed)
        return [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size, size=6 + i % 5),
                        max_new=max_new) for i in range(n_requests)]

    def bench(mesh, sm):
        eng = ServeEngine(params, cfg, slots=4, max_len=64, rt=rt,
                          mesh=mesh, tp_shard_map=sm)
        eng.run(reqs(1))  # warmup: compile every wave shape
        t0 = time.perf_counter()
        done = eng.run(reqs(2))
        wall = time.perf_counter() - t0
        tokens = sum(len(r.out) for r in done)
        st = eng.stats()
        return {{"wall_s": wall, "tokens": tokens, "tok_s": tokens / wall,
                 "cache_bytes": st["cache_bytes"],
                 "cache_bytes_per_device": st.get("cache_bytes_per_device",
                                                  st["cache_bytes"]),
                 "out": [list(r.out) for r in done]}}

    base = bench(None, None)
    mesh = make_host_mesh(1, 2)
    tp_sm = bench(mesh, True)
    tp_gspmd = bench(mesh, False)
    for r in (tp_sm, tp_gspmd):
        assert r["out"] == base["out"], "TP stream diverged from baseline"
        r["devices"] = mesh.devices.size
    for r in (base, tp_sm, tp_gspmd):
        r.pop("out")
    print("TPBENCH " + json.dumps(
        {{"single": base, "shard_map": tp_sm, "gspmd": tp_gspmd}}))
""")


def add_tp_records(suite: BenchSuite, *, smoke: bool) -> None:
    """``serve/tp*`` records: 2-forced-host-device run of the mesh engine
    (shard_map and GSPMD paths) against the single-device baseline, token
    parity asserted inside the subprocess. Forced host devices measure
    PLUMBING overhead on CPU (a 1-core container shows TP as pure cost) —
    the record's job is tracking that overhead and the per-device cache
    split, not projecting TPU scaling."""
    env = dict(os.environ)
    env.setdefault("JAX_PLATFORMS", jax.default_backend())
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=2 "
                        + env.get("XLA_FLAGS", "")).strip()
    env["PYTHONPATH"] = (str(repo_root() / "src") + os.pathsep
                         + env.get("PYTHONPATH", "")).rstrip(os.pathsep)
    res = subprocess.run(
        [sys.executable, "-c", _TP_SCRIPT.format(smoke=smoke)],
        capture_output=True, text=True, timeout=1800, env=env)
    line = next((ln for ln in res.stdout.splitlines()
                 if ln.startswith("TPBENCH ")), None)
    if line is None:
        raise RuntimeError(f"tp bench subprocess failed:\n"
                           f"{res.stdout}\n{res.stderr}")
    data = json.loads(line[len("TPBENCH "):])
    for name, rec in (("serve/tp_single_device", data["single"]),
                      ("serve/tp_shard_map", data["shard_map"]),
                      ("serve/tp_gspmd", data["gspmd"])):
        suite.add(name,
                  us_per_call=1e6 * rec["wall_s"] / max(rec["tokens"], 1),
                  tok_s=round(rec["tok_s"], 2),
                  wall_s=round(rec["wall_s"], 3),
                  tokens=rec["tokens"],
                  cache_bytes_per_device=rec["cache_bytes_per_device"],
                  cache_bytes=rec["cache_bytes"],
                  devices=rec.get("devices", 1),
                  tokens_match=True)


def main(smoke: bool = False) -> None:
    suite = BenchSuite("serve", smoke=smoke)
    # machine-speed stamp: the SLO gate on FUTURE runs divides out this
    # machine's speed relative to whoever committed the trajectory
    suite.add(CALIBRATION_RECORD, wall_ms=round(calibration_wall_ms(), 3))
    cfg = reduced(get_config("smollm-135m"))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    qparams = quantize_params(params, "itq3_s")

    slots = 4
    n_requests = 4 if smoke else 8
    max_new = 8 if smoke else 24
    max_len = 64

    results = {}
    for mode in ("host", "device"):
        r = _run_mode(qparams, cfg, sample_on_host=(mode == "host"),
                      slots=slots, n_requests=n_requests, max_new=max_new,
                      max_len=max_len, repeats=1 if smoke else 3)
        results[mode] = r
        suite.add(f"serve/decode_{mode}_sampling",
                  us_per_call=1e6 * r["wall_s"] / max(r["tokens"], 1),
                  tok_s=round(r["tok_s"], 2),
                  wall_s=round(r["wall_s"], 3),
                  tokens=r["tokens"],
                  host_syncs=r["host_syncs"],
                  syncs_per_token=round(r["syncs_per_token"], 3),
                  slots=slots)

    if results["host"]["out"] != results["device"]["out"]:
        raise AssertionError("greedy decode diverged between sampling modes")
    host, dev = results["host"], results["device"]
    suite.add("serve/device_vs_host",
              speedup_wall=round(host["wall_s"] / dev["wall_s"], 3),
              syncs_reduction=round(
                  host["syncs_per_token"] / max(dev["syncs_per_token"], 1e-9),
                  2),
              tokens_match=True)

    # donated decode cache: the per-step functional copy must be GONE —
    # a nonzero bytes-moved counter means jit stopped donating in place
    est = dev["engine_stats"]
    if est["cache_bytes_moved"] != 0:
        raise AssertionError(
            f"decode cache copied {est['cache_bytes_moved']} bytes over "
            f"{est['decode_steps']} steps: donation did not engage")
    suite.add("serve/cache_donation",
              donated=bool(est["cache_donated"]),
              bytes_moved=est["cache_bytes_moved"],
              decode_steps=est["decode_steps"],
              cache_bytes=est["cache_bytes"])

    # request-lifecycle scheduling: queue wait / TTFT / tok/s per policy
    sched = {}
    for policy in ("fifo", "priority", "sjf"):
        r = _run_scheduler(qparams, cfg, policy=policy, slots=slots,
                           n_requests=2 * n_requests, max_new=max_new,
                           max_len=max_len)
        sched[policy] = r
        suite.add(f"serve/sched_{policy}",
                  us_per_call=1e6 * r["wall_s"] / max(r["tokens"], 1),
                  policy=policy,
                  ttft_ms=round(r["ttft_ms"], 2),
                  queue_wait_ms=round(r["queue_wait_ms"], 2),
                  tok_s=round(r["tok_s"], 2),
                  tokens=r["tokens"],
                  slots=slots)

    # fault-free cost of the resilience layer: same fifo workload with
    # deadlines armed, a bounded queue, and the watchdog on — the deadline
    # and finiteness checks ride existing transfers, so this should be
    # noise-level (the record tracks that claim across PRs)
    rr = _run_scheduler(
        qparams, cfg, policy="fifo", slots=slots, n_requests=2 * n_requests,
        max_new=max_new, max_len=max_len,
        eng_kw=dict(max_queue=8 * n_requests, watchdog_timeout_s=60.0),
        deadline_ms=600_000.0)
    assert rr["tokens"] == sched["fifo"]["tokens"], \
        "resilience knobs changed fault-free token output"
    suite.add("serve/robust_overhead",
              tok_s_base=round(sched["fifo"]["tok_s"], 2),
              tok_s_resilient=round(rr["tok_s"], 2),
              overhead_ratio=round(
                  sched["fifo"]["tok_s"] / max(rr["tok_s"], 1e-9), 3),
              tokens=rr["tokens"],
              tokens_match=True)

    add_fault_records(suite, qparams, cfg, smoke=smoke)
    add_paged_records(suite, qparams, cfg, smoke=smoke)
    add_spec_records(suite, cfg, smoke=smoke)
    add_tp_records(suite, smoke=smoke)

    from benchmarks.attn_bench import add_serve_records
    add_serve_records(suite, smoke=smoke)

    # the serving-SLO gate: a full run must not regress the committed
    # scheduler trajectory beyond tolerance BEFORE it overwrites it (smoke
    # runs are sized differently and never gate)
    committed = repo_root() / "BENCH_serve.json"
    if not smoke and committed.exists():
        assert_no_slo_regression(committed, suite.records, require_all=True)

    suite.write()


if __name__ == "__main__":
    main()
