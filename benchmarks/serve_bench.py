"""Serve-path benchmark: the decode hot loop, measured end to end.

Runs the continuous-batching engine on a reduced model (random init — this
measures plumbing, not quality) in both sampling modes:

  * ``host``   — the pre-overhaul decode discipline: logits shipped out of
    the jitted step, one host argmax (= one device->host sync) per active
    slot per step.
  * ``device`` — the overhauled path: sampling inside the jitted decode,
    one (slots,) token-vector transfer per step.

and records tok/s, wall seconds, host syncs per decoded token, and the
derived speedup. Greedy decoding makes the two modes token-identical, which
is asserted — a perf number for a wrong answer is worthless.

Each engine is run once untimed (jit warmup) and then timed on a fresh
request batch; engines are reused across batches so compile time never
lands in the measurement.

Emits ``BENCH_serve.json`` at the repo root (schema: benchmarks/common.py).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BenchSuite
from repro.configs.base import get_config, reduced
from repro.models import lm
from repro.models.layers import Runtime
from repro.serve.engine import Request, ServeEngine
from repro.serve.quantized import quantize_params

RT = Runtime(compute_dtype=jnp.float32)


def _requests(n: int, vocab: int, max_new: int, seed: int) -> list[Request]:
    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt=rng.integers(0, vocab, size=6 + i % 5),
                    max_new=max_new) for i in range(n)]


def _run_mode(params, cfg, *, sample_on_host: bool, slots: int,
              n_requests: int, max_new: int, max_len: int, repeats: int = 3):
    eng = ServeEngine(params, cfg, slots=slots, max_len=max_len, rt=RT,
                      sample_on_host=sample_on_host)
    eng.run(_requests(n_requests, cfg.vocab_size, max_new, seed=1))  # warmup
    walls, out, tokens = [], None, 0
    syncs0, toks0 = eng.host_syncs, eng.tokens_decoded
    for _ in range(repeats):  # median over repeats: CPU walltime is noisy
        reqs = _requests(n_requests, cfg.vocab_size, max_new, seed=2)
        t0 = time.perf_counter()
        done = eng.run(reqs)
        walls.append(time.perf_counter() - t0)
        tokens = sum(len(r.out) for r in done)
        cur = [r.out for r in done]
        assert out is None or out == cur, "engine run is not deterministic"
        out = cur
    wall = float(np.median(walls))
    return {
        "wall_s": wall,
        "tokens": tokens,
        "tok_s": tokens / wall,
        "host_syncs": (eng.host_syncs - syncs0) // repeats,
        "syncs_per_token": (eng.host_syncs - syncs0) / max(
            eng.tokens_decoded - toks0, 1),
        "out": out,
    }


def main(smoke: bool = False) -> None:
    suite = BenchSuite("serve", smoke=smoke)
    cfg = reduced(get_config("smollm-135m"))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    qparams = quantize_params(params, "itq3_s")

    slots = 4
    n_requests = 4 if smoke else 8
    max_new = 8 if smoke else 24
    max_len = 64

    results = {}
    for mode in ("host", "device"):
        r = _run_mode(qparams, cfg, sample_on_host=(mode == "host"),
                      slots=slots, n_requests=n_requests, max_new=max_new,
                      max_len=max_len, repeats=1 if smoke else 3)
        results[mode] = r
        suite.add(f"serve/decode_{mode}_sampling",
                  us_per_call=1e6 * r["wall_s"] / max(r["tokens"], 1),
                  tok_s=round(r["tok_s"], 2),
                  wall_s=round(r["wall_s"], 3),
                  tokens=r["tokens"],
                  host_syncs=r["host_syncs"],
                  syncs_per_token=round(r["syncs_per_token"], 3),
                  slots=slots)

    if results["host"]["out"] != results["device"]["out"]:
        raise AssertionError("greedy decode diverged between sampling modes")
    host, dev = results["host"], results["device"]
    suite.add("serve/device_vs_host",
              speedup_wall=round(host["wall_s"] / dev["wall_s"], 3),
              syncs_reduction=round(
                  host["syncs_per_token"] / max(dev["syncs_per_token"], 1e-9),
                  2),
              tokens_match=True)
    from benchmarks.attn_bench import add_serve_records
    add_serve_records(suite, smoke=smoke)
    suite.write()


if __name__ == "__main__":
    main()
