"""Paper Table 3: FWHT block-size ablation (32..512).

Two quality measures per block size: reconstruction MSE on heavy-tailed
synthetic weights, and eval-loss delta on the trained bench model. The
paper's claim: quality improves monotonically with block size with
diminishing returns past 256; the transform overhead grows with
log2(block), reproduced here as the overhead column.

CSV: name,us_per_call(=quantize time),derived
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, eval_loss, trained_model
from repro.core import grids
from repro.core.fwht import blocked_fwht, fwht
from repro.core.quantize import (dequantize_blocks_ternary,
                                 quantize_blocks_ternary, to_blocks, from_blocks)


def quantize_tensor_blocksize(w, block: int, rule: str = "paper"):
    wb = to_blocks(w, block)
    data = quantize_blocks_ternary(wb, rotate=True, rule=rule)
    wh = dequantize_blocks_ternary(data, rotate=True)
    return from_blocks(wh, w.shape[-2])


def quantize_params_blocksize(params, block: int):
    """Blockwise-requantize every QUANTIZABLE leaf at the given block."""
    from repro.serve.quantized import QUANTIZABLE, MIN_REDUCTION

    def visit(path, leaf):
        name = str(getattr(path[-1], "key", path[-1]))
        if (hasattr(leaf, "ndim") and leaf.ndim >= 2 and QUANTIZABLE.search(name)
                and leaf.shape[-2] >= MIN_REDUCTION):
            fn = lambda ww: quantize_tensor_blocksize(ww, block)
            for _ in range(leaf.ndim - 2):
                fn = jax.vmap(fn)
            return fn(leaf)
        return leaf

    return jax.tree_util.tree_map_with_path(visit, params)


def main() -> None:
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_t(df=4, size=(2048, 512)) * 0.02, jnp.float32)
    cfg, params, corpus = trained_model()
    base = eval_loss(cfg, params, corpus)

    for block in [32, 64, 128, 256, 512]:
        t0 = time.time()
        wh = quantize_tensor_blocksize(w, block)
        mse = float(jnp.mean((wh - w) ** 2)) / float(jnp.var(w))
        us = (time.time() - t0) * 1e6
        qp = quantize_params_blocksize(params, block)
        dl = eval_loss(cfg, qp, corpus) - base
        # transform overhead ~ log2(block)/block-matmul cost relative model
        overhead = np.log2(block) / block * 100 * 256 / np.log2(256)
        emit(f"table3/block_{block}", us,
             f"rel_mse={mse:.4f} eval_delta={dl:+.4f} "
             f"ifwht_overhead_pct={overhead:.2f}")


if __name__ == "__main__":
    main()
