"""Decode-attention benchmark: fp32 cache vs rotated-int8 (kv_quant) cache.

Two record families, following the repro.bench.v1 convention:

* ``attn/decode_*`` (kernel suite) — the attention op alone, jitted, at
  several cache widths: per-step microseconds, derived tokens/s, and cache
  bytes per token for both layouts. This is where the bandwidth crossover
  shows: the quantized path trades a ~2x byte stream for an int8->f32 cast,
  so it pulls ahead as max_len grows past cache-resident sizes.
* ``attn/prefill_*`` (kernel suite) — a chunked-prefill span over the
  quantized cache: the fused q-tile path (scores straight from int8 codes,
  PR 5) vs the PR-4-era dequantize-the-whole-cache composition, with the
  analytic bytes each one streams per call. The fused path reads the int8
  planes once; the baseline additionally writes AND re-reads a full f32
  K/V buffer — the domain-mismatch memory cost the paper argues against.
* ``serve/kv_quant_*`` (serve suite) — the whole engine hot loop (jitted
  decode + sampling + scheduler) with ``Runtime.kv_quant`` on vs off, plus
  the ``cache_bytes`` counters and the ~0.52x ratio vs the bf16 layout.

The records are embedded into ``BENCH_kernels.json`` / ``BENCH_serve.json``
by kernel_bench.py / serve_bench.py (each suite file is written whole, so
the entries must ride in those suites); ``python -m benchmarks.attn_bench``
prints the same CSV standalone without touching the JSON trajectory.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BenchSuite, timeit
from repro.configs.base import get_config, kv_cache_bytes_per_token, reduced
from repro.kernels import attn_decode as ad
from repro.models import lm
from repro.models.layers import Runtime, _sdpa_chunked, _sdpa_decode_token
from repro.serve import kv_quant
from repro.serve.engine import Request, ServeEngine

RT = Runtime(compute_dtype=jnp.float32)
RTQ = Runtime(compute_dtype=jnp.float32, kv_quant=True)


# ---------------------------------------------------------------------------
# Kernel-suite records: the attention op alone at several cache widths
# ---------------------------------------------------------------------------

def _fp_step(q, ck, cv, k_tok, v_tok, kv_len):
    return _sdpa_decode_token(q, ck, cv, k_tok, v_tok, RT, kv_len=kv_len)


def _q8_step(q, cache, ktok_c, ktok_s, vtok_c, vtok_s, kv_len):
    return ad.decode_attn_q8(q, cache, (ktok_c, ktok_s), (vtok_c, vtok_s),
                             kv_len, backend="ref")


def add_kernel_records(suite: BenchSuite, smoke: bool = False) -> None:
    rng = np.random.default_rng(0)
    b, kv, g, hd = 4, 2, 4, 64
    # NB very large T regresses the CPU fallback: XLA CPU lowers the
    # int8->f32 cache convert to a scalar loop (~22ms for 8M elements vs
    # 2.6ms for int8->f16), swamping the byte savings. The TPU kernel loads
    # int8 natively; the serve-level records below show the fallback still
    # wins end to end at deployment shapes.
    max_lens = [256] if smoke else [256, 1024, 4096]
    iters = 2 if smoke else 5
    fp_jit = jax.jit(_fp_step)
    q8_jit = jax.jit(_q8_step)
    for t in max_lens:
        q = jnp.asarray(rng.normal(size=(b, kv, g, 1, hd)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, kv, t, hd)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, kv, t, hd)), jnp.float32)
        k_tok = jnp.asarray(rng.normal(size=(b, kv, 1, hd)), jnp.float32)
        v_tok = jnp.asarray(rng.normal(size=(b, kv, 1, hd)), jnp.float32)
        kv_len = jnp.full((b,), t, jnp.int32)

        us_fp = timeit(fp_jit, q, k, v, k_tok, v_tok, kv_len, iters=iters)
        kc, ks = kv_quant.kv_encode(k)
        vc, vs = kv_quant.kv_encode(v)
        cache = {"k": kc, "k_scale": ks, "v": vc, "v_scale": vs}
        ktok = kv_quant.kv_encode(k_tok)
        vtok = kv_quant.kv_encode(v_tok)
        us_q8 = timeit(q8_jit, q, cache, ktok[0], ktok[1], vtok[0], vtok[1],
                       kv_len, iters=iters)

        fp_bytes = 2 * kv * hd * 4          # K+V f32 vectors per token
        q8_bytes = 2 * kv * (hd + 2)        # int8 codes + fp16 scale
        suite.add(f"attn/decode_fp32_T{t}", us_fp,
                  tok_s=round(1e6 / us_fp, 1),
                  cache_bytes_per_token=fp_bytes)
        suite.add(f"attn/decode_kv_quant_T{t}", us_q8,
                  tok_s=round(1e6 / us_q8, 1),
                  cache_bytes_per_token=q8_bytes,
                  speedup_vs_fp32=round(us_fp / us_q8, 3),
                  bytes_ratio_vs_bf16=round(
                      kv_quant.cache_bytes_ratio(hd), 3))


def _prefill_dequant_step(q, cache, kv_len, q_offset):
    """PR-4-era prefill composition: decode the ENTIRE cache buffer, then
    fp chunked attention — the baseline the fused q-tile path replaces."""
    kf = kv_quant.kv_decode(cache["k"], cache["k_scale"])
    vf = kv_quant.kv_decode(cache["v"], cache["v_scale"])
    return _sdpa_chunked(q, kf, vf, RT, causal=True, q_offset=q_offset,
                         kv_len=kv_len)


def _prefill_fused_step(q, cache, kv_len, q_offset):
    return ad.prefill_attn_q8(q, cache, kv_len, q_offset, backend="auto")


def add_prefill_records(suite: BenchSuite, smoke: bool = False) -> None:
    """attn/prefill_*: one chunked-prefill span (the last `span` positions
    of a T-wide quantized cache) through both compositions, with the
    analytic bytes each one streams from/to HBM per call."""
    rng = np.random.default_rng(0)
    b, kv, g, hd, span = 4, 2, 4, 64, 64
    max_lens = [256] if smoke else [256, 1024, 4096]
    iters = 2 if smoke else 5
    deq_jit = jax.jit(_prefill_dequant_step)
    fus_jit = jax.jit(_prefill_fused_step)
    for t in max_lens:
        k = jnp.asarray(rng.normal(size=(b, kv, t, hd)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, kv, t, hd)), jnp.float32)
        kc, ks = kv_quant.kv_encode(k)
        vc, vs = kv_quant.kv_encode(v)
        cache = {"k": kc, "k_scale": ks, "v": vc, "v_scale": vs}
        q = jnp.asarray(rng.normal(size=(b, kv, g, span, hd)), jnp.float32)
        q_offset = jnp.full((b,), t - span, jnp.int32)
        kv_len = jnp.full((b,), t, jnp.int32)

        us_deq = timeit(deq_jit, q, cache, kv_len, q_offset, iters=iters)
        us_fus = timeit(fus_jit, q, cache, kv_len, q_offset, iters=iters)

        # bytes streamed per call: both read the int8 codes + fp16 scales;
        # the dequantize baseline additionally WRITES a full f32 K/V buffer
        # and re-reads it in the attention einsum
        q8_bytes = 2 * b * kv * t * (hd + 2)
        fp_buf = 2 * b * kv * t * hd * 4
        deq_bytes = q8_bytes + 2 * fp_buf
        toks = b * span
        suite.add(f"attn/prefill_dequant_T{t}", us_deq,
                  tok_s=round(toks * 1e6 / us_deq, 1),
                  bytes_streamed_mb=round(deq_bytes / 1e6, 3),
                  span=span)
        suite.add(f"attn/prefill_fused_T{t}", us_fus,
                  tok_s=round(toks * 1e6 / us_fus, 1),
                  bytes_streamed_mb=round(q8_bytes / 1e6, 3),
                  speedup_vs_dequant=round(us_deq / us_fus, 3),
                  bytes_ratio_vs_dequant=round(q8_bytes / deq_bytes, 3),
                  span=span)


# ---------------------------------------------------------------------------
# Serve-suite records: the engine hot loop with kv_quant on vs off
# ---------------------------------------------------------------------------

def _decode_tok_s(eng, steps: int, repeats: int) -> float:
    # prompts already admitted; time steady-state decode steps only
    walls = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        tokens = 0
        for _ in range(steps):
            tokens += len(eng.step())
        walls.append((time.perf_counter() - t0) / max(tokens, 1))
    return 1.0 / float(np.median(walls))


def add_serve_records(suite: BenchSuite, smoke: bool = False) -> None:
    cfg = reduced(get_config("smollm-135m"))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    slots = 4
    steps = 4 if smoke else 16
    repeats = 1 if smoke else 3
    max_lens = [128] if smoke else [256, 1024, 4096]
    results = {}
    for kvq in (False, True):
        rt = RTQ if kvq else RT
        for max_len in max_lens:
            eng = ServeEngine(params, cfg, slots=slots, max_len=max_len,
                              rt=rt)
            reqs = [Request(rid=i, prompt=np.arange(6 + i) % cfg.vocab_size
                            + 1, max_new=10 ** 9) for i in range(slots)]
            eng.admit(reqs)
            for _ in range(2):  # decode-jit warmup
                eng.step()
            tok_s = _decode_tok_s(eng, steps, repeats)
            bpt = eng.stats()["cache_bytes_per_token"]
            results[(kvq, max_len)] = (tok_s, bpt)
            name = "kv_quant" if kvq else "fp32_cache"
            suite.add(f"serve/decode_{name}_L{max_len}",
                      us_per_call=1e6 / tok_s,
                      tok_s=round(tok_s, 2),
                      cache_bytes_per_token=round(bpt, 1),
                      slots=slots)
    bf16_bpt = kv_cache_bytes_per_token(cfg, kv_quant=False)
    q8_bpt = kv_cache_bytes_per_token(cfg, kv_quant=True)
    for max_len in max_lens:
        fp, q8 = results[(False, max_len)], results[(True, max_len)]
        suite.add(f"serve/kv_quant_vs_fp32_L{max_len}",
                  speedup_tok_s=round(q8[0] / fp[0], 3),
                  cache_shrink_vs_fp32=round(q8[1] / fp[1], 3),
                  cache_ratio_vs_bf16=round(q8_bpt / bf16_bpt, 3))


def main(smoke: bool = False) -> None:
    # standalone: CSV to stdout only; the JSON suites are regenerated by
    # kernel_bench/serve_bench, which embed these records (see module doc)
    kernels = BenchSuite("kernels", smoke=smoke)
    add_kernel_records(kernels, smoke=smoke)
    add_prefill_records(kernels, smoke=smoke)
    add_serve_records(BenchSuite("serve", smoke=smoke), smoke=smoke)


if __name__ == "__main__":
    main()
