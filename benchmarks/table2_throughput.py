"""Paper Table 2 proxy: decode/prefill throughput by format.

No RTX 5090 (or any accelerator) exists in this container, so two views:

  1. **Measured** — µs/call of the *pure-JAX execution paths* on CPU
     (jit-compiled, reference semantics). CPU wall-times are comparative
     only: they rank dequant-path vs dual-domain-path overheads.
  2. **Derived** — analytic TPU v5e tok/s upper bounds from the memory
     roofline: decode is weight-streaming-bound, so
     tok/s <= HBM_bw / bytes_per_token(format). This is the roofline the
     kernel (validated in interpret mode) is designed to approach, and it
     reproduces Table 2's *shape*: 3.125-bpw ITQ3_S streams ~2.6x less
     than Q8_0 and ~1.4x less than Q4_0 per token.

CSV: name,us_per_call,derived
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core import formats, qlinear

HBM_BW = 819e9  # v5e bytes/s
D_MODEL, D_FF, LAYERS = 4096, 14336, 32  # llama-8B-class deployment
PARAMS_PER_TOKEN = LAYERS * (4 * D_MODEL * D_MODEL + 3 * D_MODEL * D_FF)


def decode_tok_s(bpw: float) -> float:
    bytes_per_tok = PARAMS_PER_TOKEN * bpw / 8.0
    return HBM_BW / bytes_per_tok


def main() -> None:
    rng = np.random.default_rng(0)
    k, n, m = 2048, 2048, 8
    w = jnp.asarray(rng.standard_t(df=4, size=(k, n)) * 0.02, jnp.float32)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)

    for fmt in ["bf16", "q8_0", "q4_0", "iq3_s", "itq3_s"]:
        qt = formats.quantize(w, fmt)
        bpw = formats.bits_per_weight(fmt)
        modes = ["dequant"] if fmt in ("bf16", "q8_0", "q4_0") else [
            "dequant", "weights", "activations"]
        for mode in modes:
            fn = jax.jit(functools.partial(qlinear.qmatmul, mode=mode,
                                           compute_dtype=jnp.float32))
            us = timeit(fn, x, qt)
            emit(f"table2/qmatmul_{fmt}_{mode}", us,
                 f"v5e_decode_tok_s={decode_tok_s(bpw):.0f} bpw={bpw}")

    # FWHT overhead of the activation-rotation path (the dual-domain cost):
    from repro.core.fwht import blocked_fwht
    fn = jax.jit(lambda xx: blocked_fwht(xx, 256))
    us = timeit(fn, x)
    flops_frac = (2 * 256 * np.log2(256)) / (2 * 256 * n)  # per block col
    emit("table2/fwht_activation_overhead", us,
         f"flops_frac_of_matmul={flops_frac:.4f} (paper reports 2.1% kernel overhead)")


if __name__ == "__main__":
    main()
