"""Aggregate the dry-run JSONs into the §Roofline table (markdown + CSV).

    PYTHONPATH=src python -m benchmarks.roofline_report [--dir reports/dryrun]

Per (arch x shape x mesh): the three roofline terms (seconds/step/device),
dominant bottleneck, MODEL_FLOPS/HLO_FLOPS usefulness ratio, collective mix,
and the derived roofline fraction (model-flops time / dominant-term time —
the "how close to peak could this run" score).
"""
from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9


def load(dirname: str, quant_mode: str | None = None):
    recs = []
    for f in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        r = json.load(open(f))
        if r.get("status") != "ok":
            continue
        if quant_mode and r.get("quant_mode") != quant_mode:
            continue
        recs.append(r)
    return recs


def row(r):
    rf = r["roofline"]
    dom = r["bottleneck"]
    # how long the *useful* model flops would take at peak, vs the dominant
    # term: the roofline fraction this compiled program could achieve.
    useful_s = r["model_flops_per_device"] / PEAK_FLOPS
    frac = useful_s / max(rf[dom.replace("_s", "") + "_s"], 1e-12)
    coll = r.get("collective_bytes", {})
    return {
        "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
        "compute_s": rf["compute_s"], "memory_s": rf["memory_s"],
        "collective_s": rf["collective_s"], "bottleneck": dom,
        "useful_frac": r.get("useful_flops_frac", 0.0),
        "roofline_frac": frac,
        "coll_gb": sum(coll.values()) / 1e9,
        "compile_s": r.get("compile_s"),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="reports/dryrun")
    ap.add_argument("--quant-mode", default=None)
    ap.add_argument("--csv", default=None)
    args = ap.parse_args()
    recs = [row(r) for r in load(args.dir, args.quant_mode)]
    recs.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))

    hdr = ("arch", "shape", "mesh", "compute_s", "memory_s", "collective_s",
           "bottleneck", "useful_frac", "roofline_frac")
    print("| " + " | ".join(hdr) + " |")
    print("|" + "---|" * len(hdr))
    for r in recs:
        print("| " + " | ".join(
            f"{r[h]:.4g}" if isinstance(r[h], float) else str(r[h])
            for h in hdr) + " |")

    if args.csv:
        import csv
        with open(args.csv, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(recs[0].keys()))
            w.writeheader()
            w.writerows(recs)
        print(f"\nwrote {args.csv}")

    # hillclimb candidates
    singles = [r for r in recs if r["mesh"] == "16x16"]
    if singles:
        worst = min(singles, key=lambda r: r["roofline_frac"])
        collb = max(singles, key=lambda r: r["collective_s"]
                    / max(r["compute_s"] + r["memory_s"] + r["collective_s"], 1e-12))
        print(f"\nworst roofline fraction : {worst['arch']} {worst['shape']} "
              f"({worst['roofline_frac']:.4f})")
        print(f"most collective-bound   : {collb['arch']} {collb['shape']} "
              f"(coll={collb['collective_s']:.3f}s of "
              f"{collb['compute_s']+collb['memory_s']+collb['collective_s']:.3f}s)")


if __name__ == "__main__":
    main()
