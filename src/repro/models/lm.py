"""Model assembly for all assigned architecture families.

One parameter/apply pair per family, all built from the same blocks and all
scanning over stacked per-layer parameters (so a 94-layer MoE compiles one
layer body, not 94):

  dense / vlm    — [frontend] + GQA attention + MLP
  moe            — GQA attention + sort-dispatch MoE
  ssm (rwkv6)    — RWKV6 time-mix/channel-mix layers (attention-free)
  hybrid (zamba2)— Mamba2 backbone with ONE shared attention block applied
                   every ``attn_every`` layers; expressed as a scan over
                   macroblocks (attn + ``every`` mambas) so the shared
                   weights are reused by construction and the KV-cache
                   slots align with scan steps (no in-scan cond/gather)
  audio (enc-dec)— encoder stack (non-causal) + decoder stack with
                   cross-attention (seamless)

The serving cache is a pytree matching the family: attention KV, Mamba2
(ssm, conv) state, RWKV6 (wkv, shift) state, or a mix.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import formats as fmt_mod
from repro.core.quantize import QTensor
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    Runtime, attention_apply, attention_init, dense, init_dense_weight,
    mlp_apply, mlp_init, norm_apply, norm_init, shard_hint,
)

Params = dict[str, Any]

__all__ = [
    "init_params", "forward", "decode_step", "score_tokens", "advance_cache",
    "init_cache", "model_flops", "sample_tokens", "top_mask", "finite_rows",
]


# ===========================================================================
# Init
# ===========================================================================

def _layer_init(key, cfg, *, cross: bool = False) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 6)
    p: Params = {"ln1": norm_init(d, cfg.norm)}
    p["attn"] = attention_init(ks[0], d, cfg.num_heads, cfg.num_kv_heads,
                               cfg.resolved_head_dim, cfg.qkv_bias)
    if cross:
        p["ln_x"] = norm_init(d, cfg.norm)
        p["xattn"] = attention_init(ks[1], d, cfg.num_heads, cfg.num_kv_heads,
                                    cfg.resolved_head_dim, False)
    p["ln2"] = norm_init(d, cfg.norm)
    if cfg.num_experts:
        p["moe"] = moe_mod.moe_init(ks[2], d, f, cfg.num_experts, cfg.activation)
    else:
        p["mlp"] = mlp_init(ks[3], d, f, cfg.activation)
    return p


def _stack_init(key, n: int, fn) -> Params:
    return jax.vmap(fn)(jax.random.split(key, n))


def init_params(key, cfg) -> Params:
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    p: Params = {
        "embed": jax.random.normal(ks[0], (cfg.vocab_size, d), jnp.float32) * 0.02,
        "ln_f": norm_init(d, cfg.norm),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = init_dense_weight(ks[1], d, cfg.vocab_size)

    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        p["layers"] = _stack_init(ks[2], cfg.num_layers, lambda k: _layer_init(k, cfg))
    elif fam == "ssm":
        p["layers"] = _stack_init(ks[2], cfg.num_layers, lambda k: ssm_mod.rwkv6_init(k, cfg))
    elif fam == "hybrid":
        every = cfg.attn_every
        n_full = cfg.num_layers // every
        tail = cfg.num_layers % every
        p["shared_attn"] = {
            "ln": norm_init(d, cfg.norm),
            "attn": attention_init(ks[3], d, cfg.num_heads, cfg.num_kv_heads,
                                   cfg.resolved_head_dim, False),
        }
        p["mamba_blocks"] = jax.vmap(
            lambda k: _stack_init(k, every, lambda kk: _mamba_layer_init(kk, cfg))
        )(jax.random.split(ks[4], n_full))
        if tail:
            p["mamba_tail"] = _stack_init(ks[5], tail, lambda k: _mamba_layer_init(k, cfg))
    elif fam == "audio":
        p["encoder"] = _stack_init(ks[2], cfg.encoder_layers, lambda k: _layer_init(k, cfg))
        p["enc_ln_f"] = norm_init(d, cfg.norm)
        p["layers"] = _stack_init(ks[6], cfg.num_layers,
                                  lambda k: _layer_init(k, cfg, cross=True))
    else:
        raise ValueError(f"unknown family {fam!r}")

    if cfg.frontend:
        p["frontend_proj"] = init_dense_weight(ks[7], cfg.frontend_dim, d)
    return p


def _mamba_layer_init(key, cfg) -> Params:
    return {"ln": norm_init(cfg.d_model, cfg.norm),
            "mamba": ssm_mod.mamba2_init(key, cfg)}


# ===========================================================================
# Caches / states
# ===========================================================================

def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16,
               *, kv_quant: bool = False) -> Params:
    """Serving cache pytree. ``kv_quant=True`` lays the self-attention KV
    cache out as rotated-int8 codes plus per-token fp16 scales (the
    serve/kv_quant.py codec): 8.25 bits/element instead of 16/32. The
    cross-attention memory (audio) stays fp — it is written once at prefill
    and re-read every step, so re-dequantizing it each step would trade its
    one-time bytes for per-step compute. Requires a power-of-two head_dim
    (every arch in the zoo qualifies)."""
    from repro.core.fwht import is_pow2

    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    fam = cfg.family
    if kv_quant and not is_pow2(hd):
        raise ValueError(f"kv_quant needs a power-of-two head_dim, got {hd}")

    def kv(n_layers, length, quant=kv_quant):
        if quant:
            return {
                "k": jnp.zeros((n_layers, batch, kvh, length, hd), jnp.int8),
                "v": jnp.zeros((n_layers, batch, kvh, length, hd), jnp.int8),
                "k_scale": jnp.zeros((n_layers, batch, kvh, length, 1),
                                     jnp.float16),
                "v_scale": jnp.zeros((n_layers, batch, kvh, length, 1),
                                     jnp.float16),
            }
        return {
            "k": jnp.zeros((n_layers, batch, kvh, length, hd), dtype),
            "v": jnp.zeros((n_layers, batch, kvh, length, hd), dtype),
        }

    if fam in ("dense", "vlm", "moe"):
        length = max_len + (cfg.frontend_len if cfg.frontend else 0)
        return {"attn": kv(cfg.num_layers, length)}
    if fam == "ssm":
        states = jax.vmap(lambda _: ssm_mod.rwkv6_empty_state(cfg, batch))(
            jnp.arange(cfg.num_layers))
        return {"ssm": states}
    if fam == "hybrid":
        every = cfg.attn_every
        n_attn = cfg.num_layers // every + (1 if cfg.num_layers % every else 0)
        states = jax.vmap(lambda _: ssm_mod.mamba2_empty_state(cfg, batch))(
            jnp.arange(cfg.num_layers))
        return {"attn": kv(n_attn, max_len), "ssm": states}
    if fam == "audio":
        # self-attn cache + cross-attn memory (filled by prefill)
        return {"attn": kv(cfg.num_layers, max_len),
                "xattn": kv(cfg.num_layers, cfg.frontend_len, quant=False)}
    raise ValueError(fam)


# ===========================================================================
# Decoder stacks
# ===========================================================================

def _dense_layer_apply(lp, x, rt, cfg, *, cache, pos, memory=None, causal=True,
                       token_cache=False):
    h, new_kv = attention_apply(
        lp["attn"], norm_apply(lp["ln1"], x, cfg.norm), rt, cfg,
        causal=causal, cache=None if cache is None else cache["attn"], pos=pos,
        token_cache=token_cache)
    x = x + h
    aux = jnp.zeros((), jnp.float32)
    new_cache = None
    if "xattn" in lp:
        xc, new_xkv = attention_apply(
            lp["xattn"], norm_apply(lp["ln_x"], x, cfg.norm), rt, cfg,
            cross=True, memory=memory,
            cache=None if cache is None else cache.get("xattn"))
        x = x + xc
        if cache is not None:
            new_cache = {"attn": new_kv, "xattn": new_xkv}
    elif cache is not None:
        new_cache = {"attn": new_kv}
    hn = norm_apply(lp["ln2"], x, cfg.norm)
    if "moe" in lp:
        m, aux = moe_mod.moe_apply(lp["moe"], hn, rt, cfg)
    else:
        m = mlp_apply(lp["mlp"], hn, rt, cfg.activation)
    return x + m, new_cache, aux


def _maybe_remat(body, rt):
    """Per-layer rematerialization: wrap the scan body so backward re-runs
    the layer instead of saving its internals (attention weights at 32k
    would otherwise dominate memory — the flash-attention discipline)."""
    if not rt.remat:
        return body
    policy = (jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
              if rt.remat_policy == "dots" else None)
    return jax.checkpoint(body, policy=policy)


def _run_decoder(params, x, rt, cfg, *, cache, pos, memory=None, causal=True):
    """Scan the main layer stack. cache: stacked leaves (L, ...) or None.
    Returns (x, new_cache, aux)."""
    fam = cfg.family

    if fam in ("dense", "vlm", "moe", "audio"):
        if cache is not None and x.shape[1] == 1 and rt.decode_token_cache:
            return _run_decoder_token(params, x, rt, cfg, cache=cache, pos=pos)

        # Paged pool: the block table (B, MAXB) has no layer axis, so it
        # cannot ride the scan xs — thread it via closure instead and merge
        # it into each layer's attn-cache slice inside the body.
        tbl = cache.get("table") if cache is not None else None

        def body(xc, inp):
            lp, c = inp
            if tbl is not None:
                c = dict(c)
                c["attn"] = dict(c["attn"], table=tbl)
            xnew, cnew, aux = _dense_layer_apply(
                lp, xc, rt, cfg, cache=c, pos=pos, memory=memory, causal=causal)
            return xnew, (cnew, aux)

        body = _maybe_remat(body, rt)

        layer_cache = None
        if cache is not None:
            layer_cache = {"attn": _kv_tree(cache["attn"])}
            if "xattn" in cache:
                layer_cache["xattn"] = _kv_tree(cache["xattn"])
        x, (new_cache, auxs) = jax.lax.scan(body, x, (params["layers"], layer_cache))
        return x, new_cache, jnp.mean(auxs)

    if fam == "ssm":
        def body(xc, inp):
            lp, st = inp
            xnew, stnew = ssm_mod.rwkv6_apply(lp, xc, rt, cfg, state=st,
                                              decode=(x.shape[1] == 1 and cache is not None))
            return xnew, stnew

        body = _maybe_remat(body, rt)
        states = cache["ssm"] if cache is not None else None
        if states is None:
            # training: still thread zero states (scan needs uniform xs)
            b = x.shape[0]
            states = jax.vmap(lambda _: ssm_mod.rwkv6_empty_state(cfg, b))(
                jnp.arange(cfg.num_layers))
            x, _ = jax.lax.scan(body, x, (params["layers"], states))
            return x, None, jnp.zeros((), jnp.float32)
        x, new_states = jax.lax.scan(body, x, (params["layers"], states))
        return x, {"ssm": new_states}, jnp.zeros((), jnp.float32)

    if fam == "hybrid":
        return _run_hybrid(params, x, rt, cfg, cache=cache, pos=pos)

    raise ValueError(fam)


def _kv_tree(kv):
    # shallow copy of every cache leaf (k/v, plus scale planes when the
    # cache is rotated-int8 quantized)
    return dict(kv)


def _write_token_kv(stacked, tok, layer_idx, pos_vec):
    """Write (B, KV, 1, HD) token K/V into the stacked (L, B, KV, T, HD)
    cache at [layer_idx, b, :, pos_b, :] — the O(1)-bytes decode write."""
    def upd(cacheB, tokB, p):
        # cacheB (L, KV, T, HD); tokB (KV, 1, HD)
        return jax.lax.dynamic_update_slice(
            cacheB, tokB[None].astype(cacheB.dtype),
            (layer_idx, jnp.int32(0), p, jnp.int32(0)))
    return jax.vmap(upd, in_axes=(1, 0, 0), out_axes=1)(stacked, tok, pos_vec)


def _write_token_kv_paged(stacked, tok, layer_idx, tbl, pos_vec):
    """Paged analogue of :func:`_write_token_kv`: scatter (B, KV, 1, HD)
    token K/V into the stacked pool (L, NB, KV, BS, HD) through the block
    table. Slot b's token at logical position p lands in pool block
    ``tbl[b, p // BS]`` at offset ``p % BS``. Inactive slots must keep
    their table rows pointing at the reserved null block 0 so their
    (garbage but finite) writes never land in a live block."""
    bs = stacked.shape[3]
    blk = jnp.take_along_axis(tbl, (pos_vec // bs)[:, None], axis=1)[:, 0]
    off = pos_vec % bs
    return stacked.at[layer_idx, blk, :, off, :].set(
        tok[:, :, 0, :].astype(stacked.dtype))


# attn-cache leaf -> the token-slice key attention_apply returns for it.
# fp caches carry {k, v}; rotated-int8 caches also carry the scale planes.
_TOK_KEYS = {"k": "k_tok", "v": "v_tok",
             "k_scale": "k_scale_tok", "v_scale": "v_scale_tok"}


def _run_decoder_token(params, x, rt, cfg, *, cache, pos):
    """Single-token decode for attention families: the KV cache rides the
    scan CARRY and each layer writes only its new token's K/V slice —
    instead of functionally rewriting the full (B, KV, T, HD) cache per
    layer through scan ys (which costs O(T) write bandwidth per layer per
    token). See EXPERIMENTS.md §Perf cell A.

    The carry is a dict over whatever leaves the attn cache has — (k, v)
    for fp caches, (k, v, k_scale, v_scale) for the rotated-int8 layout —
    so the O(1)-byte write discipline covers both."""
    b = x.shape[0]
    pos_vec = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    has_x = "xattn" in cache
    leaf_keys = sorted(cache["attn"].keys())
    tbl = cache.get("table")

    def body(carry, inp):
        xc, cdict, i = carry
        layer_attn = {lk: jax.lax.dynamic_index_in_dim(cdict[lk], i, 0, False)
                      for lk in leaf_keys}
        if tbl is not None:
            layer_attn["table"] = tbl
        if has_x:
            lp, xk, xv = inp
            layer_cache = {"attn": layer_attn, "xattn": {"k": xk, "v": xv}}
        else:
            lp = inp
            layer_cache = {"attn": layer_attn}
        xnew, cnew, aux = _dense_layer_apply(
            lp, xc, rt, cfg, cache=layer_cache, pos=pos_vec, token_cache=True)
        if tbl is not None:
            cdict = {lk: _write_token_kv_paged(
                cdict[lk], cnew["attn"][_TOK_KEYS[lk]], i, tbl, pos_vec)
                for lk in leaf_keys}
        else:
            cdict = {lk: _write_token_kv(cdict[lk], cnew["attn"][_TOK_KEYS[lk]],
                                         i, pos_vec)
                     for lk in leaf_keys}
        return (xnew, cdict, i + 1), aux

    xs = (params["layers"], cache["xattn"]["k"], cache["xattn"]["v"]) if has_x \
        else params["layers"]
    (x, cdict, _), auxs = jax.lax.scan(
        body, (x, dict(cache["attn"]), jnp.int32(0)), xs)
    new_cache = {"attn": cdict}
    if has_x:
        new_cache["xattn"] = _kv_tree(cache["xattn"])
    return x, new_cache, jnp.mean(auxs)


def _run_hybrid(params, x, rt, cfg, *, cache, pos):
    """Zamba2: scan over macroblocks (shared-attn + `every` mamba layers)."""
    every = cfg.attn_every
    n_full = cfg.num_layers // every
    tail = cfg.num_layers % every
    decode = cache is not None and x.shape[1] == 1
    b = x.shape[0]
    sa = params["shared_attn"]

    def zero_states(n):
        return jax.vmap(lambda _: ssm_mod.mamba2_empty_state(cfg, b))(jnp.arange(n))

    if cache is not None:
        ssm_states = cache["ssm"]
        kv_cache = _kv_tree(cache["attn"])
    else:
        ssm_states = zero_states(cfg.num_layers)
        kv_cache = None

    def split_states(st, lo, n):
        return jax.tree.map(lambda a: a[lo:lo + n], st)

    def mamba_seq(xc, mparams, states):
        def mbody(xx, inp):
            lp, st = inp
            h, stnew = ssm_mod.mamba2_apply(
                lp["mamba"], norm_apply(lp["ln"], xx, cfg.norm), rt, cfg,
                state=st, decode=decode)
            return xx + h, stnew
        return jax.lax.scan(mbody, xc, (mparams, states))

    def attn_once(xc, kv_slice):
        h, new_kv = attention_apply(
            sa["attn"], norm_apply(sa["ln"], xc, cfg.norm), rt, cfg,
            causal=True, cache=kv_slice, pos=pos)
        return xc + h, new_kv

    main_states = jax.tree.map(
        lambda a: a[: n_full * every].reshape(n_full, every, *a.shape[1:]),
        ssm_states)

    def block_body(xc, inp):
        mparams, mstates, kv_slice = inp
        xc, new_kv = attn_once(xc, kv_slice)
        xc, new_mstates = mamba_seq(xc, mparams, mstates)
        return xc, (new_mstates, new_kv)

    if kv_cache is not None:
        kv_main = jax.tree.map(lambda a: a[:n_full], kv_cache)
        x, (new_main_states, new_kv_main) = jax.lax.scan(
            _maybe_remat(block_body, rt), x,
            (params["mamba_blocks"], main_states, kv_main))
    else:
        def block_body_nokv(xc, inp):
            mparams, mstates = inp
            xc, _ = attn_once(xc, None)
            xc, new_mstates = mamba_seq(xc, mparams, mstates)
            return xc, new_mstates
        x, new_main_states = jax.lax.scan(
            _maybe_remat(block_body_nokv, rt), x,
            (params["mamba_blocks"], main_states))
        new_kv_main = None

    if tail:
        tail_states = split_states(ssm_states, n_full * every, tail)
        if kv_cache is not None:
            kv_tail = jax.tree.map(lambda a: a[n_full], kv_cache)
            x, new_kv_tail = attn_once(x, kv_tail)
        else:
            x, _ = attn_once(x, None)
            new_kv_tail = None
        x, new_tail_states = mamba_seq(x, params["mamba_tail"], tail_states)
    else:
        new_tail_states = None
        new_kv_tail = None

    new_cache = None
    if cache is not None:
        flat_main = jax.tree.map(
            lambda a: a.reshape(n_full * every, *a.shape[2:]), new_main_states)
        if tail:
            new_ssm = jax.tree.map(
                lambda a, t2: jnp.concatenate([a, t2], axis=0),
                flat_main, new_tail_states)
            new_kv = jax.tree.map(
                lambda m, t2: jnp.concatenate([m, t2[None]], axis=0),
                new_kv_main, new_kv_tail)
        else:
            new_ssm, new_kv = flat_main, new_kv_main
        new_cache = {"ssm": new_ssm, "attn": new_kv}
    return x, new_cache, jnp.zeros((), jnp.float32)


# ===========================================================================
# Public API: forward / decode_step
# ===========================================================================

def _embed(params, tokens, rt, cfg):
    table = params["embed"]
    if isinstance(table, QTensor):
        # a policy quantized the tied table: stored transposed (D, V),
        # blocked along D, so the tied head can matmul it directly; the
        # gather path reconstructs the table on the fly — O(D*V) dequant
        # work per call, comparable to the head matmul it ties to, and the
        # price of keeping only packed planes resident. The head path
        # dequantizes the same QTensor; XLA CSE merges the two identical
        # subexpressions inside one jitted step. Policies that can't pay
        # the cost should pin embed fp (fmt=None) and quantize lm_head only.
        emb = fmt_mod.dequantize(table, rt.compute_dtype).T
    else:
        emb = table.astype(rt.compute_dtype)
    # table gathers are row-local when D is model-sharded: shard D only
    emb = shard_hint(emb, rt, None, "embed")
    x = jnp.take(emb, tokens, axis=0)
    return shard_hint(x, rt, "batch", "seq", None)


def _head_weight(params, rt):
    """(D, V) head weight (array or QTensor). The tied embedding table is
    resharded for the head matmul — V over model, D replicated: re-laying
    it out once per step costs table-bytes, vs. psum-ing full (B, T, V)
    logits every chunk if the contraction dim stayed sharded."""
    w = params.get("lm_head")
    if w is None:
        w = params["embed"]
        if isinstance(w, QTensor):  # already stored as (D, V): matmul-ready
            return w
        w = shard_hint(w.T, rt, None, "vocab")
    return w


def _head(params, x, rt, cfg):
    x = norm_apply(params["ln_f"], x, cfg.norm)
    logits = dense(x, _head_weight(params, rt), rt)
    return shard_hint(logits, rt, "batch", "seq", "vocab")


def _encode(params, frames, rt, cfg):
    """Audio encoder (seamless): frames (B, S, F) -> memory (B, S, D)."""
    x = dense(frames.astype(rt.compute_dtype), params["frontend_proj"], rt)

    def body(xc, lp):
        xnew, _, _ = _dense_layer_apply(lp, xc, rt, cfg, cache=None, pos=0,
                                        causal=False)
        return xnew, None

    x, _ = jax.lax.scan(_maybe_remat(body, rt), x, params["encoder"])
    return norm_apply(params["enc_ln_f"], x, cfg.norm)


def forward(
    params: Params,
    tokens: jax.Array,  # (B, T)
    rt: Runtime,
    cfg,
    *,
    frontend_feats: Optional[jax.Array] = None,  # (B, P, F) patches/frames
    cache: Optional[Params] = None,
    pos: int | jax.Array = 0,
    last_only: bool = False,
    last_idx: Optional[jax.Array] = None,
) -> tuple[jax.Array, Optional[Params], jax.Array]:
    """Full-sequence forward (train / prefill).

    Returns (logits (B, T, V) — or (B, 1, V) when ``last_only`` or
    ``last_idx``, the serving prefill modes: the LM head over 32k x 152k
    logits would dwarf everything else — new_cache | None, moe_aux).
    ``last_idx`` (B,) gathers a per-row position BEFORE the head, so a
    padded-bucket prefill pays one head row per slot, at its true last
    prompt token, instead of V logits for every pad position."""
    x = _embed(params, tokens, rt, cfg)
    memory = None
    if cfg.family == "audio":
        assert frontend_feats is not None, "seamless needs encoder frames"
        memory = _encode(params, frontend_feats, rt, cfg)
    elif cfg.frontend and frontend_feats is not None:
        prefix = dense(frontend_feats.astype(rt.compute_dtype),
                       params["frontend_proj"], rt)
        x = jnp.concatenate([prefix, x], axis=1)

    x, new_cache, aux = _run_decoder(params, x, rt, cfg, cache=cache, pos=pos,
                                     memory=memory)
    if cfg.frontend and frontend_feats is not None and cfg.family != "audio":
        x = x[:, frontend_feats.shape[1]:]
    if last_only:
        x = x[:, -1:]
    elif last_idx is not None:
        x = x[jnp.arange(x.shape[0]), last_idx][:, None]
    return _head(params, x, rt, cfg), new_cache, aux


def forward_xent(
    params: Params,
    tokens: jax.Array,  # (B, T)
    labels: jax.Array,  # (B, T)
    rt: Runtime,
    cfg,
    *,
    frontend_feats: Optional[jax.Array] = None,
    chunk: int = 512,
) -> tuple[jax.Array, jax.Array]:
    """Full forward + cross-entropy WITHOUT materializing (B, T, V) logits:
    the LM head + logsumexp run per sequence-chunk inside a rematerialized
    scan, so peak memory holds one (B, chunk, V) slice. For vocab 152k at
    T=4096 this is the difference between ~50 GB of logits copies and
    ~1.5 GB (EXPERIMENTS.md §Perf, memory term).

    Returns (mean_xent, moe_aux)."""
    x = _embed(params, tokens, rt, cfg)
    memory = None
    if cfg.family == "audio":
        assert frontend_feats is not None
        memory = _encode(params, frontend_feats, rt, cfg)
    elif cfg.frontend and frontend_feats is not None:
        prefix = dense(frontend_feats.astype(rt.compute_dtype),
                       params["frontend_proj"], rt)
        x = jnp.concatenate([prefix, x], axis=1)
    x, _, aux = _run_decoder(params, x, rt, cfg, cache=None, pos=0,
                             memory=memory)
    if cfg.frontend and frontend_feats is not None and cfg.family != "audio":
        x = x[:, frontend_feats.shape[1]:]
    x = norm_apply(params["ln_f"], x, cfg.norm)

    w = _head_weight(params, rt)
    b, t, d = x.shape
    chunk = max(1, min(chunk, t))
    pad = (-t) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    nc = x.shape[1] // chunk
    xc = jnp.moveaxis(x.reshape(b, nc, chunk, d), 1, 0)
    yc = jnp.moveaxis(labels.reshape(b, nc, chunk), 1, 0)

    def body(tot, inp):
        xs, ys = inp  # (B, C, D), (B, C)
        logits = dense(xs, w, rt).astype(jnp.float32)
        logits = shard_hint(logits, rt, "batch", None, "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, jnp.maximum(ys, 0)[..., None],
                                 axis=-1)[..., 0]
        valid = (ys >= 0).astype(jnp.float32)
        return tot + jnp.sum((lse - ll) * valid), None

    tot, _ = jax.lax.scan(jax.checkpoint(body), jnp.zeros((), jnp.float32),
                          (xc, yc))
    return tot / (b * t), aux


def decode_step(
    params: Params,
    tokens: jax.Array,  # (B, 1)
    cache: Params,
    pos: jax.Array,  # int32 scalar or (B,): per-row current write index
    rt: Runtime,
    cfg,
) -> tuple[jax.Array, Params]:
    """One autoregressive step with persistent cache. Returns (logits (B, 1, V),
    new_cache)."""
    x = _embed(params, tokens, rt, cfg)
    x, new_cache, _ = _run_decoder(params, x, rt, cfg, cache=cache, pos=pos)
    return _head(params, x, rt, cfg), new_cache


def score_tokens(
    params: Params,
    tokens: jax.Array,  # (B, T) — T consecutive tokens per row
    cache: Params,
    pos: jax.Array,  # int32 scalar or (B,): write index of tokens[:, 0]
    rt: Runtime,
    cfg,
) -> tuple[jax.Array, Params]:
    """Score a T-token window per row against the persistent cache in ONE
    forward pass — the speculative-decoding verify primitive. Token ``t``
    is written to cache position ``pos + t`` and attends causally to
    everything at or before it, so ``logits[:, t]`` is the model's
    next-token distribution after consuming ``tokens[:, :t+1]`` — exactly
    what ``decode_step`` would produce after T sequential steps. Under
    ``kv_quant`` this routes through the batched ``prefill_attn_q8`` q-tile
    kernel (one fused pass over the rotated-int8 cache for all T
    positions). Returns (logits (B, T, V), new_cache with the span
    appended)."""
    x = _embed(params, tokens, rt, cfg)
    x, new_cache, _ = _run_decoder(params, x, rt, cfg, cache=cache, pos=pos)
    return _head(params, x, rt, cfg), new_cache


def advance_cache(
    params: Params,
    tokens: jax.Array,  # (B, T)
    cache: Params,
    pos: jax.Array,
    rt: Runtime,
    cfg,
) -> Params:
    """Append a token span to the cache WITHOUT computing head logits —
    used when only the KV state matters (e.g. the draft model's final
    propose step must cache position ``pos + T - 1`` so a fully-accepted
    window leaves no stale hole, but its logits are never sampled).
    Returns the new cache."""
    x = _embed(params, tokens, rt, cfg)
    _, new_cache, _ = _run_decoder(params, x, rt, cfg, cache=cache, pos=pos)
    return new_cache


def finite_rows(logits: jax.Array) -> jax.Array:
    """Per-row numeric health: True where every logit in the row is finite.

    The serving engine folds this into the jitted decode step (quantized
    stacks can degenerate at runtime — an inf/NaN KV scale plane poisons a
    row's attention — and the check must ride the step's existing token
    transfer rather than add a host sync). Reduces (..., V) -> (...) bool
    on device; rows that pass are untouched, so healthy streams stay
    bit-identical."""
    return jnp.all(jnp.isfinite(logits.astype(jnp.float32)), axis=-1)


def top_mask(
    logits: jax.Array,  # (B, V) float32
    top_k: Optional[jax.Array] = None,  # (B,) int32; 0 disables per row
    top_p: Optional[jax.Array] = None,  # (B,) float32; 1.0 disables per row
) -> jax.Array:
    """Mask logits outside the per-row top-k / top-p (nucleus) sets to -inf.

    Both filters reduce to a per-row VALUE threshold against the
    descending-sorted logits, so the whole batch is masked with one sort +
    one cumsum — no per-row loops, heterogeneous k/p in one trace. Every
    row keeps at least its argmax (k is clipped to >= 1 when enabled; the
    first nucleus token is always kept since its preceding mass is 0).
    Row-independent by construction, which the engine's batched==sequential
    bit-parity contract relies on."""
    v = logits.shape[-1]
    sorted_desc = -jnp.sort(-logits, axis=-1)
    thresh = jnp.full(logits.shape[:-1], -jnp.inf, jnp.float32)
    if top_k is not None:
        k = jnp.asarray(top_k, jnp.int32)
        kth = jnp.take_along_axis(
            sorted_desc, jnp.clip(k - 1, 0, v - 1)[..., None], axis=-1)[..., 0]
        thresh = jnp.maximum(thresh, jnp.where(k > 0, kth, -jnp.inf))
    if top_p is not None:
        p = jnp.asarray(top_p, jnp.float32)
        probs = jax.nn.softmax(sorted_desc, axis=-1)
        # keep a token iff the mass STRICTLY BEFORE it is < p: the token
        # that crosses the p boundary is included (standard nucleus rule)
        keep = (jnp.cumsum(probs, axis=-1) - probs) < p[..., None]
        pth = jnp.min(jnp.where(keep, sorted_desc, jnp.inf), axis=-1)
        thresh = jnp.maximum(thresh, jnp.where(p < 1.0, pth, -jnp.inf))
    return jnp.where(logits >= thresh[..., None], logits, -jnp.inf)


def sample_tokens(
    logits: jax.Array,  # (..., V)
    key: Optional[jax.Array] = None,
    temperature: jax.Array | float = 0.0,
    *,
    top_k: Optional[jax.Array] = None,  # (B,) per-row; None disables
    top_p: Optional[jax.Array] = None,  # (B,) per-row; None disables
) -> jax.Array:
    """Greedy argmax (``key=None``) or temperature/top-k/top-p sampling,
    on device.

    Designed to live INSIDE the jitted decode step: the engine then moves
    one (slots,) int32 vector per step across the device->host boundary
    instead of one logits row per slot. Greedy decoding passes ``key=None``
    so the hot loop traces to a bare argmax — no PRNG work (threefry over
    (B, V) is real cost on CPU). With a key, ``temperature`` is traced
    (flipping it never recompiles); both the categorical and the argmax are
    computed and selected with where, since temp <= 0 must still mean
    greedy.

    The serving path passes PER-ROW vectors: ``temperature``/``top_k``/
    ``top_p`` of shape (B,) and ``key`` as a (B, 2) batch of uint32 keys —
    every row then samples under its own knobs and its own PRNG stream
    (vmapped categorical), so heterogeneous requests batch in one jitted
    decode and each row's draw is bit-identical to sampling that row alone
    with its key. A single (2,) key with scalar temperature keeps the
    legacy shared-stream behavior."""
    logits = logits.astype(jnp.float32)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if key is None:
        return greedy
    temp = jnp.asarray(temperature, jnp.float32)
    # temperature BEFORE the nucleus filter (the standard order): top-p's
    # keep-set is computed on the distribution actually sampled from, so
    # temp > 1 widens the nucleus and temp < 1 narrows it. top-k is
    # scale-invariant either way. (Greedy rows scale by 1/1e-6; softmax's
    # max-subtraction keeps that finite, and `where` discards the draw.)
    scaled = logits / jnp.maximum(temp, 1e-6)[..., None] \
        if temp.ndim else logits / jnp.maximum(temp, 1e-6)
    if top_k is not None or top_p is not None:
        scaled = top_mask(scaled, top_k, top_p)
    if key.ndim == 2:  # (B, 2) raw key batch: one private stream per row
        sampled = jax.vmap(
            lambda k, row: jax.random.categorical(k, row, axis=-1)
        )(key, scaled).astype(jnp.int32)
    else:  # single key (typed, or raw (2,)): legacy shared stream
        sampled = jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)
    return jnp.where(temp > 0, sampled, greedy)


# ===========================================================================
# Analytic FLOPs (roofline MODEL_FLOPS term)
# ===========================================================================

def model_flops(cfg, seq_len: int, batch: int, *, decode: bool = False) -> float:
    """6*N_active*D-style estimate: matmul params * tokens * (2 fwd [+4 bwd])."""
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab_size
    hd = cfg.resolved_head_dim
    attn_p = d * hd * (cfg.num_heads * 2 + cfg.num_kv_heads * 2)
    if cfg.activation == "swiglu":
        mlp_p = 3 * d * f
    else:
        mlp_p = 2 * d * f
    if cfg.num_experts:
        mlp_p = cfg.experts_per_token * mlp_p + d * cfg.num_experts
    if cfg.family == "ssm":
        h = cfg.num_heads
        attn_p = 5 * d * d + d * d  # r,k,v,g,o + lora-ish
        mlp_p = 2 * d * f
    if cfg.family == "hybrid":
        ed = cfg.ssm_expand * d
        n_attn = cfg.num_layers // cfg.attn_every + 1
        mamba_p = d * (2 * ed + 2 * cfg.ssm_state + ed // 64) + ed * d
        per_layer = mamba_p
        total_p = cfg.num_layers * per_layer + n_attn * 0 + (attn_p + mlp_p)
    else:
        total_p = cfg.num_layers * (attn_p + mlp_p)
        if cfg.is_encoder_decoder:
            total_p += cfg.encoder_layers * (attn_p + mlp_p)
    total_p += v * d  # head
    tokens = batch * (1 if decode else seq_len)
    flops = 2.0 * total_p * tokens
    # attention score/value FLOPs (dense attention archs)
    if cfg.family not in ("ssm",):
        kv_len = seq_len
        q_len = 1 if decode else seq_len
        n_attn = (cfg.num_layers if cfg.family != "hybrid"
                  else cfg.num_layers // cfg.attn_every + 1)
        flops += 4.0 * batch * cfg.num_heads * hd * q_len * kv_len * n_attn * (
            0.5 if not decode else 1.0)
    return flops
