"""Mixture-of-Experts block: top-k router + sort-based expert-parallel
dispatch (MaxText/megablocks style).

Dispatch is computed *per batch row* (each row of the data-parallel axis
routes its own T*k assignments into per-expert capacity buffers), so the
buffer tensor is (B, E, C, D) — sharded batch-over-data and experts-over-
model — and no (tokens, E, C) one-hot is ever materialized. Assignment uses
an argsort over expert ids + rank-within-expert (tokens beyond capacity are
dropped, standard Switch semantics), which lowers to TPU-friendly sorts and
scatters instead of giant one-hot einsums.

Router weights stay replicated/full-precision by default (< 0.01% of
params); expert weights are (E, K, N) stacks — quantizable as stacked
QTensors, exercised by the serving path.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.quantize import QTensor
from repro.models.layers import Runtime, dense, init_dense_weight, shard_hint

Params = dict[str, Any]

__all__ = ["moe_init", "moe_apply"]


def moe_init(key, d: int, f: int, num_experts: int, activation: str) -> Params:
    ks = jax.random.split(key, 4)
    e = num_experts
    p = {
        "router": init_dense_weight(ks[0], d, e),
        "up": jax.vmap(lambda k: init_dense_weight(k, d, f))(jax.random.split(ks[1], e)),
        "down": jax.vmap(lambda k: init_dense_weight(k, f, d))(jax.random.split(ks[2], e)),
    }
    if activation == "swiglu":
        p["gate"] = jax.vmap(lambda k: init_dense_weight(k, d, f))(jax.random.split(ks[3], e))
    return p


def _edense(x: jax.Array, w, rt: Runtime) -> jax.Array:
    """Per-expert dense: x (E, B, C, D) @ w (E, D, F) -> (E, B, C, F)."""
    if isinstance(w, QTensor):
        return jax.vmap(
            lambda xe, *leaves: dense(
                xe, QTensor(dict(zip(w.data.keys(), leaves)), w.meta), rt
            )
        )(x, *w.data.values())
    return jnp.einsum("ebcd,edf->ebcf", x.astype(rt.compute_dtype),
                      w.astype(rt.compute_dtype))


def _expert_ffn(p: Params, x: jax.Array, rt: Runtime, activation: str) -> jax.Array:
    if activation == "swiglu":
        h = jax.nn.silu(_edense(x, p["gate"], rt)) * _edense(x, p["up"], rt)
    elif activation == "relu2":
        h = jnp.square(jax.nn.relu(_edense(x, p["up"], rt)))
    else:
        h = jax.nn.gelu(_edense(x, p["up"], rt))
    h = shard_hint(h, rt, "experts", "batch", None, "ffn")
    return _edense(h, p["down"], rt)


def moe_apply(
    p: Params,
    x: jax.Array,  # (B, T, D)
    rt: Runtime,
    cfg,
) -> tuple[jax.Array, jax.Array]:
    """Returns (output (B, T, D), load-balancing aux loss)."""
    b, t, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    cap = max(1, -(-int(rt.capacity_factor * t * k) // e))
    cap = min(cap, t * k)

    logits = dense(x, p["router"], rt).astype(jnp.float32)  # (B, T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)  # (B, T, k)
    gate_vals = gate_vals / (jnp.sum(gate_vals, axis=-1, keepdims=True) + 1e-9)

    # Switch aux loss: E * sum_e mean_tokens(P_e) * mean_tokens(assigned_e)
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(idx[..., 0], e, dtype=jnp.float32), axis=(0, 1))
    aux = e * jnp.sum(me * ce)

    def dispatch_row(xg, idxg, gateg):
        """xg (T, D), idxg/gateg (T, k) -> buffer (E, C, D) + combine meta."""
        eid = idxg.reshape(-1)  # (T*k,)
        order = jnp.argsort(eid)
        s_eid = eid[order]
        # rank of each assignment within its expert (stable: sorted order)
        first = jnp.searchsorted(s_eid, s_eid, side="left")
        rank = jnp.arange(t * k, dtype=jnp.int32) - first.astype(jnp.int32)
        keep = rank < cap
        rankc = jnp.minimum(rank, cap - 1)
        tok = (order // k).astype(jnp.int32)
        gat = gateg.reshape(-1)[order]
        contrib = xg[tok].astype(rt.compute_dtype) * keep[:, None].astype(rt.compute_dtype)
        buf = jnp.zeros((e, cap, d), rt.compute_dtype).at[s_eid, rankc].add(contrib)
        return buf, (s_eid, rankc, tok, gat * keep)

    buf, meta = jax.vmap(dispatch_row)(x, idx, gate_vals)  # buf (B, E, C, D)
    buf = shard_hint(buf.swapaxes(0, 1), rt, "experts", "batch", None, None)

    out_buf = _expert_ffn(p, buf, rt, cfg.activation)  # (E, B, C, D)
    out_buf = shard_hint(out_buf, rt, "experts", "batch", None, None)

    def combine_row(bufg, m):
        """bufg (E, C, D); meta (T*k,)-arrays -> (T, D)."""
        s_eid, rankc, tok, w = m
        vals = bufg[s_eid, rankc] * w[:, None].astype(bufg.dtype)
        return jnp.zeros((t, d), bufg.dtype).at[tok].add(vals)

    if rt.rules is not None and rt.rules.assignments.get("experts") and rt.mesh is not None:
        out = _combine_ep_shardmap(out_buf, meta, rt, t, d, e)
    else:
        out = jax.vmap(combine_row)(out_buf.swapaxes(0, 1), meta)
    return out.astype(rt.compute_dtype), aux


def _combine_ep_shardmap(out_buf, meta, rt: Runtime, t: int, d: int, e: int):
    """Expert-parallel combine with the all-reduce at (T, D) width.

    The naive gather-from-E-sharded-buffer makes SPMD all-reduce the full
    (T*k, D) gathered tensor (each shard contributes zeros for remote
    experts). Doing the combine *inside* shard_map lets each shard gather
    only its local experts' outputs, scatter-add them into a local (T, D)
    partial, and psum THAT — k (=8 for the assigned MoEs) times fewer
    collective bytes (EXPERIMENTS.md §Perf cell B).

    out_buf: (E, B, C, D) sharded (experts->model, batch on B);
    meta arrays: (B, T*k) replicated over model."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = rt.mesh
    msize = mesh.shape["model"]
    e_local = e // msize
    batch_ax = rt.rules.assignments.get("batch")

    def local_combine(bufl, s_eid, rankc, tok, w):
        # bufl (E/m, B_loc, C, D); meta (B_loc, T*k)
        e_lo = jax.lax.axis_index("model") * e_local

        def one_row(bufr, se, rk, tk, ww):
            loc = se.astype(jnp.int32) - e_lo
            ok = (loc >= 0) & (loc < e_local)
            locc = jnp.clip(loc, 0, e_local - 1)
            vals = bufr[locc, rk] * (ww * ok).astype(bufr.dtype)[:, None]
            return jnp.zeros((t, d), bufr.dtype).at[tk].add(vals)

        part = jax.vmap(one_row, in_axes=(1, 0, 0, 0, 0))(bufl, s_eid, rankc, tok, w)
        return jax.lax.psum(part, "model")

    fn = shard_map(
        local_combine, mesh=mesh,
        in_specs=(P("model", batch_ax), P(batch_ax), P(batch_ax),
                  P(batch_ax), P(batch_ax)),
        out_specs=P(batch_ax),
        check_rep=False)
    s_eid, rankc, tok, w = meta
    return fn(out_buf, s_eid, rankc, tok, w)
