"""Transformer building blocks — pure JAX, quantization-aware.

Every matmul weight flows through :func:`dense`, which dispatches on the
leaf type: a plain ``jax.Array`` (training / fp serving) or a
:class:`~repro.core.quantize.QTensor` (ITQ3_S-family quantized serving).
That single seam is how the paper's format becomes a first-class feature of
the whole framework: any architecture in the zoo can be served quantized by
mapping ``quantize`` over its parameter tree.

Attention uses query-chunked softmax (scan over query blocks, full-width
keys) so 32k-token prefill never materializes a (T, T) score tensor; KV
cache layout is (B, KV_heads, T, head_dim) to give the sharding layer a
clean choice between head-sharding and sequence-sharding (see
sharding/rules.py).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.qlinear import qmatmul
from repro.core.quantize import QTensor
from repro.kernels.attn_decode import decode_attn_q8, prefill_attn_q8
from repro.serve.kv_quant import kv_encode

__all__ = [
    "Runtime", "dense", "norm_apply", "rope", "mlp_init", "mlp_apply",
    "attention_init", "attention_apply", "init_dense_weight", "shard_hint",
]

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class Runtime:
    """Execution-time knobs threaded through every apply function."""

    compute_dtype: Any = jnp.bfloat16
    quant_mode: str = "activations"  # qmatmul mode for QTensor weights
    backend: str = "auto"  # qmatmul backend: auto | ref | pallas
    use_kernel: bool = False  # deprecated: force backend="pallas"
    tile_m: Any = None  # Pallas tile override; None = autotune cache/defaults
    tile_n: Any = None
    autotune: bool = False  # eagerly tune kernel tiles on engine boot (TPU)
    attn_chunk: int = 512  # query-chunk size for softmax attention
    attn_tile_q: Any = None  # quantized-cache attention query-tile; None = default
    attn_tile_k: Any = None  # quantized-cache attention key-tile; None = default
    capacity_factor: float = 1.25  # MoE expert capacity factor
    remat: bool = False  # rematerialize each layer (training)
    remat_policy: str = "none"  # none | dots  (what each layer may save)
    decode_token_cache: bool = True  # O(1)-byte decode cache writes (perf log A2)
    kv_quant: bool = False  # rotated-int8 KV cache (serve/kv_quant.py codec)
    # W3A8 integer compute path: rotate + int8-quantize activations and
    # contract against the ternary codes with int32 accumulation
    # (core/act_quant.py). Off by default — the float path stays
    # bit-identical to historical streams; QMeta.act_quant opts individual
    # weight paths out even when this is on.
    act_quant: bool = False
    rwkv_mode: str = "chunked"  # chunked (MXU) | scan (stepwise reference)
    rules: Any = None  # sharding.rules.Rules | None
    mesh: Any = None
    # Tensor-parallel serving: run quantized matmuls / fused cache attention
    # as explicit shard_maps over the mesh (serve/tp.py) instead of leaving
    # the partitioning to GSPMD. Required on real TPU (GSPMD cannot split a
    # pallas_call); optional on CPU/ref where both paths are bit-identical.
    tp_shard_map: bool = False


def shard_hint(x: jax.Array, rt: Runtime, *names: Optional[str]) -> jax.Array:
    """Apply a logical sharding constraint if rules are active."""
    if rt.rules is None:
        return x
    return rt.rules.constrain(x, names, mesh=rt.mesh)


def dense(x: jax.Array, w, rt: Runtime, bias=None) -> jax.Array:
    """``x @ w (+ bias)`` with QTensor dispatch (the quantization seam).

    The ref-vs-Pallas choice lives inside :func:`qmatmul` — this seam only
    forwards the Runtime knobs, so every registered format (and every
    future one) serves through the same line of code."""
    if isinstance(w, QTensor):
        backend = "pallas" if rt.use_kernel else rt.backend
        if rt.tp_shard_map and rt.rules is not None:
            from repro.serve import tp as tp_mod  # lazy: layers <-> serve
            y = tp_mod.tp_qmatmul(x, w, rt.rules, mode=rt.quant_mode,
                                  backend=backend,
                                  compute_dtype=rt.compute_dtype,
                                  tm=rt.tile_m, tn=rt.tile_n,
                                  act_quant=rt.act_quant)
        else:
            y = qmatmul(x, w, mode=rt.quant_mode, backend=backend,
                        compute_dtype=rt.compute_dtype,
                        tm=rt.tile_m, tn=rt.tile_n,
                        act_quant=rt.act_quant)
    else:
        y = jnp.matmul(x.astype(rt.compute_dtype), w.astype(rt.compute_dtype))
    if bias is not None:
        y = y + bias.astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_dense_weight(key, k: int, n: int, dtype=jnp.float32) -> jax.Array:
    std = 1.0 / math.sqrt(k)
    return jax.random.truncated_normal(key, -3, 3, (k, n), dtype) * std


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_init(d: int, kind: str) -> Params:
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def norm_apply(p: Params, x: jax.Array, kind: str, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    if kind == "rmsnorm":
        x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    elif kind == "layernorm":
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.var(x, axis=-1, keepdims=True)
        x = (x - mu) * jax.lax.rsqrt(var + eps)
    else:
        raise ValueError(f"unknown norm {kind!r}")
    x = x * p["scale"]
    if "bias" in p:
        x = x + p["bias"]
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float, pct: float = 1.0) -> jax.Array:
    """Rotary embedding on the trailing head_dim of x (..., T, HD).

    ``positions``: (..., T) int32 absolute positions. ``pct`` < 1 rotates
    only the leading fraction of head_dim (stablelm partial rotary)."""
    hd = x.shape[-1]
    rot = int(hd * pct)
    rot -= rot % 2
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    half = rot // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., T, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = xr[..., :half], xr[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1)


# ---------------------------------------------------------------------------
# MLP (swiglu | gelu | relu2)
# ---------------------------------------------------------------------------

def mlp_init(key, d: int, f: int, activation: str) -> Params:
    ks = jax.random.split(key, 3)
    p = {"down": init_dense_weight(ks[2], f, d)}
    if activation == "swiglu":
        p["gate"] = init_dense_weight(ks[0], d, f)
        p["up"] = init_dense_weight(ks[1], d, f)
    else:
        p["up"] = init_dense_weight(ks[1], d, f)
    return p


def mlp_apply(p: Params, x: jax.Array, rt: Runtime, activation: str) -> jax.Array:
    if activation == "swiglu":
        h = jax.nn.silu(dense(x, p["gate"], rt)) * dense(x, p["up"], rt)
    elif activation == "gelu":
        h = jax.nn.gelu(dense(x, p["up"], rt))
    elif activation == "relu2":
        h = jnp.square(jax.nn.relu(dense(x, p["up"], rt)))
    else:
        raise ValueError(f"unknown activation {activation!r}")
    h = shard_hint(h, rt, "batch", "seq", "ffn")
    return dense(h, p["down"], rt)


# ---------------------------------------------------------------------------
# Attention (GQA, query-chunked softmax, optional cross-attention)
# ---------------------------------------------------------------------------

def attention_init(key, d: int, heads: int, kv_heads: int, head_dim: int,
                   qkv_bias: bool = False) -> Params:
    ks = jax.random.split(key, 4)
    p = {
        "wq": init_dense_weight(ks[0], d, heads * head_dim),
        "wk": init_dense_weight(ks[1], d, kv_heads * head_dim),
        "wv": init_dense_weight(ks[2], d, kv_heads * head_dim),
        "wo": init_dense_weight(ks[3], heads * head_dim, d),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((heads * head_dim,), jnp.float32)
        p["bk"] = jnp.zeros((kv_heads * head_dim,), jnp.float32)
        p["bv"] = jnp.zeros((kv_heads * head_dim,), jnp.float32)
    return p


def _sdpa_chunked(q, k, v, rt: Runtime, *, causal: bool, q_offset=None,
                  kv_len=None):
    """q (B, KV, G, Tq, HD); k,v (B, KV, Tk, HD) -> (B, KV, G, Tq, HD).

    Scans over query chunks; each chunk sees the full key width, with a
    causal mask from absolute positions (q_offset (B,) + local index).
    kv_len (B,) masks out unwritten cache slots during decode — positions
    are per-batch-row vectors so slot-batched serving works ragged."""
    b, kvh, g, tq, hd = q.shape
    tk = k.shape[2]
    scale = 1.0 / math.sqrt(hd)
    # keep K/V in their storage dtype (bf16): the MXU accumulates in f32
    # via preferred_element_type, so upcasting the whole 32k cache per
    # layer (2x its bytes in pure convert traffic) buys nothing.
    kf, vf = k, v
    kpos = jnp.arange(tk)
    if q_offset is None:
        q_offset = jnp.zeros((b,), jnp.int32)

    chunk = max(1, min(rt.attn_chunk, tq))
    pad = (-tq) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
    nq = q.shape[3] // chunk
    qc = q.reshape(b, kvh, g, nq, chunk, hd)
    qc = jnp.moveaxis(qc, 3, 0)  # (nq, B, KV, G, chunk, HD)

    def one_chunk(ci, qi):
        s = jnp.einsum("bkgqd,bktd->bkgqt", qi.astype(kf.dtype), kf,
                       preferred_element_type=jnp.float32) * scale
        # masks broadcast as (B, 1, 1, chunk, tk)
        mask = jnp.ones((b, 1, 1, chunk, tk), bool)
        if causal:
            qpos = q_offset[:, None] + ci * chunk + jnp.arange(chunk)  # (B, chunk)
            mask = mask & (kpos[None, None, None, None, :]
                           <= qpos[:, None, None, :, None])
        if kv_len is not None:
            mask = mask & (kpos[None, None, None, None, :]
                           < kv_len[:, None, None, None, None])
        s = jnp.where(mask, s, -1e30)
        w = jax.nn.softmax(s, axis=-1)  # f32 softmax
        return jnp.einsum("bkgqt,bktd->bkgqd", w.astype(vf.dtype), vf,
                          preferred_element_type=jnp.float32)

    if nq == 1:
        out = one_chunk(0, qc[0])[None]
    else:
        # checkpoint each chunk: backward recomputes scores/softmax instead
        # of saving (B, KV, G, chunk, Tk) residuals per chunk (flash-style)
        body = jax.checkpoint(lambda args: one_chunk(*args))
        out = jax.lax.map(body, (jnp.arange(nq), qc))
    out = jnp.moveaxis(out, 0, 3).reshape(b, kvh, g, nq * chunk, hd)
    return out[..., :tq, :].astype(rt.compute_dtype)


def _decode_q8(q, cache, k_tok, v_tok, kv_len, rt: Runtime):
    """Quantized-cache decode attention, shard_mapped over kv_heads when
    tensor-parallel serving is active (serve/tp.py)."""
    if rt.tp_shard_map and rt.rules is not None:
        from repro.serve import tp as tp_mod  # lazy: layers <-> serve
        return tp_mod.tp_decode_attn_q8(q, cache, k_tok, v_tok, kv_len,
                                        rt.rules, backend=rt.backend,
                                        tt=rt.attn_tile_k)
    return decode_attn_q8(q, cache, k_tok, v_tok, kv_len,
                          backend=rt.backend, tt=rt.attn_tile_k)


def _prefill_q8(q, cache, kv_len, q_offset, rt: Runtime):
    """Quantized-cache prefill attention, shard_mapped under TP."""
    if rt.tp_shard_map and rt.rules is not None:
        from repro.serve import tp as tp_mod  # lazy: layers <-> serve
        return tp_mod.tp_prefill_attn_q8(q, cache, kv_len, q_offset,
                                         rt.rules, backend=rt.backend,
                                         tq=rt.attn_tile_q,
                                         tt=rt.attn_tile_k)
    return prefill_attn_q8(q, cache, kv_len, q_offset, backend=rt.backend,
                           tq=rt.attn_tile_q, tt=rt.attn_tile_k)


def attention_apply(
    p: Params,
    x: jax.Array,  # (B, T, D)
    rt: Runtime,
    cfg,
    *,
    causal: bool = True,
    cache: Optional[Params] = None,  # {"k","v": (B, KV, S, HD)}
    pos: int | jax.Array = 0,
    memory: Optional[jax.Array] = None,  # cross-attention source (B, S, D)
    cross: bool = False,
    token_cache: bool = False,  # decode: return token K/V, don't rewrite cache
) -> tuple[jax.Array, Optional[Params]]:
    """Returns (output (B, T, D), updated cache or None).

    Self-attention (cross=False): RoPE on q/k, causal, optional rolling KV
    cache written at ``pos``. Cross-attention (cross=True): K/V projected
    from ``memory`` when given (train / prefill, cache overwritten), or read
    straight from the cache (decode)."""
    b, t, d = x.shape
    h, kvh = cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    g = h // kvh

    q = dense(x, p["wq"], rt, p.get("bq"))
    q = q.reshape(b, t, kvh, g, hd)

    if cross:
        if memory is not None:
            k = dense(memory, p["wk"], rt).reshape(b, memory.shape[1], kvh, hd)
            v = dense(memory, p["wv"], rt).reshape(b, memory.shape[1], kvh, hd)
            k, v = k.swapaxes(1, 2), v.swapaxes(1, 2)
            new_cache = None
            if cache is not None:
                new_cache = {"k": k.astype(cache["k"].dtype),
                             "v": v.astype(cache["v"].dtype)}
        else:
            if cache is None:
                raise ValueError("cross-attention decode needs cached memory K/V")
            k, v = cache["k"], cache["v"]
            new_cache = cache
        q = q.reshape(b, t, kvh * g, hd).swapaxes(1, 2).reshape(b, kvh, g, t, hd)
        q = shard_hint(q, rt, "batch", "kv_heads", None, None, None)
        out = _sdpa_chunked(q, k, v, rt, causal=False, q_offset=0, kv_len=None)
        out = out.reshape(b, h, -1, hd)[:, :, :t, :].swapaxes(1, 2).reshape(b, t, h * hd)
        return dense(out, p["wo"], rt), new_cache

    # ---- self-attention ----
    k = dense(x, p["wk"], rt, p.get("bk")).reshape(b, t, kvh, hd)
    v = dense(x, p["wv"], rt, p.get("bv")).reshape(b, t, kvh, hd)

    # positions are per-batch-row (ragged slot-batched serving); scalars
    # broadcast to a vector.
    pos_vec = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    qpos = pos_vec[:, None] + jnp.arange(t)  # (B, T)
    q = rope(q.reshape(b, t, kvh * g, hd).swapaxes(1, 2),
             qpos[:, None, :], cfg.rope_theta, cfg.rotary_pct)  # (B, H, T, HD)
    q = q.reshape(b, kvh, g, t, hd)
    k = rope(k.swapaxes(1, 2), qpos[:, None, :], cfg.rope_theta, cfg.rotary_pct)
    v = v.swapaxes(1, 2)  # (B, KV, T, HD)

    q = shard_hint(q, rt, "batch", "kv_heads", None, None, None)
    kv_len = None
    new_cache = None
    quant_cache = cache is not None and "k_scale" in cache
    if cache is not None and t == 1 and token_cache:
        # vLLM-style decode: do NOT rewrite the cache functionally — attend
        # against the stale cache (kv_len masks slot >= pos) plus an
        # explicit self-term for the new token, and hand the (B, KV, 1, HD)
        # token K/V back to the caller, which writes just that slice into
        # the scan-carried cache buffer. Cuts the per-layer cache write
        # from O(T) to O(1) bytes (EXPERIMENTS.md §Perf, cell A).
        if quant_cache:
            # rotated-int8 cache: the token's K/V go through the codec HERE
            # so the self term attends against exactly the values every
            # later step will read back from the cache.
            kq, ks = kv_encode(k)
            vq, vs = kv_encode(v)
            out = _decode_q8(q, cache, (kq, ks), (vq, vs), pos_vec, rt)
            out = out.astype(rt.compute_dtype)
            tok = {"k_tok": kq, "v_tok": vq,
                   "k_scale_tok": ks, "v_scale_tok": vs}
        else:
            out = _sdpa_decode_token(q, cache["k"], cache["v"], k, v, rt,
                                     kv_len=pos_vec)
            tok = {"k_tok": k, "v_tok": v}
        out = out.reshape(b, h, 1, hd).swapaxes(1, 2).reshape(b, t, h * hd)
        return dense(out, p["wo"], rt), tok
    if quant_cache:
        # prefill (or functional-cache decode) over the quantized cache:
        # encode the new K/V span and write codes+scales at pos.
        kq, ks = kv_encode(k)
        vq, vs = kv_encode(v)
        if "table" in cache:
            # paged pool: scatter the span through the block table. Leaves
            # are (NB, KV, BS, X); token p of slot b lands in block
            # tbl[b, p // BS] at offset p % BS. Slots whose rows point at
            # the reserved null block 0 (padding / inactive) scatter finite
            # garbage there — never read, masked by kv_len.
            tbl = cache["table"]
            bs = cache["k"].shape[2]
            span = pos_vec[:, None] + jnp.arange(t)  # (B, T)
            blk = jnp.take_along_axis(tbl, span // bs, axis=1)  # (B, T)
            off = span % bs

            def scat(pool, vals):  # pool (NB, KV, BS, X); vals (B, KV, T, X)
                return pool.at[blk, :, off, :].set(
                    jnp.swapaxes(vals, 1, 2).astype(pool.dtype))

            new_cache = {"k": scat(cache["k"], kq),
                         "v": scat(cache["v"], vq),
                         "k_scale": scat(cache["k_scale"], ks),
                         "v_scale": scat(cache["v_scale"], vs)}
            read_cache = dict(new_cache, table=tbl)
        else:
            upd = jax.vmap(partial(jax.lax.dynamic_update_slice_in_dim, axis=1))
            ck = upd(cache["k"], kq, pos_vec)
            cks = upd(cache["k_scale"], ks.astype(cache["k_scale"].dtype),
                      pos_vec)
            cv = upd(cache["v"], vq, pos_vec)
            cvs = upd(cache["v_scale"], vs.astype(cache["v_scale"].dtype),
                      pos_vec)
            ck = shard_hint(ck, rt, "batch", "kv_heads", "kv_seq", None)
            cv = shard_hint(cv, rt, "batch", "kv_heads", "kv_seq", None)
            cks = shard_hint(cks, rt, "batch", "kv_heads", "kv_seq", None)
            cvs = shard_hint(cvs, rt, "batch", "kv_heads", "kv_seq", None)
            new_cache = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs}
            read_cache = new_cache
        if t == 1:
            # single-token decode WITHOUT the scan-carry mechanism (hybrid's
            # shared attention block, or decode_token_cache=False): same
            # dequantize-free path as the token-cache branch — attend the
            # PRE-write cache plus the encoded self term — instead of
            # dequantizing the whole max_len cache every step. Only the
            # functional write above touches the full buffers.
            out = _decode_q8(q, cache, (kq, ks), (vq, vs), pos_vec, rt)
        else:
            # prefill: fused q-tile attention straight over the POST-write
            # codes. Scores stay in the rotated domain ((Hq).(Hk) == q.k)
            # and the span's own keys were just written at
            # pos..pos+t-1, so the causal mask (kpos <= pos + qpos) merges
            # the in-flight span's self-attention block into the same
            # cache pass — the decode path's self-token merge generalized
            # to a width-t span. The full cache buffer is NEVER
            # dequantized: chunked prefill streams int8 codes only.
            out = _prefill_q8(q, read_cache, pos_vec + t, pos_vec, rt)
        out = out.astype(rt.compute_dtype)
        out = out.reshape(b, h, t, hd).swapaxes(1, 2).reshape(b, t, h * hd)
        return dense(out, p["wo"], rt), new_cache
    elif cache is not None:
        upd = jax.vmap(partial(jax.lax.dynamic_update_slice_in_dim, axis=1))
        ck = upd(cache["k"], k.astype(cache["k"].dtype), pos_vec)
        cv = upd(cache["v"], v.astype(cache["v"].dtype), pos_vec)
        ck = shard_hint(ck, rt, "batch", "kv_heads", "kv_seq", None)
        cv = shard_hint(cv, rt, "batch", "kv_heads", "kv_seq", None)
        new_cache = {"k": ck, "v": cv}
        k, v = ck, cv
        kv_len = pos_vec + t
        causal = t > 1  # within-step causality only; cache masked by kv_len
    else:
        k = shard_hint(k, rt, "batch", "kv_heads", "kv_seq", None)
        v = shard_hint(v, rt, "batch", "kv_heads", "kv_seq", None)

    out = _sdpa_chunked(q, k, v, rt, causal=causal, q_offset=pos_vec,
                        kv_len=kv_len)
    out = out.reshape(b, h, -1, hd)[:, :, :t, :].swapaxes(1, 2).reshape(b, t, h * hd)
    return dense(out, p["wo"], rt), new_cache


def _sdpa_decode_token(q, ck, cv, k_tok, v_tok, rt: Runtime, *, kv_len):
    """Single-token decode attention against a cache that does NOT yet
    contain the current token: softmax over [cached scores | self score].

    q (B, KV, G, 1, HD); ck/cv (B, KV, Tk, HD); k_tok/v_tok (B, KV, 1, HD);
    kv_len (B,) = number of valid cached positions (== current pos)."""
    b, kvh, g, _, hd = q.shape
    tk = ck.shape[2]
    scale = 1.0 / math.sqrt(hd)
    qc = q.astype(ck.dtype)
    s_cache = jnp.einsum("bkgqd,bktd->bkgqt", qc, ck,
                         preferred_element_type=jnp.float32) * scale
    kpos = jnp.arange(tk)
    mask = kpos[None, None, None, None, :] < kv_len[:, None, None, None, None]
    s_cache = jnp.where(mask, s_cache, -1e30)
    s_self = jnp.einsum("bkgqd,bkqd->bkgq", qc, k_tok.astype(qc.dtype),
                        preferred_element_type=jnp.float32)[..., None] * scale
    s = jnp.concatenate([s_cache, s_self], axis=-1)
    w = jax.nn.softmax(s, axis=-1)
    w_cache, w_self = w[..., :tk], w[..., tk:]
    out = jnp.einsum("bkgqt,bktd->bkgqd", w_cache.astype(cv.dtype), cv,
                     preferred_element_type=jnp.float32)
    out = out + w_self.astype(jnp.float32) * v_tok[:, :, None].astype(jnp.float32)
    return out.astype(rt.compute_dtype)
