"""State-space / linear-attention blocks: Mamba2 (SSD) and RWKV6 (Finch).

Mamba2 uses the chunked SSD algorithm — intra-chunk quadratic attention
with decay masks + inter-chunk state carried by a scan — which maps the
recurrence onto MXU matmuls (the TPU-native formulation; a pure time-step
scan would serialize on the VPU). Decay masks are built from pairwise
*differences* of cumulative log-decays, so every exponentiated quantity is
<= 0 and the computation is stable in f32 for any chunk length.

RWKV6 has per-channel data-dependent decay, which makes the chunked mask
per-channel (a (T, T, D) tensor — infeasible); we therefore implement the
honest O(T) time scan for train/prefill and the O(1) state update for
decode — decode being exactly the regime the long_500k shape targets.
Chunked RWKV6 is listed as a hillclimb candidate in EXPERIMENTS.md.

Both blocks expose the same (x, state) -> (y, state) interface; states are
the serving "cache" for SSM layers.
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import Runtime, dense, init_dense_weight, norm_apply, shard_hint

Params = dict[str, Any]

__all__ = [
    "mamba2_init", "mamba2_apply", "mamba2_empty_state",
    "rwkv6_init", "rwkv6_apply", "rwkv6_empty_state",
]

MAMBA_HEADDIM = 64
CHUNK = 128


# ===========================================================================
# Mamba2 (SSD)
# ===========================================================================

def mamba2_dims(cfg):
    ed = cfg.ssm_expand * cfg.d_model
    heads = ed // MAMBA_HEADDIM
    return ed, heads, cfg.ssm_state


def mamba2_init(key, cfg) -> Params:
    """Projections are stored per-component (z | x | B | C | dt) rather than
    as one fused in_proj so each can carry its own TP sharding: z/x column-
    shard over 'model' (heads), B/C/dt are small and replicated — the
    Megatron-style Mamba TP layout."""
    d = cfg.d_model
    ed, h, n = mamba2_dims(cfg)
    ks = jax.random.split(key, 8)
    return {
        "wz": init_dense_weight(ks[0], d, ed),
        "wx": init_dense_weight(ks[1], d, ed),
        "wB": init_dense_weight(ks[2], d, n),
        "wC": init_dense_weight(ks[3], d, n),
        "wdt": init_dense_weight(ks[4], d, h),
        "conv_x": jax.random.normal(ks[5], (cfg.ssm_conv, ed), jnp.float32) * 0.1,
        "conv_B": jax.random.normal(ks[6], (cfg.ssm_conv, n), jnp.float32) * 0.1,
        "conv_C": jax.random.normal(ks[7], (cfg.ssm_conv, n), jnp.float32) * 0.1,
        "conv_b": jnp.zeros((ed + 2 * n,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.full((h,), math.log(math.e - 1) - 2.0, jnp.float32),
        "norm": {"scale": jnp.ones((ed,), jnp.float32)},
        "out_proj": init_dense_weight(ks[4], ed, d),
    }


def mamba2_empty_state(cfg, batch: int, dtype=jnp.float32) -> Params:
    ed, h, n = mamba2_dims(cfg)
    return {
        "ssm": jnp.zeros((batch, h, n, MAMBA_HEADDIM), dtype),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, ed + 2 * n), dtype),
    }


def _segsum(logd: jax.Array) -> jax.Array:
    """Stable pairwise decay exponent: out[t, s] = sum_{s < u <= t} logd[u]
    (for t >= s; -inf above diagonal). logd (..., T)."""
    t = logd.shape[-1]
    cs = jnp.cumsum(logd, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # (..., T, T): L_t - L_s
    mask = jnp.tril(jnp.ones((t, t), bool))
    return jnp.where(mask, diff, -jnp.inf)


def _mamba2_chunk_scan(xh, dt, Bm, Cm, A, *, state):
    """Chunked SSD. xh (B,T,H,P), dt (B,T,H), Bm/Cm (B,T,N), A (H,) > 0.

    Returns (y (B,T,H,P), final_state (B,H,N,P))."""
    b, t, h, p = xh.shape
    n = Bm.shape[-1]
    lc = min(CHUNK, t)
    pad = (-t) % lc
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    nc = xh.shape[1] // lc

    def csplit(a):
        return a.reshape(b, nc, lc, *a.shape[2:]).swapaxes(0, 1)  # (nc, B, lc, ...)

    xs, dts, Bs, Cs = map(csplit, (xh, dt, Bm, Cm))
    logd_all = -(A[None, None, :] * dts)  # (nc, B, lc, H) log decay <= 0

    def chunk_step(s, inp):
        xc, dtc, bc, cc, logd = inp  # (B, lc, H, P) (B, lc, H) (B, lc, N) ...
        xbar = xc * dtc[..., None]  # fold dt into input
        seg = _segsum(logd.swapaxes(1, 2))  # (B, H, lc, lc)
        decay = jnp.exp(seg)
        # intra-chunk: y[t] += C_t . B_s (decay t<-s) xbar_s
        scores = jnp.einsum("btn,bsn->bts", cc, bc)[:, None] * decay  # (B,H,lc,lc)
        y = jnp.einsum("bhts,bshp->bthp", scores, xbar)
        # inter-chunk: y[t] += C_t . (decay_to_t * s_in)
        cum = jnp.cumsum(logd, axis=1)  # (B, lc, H)
        y = y + jnp.einsum("btn,bhnp->bthp", cc, s) * jnp.exp(cum)[..., None]
        # state update: s' = decay_all * s + sum_s decay_from_s B_s xbar_s
        tot = cum[:, -1]  # (B, H)
        rem = jnp.exp(tot[:, None] - cum)  # decay from step s to chunk end
        s_new = jnp.exp(tot)[..., None, None] * s + jnp.einsum(
            "bsn,bshp->bhnp", bc, xbar * rem[..., None])
        return s_new, y

    state, ys = jax.lax.scan(chunk_step, state, (xs, dts, Bs, Cs, logd_all))
    y = ys.swapaxes(0, 1).reshape(b, nc * lc, h, p)[:, :t]
    return y, state


def mamba2_apply(
    p: Params,
    x: jax.Array,  # (B, T, D)
    rt: Runtime,
    cfg,
    *,
    state: Optional[Params] = None,
    decode: bool = False,
) -> tuple[jax.Array, Optional[Params]]:
    b, t, d = x.shape
    ed, h, n = mamba2_dims(cfg)
    z = dense(x, p["wz"], rt).astype(jnp.float32)
    xh = dense(x, p["wx"], rt).astype(jnp.float32)
    xh = shard_hint(xh, rt, "batch", None, "heads")
    z = shard_hint(z, rt, "batch", None, "heads")
    Bm = dense(x, p["wB"], rt).astype(jnp.float32)
    Cm = dense(x, p["wC"], rt).astype(jnp.float32)
    dt = dense(x, p["wdt"], rt).astype(jnp.float32)
    dt = jax.nn.softplus(dt + p["dt_bias"])  # (B, T, H)
    A = jnp.exp(p["A_log"])  # (H,) positive

    conv_in = jnp.concatenate([xh, Bm, Cm], axis=-1)  # (B, T, ed+2n)
    conv_w = jnp.concatenate([p["conv_x"], p["conv_B"], p["conv_C"]], axis=-1)
    kw = cfg.ssm_conv
    if decode:
        assert t == 1
        window = jnp.concatenate([state["conv"].astype(jnp.float32), conv_in], axis=1)
        new_conv = window[:, 1:]
        conv = jnp.einsum("bkc,kc->bc", window, conv_w) + p["conv_b"]
        conv = jax.nn.silu(conv)[:, None]  # (B, 1, C)
    else:
        prevk = (state["conv"].astype(jnp.float32) if state is not None
                 else jnp.zeros((b, kw - 1, ed + 2 * n), jnp.float32))
        window = jnp.concatenate([prevk, conv_in], axis=1)
        new_conv = window[:, -(kw - 1):]
        stacked = jnp.stack([window[:, i : i + t] for i in range(kw)], axis=2)
        conv = jnp.einsum("btkc,kc->btc", stacked, conv_w) + p["conv_b"]
        conv = jax.nn.silu(conv)

    xh_c, B_c, C_c = jnp.split(conv, [ed, ed + n], axis=-1)
    xhh = xh_c.reshape(b, t, h, MAMBA_HEADDIM)

    if decode:
        s = state["ssm"].astype(jnp.float32)  # (B, H, N, P)
        a = jnp.exp(-(A * dt[:, 0]))  # (B, H)
        xbar = xhh[:, 0] * dt[:, 0][..., None]  # (B, H, P)
        s_new = a[..., None, None] * s + jnp.einsum(
            "bn,bhp->bhnp", B_c[:, 0], xbar)
        y = jnp.einsum("bn,bhnp->bhp", C_c[:, 0], s_new)[:, None]  # (B,1,H,P)
        new_state = {"ssm": s_new, "conv": new_conv}
    else:
        s0 = (state["ssm"].astype(jnp.float32) if state is not None
              else jnp.zeros((b, h, n, MAMBA_HEADDIM), jnp.float32))
        y, s_new = _mamba2_chunk_scan(xhh, dt, B_c, C_c, A, state=s0)
        new_state = {"ssm": s_new, "conv": new_conv} if state is not None else None

    y = y + xhh * p["D"][None, None, :, None]  # skip connection
    y = y.reshape(b, t, ed)
    y = norm_apply(p["norm"], y, "rmsnorm") * jax.nn.silu(z)
    out = dense(y.astype(rt.compute_dtype), p["out_proj"], rt)
    return out, new_state


# ===========================================================================
# RWKV6 (Finch)
# ===========================================================================

def rwkv6_dims(cfg):
    hd = cfg.resolved_head_dim
    return cfg.num_heads, hd


def rwkv6_init(key, cfg) -> Params:
    d = cfg.d_model
    h, hd = rwkv6_dims(cfg)
    lora = max(32, d // 32)
    ks = jax.random.split(key, 12)
    return {
        # token-shift mix coefficients per stream (r, k, v, w, g)
        "mu": jax.random.uniform(ks[0], (5, d), jnp.float32),
        "wr": init_dense_weight(ks[1], d, h * hd),
        "wk": init_dense_weight(ks[2], d, h * hd),
        "wv": init_dense_weight(ks[3], d, h * hd),
        "wg": init_dense_weight(ks[4], d, h * hd),
        "wo": init_dense_weight(ks[5], h * hd, d),
        # data-dependent decay (Finch): w = exp(-exp(base + LoRA(x_w)))
        "w_base": jnp.full((h * hd,), -1.0, jnp.float32),
        "w_lora_a": init_dense_weight(ks[6], d, lora),
        "w_lora_b": init_dense_weight(ks[7], lora, h * hd) * 0.1,
        "u": jax.random.normal(ks[8], (h, hd), jnp.float32) * 0.1,  # bonus
        "ln_out": {"scale": jnp.ones((h * hd,), jnp.float32),
                   "bias": jnp.zeros((h * hd,), jnp.float32)},
        "ln1": {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)},
        "ln2": {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)},
        # channel-mix
        "cm_mu": jax.random.uniform(ks[9], (2, d), jnp.float32),
        "cm_k": init_dense_weight(ks[10], d, cfg.d_ff),
        "cm_v": init_dense_weight(ks[11], cfg.d_ff, d),
    }


def rwkv6_empty_state(cfg, batch: int, dtype=jnp.float32) -> Params:
    h, hd = rwkv6_dims(cfg)
    d = cfg.d_model
    return {
        "wkv": jnp.zeros((batch, h, hd, hd), dtype),
        "tm_prev": jnp.zeros((batch, d), dtype),
        "cm_prev": jnp.zeros((batch, d), dtype),
    }


def _token_shift(x: jax.Array, prev: jax.Array) -> jax.Array:
    """x (B,T,D) -> previous-token stream (B,T,D) with carry-in ``prev``."""
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


RWKV_CHUNK = 16  # exp(-L) <= e^(e*16) ~ 8e18: safely inside f32 range


def _rwkv6_chunk_scan(r, k, v, logw, u, s0, *, chunk: int = RWKV_CHUNK):
    """Chunked WKV6 (GLA-style): intra-chunk attention-like matmuls + an
    inter-chunk state scan — MXU work instead of T sequential VPU steps.

    Recurrence: S_t = diag(w_t) S_{t-1} + k_t v_t^T;
                y_t = r_t S_{t-1} + (r_t . (u*k_t)) v_t.
    With L_t = cumsum(log w) inside a chunk (log w <= 0 by the RWKV6
    parametrization), define qt = r_t * exp(L_{t-1}), kt~ = k_t * exp(-L_t):
    intra-chunk scores A[t,s] = qt . kt~_s (strictly causal), inter-chunk
    y += qt @ S_in, and S_out = diag(exp(L_last)) S_in + (k*exp(L_last -
    L))^T v. exp(-L_t) is bounded by e^(e*chunk) — stable in f32 for
    chunk <= 16 given logw >= -e (w_base+lora clipped at 1).

    r,k,v,logw: (B, T, H, hd); u: (H, hd); s0: (B, H, hd, hd).
    Returns (y (B,T,H,hd), s_final)."""
    b, t, h, hd = r.shape
    lc = min(chunk, t)
    pad = (-t) % lc
    if pad:
        zpad = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zpad(r), zpad(k), zpad(v)
        logw = zpad(logw)  # log w = 0 -> no decay, zero k/v -> state no-op
    nc = r.shape[1] // lc

    def csplit(a):
        return a.reshape(b, nc, lc, h, hd).swapaxes(0, 1)  # (nc, B, lc, H, hd)

    rs, ks, vs, lws = map(csplit, (r, k, v, logw))
    smask = jnp.tril(jnp.ones((lc, lc), bool), k=-1)  # strictly causal

    def step(S, inp):
        rc, kc, vc, lw = inp  # (B, lc, H, hd)
        Lt = jnp.cumsum(lw, axis=1)
        qt = rc * jnp.exp(Lt - lw)  # r_t * exp(L_{t-1})
        ktil = kc * jnp.exp(-Lt)
        A = jnp.einsum("bthd,bshd->bhts", qt, ktil)
        A = jnp.where(smask[None, None], A, 0.0)
        y = jnp.einsum("bhts,bshd->bthd", A, vc)
        y = y + jnp.einsum("bthk,bhkv->bthv", qt, S)
        bonus = jnp.einsum("bthd,bthd->bth", rc, u[None, None] * kc)
        y = y + bonus[..., None] * vc
        Ltot = Lt[:, -1]  # (B, H, hd)
        krem = kc * jnp.exp(Ltot[:, None] - Lt)
        S = jnp.exp(Ltot)[..., None] * S + jnp.einsum("bshk,bshv->bhkv", krem, vc)
        return S, y

    s_final, ys = jax.lax.scan(step, s0, (rs, ks, vs, lws))
    y = ys.swapaxes(0, 1).reshape(b, nc * lc, h, hd)
    return y[:, :t], s_final


def rwkv6_apply(
    p: Params,
    x: jax.Array,  # (B, T, D) — time-mix half; call twice per layer
    rt: Runtime,
    cfg,
    *,
    state: Optional[Params] = None,
    decode: bool = False,
) -> tuple[jax.Array, Optional[Params]]:
    """Full RWKV6 layer: x -> x + time_mix(ln1(x)); -> x + channel_mix(ln2(x)).

    Norms and residuals live inside (RWKV's token-shift operates on the
    normed stream, and the shift carries across steps via the state).
    Returns (x_new, new_state)."""
    b, t, d = x.shape
    h, hd = rwkv6_dims(cfg)
    st = state if state is not None else rwkv6_empty_state(cfg, b)

    x_res = x.astype(jnp.float32)
    xf = norm_apply(p["ln1"], x_res, "layernorm").astype(jnp.float32)
    prev = _token_shift(xf, st["tm_prev"].astype(jnp.float32))
    mu = p["mu"][:, None, None, :]  # (5, 1, 1, D)
    # materialize the 5 shifted streams in compute dtype: they only feed
    # matmuls, and 5x(B,T,D) in f32 was the dominant elementwise traffic
    # of the whole block (EXPERIMENTS.md §Perf cell C, iteration C2)
    xs = (xf[None] + (prev - xf)[None] * mu).astype(rt.compute_dtype)

    r = dense(xs[0], p["wr"], rt).reshape(b, t, h, hd).astype(jnp.float32)
    k = dense(xs[1], p["wk"], rt).reshape(b, t, h, hd).astype(jnp.float32)
    v = dense(xs[2], p["wv"], rt).reshape(b, t, h, hd).astype(jnp.float32)
    g = dense(xs[4], p["wg"], rt).astype(jnp.float32)
    dd = jnp.matmul(jnp.tanh(jnp.matmul(xs[3].astype(jnp.float32), p["w_lora_a"])),
                    p["w_lora_b"])
    logw = -jnp.exp(jnp.clip(p["w_base"] + dd, -8.0, 1.0))  # (B,T,H*hd) <= 0
    w = jnp.exp(logw).reshape(b, t, h, hd)  # decay in (0, 1)
    u = p["u"]  # (H, hd)

    s0 = st["wkv"].astype(jnp.float32)  # (B, H, hd_k, hd_v)

    def step(s, inp):
        rt_, kt, vt, wt = inp  # each (B, H, hd)
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        y = jnp.einsum("bhk,bhkv->bhv", rt_, s + u[None, :, :, None] * kv)
        s = wt[..., None] * s + kv
        return s, y

    if decode:
        seq = (r.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
               w.swapaxes(0, 1))
        s_new, y = step(s0, jax.tree.map(lambda a: a[0], seq))
        y = y[:, None]  # (B, 1, H, hd)
    elif rt.rwkv_mode == "chunked":
        # MXU-form WKV6: 16-step chunks as matmuls + per-chunk state scan
        # (EXPERIMENTS.md §Perf cell C — ~T/chunk fewer state traversals)
        y, s_new = _rwkv6_chunk_scan(r, k, v, logw.reshape(b, t, h, hd), u, s0)
    else:
        seq = (r.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
               w.swapaxes(0, 1))
        s_new, ys = jax.lax.scan(step, s0, seq)
        y = ys.swapaxes(0, 1)  # (B, T, H, hd)

    y = y.reshape(b, t, h * hd)
    y = norm_apply(p["ln_out"], y, "layernorm")
    y = (y * jax.nn.silu(g)).astype(rt.compute_dtype)
    tm_out = dense(y, p["wo"], rt)

    # residual + channel-mix (its own LN + token shift)
    x2 = x_res + tm_out.astype(jnp.float32)
    x2n = norm_apply(p["ln2"], x2, "layernorm").astype(jnp.float32)
    prev2 = _token_shift(x2n, st["cm_prev"].astype(jnp.float32))
    xk = x2n + (prev2 - x2n) * p["cm_mu"][0]
    kcm = jnp.square(jax.nn.relu(dense(xk.astype(rt.compute_dtype), p["cm_k"], rt)))
    kcm = shard_hint(kcm, rt, "batch", "seq", "ffn")
    cm_out = dense(kcm, p["cm_v"], rt)

    out = (x2 + cm_out.astype(jnp.float32)).astype(rt.compute_dtype)
    new_state = None
    if state is not None:
        new_state = {
            "wkv": s_new,
            "tm_prev": xf[:, -1],
            "cm_prev": x2n[:, -1],
        }
    return out, new_state
