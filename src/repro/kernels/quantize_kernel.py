"""Pallas TPU kernel: offline ITQ3_S quantization (paper Algorithm 1).

Quantizing a 235B-parameter model is itself a bandwidth-bound batch job —
every weight is read once, rotated, scaled and written back at 3 bits.
This kernel fuses the whole of Algorithm 1 per 256-block tile in VMEM:

    rotate (MXU H-matmul) -> sigma/mu -> d_k = c*sigma, z_k = -round(mu/d)
    -> round/clamp to the ternary grid -> emit codes + fp scales

Output codes are the *unpacked* {0,1,2} bytes; the planar bit-pack is a
cheap pure-jnp epilogue (packing.py) — packing inside the kernel would
need cross-lane byte shuffles for no bandwidth benefit (codes are 1/4 the
input bytes either way).

Validated against core.quantize (the pure-jnp Algorithm 1) in
tests/test_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.fwht import hadamard_matrix
from repro.core import grids

__all__ = ["quantize_blocks_pallas"]

BLOCK = 256


def _quant_kernel(h_ref, w_ref, codes_ref, d_ref, z_ref, *, alpha: float):
    """w_ref: (TM, 256) raw weight blocks -> ternary codes + scales."""
    w = w_ref[...].astype(jnp.float32)
    h = h_ref[...]
    wr = jnp.dot(w, h, preferred_element_type=jnp.float32)  # rotate (MXU)
    mu = jnp.mean(wr, axis=-1, keepdims=True)
    sigma = jnp.sqrt(jnp.maximum(jnp.mean((wr - mu) ** 2, axis=-1, keepdims=True), 0.0))
    d = (alpha * sigma).astype(jnp.float16).astype(jnp.float32)  # fp16 storage grid
    safe = jnp.where(d > 0, d, 1.0)
    z = jnp.clip(-jnp.round(mu / safe), -1.0, 1.0)
    q = jnp.clip(jnp.round(wr / safe) + z, -1.0, 1.0)
    codes_ref[...] = (q + 1.0).astype(jnp.uint8)
    d_ref[...] = d[:, 0].astype(jnp.float16)
    z_ref[...] = z[:, 0].astype(jnp.float16)


@functools.partial(jax.jit, static_argnames=("rule", "tm", "interpret"))
def quantize_blocks_pallas(
    wb: jax.Array,  # (NB, 256) flattened weight blocks
    *,
    rule: str = "paper",
    tm: int = 256,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Algorithm 1 over a stream of 256-blocks. Returns (codes {0,1,2}
    (NB, 256) uint8, scales (NB,) f16, zps (NB,) f16)."""
    nb, block = wb.shape
    if block != BLOCK:
        raise ValueError(f"block dim must be {BLOCK}, got {block}")
    alpha = grids.SCALE_RULES[rule]
    tm = max(8, min(tm, nb))
    pad = (-nb) % tm
    if pad:
        wb = jnp.pad(wb, ((0, pad), (0, 0)))
    nbp = wb.shape[0]
    h = hadamard_matrix(BLOCK, dtype=jnp.float32)

    codes, d, z = pl.pallas_call(
        functools.partial(_quant_kernel, alpha=float(alpha)),
        grid=(nbp // tm,),
        in_specs=[
            pl.BlockSpec((BLOCK, BLOCK), lambda i: (0, 0)),
            pl.BlockSpec((tm, BLOCK), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tm, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((tm,), lambda i: (i,)),
            pl.BlockSpec((tm,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nbp, BLOCK), jnp.uint8),
            jax.ShapeDtypeStruct((nbp,), jnp.float16),
            jax.ShapeDtypeStruct((nbp,), jnp.float16),
        ],
        interpret=interpret,
    )(h, wb)
    return codes[:nb], d[:nb], z[:nb]
