"""Benchmark-driven (tm, tn) tile selection with an on-disk JSON cache.

The fused kernels take tile sizes as static arguments; the best choice
depends on the matmul shape, format, and the device generation — exactly
the knobs a human would sweep by hand. This module owns that sweep:

  * :func:`get_tiles` — the *lookup* used by ``qmatmul(..., tm=None)``:
    returns the cached winner for (device_kind, backend, fmt, M, N, K), or
    the deterministic defaults (DEFAULT_TM, DEFAULT_TN) on a miss. Pure
    lookup — never benchmarks — so it is safe to call at trace time, and in
    interpret mode (no real accelerator; timings would be meaningless) it
    is the *only* path: interpret keys never get benchmarked entries unless
    a caller explicitly forces tuning (tests do, on tiny shapes).
  * :func:`autotune` — the *sweep*: times the real kernel over the
    candidate lattice and records the winner in the cache file.
  * :func:`tune_params_shapes` — eager whole-model warmup: collect every
    QTensor matmul shape in a param tree and tune each at batch M. Wired to
    ``ServeEngine`` via ``Runtime(autotune=True)`` and to
    ``launch/serve.py --autotune``.

Cache file: ``$REPRO_AUTOTUNE_CACHE`` or ``~/.cache/repro/autotune.json``,
keyed per device kind so one home directory can serve CPU + several TPU
generations. M is bucketed (matvec regime below MATVEC_MAX_M, else next
power of two) so a decode shape tuned at 4 slots also serves 3.
"""
from __future__ import annotations

import json
import os
import tempfile
import time
import warnings
from pathlib import Path
from typing import Optional

import jax
import numpy as np

__all__ = [
    "DEFAULT_TM", "DEFAULT_TN", "get_tiles", "record", "autotune",
    "tune_params_shapes", "cache_path", "clear_memory_cache", "candidates",
    "get_attn_tiles", "record_attn", "autotune_attn", "attn_candidates",
]

DEFAULT_TM = 256
DEFAULT_TN = 256
_TM_LADDER = (8, 16, 32, 64, 128, 256)
_TN_LADDER = (64, 128, 256, 512)
_TQ_LADDER = (32, 64, 128, 256)   # attention query-tile widths
_TT_LADDER = (128, 256, 512)      # attention key-tile widths

_mem_cache: Optional[dict] = None


def cache_path() -> Path:
    env = os.environ.get("REPRO_AUTOTUNE_CACHE")
    if env:
        return Path(env)
    return Path.home() / ".cache" / "repro" / "autotune.json"


def clear_memory_cache() -> None:
    """Drop the in-process cache so the next lookup re-reads the file."""
    global _mem_cache
    _mem_cache = None


def _load() -> dict:
    global _mem_cache
    if _mem_cache is None:
        p = cache_path()
        try:
            with open(p) as f:
                _mem_cache = json.load(f)
        except FileNotFoundError:
            _mem_cache = {}
        except (OSError, ValueError) as e:
            # a corrupt or unreadable cache (e.g. torn by a concurrent
            # writer) degrades to "no tuned entries" — the defaults are
            # shape-safe everywhere, so warn instead of killing the caller
            warnings.warn(
                f"ignoring unreadable autotune cache {p} ({e}); "
                f"falling back to default tiles", RuntimeWarning,
                stacklevel=2)
            _mem_cache = {}
    return _mem_cache


def _save(cache: dict) -> None:
    p = cache_path()
    p.parent.mkdir(parents=True, exist_ok=True)
    # unique tmp per writer: a fixed tmp name lets two concurrent processes
    # (parallel CI shards) interleave writes and publish a torn file
    fd, tmp = tempfile.mkstemp(dir=p.parent, prefix=p.name + ".",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(cache, f, indent=1, sort_keys=True)
        os.replace(tmp, p)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def device_kind(interpret: bool = False) -> str:
    if interpret:
        return "interpret"
    return jax.devices()[0].device_kind.replace(" ", "_")


def _bucket_m(m: int) -> int:
    """Round M up so nearby batch sizes share one tuned entry."""
    from repro.kernels.itq3_matvec import MATVEC_MAX_M

    if m <= MATVEC_MAX_M:
        return MATVEC_MAX_M  # matvec regime: tm is M itself, only tn matters
    b = MATVEC_MAX_M
    while b < m:
        b *= 2
    return b


def _key(m: int, n: int, k: int, fmt: str, *, backend: str,
         interpret: bool, act_quant: bool = False) -> str:
    # the W3A8 integer kernels have their own cost surface (no IFWHT MXU
    # passes, int8 operand tiling), so int8-path winners live under a
    # distinct key component; float-path keys are unchanged, preserving
    # every previously tuned cache entry.
    path = "|int8" if act_quant else ""
    return (f"{device_kind(interpret)}|{backend}|{fmt}{path}"
            f"|m{_bucket_m(m)}|n{n}|k{k}")


def candidates(m: int, n: int, k: int) -> list[tuple[int, int]]:
    """The (tm, tn) lattice worth sweeping for this shape."""
    from repro.kernels.itq3_matvec import MATVEC_MAX_M

    tms = [t for t in _TM_LADDER if t <= max(m, 8)] or [max(m, 1)]
    if m <= MATVEC_MAX_M:
        tms = [m]  # matvec kernel: no M tiling
    tns = [t for t in _TN_LADDER if t <= n] or [n]
    return [(tm, tn) for tm in tms for tn in tns]


def get_tiles(m: int, n: int, k: int, fmt: str, *, backend: str = "pallas",
              interpret: bool = False,
              act_quant: bool = False) -> tuple[int, int]:
    """Cached winner for this shape, or the deterministic defaults.

    Never benchmarks — interpret mode (and any untuned shape) always
    resolves to (DEFAULT_TM, DEFAULT_TN); the kernels clamp to the actual
    M/N, so the defaults are shape-safe everywhere. ``act_quant=True``
    looks up the int8-path key family.
    """
    ent = _load().get(_key(m, n, k, fmt, backend=backend, interpret=interpret,
                           act_quant=act_quant))
    if ent:
        return int(ent["tm"]), int(ent["tn"])
    return DEFAULT_TM, DEFAULT_TN


def record(m: int, n: int, k: int, fmt: str, tm: int, tn: int, *,
           backend: str = "pallas", interpret: bool = False,
           act_quant: bool = False, us: Optional[float] = None,
           save: bool = True) -> str:
    """Store a winner (used by :func:`autotune` and by tests)."""
    cache = _load()
    key = _key(m, n, k, fmt, backend=backend, interpret=interpret,
               act_quant=act_quant)
    cache[key] = {"tm": int(tm), "tn": int(tn)}
    if us is not None:
        cache[key]["us"] = round(float(us), 2)
    if save:
        _save(cache)
    return key


def _time_call(fn, iters: int = 3) -> float:
    for _ in range(1):
        jax.block_until_ready(fn())
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


def autotune(m: int, n: int, k: int, fmt: str = "itq3_s", *,
             mode: str = "weights", act_quant: bool = False,
             interpret: Optional[bool] = None,
             iters: int = 3, save: bool = True,
             force_interpret_bench: bool = False) -> tuple[int, int]:
    """Benchmark the candidate lattice for one shape and cache the winner.

    In interpret mode the sweep is skipped (timings there measure the
    Pallas interpreter, not hardware) and the defaults are returned —
    unless ``force_interpret_bench`` (tests, tiny shapes only).
    ``act_quant=True`` sweeps the W3A8 integer kernels and records under
    the int8 key family, so ``qmatmul(tm=None)`` autotunes both paths.
    """
    from repro.core import formats
    from repro.kernels.ops import auto_interpret, qmatmul_kernel

    if interpret is None:
        interpret = auto_interpret()
    if interpret and not force_interpret_bench:
        return DEFAULT_TM, DEFAULT_TN

    rng = np.random.default_rng(0)
    w = np.asarray(rng.normal(size=(k, n)) * 0.02, np.float32)
    x = np.asarray(rng.normal(size=(m, k)), np.float32)
    qt = formats.quantize(w, fmt)

    best, best_us = (DEFAULT_TM, DEFAULT_TN), float("inf")
    for tm, tn in candidates(m, n, k):
        us = _time_call(
            lambda: qmatmul_kernel(x, qt, mode=mode, act_quant=act_quant,
                                   tm=tm, tn=tn,
                                   interpret=interpret), iters=iters)
        if us < best_us:
            best, best_us = (tm, tn), us
    record(m, n, k, fmt, *best, interpret=interpret, act_quant=act_quant,
           us=best_us, save=save)
    return best


# --- fused-attention (tq, tt) tiles ----------------------------------------
#
# The attn_decode kernel's tiles live in the SAME cache file under their own
# key family: (device, "attn", cache-length bucket, head_dim, n_heads).
# Sequence length buckets to the next power of two (a cache tuned at 32k
# serves 20k), head counts matter because the grid row count R = B*KV trades
# against per-row tile work.

def _bucket_t(t: int) -> int:
    b = 256
    while b < t:
        b *= 2
    return b


# Speculative-decoding verify passes run the q-tile kernel at a NARROW
# query width (K+1 draft-window positions, typically <= 16) over a long
# cache — a cost surface the wide-prefill winners don't transfer to (the
# best tq is the window itself, and the best tt trades differently when
# the per-row q work is tiny). Narrow widths therefore get their own key
# component: a ``|qN`` suffix with N the window bucketed to a power of
# two. Wide-prefill keys are unchanged, preserving every previously tuned
# cache entry.
SPEC_QWIDTH_MAX = 16


def _bucket_q(q_width: int) -> int:
    b = 1
    while b < q_width:
        b *= 2
    return b


def _attn_key(t: int, head_dim: int, n_heads: int, *, interpret: bool,
              q_width: Optional[int] = None) -> str:
    qpart = f"|q{_bucket_q(q_width)}" if q_width is not None else ""
    return (f"{device_kind(interpret)}|attn|t{_bucket_t(t)}"
            f"|hd{head_dim}|h{n_heads}{qpart}")


def attn_candidates(t: int, head_dim: int, *, decode: bool = False,
                    q_width: Optional[int] = None) -> list[tuple[int, int]]:
    """The (tq, tt) lattice worth sweeping. Decode is the TQ=1
    specialization — only the key-tile width matters. A narrow ``q_width``
    (speculative verify) caps the query tile at the window itself: wider
    tiles would only pad."""
    tts = [c for c in _TT_LADDER if c <= max(t, _TT_LADDER[0])] or [max(t, 1)]
    if decode:
        tqs = [1]
    elif q_width is not None:
        tqs = sorted({w for w in (1, 2, 4, 8, _bucket_q(q_width))
                      if w <= _bucket_q(q_width)})
    else:
        tqs = list(_TQ_LADDER)
    return [(tq, tt) for tq in tqs for tt in tts]


def get_attn_tiles(t: int, head_dim: int, n_heads: int, *,
                   interpret: bool = False,
                   q_width: Optional[int] = None) -> tuple[int, int]:
    """Cached (tq, tt) winner for this attention shape, or the
    deterministic defaults. Pure lookup, exactly like :func:`get_tiles`:
    interpret mode always resolves to (DEFAULT_TQ, DEFAULT_TT) unless a
    test recorded an entry explicitly. With ``q_width`` the narrow-window
    key family is consulted first, falling back to the base (wide) key so
    an untuned verify shape still benefits from a tuned tt."""
    from repro.kernels.attn_decode import DEFAULT_TQ, DEFAULT_TT

    cache = _load()
    if q_width is not None:
        ent = cache.get(_attn_key(t, head_dim, n_heads, interpret=interpret,
                                  q_width=q_width))
        if ent:
            return int(ent["tq"]), int(ent["tt"])
    ent = cache.get(_attn_key(t, head_dim, n_heads, interpret=interpret))
    if ent:
        return int(ent["tq"]), int(ent["tt"])
    return DEFAULT_TQ, DEFAULT_TT


def record_attn(t: int, head_dim: int, n_heads: int, tq: int, tt: int, *,
                interpret: bool = False, us: Optional[float] = None,
                save: bool = True, q_width: Optional[int] = None) -> str:
    """Store an attention tile winner (used by :func:`autotune_attn` and by
    tests)."""
    cache = _load()
    key = _attn_key(t, head_dim, n_heads, interpret=interpret,
                    q_width=q_width)
    cache[key] = {"tq": int(tq), "tt": int(tt)}
    if us is not None:
        cache[key]["us"] = round(float(us), 2)
    if save:
        _save(cache)
    return key


def autotune_attn(t: int, head_dim: int, n_heads: int, *, batch: int = 4,
                  g: int = 1, decode: bool = False,
                  interpret: Optional[bool] = None, iters: int = 3,
                  save: bool = True, q_width: Optional[int] = None,
                  force_interpret_bench: bool = False) -> tuple[int, int]:
    """Benchmark the fused attention kernel's (tq, tt) lattice on a
    synthetic rotated-int8 cache and record the winner. Interpret mode
    skips the sweep (same contract as :func:`autotune`). ``q_width``
    sweeps (and records under) the narrow-window verify family."""
    from repro.kernels.attn_decode import (
        DEFAULT_TQ, DEFAULT_TT, attn_q8_pallas,
    )
    from repro.kernels.ops import auto_interpret

    if interpret is None:
        interpret = auto_interpret()
    if interpret and not force_interpret_bench:
        return DEFAULT_TQ, DEFAULT_TT

    rng = np.random.default_rng(0)
    r = batch * n_heads
    if decode:
        tq_total = 1
    elif q_width is not None:
        tq_total = q_width
    else:
        tq_total = min(t, 512)
    q = np.asarray(rng.normal(size=(r, tq_total, g, head_dim)), np.float32)
    kc = rng.integers(-127, 128, size=(r, t, head_dim)).astype(np.int8)
    vc = rng.integers(-127, 128, size=(r, t, head_dim)).astype(np.int8)
    ks = np.abs(rng.normal(size=(r, t))).astype(np.float32) * 0.02
    vs = np.abs(rng.normal(size=(r, t))).astype(np.float32) * 0.02
    kv_len = np.full((r,), t, np.int32)
    off = np.zeros((r,), np.int32)

    best, best_us = (DEFAULT_TQ, DEFAULT_TT), float("inf")
    for tq, tt in attn_candidates(t, head_dim, decode=decode,
                                  q_width=q_width):
        us = _time_call(
            lambda: attn_q8_pallas(
                q, kc, ks, vc, vs, kv_len, off,
                sm_scale=head_dim ** -0.5, causal=not decode, tq=tq, tt=tt,
                interpret=interpret), iters=iters)
        if us < best_us:
            best, best_us = (tq, tt), us
    record_attn(t, head_dim, n_heads, *best, interpret=interpret,
                us=best_us, save=save, q_width=q_width)
    return best


def tune_params_shapes(params, m: int, *, interpret: Optional[bool] = None,
                       act_quant: bool = False,
                       **kw) -> list[tuple[int, int, int, str]]:
    """Tune every distinct QTensor matmul shape in ``params`` at batch M.

    Returns the list of (m, n, k, fmt) shapes tuned; empty in interpret
    mode (CPU serving keeps the deterministic defaults). With
    ``act_quant=True`` each shape is additionally tuned on the W3A8
    integer kernels (its own key family), so an engine booted with the
    integer path on warms both caches.
    """
    from repro.core.quantize import QTensor
    from repro.kernels.ops import auto_interpret

    if interpret is None:
        interpret = auto_interpret()
    if interpret:
        return []
    shapes = set()
    for leaf in jax.tree.leaves(
            params, is_leaf=lambda x: isinstance(x, QTensor)):
        if isinstance(leaf, QTensor) and len(leaf.meta.shape) == 2:
            shapes.add((leaf.meta.shape[0], leaf.meta.n, leaf.meta.fmt))
    tuned = []
    for k, n, fmt in sorted(shapes):
        autotune(m, n, k, fmt, interpret=interpret, **kw)
        if act_quant:
            autotune(m, n, k, fmt, interpret=interpret, act_quant=True, **kw)
        tuned.append((m, n, k, fmt))
    return tuned
