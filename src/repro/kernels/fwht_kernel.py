"""Pallas TPU kernel: blocked 256-point Walsh-Hadamard transform.

TPU adaptation of the paper's ``ifwht_256`` CUDA shared-memory butterfly
(Listing 2): instead of 8 ``__syncthreads``-separated butterfly stages, each
grid cell performs a single (TM, 256) x (256, 256) matmul against the
constant normalized Hadamard matrix on the MXU. On a systolic array this is
one pipelined pass at full MXU rate — the analogue of "free in the load
stage" — whereas a butterfly network would be 8 serial VPU op-chains over
the same VMEM tile (see DESIGN.md §2). H is passed as a kernel operand
mapped to the same (256, 256) block for every grid cell, so it is fetched
into VMEM once and stays resident.

Because H is involutory, this one kernel is both the forward FWHT (offline
quantization, activation rotation) and the inverse FWHT (paper Algorithm 2
step 3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.fwht import hadamard_matrix, is_pow2

__all__ = ["fwht_pallas"]

DEFAULT_TM = 256


def _fwht_kernel(h_ref, x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    h = h_ref[...]
    o_ref[...] = jnp.dot(x, h, preferred_element_type=jnp.float32).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "tm", "interpret"))
def fwht_pallas(
    x: jax.Array,
    *,
    block: int = 256,
    tm: int = DEFAULT_TM,
    interpret: bool = True,
) -> jax.Array:
    """Blockwise FWHT along the trailing axis of ``x`` (2-D ``(M, K)``,
    K % block == 0). Returns same shape/dtype.

    ``interpret=True`` executes on CPU for validation; on a real TPU pass
    ``interpret=False``.
    """
    if x.ndim != 2:
        raise ValueError(f"fwht_pallas expects 2-D input, got {x.shape}")
    m, k = x.shape
    if not is_pow2(block) or k % block != 0:
        raise ValueError(f"K={k} must be a multiple of pow2 block={block}")
    tm = min(tm, m) if m >= 8 else m
    pad_m = (-m) % tm
    if pad_m:
        x = jnp.pad(x, ((0, pad_m), (0, 0)))
    mp = x.shape[0]
    h = hadamard_matrix(block, dtype=jnp.float32)

    out = pl.pallas_call(
        _fwht_kernel,
        grid=(mp // tm, k // block),
        in_specs=[
            pl.BlockSpec((block, block), lambda i, j: (0, 0)),  # H: resident
            pl.BlockSpec((tm, block), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((tm, block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, k), x.dtype),
        interpret=interpret,
    )(h, x)
    return out[:m]
