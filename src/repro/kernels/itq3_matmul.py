"""Pallas TPU kernel: fused ITQ3_S dequantize + rotate + matmul.

The TPU analogue of the paper's ``load_tiles_itq3_s`` + MMQ pipeline (§5.2):
packed 3-bit weights stream from HBM at 3.125 bits/weight and are expanded
to a full-precision weight tile *inside VMEM*, never materialized in HBM.

Per grid cell (i, j, k) — output tile (i, j), reduction block k:

  1. **Load** the packed planes for TN output features of block k:
     ``plane2`` (TN, 64) uint8 and ``plane1`` (TN, 32) uint8 — 96 bytes per
     256 weights, the paper's exact storage budget.
  2. **Unpack** with lane-parallel shifts/masks. The planar-interleaved
     layout (packing.py) yields four contiguous 64-wide chunks per uniform
     shift — the VREG-lane version of the paper's DP4A nibble interleave.
  3. **Dequantize** on the grid: ``w = d_k * (q - z_k)`` (ternary) or the
     5-level escape decode (itq3_x), or sub-block scales (itq3_s_sub).
  4. **Rotate** (``rotate_weights=True``, paper-faithful): apply the inverse
     FWHT as four (TN, 64) @ (64, 256) MXU matmuls against static row-slices
     of H_256 — replacing the CUDA 8-stage shared-memory butterfly with
     systolic-array passes (DESIGN.md §2), and avoiding any in-kernel
     reshape of the unpacked chunks.
  5. **Accumulate** ``acc += x_tile @ w_tile^T`` in f32 scratch; the output
     tile is written once at k == KB-1.

With ``rotate_weights=False`` the same kernel contracts the dequantized
codes directly — used both for the IQ3_S no-rotation baseline and for the
beyond-paper *activation-domain* path (ops.py rotates x blockwise first;
the zero-point then couples in the rotated domain with no extra term since
z is folded into the dequantized tile).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.fwht import hadamard_matrix

__all__ = ["itq3_matmul_pallas"]

BLOCK = 256
NCHUNK = 4  # 256 = 4 chunks of 64 (one per 2-bit position in a plane2 byte)
CHUNK = BLOCK // NCHUNK  # 64


def _decode_chunk(p2, p1, c: int, *, fivelevel: bool):
    """Chunk c (elements c*64..c*64+63) integer grid values from the planes.

    p2: (TN, 64) uint8, p1: (TN, 32) uint8. Planar-interleaved layout:
    plane2 byte i, bit-pair c  <-> element c*64 + i;
    plane1 byte i, bit b       <-> element b*32 + i.
    """
    payload = ((p2 >> (2 * c)) & 0x3).astype(jnp.int8) - 1  # {-1,0,1}
    if not fivelevel:
        return payload.astype(jnp.float32)
    sel_lo = (p1 >> (2 * c)) & 0x1        # elements c*64 + [0..31]
    sel_hi = (p1 >> (2 * c + 1)) & 0x1    # elements c*64 + [32..63]
    sel = jnp.concatenate([sel_lo, sel_hi], axis=-1).astype(jnp.int8)
    return (payload * (1 + sel)).astype(jnp.float32)


def _itq3_matmul_kernel(
    h_ref,    # (256, 256) f32 — Hadamard (only read when rotate_weights)
    x_ref,    # (TM, 256)
    p2_ref,   # (TN, 1, 64) uint8
    p1_ref,   # (TN, 1, 32) uint8
    sc_ref,   # (TN, 1) f32  |  (TN, 1, SUB) f32 for sub-block scales
    zp_ref,   # (TN, 1) f32
    o_ref,    # (TM, TN)
    acc_ref,  # scratch (TM, TN) f32
    *,
    rotate_weights: bool,
    fivelevel: bool,
    sub_blocks: int,
    kb: int,
):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    p2 = p2_ref[:, 0, :]
    p1 = p1_ref[:, 0, :]
    x = x_ref[...].astype(jnp.float32)

    if sub_blocks:
        d_sub = sc_ref[:, 0, :].astype(jnp.float32)  # (TN, SUB)
    else:
        d = sc_ref[...].astype(jnp.float32)  # (TN, 1)
        z = zp_ref[...].astype(jnp.float32)  # (TN, 1)

    if rotate_weights:
        w_rot = jnp.zeros((p2.shape[0], BLOCK), dtype=jnp.float32)

    acc = jnp.zeros_like(acc_ref)
    for c in range(NCHUNK):
        q = _decode_chunk(p2, p1, c, fivelevel=fivelevel)  # (TN, 64)
        if sub_blocks:
            # element e = c*64 + i lives in sub-block e // (256//SUB).
            per = BLOCK // sub_blocks  # elements per sub-block
            lo = (c * CHUNK) // per
            # chunk spans CHUNK//per sub-blocks, each of `per` elements
            reps = [d_sub[:, lo + s : lo + s + 1] for s in range(CHUNK // per)]
            d_c = jnp.concatenate(
                [jnp.broadcast_to(r, (r.shape[0], per)) for r in reps], axis=-1
            )
            w_c = d_c * q
        else:
            w_c = d * (q - z)

        if rotate_weights:
            # IFWHT via MXU: accumulate w_c @ H[c*64:(c+1)*64, :]
            h_slice = h_ref[c * CHUNK : (c + 1) * CHUNK, :]
            w_rot = w_rot + jnp.dot(w_c, h_slice, preferred_element_type=jnp.float32)
        else:
            x_c = x[:, c * CHUNK : (c + 1) * CHUNK]
            acc = acc + jax.lax.dot_general(
                x_c, w_c, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            )

    if rotate_weights:
        acc = jax.lax.dot_general(
            x, w_rot, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )

    acc_ref[...] += acc

    @pl.when(k == kb - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "rotate_weights", "fivelevel", "sub_blocks", "tm", "tn", "interpret", "out_dtype",
    ),
)
def itq3_matmul_pallas(
    x: jax.Array,        # (M, K_pad) — K_pad = KB * 256
    plane2: jax.Array,   # (N, KB, 64) uint8
    plane1: jax.Array,   # (N, KB, 32) uint8
    scales: jax.Array,   # (N, KB) f16/f32  |  (N, KB, SUB)
    zps: jax.Array,      # (N, KB) f16/f32
    *,
    rotate_weights: bool = True,
    fivelevel: bool = False,
    sub_blocks: int = 0,
    tm: int = 256,
    tn: int = 256,
    interpret: bool = True,
    out_dtype=jnp.float32,
) -> jax.Array:
    """Fused ITQ3_S matmul: returns ``x @ W_hat`` of shape (M, N)."""
    m, kpad = x.shape
    n, kb = plane2.shape[0], plane2.shape[1]
    if kpad != kb * BLOCK:
        raise ValueError(f"x K dim {kpad} != KB*256 = {kb * BLOCK}")

    tm = max(1, min(tm, m))
    tn = max(1, min(tn, n))
    pad_m, pad_n = (-m) % tm, (-n) % tn
    if pad_m:
        x = jnp.pad(x, ((0, pad_m), (0, 0)))
    if pad_n:
        pad = [(0, pad_n)] + [(0, 0)] * (plane2.ndim - 1)
        plane2 = jnp.pad(plane2, pad)
        plane1 = jnp.pad(plane1, [(0, pad_n)] + [(0, 0)] * (plane1.ndim - 1))
        scales = jnp.pad(scales, [(0, pad_n)] + [(0, 0)] * (scales.ndim - 1))
        zps = jnp.pad(zps, [(0, pad_n)] + [(0, 0)] * (zps.ndim - 1))
    mp, np_ = x.shape[0], plane2.shape[0]

    scales = scales.astype(jnp.float32)
    zps = zps.astype(jnp.float32)
    h = hadamard_matrix(BLOCK, dtype=jnp.float32)

    if sub_blocks:
        sc_spec = pl.BlockSpec((tn, 1, sub_blocks), lambda i, j, k: (j, k, 0))
    else:
        sc_spec = pl.BlockSpec((tn, 1), lambda i, j, k: (j, k))

    kernel = functools.partial(
        _itq3_matmul_kernel,
        rotate_weights=rotate_weights,
        fivelevel=fivelevel,
        sub_blocks=sub_blocks,
        kb=kb,
    )
    out = pl.pallas_call(
        kernel,
        grid=(mp // tm, np_ // tn, kb),
        in_specs=[
            pl.BlockSpec((BLOCK, BLOCK), lambda i, j, k: (0, 0)),  # H resident
            pl.BlockSpec((tm, BLOCK), lambda i, j, k: (i, k)),
            pl.BlockSpec((tn, 1, CHUNK), lambda i, j, k: (j, k, 0)),
            pl.BlockSpec((tn, 1, BLOCK // 8), lambda i, j, k: (j, k, 0)),
            sc_spec,
            pl.BlockSpec((tn, 1), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((tm, tn), jnp.float32)],
        interpret=interpret,
    )(h, x, plane2, plane1, scales, zps)
    return out[:m, :n]
