"""Pallas TPU kernel: fused ITQ3_S dequantize + rotate + matmul.

The TPU analogue of the paper's ``load_tiles_itq3_s`` + MMQ pipeline (§5.2):
packed 3-bit weights stream from HBM at 3.125 bits/weight and are expanded
to a full-precision weight tile *inside VMEM*, never materialized in HBM.

Per weight tile (output strip j, reduction block k) the expansion is:

  1. **Load** the packed planes for TN output features of block k:
     ``plane2`` (TN, 64) uint8 and ``plane1`` (TN, 32) uint8 — 96 bytes per
     256 weights, the paper's exact storage budget.
  2. **Unpack** with lane-parallel shifts/masks. The planar-interleaved
     layout (packing.py) yields four contiguous 64-wide chunks per uniform
     shift — the VREG-lane version of the paper's DP4A nibble interleave.
  3. **Dequantize** on the grid: ``w = d_k * (q - z_k)`` (ternary) or the
     5-level escape decode (itq3_x), or sub-block scales (itq3_s_sub).
  4. **Rotate** (``rotate_weights=True``, paper-faithful): apply the inverse
     FWHT as four (TN, 64) @ (64, 256) MXU matmuls against static row-slices
     of H_256 — replacing the CUDA 8-stage shared-memory butterfly with
     systolic-array passes (DESIGN.md §2), and avoiding any in-kernel
     reshape of the unpacked chunks.

That expansion is the expensive part of the kernel, and it depends only on
(j, k) — never on the M tile. Two grid schedules share it:

* **flat** (grid ``(MB, NB, KB)``, K innermost): the tile is expanded per
  (i, j, k) cell — no extra scratch, but the same weight tile is re-decoded
  and re-rotated for every M tile. Used when M fits one tile (decode) or
  when the hoist scratch would not fit VMEM.
* **hoisted** (grid ``(NB, MB, KB)``, K innermost, M middle): a
  (KB, TN, 256) VMEM scratch caches the expanded strip for the current j;
  it is filled once at i == 0 and *reused* by every subsequent M tile —
  prefill-width batches stop paying MB redundant unpack+dequant+IFWHT
  passes per weight strip. Requires the grid to execute sequentially
  (TPU grids and interpret mode both do).

Both schedules accumulate ``acc += x_tile @ w_tile^T`` in (TM, TN) f32
scratch with K innermost and flush the output tile once at k == KB-1, and
both consume the expanded tile through one dot per k-block — so they are
bit-identical to each other (and to kernels/itq3_matvec.py, which uses the
same ``dequant_rotate_tile`` helper in the same order).

With ``rotate_weights=False`` the same pipeline skips step 4 — used both
for the IQ3_S no-rotation baseline and for the beyond-paper
*activation-domain* path (ops.py rotates x blockwise first; the zero-point
then couples in the rotated domain with no extra term since z is folded
into the dequantized tile).

**W3A8 integer variants** (``itq3_matmul_int8_pallas``): when the
activations themselves are quantized into the rotation domain
(core/act_quant.py), steps 3-4 disappear entirely — the tile expansion is
unpack + integer zero-point fold (``decode_wint_tile``, exact in int8
because z is integer-valued), the MAC is int8 x int8 -> int32
(``preferred_element_type=jnp.int32``, the MXU's DP4A analogue), the
per-block weight scale ``d`` lands on the int32 partial, and the per-row
activation scale is applied once at flush. Same flat/hoisted schedules;
the hoisted int8 strip costs 1/4 of the float scratch bytes.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.fwht import hadamard_matrix

__all__ = ["itq3_matmul_pallas", "itq3_matmul_int8_pallas",
           "dequant_rotate_tile", "decode_wint_tile", "pad_packed_n",
           "BLOCK"]

BLOCK = 256
NCHUNK = 4  # 256 = 4 chunks of 64 (one per 2-bit position in a plane2 byte)
CHUNK = BLOCK // NCHUNK  # 64

# Hoisting caches the expanded (KB, TN, 256) f32 weight strip in VMEM;
# don't hoist past this budget (leaves room for x/acc/H tiles in ~16MB VMEM).
HOIST_VMEM_BUDGET = int(os.environ.get("REPRO_HOIST_VMEM_BUDGET", 8 * 2**20))


def _decode_chunk_int(p2, p1, c: int, *, fivelevel: bool):
    """Chunk c (elements c*64..c*64+63) integer grid values from the planes,
    kept in **int8** — shared by the float expansion (which casts) and the
    W3A8 integer kernels (which contract it directly).

    p2: (TN, 64) uint8, p1: (TN, 32) uint8. Planar-interleaved layout:
    plane2 byte i, bit-pair c  <-> element c*64 + i;
    plane1 byte i, bit b       <-> element b*32 + i.
    """
    payload = ((p2 >> (2 * c)) & 0x3).astype(jnp.int8) - 1  # {-1,0,1}
    if not fivelevel:
        return payload
    sel_lo = (p1 >> (2 * c)) & 0x1        # elements c*64 + [0..31]
    sel_hi = (p1 >> (2 * c + 1)) & 0x1    # elements c*64 + [32..63]
    sel = jnp.concatenate([sel_lo, sel_hi], axis=-1).astype(jnp.int8)
    return payload * (1 + sel)


def _decode_chunk(p2, p1, c: int, *, fivelevel: bool):
    """Float view of :func:`_decode_chunk_int` (the float-path kernels)."""
    return _decode_chunk_int(p2, p1, c, fivelevel=fivelevel).astype(jnp.float32)


def dequant_rotate_tile(h_ref, p2, p1, sc_ref, zp_ref, *, rotate_weights: bool,
                        fivelevel: bool, sub_blocks: int) -> jax.Array:
    """Expand one packed weight tile to its (TN, 256) f32 dequantized (and
    optionally IFWHT-rotated) form — steps 2-4 of the pipeline above.

    Shared by every kernel variant (flat/hoisted/matvec) so they stay
    bit-identical: same chunk order, same per-chunk ops, same MXU slices.
    """
    if sub_blocks:
        d_sub = sc_ref[:, 0, :].astype(jnp.float32)  # (TN, SUB)
    else:
        d = sc_ref[...].astype(jnp.float32)  # (TN, 1)
        z = zp_ref[...].astype(jnp.float32)  # (TN, 1)

    chunks = []
    for c in range(NCHUNK):
        q = _decode_chunk(p2, p1, c, fivelevel=fivelevel)  # (TN, 64)
        if sub_blocks:
            # element e = c*64 + i lives in sub-block e // (256//SUB).
            per = BLOCK // sub_blocks  # elements per sub-block
            lo = (c * CHUNK) // per
            # chunk spans CHUNK//per sub-blocks, each of `per` elements
            reps = [d_sub[:, lo + s : lo + s + 1] for s in range(CHUNK // per)]
            d_c = jnp.concatenate(
                [jnp.broadcast_to(r, (r.shape[0], per)) for r in reps], axis=-1
            )
            chunks.append(d_c * q)
        else:
            chunks.append(d * (q - z))

    if not rotate_weights:
        return jnp.concatenate(chunks, axis=-1)  # (TN, 256)
    w_rot = jnp.zeros((p2.shape[0], BLOCK), dtype=jnp.float32)
    for c in range(NCHUNK):
        # IFWHT via MXU: accumulate w_c @ H[c*64:(c+1)*64, :]
        h_slice = h_ref[c * CHUNK : (c + 1) * CHUNK, :]
        w_rot = w_rot + jnp.dot(chunks[c], h_slice,
                                preferred_element_type=jnp.float32)
    return w_rot


def decode_wint_tile(p2, p1, zp_ref, *, fivelevel: bool,
                     sub_blocks: int) -> jax.Array:
    """Expand one packed weight tile to its (TN, 256) **int8** integer form
    ``wint = q - z`` — the W3A8 counterpart of :func:`dequant_rotate_tile`.

    No rotation and no float math: the zero-point is integer-valued by
    construction (sub-block formats store z = 0), so the tile is exact in
    int8 ({-2..2} ternary / {-4..4} fivelevel) and feeds the MXU as an
    int8 x int8 -> int32 contraction operand. Shared by the flat, hoisted
    and matvec int8 kernels so they stay bit-identical.
    """
    w = jnp.concatenate(
        [_decode_chunk_int(p2, p1, c, fivelevel=fivelevel)
         for c in range(NCHUNK)], axis=-1)  # (TN, 256) int8
    if sub_blocks:
        return w
    return w - zp_ref[...].astype(jnp.int8)  # (TN, 1) integer-valued


def _accumulate_int8(acc_ref, xq, w, sc_ref, *, sub_blocks: int):
    """acc += d_k * (xq . wint^T) with int32 MACs; the per-block weight
    scale lands on the int32 partial (it varies per (n, k) so it cannot be
    deferred to the flush like the activation row scale)."""
    if sub_blocks:
        per = BLOCK // sub_blocks
        d_sub = sc_ref[:, 0, :].astype(jnp.float32)  # (TN, SUB)
        for s in range(sub_blocks):
            p = jax.lax.dot_general(
                xq[:, s * per:(s + 1) * per], w[:, s * per:(s + 1) * per],
                (((1,), (1,)), ((), ())), preferred_element_type=jnp.int32)
            acc_ref[...] += p.astype(jnp.float32) * d_sub[:, s][None, :]
    else:
        d = sc_ref[...].astype(jnp.float32)  # (TN, 1)
        p = jax.lax.dot_general(
            xq, w, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.int32)
        acc_ref[...] += p.astype(jnp.float32) * d[:, 0][None, :]


def _itq3_matmul_int8_kernel(
    x_ref,    # (TM, 256) int8 — rotation-domain activation codes
    xs_ref,   # (TM, 1) f32 — per-row activation scale
    p2_ref,   # (TN, 1, 64) uint8
    p1_ref,   # (TN, 1, 32) uint8
    sc_ref,   # (TN, 1) f32  |  (TN, 1, SUB) f32
    zp_ref,   # (TN, 1) f32 (integer-valued)
    o_ref,    # (TM, TN)
    acc_ref,  # scratch (TM, TN) f32
    *,
    fivelevel: bool,
    sub_blocks: int,
    kb: int,
):
    """Flat int8 schedule: grid (MB, NB, KB). No Hadamard operand and no
    in-kernel rotation — the FWHT already happened once on the activation
    side (act_encode), so the per-tile work is unpack + one int dot."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = decode_wint_tile(p2_ref[:, 0, :], p1_ref[:, 0, :], zp_ref,
                         fivelevel=fivelevel, sub_blocks=sub_blocks)
    _accumulate_int8(acc_ref, x_ref[...], w, sc_ref, sub_blocks=sub_blocks)

    @pl.when(k == kb - 1)
    def _flush():
        o_ref[...] = (acc_ref[...] * xs_ref[...]).astype(o_ref.dtype)


def _itq3_matmul_int8_hoisted_kernel(
    x_ref, xs_ref, p2_ref, p1_ref, sc_ref, zp_ref, o_ref,
    acc_ref,  # scratch (TM, TN) f32
    w_ref,    # scratch (KB, TN, 256) int8 — expanded strip for current j
    *,
    fivelevel: bool,
    sub_blocks: int,
    kb: int,
):
    """Hoisted int8 schedule: grid (NB, MB, KB); the int8 strip costs 1/4
    of the float path's scratch bytes, so it fits VMEM at 4x the KB*TN."""
    i = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(i == 0)
    def _expand():
        w_ref[pl.ds(k, 1)] = decode_wint_tile(
            p2_ref[:, 0, :], p1_ref[:, 0, :], zp_ref,
            fivelevel=fivelevel, sub_blocks=sub_blocks)[None]

    _accumulate_int8(acc_ref, x_ref[...], w_ref[pl.ds(k, 1)][0], sc_ref,
                     sub_blocks=sub_blocks)

    @pl.when(k == kb - 1)
    def _flush():
        o_ref[...] = (acc_ref[...] * xs_ref[...]).astype(o_ref.dtype)


def pad_packed_n(pad_n: int, *operands):
    """Pad the packed-operand N (leading) dim of planes/scales/zps; shared
    by the tiled and matvec wrappers."""
    if not pad_n:
        return operands
    return tuple(
        jnp.pad(a, [(0, pad_n)] + [(0, 0)] * (a.ndim - 1)) for a in operands)


def _accumulate(acc_ref, x_ref, w):
    x = x_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)


def _itq3_matmul_kernel(
    h_ref,    # (256, 256) f32 — Hadamard (only read when rotate_weights)
    x_ref,    # (TM, 256)
    p2_ref,   # (TN, 1, 64) uint8
    p1_ref,   # (TN, 1, 32) uint8
    sc_ref,   # (TN, 1) f32  |  (TN, 1, SUB) f32 for sub-block scales
    zp_ref,   # (TN, 1) f32
    o_ref,    # (TM, TN)
    acc_ref,  # scratch (TM, TN) f32
    *,
    rotate_weights: bool,
    fivelevel: bool,
    sub_blocks: int,
    kb: int,
):
    """Flat schedule: grid (MB, NB, KB), expand the weight tile per cell."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = dequant_rotate_tile(h_ref, p2_ref[:, 0, :], p1_ref[:, 0, :],
                            sc_ref, zp_ref, rotate_weights=rotate_weights,
                            fivelevel=fivelevel, sub_blocks=sub_blocks)
    _accumulate(acc_ref, x_ref, w)

    @pl.when(k == kb - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _itq3_matmul_hoisted_kernel(
    h_ref, x_ref, p2_ref, p1_ref, sc_ref, zp_ref, o_ref,
    acc_ref,  # scratch (TM, TN) f32
    w_ref,    # scratch (KB, TN, 256) f32 — expanded strip for current j
    *,
    rotate_weights: bool,
    fivelevel: bool,
    sub_blocks: int,
    kb: int,
):
    """Hoisted schedule: grid (NB, MB, KB). The expanded weight strip for
    output tile j is computed once (first M tile) and served from VMEM
    scratch for every later M tile."""
    i = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(i == 0)
    def _expand():
        w_ref[pl.ds(k, 1)] = dequant_rotate_tile(
            h_ref, p2_ref[:, 0, :], p1_ref[:, 0, :], sc_ref, zp_ref,
            rotate_weights=rotate_weights, fivelevel=fivelevel,
            sub_blocks=sub_blocks)[None]

    _accumulate(acc_ref, x_ref, w_ref[pl.ds(k, 1)][0])

    @pl.when(k == kb - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "rotate_weights", "fivelevel", "sub_blocks", "tm", "tn", "interpret",
        "out_dtype", "hoist",
    ),
)
def itq3_matmul_pallas(
    x: jax.Array,        # (M, K_pad) — K_pad = KB * 256
    plane2: jax.Array,   # (N, KB, 64) uint8
    plane1: jax.Array,   # (N, KB, 32) uint8
    scales: jax.Array,   # (N, KB) f16/f32  |  (N, KB, SUB)
    zps: jax.Array,      # (N, KB) f16/f32
    *,
    rotate_weights: bool = True,
    fivelevel: bool = False,
    sub_blocks: int = 0,
    tm: int = 256,
    tn: int = 256,
    interpret: bool = True,
    out_dtype=jnp.float32,
    hoist: bool | None = None,
) -> jax.Array:
    """Fused ITQ3_S matmul: returns ``x @ W_hat`` of shape (M, N).

    ``hoist=None`` auto-selects the hoisted schedule when there is more than
    one M tile and the expanded weight strip fits the VMEM budget.
    """
    m, kpad = x.shape
    n, kb = plane2.shape[0], plane2.shape[1]
    if kpad != kb * BLOCK:
        raise ValueError(f"x K dim {kpad} != KB*256 = {kb * BLOCK}")

    tm = max(1, min(tm, m))
    tn = max(1, min(tn, n))
    pad_m, pad_n = (-m) % tm, (-n) % tn
    if pad_m:
        x = jnp.pad(x, ((0, pad_m), (0, 0)))
    plane2, plane1, scales, zps = pad_packed_n(
        pad_n, plane2, plane1, scales, zps)
    mp, np_ = x.shape[0], plane2.shape[0]
    mb = mp // tm

    scales = scales.astype(jnp.float32)
    zps = zps.astype(jnp.float32)
    h = hadamard_matrix(BLOCK, dtype=jnp.float32)

    if hoist is None:
        hoist = mb > 1 and kb * tn * BLOCK * 4 <= HOIST_VMEM_BUDGET

    kernel_kw = dict(rotate_weights=rotate_weights, fivelevel=fivelevel,
                     sub_blocks=sub_blocks, kb=kb)
    scratch = [pltpu.VMEM((tm, tn), jnp.float32)]
    if hoist:
        # grid (j, i, k): i (M tiles) revisits j's weight strip; the strip
        # is expanded once at i == 0 into scratch and reused after.
        grid = (np_ // tn, mb, kb)
        x_idx = lambda j, i, k: (i, k)
        w_idx = lambda j, i, k: (j, k, 0)
        s_idx2 = lambda j, i, k: (j, k)
        o_idx = lambda j, i, k: (i, j)
        sc_idx3 = lambda j, i, k: (j, k, 0)
        kernel = functools.partial(_itq3_matmul_hoisted_kernel, **kernel_kw)
        scratch.append(pltpu.VMEM((kb, tn, BLOCK), jnp.float32))
    else:
        grid = (mb, np_ // tn, kb)
        x_idx = lambda i, j, k: (i, k)
        w_idx = lambda i, j, k: (j, k, 0)
        s_idx2 = lambda i, j, k: (j, k)
        o_idx = lambda i, j, k: (i, j)
        sc_idx3 = lambda i, j, k: (j, k, 0)
        kernel = functools.partial(_itq3_matmul_kernel, **kernel_kw)

    if sub_blocks:
        sc_spec = pl.BlockSpec((tn, 1, sub_blocks), sc_idx3)
    else:
        sc_spec = pl.BlockSpec((tn, 1), s_idx2)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK, BLOCK), lambda *_: (0, 0)),  # H resident
            pl.BlockSpec((tm, BLOCK), x_idx),
            pl.BlockSpec((tn, 1, CHUNK), w_idx),
            pl.BlockSpec((tn, 1, BLOCK // 8), w_idx),
            sc_spec,
            pl.BlockSpec((tn, 1), s_idx2),
        ],
        out_specs=pl.BlockSpec((tm, tn), o_idx),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(h, x, plane2, plane1, scales, zps)
    return out[:m, :n]


@functools.partial(
    jax.jit,
    static_argnames=(
        "fivelevel", "sub_blocks", "tm", "tn", "interpret", "out_dtype",
        "hoist",
    ),
)
def itq3_matmul_int8_pallas(
    xq: jax.Array,       # (M, K_pad) int8 — act_encode codes, K_pad = KB*256
    xscale: jax.Array,   # (M, 1) f32 — per-row activation scale
    plane2: jax.Array,   # (N, KB, 64) uint8
    plane1: jax.Array,   # (N, KB, 32) uint8
    scales: jax.Array,   # (N, KB) f16/f32  |  (N, KB, SUB)
    zps: jax.Array,      # (N, KB) f16/f32 (integer-valued)
    *,
    fivelevel: bool = False,
    sub_blocks: int = 0,
    tm: int = 256,
    tn: int = 256,
    interpret: bool = True,
    out_dtype=jnp.float32,
    hoist: bool | None = None,
) -> jax.Array:
    """W3A8 fused matmul: ``(M, N) = xscale * ((xq @ wint^T) scaled by d)``
    with int8 x int8 -> int32 MACs. The activations arrive already rotated
    and quantized (kernels/ops.py / core/act_quant.py); there is no
    Hadamard operand and no in-kernel rotation. ``hoist=None`` auto-selects
    the hoisted schedule under the same VMEM budget as the float kernel —
    the int8 strip is 4x smaller, so it hoists at 4x the KB*TN.
    """
    m, kpad = xq.shape
    n, kb = plane2.shape[0], plane2.shape[1]
    if xq.dtype != jnp.int8:
        raise ValueError(f"int8 kernel expects int8 codes, got {xq.dtype}")
    if kpad != kb * BLOCK:
        raise ValueError(f"xq K dim {kpad} != KB*256 = {kb * BLOCK}")

    tm = max(1, min(tm, m))
    tn = max(1, min(tn, n))
    pad_m, pad_n = (-m) % tm, (-n) % tn
    if pad_m:
        xq = jnp.pad(xq, ((0, pad_m), (0, 0)))
        xscale = jnp.pad(xscale, ((0, pad_m), (0, 0)))
    plane2, plane1, scales, zps = pad_packed_n(
        pad_n, plane2, plane1, scales, zps)
    mp, np_ = xq.shape[0], plane2.shape[0]
    mb = mp // tm

    xscale = xscale.astype(jnp.float32)
    scales = scales.astype(jnp.float32)
    zps = zps.astype(jnp.float32)

    if hoist is None:
        hoist = mb > 1 and kb * tn * BLOCK <= HOIST_VMEM_BUDGET

    kernel_kw = dict(fivelevel=fivelevel, sub_blocks=sub_blocks, kb=kb)
    scratch = [pltpu.VMEM((tm, tn), jnp.float32)]
    if hoist:
        grid = (np_ // tn, mb, kb)
        x_idx = lambda j, i, k: (i, k)
        xs_idx = lambda j, i, k: (i, 0)
        w_idx = lambda j, i, k: (j, k, 0)
        s_idx2 = lambda j, i, k: (j, k)
        o_idx = lambda j, i, k: (i, j)
        sc_idx3 = lambda j, i, k: (j, k, 0)
        kernel = functools.partial(_itq3_matmul_int8_hoisted_kernel,
                                   **kernel_kw)
        scratch.append(pltpu.VMEM((kb, tn, BLOCK), jnp.int8))
    else:
        grid = (mb, np_ // tn, kb)
        x_idx = lambda i, j, k: (i, k)
        xs_idx = lambda i, j, k: (i, 0)
        w_idx = lambda i, j, k: (j, k, 0)
        s_idx2 = lambda i, j, k: (j, k)
        o_idx = lambda i, j, k: (i, j)
        sc_idx3 = lambda i, j, k: (j, k, 0)
        kernel = functools.partial(_itq3_matmul_int8_kernel, **kernel_kw)

    if sub_blocks:
        sc_spec = pl.BlockSpec((tn, 1, sub_blocks), sc_idx3)
    else:
        sc_spec = pl.BlockSpec((tn, 1), s_idx2)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, BLOCK), x_idx),
            pl.BlockSpec((tm, 1), xs_idx),
            pl.BlockSpec((tn, 1, CHUNK), w_idx),
            pl.BlockSpec((tn, 1, BLOCK // 8), w_idx),
            sc_spec,
            pl.BlockSpec((tn, 1), s_idx2),
        ],
        out_specs=pl.BlockSpec((tm, tn), o_idx),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(xq, xscale, plane2, plane1, scales, zps)
    return out[:m, :n]
