"""Pure-jnp oracles for the Pallas kernels.

Each function computes exactly what the corresponding kernel must produce,
built from the independently-tested :mod:`repro.core` primitives. Kernel
tests sweep shapes/dtypes and ``assert_allclose`` against these.

The float oracle keeps the unpacked codes in **int8 until the contraction**
(the PR 5 leftover): the stored zero-point is integer-valued, so
``wint = q - z`` is an exact int8 tensor and the only full-weight-size f32
tensor XLA ever sees is the convert fused into the dot itself — no
dequantized weight, and no FWHT over the (N, K)-sized weight tensor. The
rotation rides on the activation side instead via the isometry
``x . H w = (H x) . w`` (H involutory + symmetric); the per-block scale
``d`` lands on the (..., N, KB) partials. A jaxpr spy test pins this down.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.fwht import blocked_fwht
from repro.core.quantize import decode_wint

__all__ = ["fwht_ref", "itq3_matmul_ref", "itq3_matmul_int8_ref",
           "decode_wint"]


def fwht_ref(x: jax.Array, block: int = 256) -> jax.Array:
    """Oracle for kernels.fwht_kernel.fwht_pallas."""
    return blocked_fwht(x.astype(jnp.float32), block=block).astype(x.dtype)


def _scaled_partials(xr: jax.Array, wint: jax.Array, scales: jax.Array, *,
                     sub_blocks: int, block: int) -> jax.Array:
    """``sum_b d_b * (xr_b . wint_b)``: contract int8 codes against (already
    rotated) activations blockwise, then apply the per-(n, block) weight
    scale to the partials. The einsum promotes wint in-dot — codes stay
    int8 in HBM."""
    d = scales.astype(jnp.float32)
    if sub_blocks:
        per = block // sub_blocks
        *lead, kb, _ = xr.shape
        xs = xr.reshape(*lead, kb, sub_blocks, per)
        ws = wint.reshape(*wint.shape[:-1], sub_blocks, per)
        part = jnp.einsum("...ksp,nksp->...nks", xs, ws)  # (..., N, KB, SUB)
        return jnp.einsum("...nks,nks->...n", part, d)
    part = jnp.einsum("...kb,nkb->...nk", xr, wint)  # (..., N, KB)
    return jnp.einsum("...nk,nk->...n", part, d)


def itq3_matmul_ref(
    x: jax.Array,
    plane2: jax.Array,
    plane1: jax.Array,
    scales: jax.Array,
    zps: jax.Array,
    *,
    rotate_weights: bool = True,
    fivelevel: bool = False,
    sub_blocks: int = 0,
    out_dtype=jnp.float32,
) -> jax.Array:
    """Oracle for kernels.itq3_matmul.itq3_matmul_pallas.

    x: (M, KB*256); planes (N, KB, 64)/(N, KB, 32); scales (N, KB[, SUB]).
    ``rotate_weights=True`` is computed as ``(H x) . (d (q - z))`` — the
    same value as rotating the weights, without ever materializing them.
    """
    block = plane2.shape[-1] * 4
    kb = plane2.shape[1]
    wint = decode_wint(plane2, plane1, zps, fivelevel=fivelevel,
                       sub_blocks=sub_blocks)
    xf = x.astype(jnp.float32)
    if rotate_weights:
        xf = blocked_fwht(xf, block=block)
    xr = xf.reshape(*x.shape[:-1], kb, block)
    y = _scaled_partials(xr, wint, scales, sub_blocks=sub_blocks, block=block)
    return y.astype(out_dtype)


def itq3_matmul_int8_ref(
    xq: jax.Array,
    xscale: jax.Array,
    plane2: jax.Array,
    plane1: jax.Array,
    scales: jax.Array,
    zps: jax.Array,
    *,
    fivelevel: bool = False,
    sub_blocks: int = 0,
    out_dtype=jnp.float32,
) -> jax.Array:
    """Oracle for the int8-accumulation kernels (W3A8 path).

    xq: (M, KB*256) int8 rotation-domain activation codes (act_encode);
    xscale: (M, 1) f32 per-row scale. Contractions are exact int8 x int8 ->
    int32; the weight scale ``d`` lands on the int32 block partials and the
    row scale once at the end — the same order as the kernels' flush.
    """
    block = plane2.shape[-1] * 4
    n, kb = plane2.shape[0], plane2.shape[1]
    wint = decode_wint(plane2, plane1, zps, fivelevel=fivelevel,
                       sub_blocks=sub_blocks)
    xb = xq.reshape(*xq.shape[:-1], kb, block)
    d = scales.astype(jnp.float32)
    if sub_blocks:
        per = block // sub_blocks
        xs = xb.reshape(*xb.shape[:-1], sub_blocks, per)
        ws = wint.reshape(n, kb, sub_blocks, per)
        part = jnp.einsum("...ksp,nksp->...nks", xs, ws,
                          preferred_element_type=jnp.int32)
        y = jnp.einsum("...nks,nks->...n", part.astype(jnp.float32), d)
    else:
        part = jnp.einsum("...kb,nkb->...nk", xb, wint,
                          preferred_element_type=jnp.int32)
        y = jnp.einsum("...nk,nk->...n", part.astype(jnp.float32), d)
    return (y * xscale.astype(jnp.float32)).astype(out_dtype)
