"""Pure-jnp oracles for the Pallas kernels.

Each function computes exactly what the corresponding kernel must produce,
built from the independently-tested :mod:`repro.core` primitives. Kernel
tests sweep shapes/dtypes and ``assert_allclose`` against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.fwht import blocked_fwht
from repro.core.quantize import decode_values

__all__ = ["fwht_ref", "itq3_matmul_ref"]


def fwht_ref(x: jax.Array, block: int = 256) -> jax.Array:
    """Oracle for kernels.fwht_kernel.fwht_pallas."""
    return blocked_fwht(x.astype(jnp.float32), block=block).astype(x.dtype)


def itq3_matmul_ref(
    x: jax.Array,
    plane2: jax.Array,
    plane1: jax.Array,
    scales: jax.Array,
    zps: jax.Array,
    *,
    rotate_weights: bool = True,
    fivelevel: bool = False,
    sub_blocks: int = 0,
    out_dtype=jnp.float32,
) -> jax.Array:
    """Oracle for kernels.itq3_matmul.itq3_matmul_pallas.

    x: (M, KB*256); planes (N, KB, 64)/(N, KB, 32); scales (N, KB[, SUB]).
    """
    block = plane2.shape[-1] * 4
    n, kb = plane2.shape[0], plane2.shape[1]
    qv = decode_values(plane2, plane1, fivelevel=fivelevel).astype(jnp.float32)
    if sub_blocks:
        d = jnp.repeat(scales.astype(jnp.float32), block // sub_blocks, axis=-1)
        vals = d * qv
    else:
        vals = scales.astype(jnp.float32)[..., None] * (
            qv - zps.astype(jnp.float32)[..., None]
        )
    if rotate_weights:
        vals = blocked_fwht(vals, block=block)
    w = vals.reshape(n, kb * block).T  # (K_pad, N)
    return jnp.matmul(x.astype(jnp.float32), w).astype(out_dtype)
