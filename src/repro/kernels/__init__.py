"""Pallas TPU kernels for the paper's compute hot-spots.

  fwht_kernel.py   blocked 256-point Walsh-Hadamard transform (MXU
                   constant-matmul form — the TPU adaptation of the CUDA
                   shared-memory butterfly, DESIGN.md §2)
  itq3_matmul.py   fused unpack -> dequant -> rotate -> matmul for the
                   ITQ3_S format family (the paper's load_tiles_itq3_s +
                   MMQ pipeline as one pallas_call); flat + weight-hoisted
                   grid schedules
  itq3_matvec.py   decode-shaped small-M specialization (N-major plane
                   streaming, no M tiling); bit-identical to itq3_matmul
  attn_decode.py   fused online-softmax decode attention over the
                   rotated-int8 KV cache (dequantize-free scores via the
                   FWHT isometry; serve/kv_quant.py codec)
  autotune.py      benchmark-driven (tm, tn) tile selection with an
                   on-disk per-device JSON cache
  ops.py           jitted public wrappers (auto interpret on CPU; shape
                   dispatch between matvec and tiled kernels)
  ref.py           pure-jnp oracles; every kernel is allclose-swept
                   against these in tests/test_kernels.py
"""
