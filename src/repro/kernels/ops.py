"""Jitted public wrappers around the Pallas kernels.

``qmatmul_kernel`` is the kernel-backed counterpart of
:func:`repro.core.qlinear.qmatmul`: it accepts the same QTensor and mode
vocabulary and dispatches twice:

**mode** (where the rotation lands):

  mode="weights"      -> fused kernel with in-kernel IFWHT (paper §5.2)
  mode="activations"  -> blocked-FWHT kernel on x, then the same fused
                         kernel with rotation disabled (DESIGN.md §2
                         dual-domain optimization)

**shape** (which kernel runs the contraction):

  M <= MATVEC_MAX_M   -> kernels/itq3_matvec.py — the decode-shaped
                         N-major streaming kernel (no M tiling); ``tm``
                         is ignored there.
  M >  MATVEC_MAX_M   -> kernels/itq3_matmul.py — the tiled kernel, with
                         the weight-tile expansion hoisted across M tiles
                         when it fits VMEM.

The two kernels share the weight-tile expansion helper and accumulate in
the same order, so the dispatch is bit-exact: callers never observe which
kernel ran.

``tm``/``tn`` default to None = resolve via :mod:`repro.kernels.autotune`
(cached per-device winners, deterministic defaults in interpret mode).
``interpret`` defaults to "auto": interpret=True unless running on real TPU
hardware. All wrappers handle reduction-dim padding and arbitrary leading
batch dims.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import formats as fmt_mod
from repro.core.act_quant import act_encode
from repro.core.qlinear import resolve_mode
from repro.core.quantize import QTensor, pad_last_dim
from repro.kernels import autotune as autotune_mod
from repro.kernels.fwht_kernel import fwht_pallas
from repro.kernels.itq3_matmul import (
    BLOCK, itq3_matmul_int8_pallas, itq3_matmul_pallas,
)
from repro.kernels.itq3_matvec import (
    MATVEC_MAX_M, itq3_matvec_int8_pallas, itq3_matvec_pallas,
)

__all__ = ["auto_interpret", "blocked_fwht_op", "qmatmul_kernel"]


def auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def blocked_fwht_op(x: jax.Array, block: int = 256, *, interpret: bool | None = None) -> jax.Array:
    """Blockwise FWHT along the last axis for any-rank ``x``."""
    if interpret is None:
        interpret = auto_interpret()
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)
    out = fwht_pallas(x2, block=block, interpret=interpret)
    return out.reshape(*lead, k)


def qmatmul_kernel(
    x: jax.Array,
    qt: QTensor,
    *,
    mode: str = "weights",
    act_quant: bool = False,
    tm: int | None = None,
    tn: int | None = None,
    interpret: bool | None = None,
    out_dtype=jnp.float32,
) -> jax.Array:
    """Kernel-backed ``x (..., K) @ W_hat (K, N) -> (..., N)`` for the
    ITQ3_S format family.

    ``act_quant=True`` runs the W3A8 integer path: rotate + int8-quantize
    the activations once (Pallas blocked FWHT + act_encode), then dispatch
    by shape to the int8 kernels — int8 x int8 -> int32 MACs, weight scale
    on the block partial, row scale at flush. ``mode`` is moot there (the
    rotation always lands on the activation side); tiles resolve through
    the autotune cache under the int8 key family.
    """
    if interpret is None:
        interpret = auto_interpret()
    m = qt.meta
    if not fmt_mod.get_format(m.fmt).supports_fused:
        raise ValueError(f"kernel path supports the ternary family, got {m.fmt}")

    mode = resolve_mode(x, m, mode)
    lead = x.shape[:-1]
    xp = pad_last_dim(x.reshape(-1, x.shape[-1]), m.block)

    dsign = qt.data.get("dsign")
    if act_quant:
        xq, xs = act_encode(
            xp, block=m.block, rotate=m.rotate, dsign=dsign,
            fwht_fn=lambda a, b: blocked_fwht_op(a, b, interpret=interpret))
        rows = xq.shape[0]
        if tm is None or tn is None:
            a_tm, a_tn = autotune_mod.get_tiles(
                rows, m.n, m.shape[0], m.fmt, interpret=interpret,
                act_quant=True)
            tm = a_tm if tm is None else tm
            tn = a_tn if tn is None else tn
        common = dict(fivelevel=m.fivelevel, sub_blocks=m.sub_blocks, tn=tn,
                      interpret=interpret, out_dtype=out_dtype)
        if rows <= MATVEC_MAX_M:
            out = itq3_matvec_int8_pallas(
                xq, xs, qt.data["plane2"], qt.data["plane1"],
                qt.data["scales"], qt.data["zps"], **common)
        else:
            out = itq3_matmul_int8_pallas(
                xq, xs, qt.data["plane2"], qt.data["plane1"],
                qt.data["scales"], qt.data["zps"], tm=tm, **common)
        return out.reshape(*lead, m.n)

    rotate = m.rotate
    if rotate:
        if mode == "activations":
            xb = xp.reshape(xp.shape[0], -1, m.block)
            if dsign is not None:
                xb = xb * dsign.astype(xb.dtype)
            xp = xb.reshape(xp.shape)
            xp = blocked_fwht_op(xp, block=m.block, interpret=interpret)
            rotate_weights = False
        elif mode == "weights":
            if dsign is not None:
                # w_hat = D H v  =>  y = (H v)^T (D x): pre-scale x by D.
                xb = xp.reshape(xp.shape[0], -1, m.block) * dsign.astype(xp.dtype)
                xp = xb.reshape(xp.shape)
            rotate_weights = True
        else:
            raise ValueError(f"unknown kernel mode {mode!r}")
    else:
        rotate_weights = False  # iq3_s baseline: contract codes directly

    rows = xp.shape[0]
    if tm is None or tn is None:
        # key on the LOGICAL K (m.shape[0]) — the same K the tuner records
        # under — not xp's block-padded width, which diverges whenever the
        # model dim isn't a multiple of 256 (e.g. smollm's d_model=576)
        a_tm, a_tn = autotune_mod.get_tiles(rows, m.n, m.shape[0], m.fmt,
                                            interpret=interpret)
        tm = a_tm if tm is None else tm
        tn = a_tn if tn is None else tn

    common = dict(rotate_weights=rotate_weights, fivelevel=m.fivelevel,
                  sub_blocks=m.sub_blocks, tn=tn, interpret=interpret,
                  out_dtype=out_dtype)
    if rows <= MATVEC_MAX_M:
        out = itq3_matvec_pallas(
            xp, qt.data["plane2"], qt.data["plane1"], qt.data["scales"],
            qt.data["zps"], **common)
    else:
        out = itq3_matmul_pallas(
            xp, qt.data["plane2"], qt.data["plane1"], qt.data["scales"],
            qt.data["zps"], tm=tm, **common)
    return out.reshape(*lead, m.n)
