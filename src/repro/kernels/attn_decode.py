"""Pallas TPU kernel: fused decode attention over the rotated-int8 KV cache.

The serving counterpart of ``serve/kv_quant.py`` (paper §7.2): the cache
stores each K/V token vector FWHT-rotated and int8-quantized with a
per-vector fp16 scale. Because H is an isometry,

    q . k  =  (H q) . (H k)

so the score pass needs NO K-side dequantization: the kernel streams int8
K tiles straight from the cache, contracts them against the *rotated*
query on the MXU, and multiplies the per-token scale into the score row.
V dequantizes per tile, but only to its ROTATED values and only after the
softmax weight is known: the kernel folds the per-token V scale into the
weight row (``(p * v_scale) @ v_codes``), accumulates the weighted sum in
the rotated domain, and leaves the single inverse FWHT for the caller —
``sum_t w_t (H v_t) = H (sum_t w_t v_t)``, so one head_dim-point transform
per step undoes the rotation for every cached token at once. A full
dequantized V tile is never materialized anywhere.

Grid ``(R, NT)`` — one row per (batch, kv_head) pair, key tiles innermost —
with a running online-softmax state in VMEM scratch:

    m   (G, 1)  running max over key tiles
    l   (G, 1)  running denominator
    acc (G, HD) running weighted V sum (unnormalized)

Tiles are masked by ``kv_len[r]`` (per-row valid cache length: slot-batched
serving is ragged), so pad tiles and unwritten cache slots contribute
nothing. The kernel returns the UNNORMALIZED (acc, m, l) triple: decode
attends against a cache that does not yet contain the current token, so the
caller merges the self-token term (one more online-softmax step) and
normalizes — see :func:`decode_attn_q8`.

Dispatch mirrors qmatmul: ``backend="auto"`` runs the kernel on real TPU
hardware for power-of-two head dims with HD a lane multiple, and falls back
to :func:`decode_attn_q8_ref` — the same math as jnp einsums — in interpret
mode or for odd shapes. The two paths share score/weight formulas exactly
(scores from codes, V scale folded into the weight row), so greedy token
streams are identical across backends.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.fwht import fwht, is_pow2

__all__ = [
    "attn_decode_q8_pallas", "decode_attn_q8", "decode_attn_q8_ref",
    "kernel_supported", "DEFAULT_TT",
]

DEFAULT_TT = 256  # key-tile width (tokens streamed per grid step)
NEG_INF = -1e30


def kernel_supported(head_dim: int, *, interpret: bool) -> bool:
    """Shape gate for the fused kernel. Interpret mode takes any pow2
    head_dim (tests sweep the zoo's 32..128); real TPU lowering additionally
    wants HD to fill whole 128-wide lanes."""
    if not is_pow2(head_dim):
        return False
    return interpret or head_dim % 128 == 0


def _attn_decode_kernel(
    len_ref,  # (1, 1) int32 SMEM — valid cache length for this row
    q_ref,    # (1, G, HD) f32 — rotated query row
    kc_ref,   # (1, TT, HD) int8 — K codes tile
    ks_ref,   # (1, TT) f32 — K per-token scales
    vc_ref,   # (1, TT, HD) int8 — V codes tile
    vs_ref,   # (1, TT) f32 — V per-token scales
    o_ref,    # (1, G, HD) f32 — unnormalized weighted V sum
    m_ref,    # (1, G, 1) f32 — running max
    l_ref,    # (1, G, 1) f32 — running denominator
    acc_ref,  # scratch (G, HD) f32
    mx_ref,   # scratch (G, 1) f32
    dn_ref,   # scratch (G, 1) f32
    *,
    sm_scale: float,
    tt: int,
    nt: int,
):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        mx_ref[...] = jnp.full_like(mx_ref, NEG_INF)
        dn_ref[...] = jnp.zeros_like(dn_ref)

    q = q_ref[0]  # (G, HD) f32, already rotated
    kc = kc_ref[0].astype(jnp.float32)  # (TT, HD)
    # dequantize-free scores: (Hq).(Hk) == q.k, per-token scale on the row
    s = jax.lax.dot_general(q, kc, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s * (ks_ref[...] * sm_scale)  # (G, TT) * (1, TT)

    kpos = t * tt + jax.lax.broadcasted_iota(jnp.int32, (1, tt), 1)
    valid = kpos < len_ref[0, 0]  # (1, TT)
    s = jnp.where(valid, s, NEG_INF)

    m_old = mx_ref[...]  # (G, 1)
    m_new = jnp.maximum(m_old, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_old - m_new)
    p = jnp.exp(s - m_new)
    p = jnp.where(valid, p, 0.0)  # NEG_INF - NEG_INF == 0 would leak exp(0)
    mx_ref[...] = m_new
    dn_ref[...] = dn_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    # V dequant folded into the weight row: (p * v_scale) @ v_codes
    pv = p * vs_ref[...]
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        pv, vc_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(t == nt - 1)
    def _flush():
        o_ref[...] = acc_ref[...][None]
        m_ref[...] = mx_ref[...][None]
        l_ref[...] = dn_ref[...][None]


@functools.partial(jax.jit, static_argnames=("tt", "interpret", "sm_scale"))
def attn_decode_q8_pallas(
    q_rot: jax.Array,    # (R, G, HD) f32 — ROTATED queries, R = B*KV rows
    k_codes: jax.Array,  # (R, T, HD) int8
    k_scale: jax.Array,  # (R, T) f16/f32
    v_codes: jax.Array,  # (R, T, HD) int8
    v_scale: jax.Array,  # (R, T) f16/f32
    kv_len: jax.Array,   # (R,) int32 — valid cache positions per row
    *,
    sm_scale: float,
    tt: int = DEFAULT_TT,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Online-softmax decode attention over the quantized cache.

    Returns the UNNORMALIZED triple ``(acc (R, G, HD), m (R, G, 1),
    l (R, G, 1))`` so the caller can merge the current token's self term
    before normalizing (the cache never holds the in-flight token)."""
    r, g, hd = q_rot.shape
    t = k_codes.shape[1]
    tt = max(1, min(tt, t))
    pad_t = (-t) % tt
    if pad_t:
        pad3 = ((0, 0), (0, pad_t), (0, 0))
        k_codes = jnp.pad(k_codes, pad3)
        v_codes = jnp.pad(v_codes, pad3)
        k_scale = jnp.pad(k_scale, ((0, 0), (0, pad_t)))
        v_scale = jnp.pad(v_scale, ((0, 0), (0, pad_t)))
    tp = k_codes.shape[1]
    nt = tp // tt

    kernel = functools.partial(_attn_decode_kernel, sm_scale=sm_scale,
                               tt=tt, nt=nt)
    grid = (r, nt)
    out, m, l = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, t_: (i, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, g, hd), lambda i, t_: (i, 0, 0)),
            pl.BlockSpec((1, tt, hd), lambda i, t_: (i, t_, 0)),
            pl.BlockSpec((1, tt), lambda i, t_: (i, t_)),
            pl.BlockSpec((1, tt, hd), lambda i, t_: (i, t_, 0)),
            pl.BlockSpec((1, tt), lambda i, t_: (i, t_)),
        ],
        out_specs=[
            pl.BlockSpec((1, g, hd), lambda i, t_: (i, 0, 0)),
            pl.BlockSpec((1, g, 1), lambda i, t_: (i, 0, 0)),
            pl.BlockSpec((1, g, 1), lambda i, t_: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, g, hd), jnp.float32),
            jax.ShapeDtypeStruct((r, g, 1), jnp.float32),
            jax.ShapeDtypeStruct((r, g, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((g, hd), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
        interpret=interpret,
    )(kv_len.astype(jnp.int32).reshape(r, 1), q_rot.astype(jnp.float32),
      k_codes, k_scale.astype(jnp.float32), v_codes,
      v_scale.astype(jnp.float32))
    return out, m, l


def _merge_self_token(acc, m, l, s_self, v_self):
    """One more online-softmax step for the current token, then normalize.

    acc (..., G, HD), m/l (..., G, 1); s_self (..., G, 1) score of the new
    token; v_self (..., 1, HD) its dequantized V row."""
    m_tot = jnp.maximum(m, s_self)
    alpha = jnp.exp(m - m_tot)
    p_self = jnp.exp(s_self - m_tot)  # (..., G, 1)
    l_tot = l * alpha + p_self
    out = acc * alpha + p_self * v_self
    return out / l_tot


def decode_attn_q8_ref(
    q_rot: jax.Array,       # (B, KV, G, HD) f32 rotated queries
    k_codes: jax.Array,     # (B, KV, T, HD) int8
    k_scale: jax.Array,     # (B, KV, T, 1)
    v_codes: jax.Array,     # (B, KV, T, HD) int8
    v_scale: jax.Array,     # (B, KV, T, 1)
    kv_len: jax.Array,      # (B,) int32
    *,
    sm_scale: float,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """jnp reference for the kernel's cache pass: identical score and
    V-scale-folding formulas, plain (non-online) max/sum over the full key
    width. Returns the same unnormalized (acc, m, l) triple."""
    s = jnp.einsum("bkgd,bktd->bkgt", q_rot.astype(jnp.float32),
                   k_codes.astype(jnp.float32))
    s = s * (jnp.swapaxes(k_scale.astype(jnp.float32), -1, -2) * sm_scale)
    tk = k_codes.shape[2]
    kpos = jnp.arange(tk)
    valid = kpos[None, None, None, :] < kv_len[:, None, None, None]
    s = jnp.where(valid, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)  # (B, KV, G, 1)
    p = jnp.where(valid, jnp.exp(s - m), 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    pv = p * jnp.swapaxes(v_scale.astype(jnp.float32), -1, -2)
    acc = jnp.einsum("bkgt,bktd->bkgd", pv, v_codes.astype(jnp.float32))
    return acc, m, l


def decode_attn_q8(
    q: jax.Array,            # (B, KV, G, 1, HD) UNROTATED queries
    cache: dict,             # {"k","v": int8 (B,KV,T,HD); "k_scale","v_scale": (B,KV,T,1)}
    k_tok: tuple[jax.Array, jax.Array],  # encoded current-token K: (codes (B,KV,1,HD), scale (B,KV,1,1))
    v_tok: tuple[jax.Array, jax.Array],  # encoded current-token V
    kv_len: jax.Array,       # (B,) int32 — valid cached positions (== pos)
    *,
    backend: str = "auto",
    interpret: bool | None = None,
) -> jax.Array:
    """Single-token decode attention against the rotated-int8 cache.

    The current token rides OUTSIDE the cache (same discipline as the fp
    ``_sdpa_decode_token``): its K/V arrive already encoded through the same
    codec that will write them to the cache, so the self term sees exactly
    the values every later step will read back — greedy streams match the
    dequantize-then-attend reference bit-for-decision.

    Returns (B, KV, G, 1, HD) f32."""
    from repro.kernels.ops import auto_interpret  # local: avoid import cycle

    if interpret is None:
        interpret = auto_interpret()
    b, kv, g, _, hd = q.shape
    sm_scale = 1.0 / math.sqrt(hd)
    q_rot = fwht(q[..., 0, :].astype(jnp.float32))  # (B, KV, G, HD)

    use_kernel = backend == "pallas" or (
        backend == "auto" and not interpret and kernel_supported(
            hd, interpret=interpret))
    if use_kernel:
        r = b * kv
        acc, m, l = attn_decode_q8_pallas(
            q_rot.reshape(r, g, hd),
            cache["k"].reshape(r, -1, hd), cache["k_scale"].reshape(r, -1),
            cache["v"].reshape(r, -1, hd), cache["v_scale"].reshape(r, -1),
            jnp.broadcast_to(kv_len[:, None], (b, kv)).reshape(r),
            sm_scale=sm_scale, interpret=interpret)
        acc = acc.reshape(b, kv, g, hd)
        m = m.reshape(b, kv, g, 1)
        l = l.reshape(b, kv, g, 1)
    else:
        acc, m, l = decode_attn_q8_ref(
            q_rot, cache["k"], cache["k_scale"], cache["v"],
            cache["v_scale"], kv_len, sm_scale=sm_scale)

    kc_tok, ks_tok = k_tok
    vc_tok, vs_tok = v_tok
    # self score through the SAME dequantize-free formula: (Hq).codes * scale
    s_self = jnp.einsum("bkgd,bkd->bkg", q_rot,
                        kc_tok[..., 0, :].astype(jnp.float32))[..., None]
    s_self = s_self * (ks_tok[..., 0, :].astype(jnp.float32)[:, :, None]
                       * sm_scale)
    # codes * scale recovers the ROTATED V row (H v); it stays rotated here
    v_self = (vc_tok.astype(jnp.float32)
              * vs_tok.astype(jnp.float32))  # (B, KV, 1, HD)
    out = _merge_self_token(acc, m, l, s_self, v_self)
    # The cache holds H v, so the weighted sum is sum_t w_t (H v_t)
    # = H (sum_t w_t v_t): the rotation commutes with the convex combination
    # and ONE inverse FWHT per step — outside the key-tile loop, outside the
    # kernel — undoes it for every cached token at once.
    out = fwht(out)
    return out[..., None, :]  # (B, KV, G, 1, HD)
