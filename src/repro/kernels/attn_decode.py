"""Pallas TPU kernel: fused attention over the rotated-int8 KV cache.

The serving counterpart of ``serve/kv_quant.py`` (paper §7.2): the cache
stores each K/V token vector FWHT-rotated and int8-quantized with a
per-vector fp16 scale. Because H is an isometry,

    q . k  =  (H q) . (H k)

so the score pass needs NO K-side dequantization: the kernel streams int8
K tiles straight from the cache, contracts them against the *rotated*
query on the MXU, and multiplies the per-token scale into the score row.
V dequantizes per tile, but only to its ROTATED values and only after the
softmax weight is known: the kernel folds the per-token V scale into the
weight row (``(p * v_scale) @ v_codes``), accumulates the weighted sum in
the rotated domain, and leaves the single inverse FWHT for the caller —
``sum_t w_t (H v_t) = H (sum_t w_t v_t)``, so one head_dim-point transform
per query span undoes the rotation for every cached token at once. A full
dequantized K/V buffer is never materialized anywhere.

One kernel serves both serving regimes, dispatched by query width:

* **decode** (``q_len == 1``): grid ``(R, 1, NT)`` — the TQ=1
  specialization. The current token rides OUTSIDE the cache, so the kernel
  runs causal-free over ``kv_len`` cached positions and returns the
  UNNORMALIZED ``(acc, m, l)`` triple; :func:`decode_attn_q8` merges the
  encoded self-token term (one more online-softmax step) and normalizes.
* **prefill** (``q_len > 1``): grid ``(R, NQ, NT)`` — a query-tile
  dimension with key tiles innermost. The in-flight span's K/V codes are
  already written into the cache at ``q_offset..q_offset+q_len-1``, so the
  causal mask ``q_offset + qpos >= kpos`` inside the key-tile loop merges
  the span's self-attention block into the same cache pass — the
  width-``q_len`` generalization of the decode path's
  :func:`_merge_self_token`. Chunked prefill therefore NEVER dequantizes
  the cache buffer; :func:`prefill_attn_q8` normalizes and applies the one
  inverse FWHT per query span.

Each grid row is one (batch, kv_head) pair with a running online-softmax
state in VMEM scratch:

    m   (TQ*G, 1)   running max over key tiles
    l   (TQ*G, 1)   running denominator
    acc (TQ*G, HD)  running weighted V sum (unnormalized)

Tiles are masked by ``kv_len[r]`` (per-row valid cache length: slot-batched
serving is ragged), so pad tiles and unwritten cache slots contribute
nothing.

Dispatch mirrors qmatmul: ``backend="auto"`` runs the kernel on real TPU
hardware for power-of-two head dims with HD a lane multiple, and falls back
to the jnp reference — the same math as einsums — in interpret mode or for
odd shapes; ``backend="pallas"`` on an unsupported shape fails fast with a
ValueError naming the gate instead of dying in Pallas lowering. The
backends share score/weight formulas exactly (scores from codes, V scale
folded into the weight row), so greedy token streams are identical.

**Paged layout** (serve/paged.py): the same kernels also read a BLOCK-POOL
cache — K/V planes stored as ``(num_blocks*KV, block_size, HD)`` pooled
rows instead of per-slot rows, with a per-row int32 block table mapping
each slot's logical key tile to its pool row. The table rides as a THIRD
scalar-prefetch operand, so the key-tile index map does exactly one more
gather: ``row = table[i, tile // tiles_per_block]`` instead of ``row = i``.
The kernel body, masks, and early exit are untouched (masks key on the
LOGICAL grid position), so paged and dense attention are bitwise identical
whenever the gathered blocks hold the same codes/scales — the property
tests/test_paged.py pins. The jnp reference path gathers ``pool[table]``
back into the dense per-slot view and reuses the dense reference math.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.fwht import fwht, is_pow2

__all__ = [
    "attn_q8_pallas", "attn_decode_q8_pallas", "decode_attn_q8",
    "decode_attn_q8_ref", "prefill_attn_q8", "prefill_attn_q8_ref",
    "paged_row_table", "paged_to_dense",
    "kernel_supported", "DEFAULT_TT", "DEFAULT_TQ", "ATTN_BACKENDS",
]

DEFAULT_TT = 256  # key-tile width (tokens streamed per grid step)
DEFAULT_TQ = 128  # query-tile width (prefill rows per grid step)
NEG_INF = -1e30
ATTN_BACKENDS = ("auto", "ref", "pallas")


def kernel_supported(head_dim: int, *, interpret: bool) -> bool:
    """Shape gate for the fused kernel. Interpret mode takes any pow2
    head_dim (tests sweep the zoo's 32..128); real TPU lowering additionally
    wants HD to fill whole 128-wide lanes."""
    if not is_pow2(head_dim):
        return False
    return interpret or head_dim % 128 == 0


def _use_kernel(backend: str, head_dim: int, *, interpret: bool) -> bool:
    """Resolve the backend knob to kernel-or-ref, failing FAST (mirroring
    qmatmul's dispatch errors) when ``backend="pallas"`` is forced onto a
    shape the kernel can't lower — a non-pow2 or, on real TPU, a
    lane-partial head_dim would otherwise die deep inside Pallas."""
    if backend not in ATTN_BACKENDS:
        raise ValueError(f"backend {backend!r} not in {ATTN_BACKENDS}")
    if backend == "pallas":
        if not kernel_supported(head_dim, interpret=interpret):
            gate = ("must be a power of two" if not is_pow2(head_dim)
                    else "must fill whole 128-wide lanes on real TPU "
                         "(head_dim % 128 == 0)")
            raise ValueError(
                f"attention kernel shape gate: head_dim {head_dim} {gate}; "
                f"use backend='ref' or 'auto' for this shape")
        return True
    if backend == "ref":
        return False
    return not interpret and kernel_supported(head_dim, interpret=interpret)


def _tile_limit(len_val, off_val, qi, *, tq: int, causal: bool):
    """Exclusive key-position bound for query tile ``qi``: valid cache
    length, tightened under causality to the tile's LAST query row (no key
    past ``off + (qi+1)*tq - 1`` can ever be attended by this tile)."""
    limit = len_val
    if causal:
        limit = jnp.minimum(limit, off_val + (qi + 1) * tq)
    return limit


def _last_tile(limit, *, tt: int):
    """Index of the last key tile carrying any valid position:
    ``ceil(limit/tt) - 1``, floored at 0 (an empty row still needs one
    well-defined block index)."""
    return jnp.maximum((limit + tt - 1) // tt - 1, 0)


def _attn_q8_kernel(
    len_ref,  # (R,) int32 scalar-prefetch — valid cache length per row
    off_ref,  # (R,) int32 scalar-prefetch — absolute position of query 0
    q_ref,    # (1, TQ, G, HD) f32 — rotated query tile
    kc_ref,   # (1, TT, HD) int8 — K codes tile
    ks_ref,   # (1, TT) f32 — K per-token scales
    vc_ref,   # (1, TT, HD) int8 — V codes tile
    vs_ref,   # (1, TT) f32 — V per-token scales
    o_ref,    # (1, TQ, G, HD) f32 — unnormalized weighted V sum
    m_ref,    # (1, TQ, G, 1) f32 — running max
    l_ref,    # (1, TQ, G, 1) f32 — running denominator
    acc_ref,  # scratch (TQ*G, HD) f32
    mx_ref,   # scratch (TQ*G, 1) f32
    dn_ref,   # scratch (TQ*G, 1) f32
    *,
    sm_scale: float,
    tq: int,
    g: int,
    tt: int,
    nt: int,
    causal: bool,
    early_exit: bool,
):
    r = pl.program_id(0)
    qt = pl.program_id(1)
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        mx_ref[...] = jnp.full_like(mx_ref, NEG_INF)
        dn_ref[...] = jnp.zeros_like(dn_ref)

    limit = _tile_limit(len_ref[r], off_ref[r], qt, tq=tq, causal=causal)
    # Tile-level early exit: grid steps past ceil(limit/tt) tiles are
    # fully masked (every kpos fails the len/causal test), so skip their
    # compute entirely — their DMA was already elided by the clamped
    # index maps (same block index => Pallas skips the re-fetch). The
    # masks below keep using the GRID position t, so a skipped tile
    # contributes exactly nothing either way (the early_exit=False parity
    # configuration runs the full loop to prove it).
    run = (t * tt < limit) if early_exit else (t >= 0)

    @pl.when(run)
    def _update():
        rows = tq * g
        hd = q_ref.shape[-1]
        q = q_ref[0].reshape(rows, hd)  # (TQ*G, HD) f32, already rotated
        kc = kc_ref[0].astype(jnp.float32)  # (TT, HD)
        # dequantize-free scores: (Hq).(Hk) == q.k, per-token scale on row
        s = jax.lax.dot_general(q, kc, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * (ks_ref[...] * sm_scale)  # (rows, TT) * (1, TT)

        kpos = t * tt + jax.lax.broadcasted_iota(jnp.int32, (1, tt), 1)
        valid = kpos < len_ref[r]  # (1, TT)
        if causal:
            # flattened row i is query (i // g): absolute position off +
            # qt*TQ + i//g must not look past itself into the key tile
            qpos = (off_ref[r] + qt * tq
                    + jax.lax.broadcasted_iota(jnp.int32, (rows, 1), 0) // g)
            valid_c = valid & (kpos <= qpos)  # (rows, TT)
        else:
            valid_c = valid
        s = jnp.where(valid_c, s, NEG_INF)

        m_old = mx_ref[...]  # (rows, 1)
        m_new = jnp.maximum(m_old, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_old - m_new)
        p = jnp.exp(s - m_new)
        p = jnp.where(valid_c, p, 0.0)  # NEG_INF - NEG_INF would leak exp(0)
        mx_ref[...] = m_new
        dn_ref[...] = dn_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        # V dequant folded into the weight row: (p * v_scale) @ v_codes
        pv = p * vs_ref[...]
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            pv, vc_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(t == nt - 1)
    def _flush():
        hd = q_ref.shape[-1]
        o_ref[...] = acc_ref[...].reshape(1, tq, g, hd)
        m_ref[...] = mx_ref[...].reshape(1, tq, g, 1)
        l_ref[...] = dn_ref[...].reshape(1, tq, g, 1)


@functools.partial(jax.jit, static_argnames=("tq", "tt", "causal",
                                             "interpret", "sm_scale",
                                             "early_exit", "block_size"))
def attn_q8_pallas(
    q_rot: jax.Array,     # (R, TQ_total, G, HD) f32 — ROTATED queries
    k_codes: jax.Array,   # (R, T, HD) int8 — or (PR, BS, HD) pooled blocks
    k_scale: jax.Array,   # (R, T) f16/f32 — or (PR, BS)
    v_codes: jax.Array,   # (R, T, HD) int8 — or (PR, BS, HD)
    v_scale: jax.Array,   # (R, T) f16/f32 — or (PR, BS)
    kv_len: jax.Array,    # (R,) int32 — valid cache positions per row
    q_offset: jax.Array,  # (R,) int32 — absolute position of query 0
    table: jax.Array | None = None,  # (R, MAXB) int32 pool-row block table
    *,
    sm_scale: float,
    causal: bool = True,
    tq: int = DEFAULT_TQ,
    tt: int = DEFAULT_TT,
    interpret: bool = True,
    early_exit: bool = True,
    block_size: int | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Online-softmax attention over the quantized cache, tiled over both
    queries and keys (grid ``(R, NQ, NT)``, key tiles innermost).

    ``kv_len``/``q_offset`` ride as SCALAR-PREFETCH operands
    (:class:`pltpu.PrefetchScalarGridSpec`), so the K/V tile index maps can
    read them: with ``early_exit=True`` (default) every key-tile index past
    ``ceil(limit/tt)`` — where ``limit`` is the row's valid length,
    causally tightened per query tile — is CLAMPED to the last needed tile.
    Pallas skips the DMA for a revisited block index and ``pl.when``
    predicates away the compute, so a 4-token decode against a 32k-slot
    cache streams one tile, not 128. ``early_exit=False`` runs the full
    key loop (the parity configuration: both must agree bitwise, because
    skipped tiles are exactly the fully-masked ones).

    With ``table``/``block_size`` set, the K/V operands are a BLOCK POOL:
    ``(pool_rows, block_size, ...)`` planes whose row for logical key tile
    ``ti`` of grid row ``i`` is ``table[i, ti*tt // block_size]`` — the
    per-slot block table already multiplied out to pool-row units by the
    caller (serve/paged.py). The index maps do that one extra gather; the
    kernel body and its kv_len/causal masks keep using LOGICAL positions
    ``ti*tt + j``, so a paged pass is bitwise identical to the dense pass
    over the same token contents. ``tt`` is clamped to divide
    ``block_size`` (a key tile never straddles two pool blocks).

    Returns the UNNORMALIZED triple ``(acc (R, TQ, G, HD), m (R, TQ, G, 1),
    l (R, TQ, G, 1))`` so the caller chooses what to merge before
    normalizing (decode merges the in-flight token's self term; prefill,
    whose span is already in the cache, just divides)."""
    r, tq_total, g, hd = q_rot.shape
    paged = table is not None
    if paged:
        if block_size is None:
            raise ValueError("paged attention needs block_size with table")
        bs = int(block_size)
        if k_codes.shape[1] != bs:
            raise ValueError(
                f"pooled K/V planes must be (pool_rows, block_size, ...); "
                f"got {k_codes.shape} for block_size {bs}")
        # a key tile must never straddle two pool blocks: largest common
        # divisor keeps power-of-two tunings intact (min of the two)
        tt = math.gcd(max(1, min(tt, bs)), bs)
        tpb = bs // tt  # key tiles per pool block
        nt = table.shape[1] * tpb  # logical tiles = MAXB blocks * tpb
    else:
        t = k_codes.shape[1]
        tt = max(1, min(tt, t))
        pad_t = (-t) % tt
        if pad_t:
            pad3 = ((0, 0), (0, pad_t), (0, 0))
            k_codes = jnp.pad(k_codes, pad3)
            v_codes = jnp.pad(v_codes, pad3)
            k_scale = jnp.pad(k_scale, ((0, 0), (0, pad_t)))
            v_scale = jnp.pad(v_scale, ((0, 0), (0, pad_t)))
        nt = k_codes.shape[1] // tt

    tq = max(1, min(tq, tq_total))
    pad_q = (-tq_total) % tq
    if pad_q:
        # pad queries attend to extra (still kv_len-masked) keys and are
        # sliced away below: zero rows, never NaN rows
        q_rot = jnp.pad(q_rot, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    nq = q_rot.shape[1] // tq

    def kv_tile(i, qi, ti, len_ref, off_ref):
        if not early_exit:
            return (i, ti, 0)
        limit = _tile_limit(len_ref[i], off_ref[i], qi, tq=tq, causal=causal)
        # revisit the last needed tile for every ti beyond it: an unchanged
        # block index is Pallas's "don't re-DMA" signal
        return (i, jnp.minimum(ti, _last_tile(limit, tt=tt)), 0)

    def kv_tile_paged(i, qi, ti, len_ref, off_ref, tbl_ref):
        if early_exit:
            limit = _tile_limit(len_ref[i], off_ref[i], qi, tq=tq,
                                causal=causal)
            ti = jnp.minimum(ti, _last_tile(limit, tt=tt))
        # the one extra scalar-prefetch gather paging costs: logical tile
        # -> (pool row via the block table, tile offset within the block)
        return (tbl_ref[i, ti // tpb], ti % tpb, 0)

    def kv_scale_tile(i, qi, ti, *refs):
        return (kv_tile_paged if paged else kv_tile)(i, qi, ti, *refs)[:2]

    kv_map = kv_tile_paged if paged else kv_tile

    def q_map(i, qi, ti, *refs):
        return (i, qi, 0, 0)

    kernel = functools.partial(_attn_q8_kernel, sm_scale=sm_scale, tq=tq,
                               g=g, tt=tt, nt=nt, causal=causal,
                               early_exit=early_exit)
    if paged:
        # scalar-prefetch refs lead the kernel args; the body never reads
        # the table (only the index maps do), so drop it before dispatch
        kernel = functools.partial(_drop_table_ref, kernel)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3 if paged else 2,  # kv_len, q_offset[, table]
        grid=(r, nq, nt),
        in_specs=[
            pl.BlockSpec((1, tq, g, hd), q_map),
            pl.BlockSpec((1, tt, hd), kv_map),
            pl.BlockSpec((1, tt), kv_scale_tile),
            pl.BlockSpec((1, tt, hd), kv_map),
            pl.BlockSpec((1, tt), kv_scale_tile),
        ],
        out_specs=[
            pl.BlockSpec((1, tq, g, hd), q_map),
            pl.BlockSpec((1, tq, g, 1), q_map),
            pl.BlockSpec((1, tq, g, 1), q_map),
        ],
        scratch_shapes=[
            pltpu.VMEM((tq * g, hd), jnp.float32),
            pltpu.VMEM((tq * g, 1), jnp.float32),
            pltpu.VMEM((tq * g, 1), jnp.float32),
        ],
    )
    scalars = [kv_len.astype(jnp.int32), q_offset.astype(jnp.int32)]
    if paged:
        scalars.append(table.astype(jnp.int32))
    out, m, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((r, nq * tq, g, hd), jnp.float32),
            jax.ShapeDtypeStruct((r, nq * tq, g, 1), jnp.float32),
            jax.ShapeDtypeStruct((r, nq * tq, g, 1), jnp.float32),
        ],
        interpret=interpret,
    )(*scalars, q_rot.astype(jnp.float32), k_codes,
      k_scale.astype(jnp.float32), v_codes, v_scale.astype(jnp.float32))
    if pad_q:
        out, m, l = out[:, :tq_total], m[:, :tq_total], l[:, :tq_total]
    return out, m, l


def _drop_table_ref(kernel, len_ref, off_ref, tbl_ref, *rest):
    """Adapter for the paged call: the block table is scalar-prefetch
    operand #3 (index maps read it) but the kernel body has no use for it."""
    return kernel(len_ref, off_ref, *rest)


def attn_decode_q8_pallas(
    q_rot: jax.Array,    # (R, G, HD) f32 — ROTATED queries, R = B*KV rows
    k_codes: jax.Array,  # (R, T, HD) int8 — or (PR, BS, HD) pooled blocks
    k_scale: jax.Array,  # (R, T) f16/f32 — or (PR, BS)
    v_codes: jax.Array,  # (R, T, HD) int8 — or (PR, BS, HD)
    v_scale: jax.Array,  # (R, T) f16/f32 — or (PR, BS)
    kv_len: jax.Array,   # (R,) int32 — valid cache positions per row
    table: jax.Array | None = None,  # (R, MAXB) int32 pool-row block table
    *,
    sm_scale: float,
    tt: int = DEFAULT_TT,
    interpret: bool = True,
    early_exit: bool = True,
    block_size: int | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Decode attention over the quantized cache: the TQ=1, causal-free
    specialization of :func:`attn_q8_pallas` (decode attends a cache that
    does not yet contain the current token, so no in-span causality
    exists). Returns the unnormalized ``(acc (R, G, HD), m (R, G, 1),
    l (R, G, 1))`` triple — see :func:`decode_attn_q8` for the self-token
    merge."""
    r = q_rot.shape[0]
    acc, m, l = attn_q8_pallas(
        q_rot[:, None], k_codes, k_scale, v_codes, v_scale, kv_len,
        jnp.zeros((r,), jnp.int32), table, sm_scale=sm_scale, causal=False,
        tq=1, tt=tt, interpret=interpret, early_exit=early_exit,
        block_size=block_size)
    return acc[:, 0], m[:, 0], l[:, 0]


def _merge_self_token(acc, m, l, s_self, v_self):
    """One more online-softmax step for the current token, then normalize.

    acc (..., G, HD), m/l (..., G, 1); s_self (..., G, 1) score of the new
    token; v_self (..., 1, HD) its dequantized V row."""
    m_tot = jnp.maximum(m, s_self)
    alpha = jnp.exp(m - m_tot)
    p_self = jnp.exp(s_self - m_tot)  # (..., G, 1)
    l_tot = l * alpha + p_self
    out = acc * alpha + p_self * v_self
    return out / l_tot


def decode_attn_q8_ref(
    q_rot: jax.Array,       # (B, KV, G, HD) f32 rotated queries
    k_codes: jax.Array,     # (B, KV, T, HD) int8
    k_scale: jax.Array,     # (B, KV, T, 1)
    v_codes: jax.Array,     # (B, KV, T, HD) int8
    v_scale: jax.Array,     # (B, KV, T, 1)
    kv_len: jax.Array,      # (B,) int32
    *,
    sm_scale: float,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """jnp reference for the kernel's cache pass: identical score and
    V-scale-folding formulas, plain (non-online) max/sum over the full key
    width. Returns the same unnormalized (acc, m, l) triple."""
    s = jnp.einsum("bkgd,bktd->bkgt", q_rot.astype(jnp.float32),
                   k_codes.astype(jnp.float32))
    s = s * (jnp.swapaxes(k_scale.astype(jnp.float32), -1, -2) * sm_scale)
    tk = k_codes.shape[2]
    kpos = jnp.arange(tk)
    valid = kpos[None, None, None, :] < kv_len[:, None, None, None]
    s = jnp.where(valid, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)  # (B, KV, G, 1)
    p = jnp.where(valid, jnp.exp(s - m), 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    pv = p * jnp.swapaxes(v_scale.astype(jnp.float32), -1, -2)
    acc = jnp.einsum("bkgt,bktd->bkgd", pv, v_codes.astype(jnp.float32))
    return acc, m, l


def prefill_attn_q8_ref(
    q_rot: jax.Array,       # (B, KV, G, TQ, HD) f32 rotated queries
    k_codes: jax.Array,     # (B, KV, T, HD) int8
    k_scale: jax.Array,     # (B, KV, T, 1)
    v_codes: jax.Array,     # (B, KV, T, HD) int8
    v_scale: jax.Array,     # (B, KV, T, 1)
    kv_len: jax.Array,      # (B,) int32
    q_offset: jax.Array,    # (B,) int32
    *,
    sm_scale: float,
    causal: bool = True,
    chunk: int = DEFAULT_TQ,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """jnp reference for the q-tile cache pass: same score and
    V-scale-folding formulas as the kernel, scanned over query chunks so a
    32k-token prefill never materializes a (TQ, T) score tensor for the
    whole span at once — and never a dequantized K/V buffer (scores come
    straight from the codes). Returns unnormalized (acc (B, KV, G, TQ, HD),
    m, l (B, KV, G, TQ, 1))."""
    b, kv, g, tq_total, hd = q_rot.shape
    tk = k_codes.shape[2]
    kc = k_codes.astype(jnp.float32)
    vc = v_codes.astype(jnp.float32)
    ks_row = jnp.swapaxes(k_scale.astype(jnp.float32), -1, -2)  # (B,KV,1,Tk)
    vs_row = jnp.swapaxes(v_scale.astype(jnp.float32), -1, -2)
    kpos = jnp.arange(tk)
    len_mask = kpos[None, None, None, None, :] < kv_len[
        :, None, None, None, None]

    chunk = max(1, min(chunk, tq_total))
    pad = (-tq_total) % chunk
    q = q_rot.astype(jnp.float32)
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
    nq = q.shape[3] // chunk
    qc = jnp.moveaxis(q.reshape(b, kv, g, nq, chunk, hd), 3, 0)

    def one_chunk(ci, qi):
        s = jnp.einsum("bkgqd,bktd->bkgqt", qi, kc)
        s = s * (ks_row[:, :, None] * sm_scale)  # (B,KV,1,1,Tk) broadcast
        valid = len_mask
        if causal:
            qpos = (q_offset[:, None] + ci * chunk
                    + jnp.arange(chunk))  # (B, chunk)
            valid = valid & (kpos[None, None, None, None, :]
                             <= qpos[:, None, None, :, None])
        s = jnp.where(valid, s, NEG_INF)
        m = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.where(valid, jnp.exp(s - m), 0.0)
        l = jnp.sum(p, axis=-1, keepdims=True)
        pv = p * vs_row[:, :, None]
        acc = jnp.einsum("bkgqt,bktd->bkgqd", pv, vc)
        return acc, m, l

    if nq == 1:
        acc, m, l = one_chunk(0, qc[0])
        acc, m, l = acc[None], m[None], l[None]
    else:
        body = jax.checkpoint(lambda args: one_chunk(*args))
        acc, m, l = jax.lax.map(body, (jnp.arange(nq), qc))

    def unchunk(a):
        a = jnp.moveaxis(a, 0, 3)  # (B, KV, G, nq, chunk, ...)
        a = a.reshape(b, kv, g, nq * chunk, a.shape[-1])
        return a[:, :, :, :tq_total]
    return unchunk(acc), unchunk(m), unchunk(l)


def paged_row_table(table: jax.Array, kv_heads: int) -> jax.Array:
    """Expand a per-slot pool-BLOCK table (B, MAXB) to the per-(b, kv_head)
    pool-ROW table (B*KV, MAXB) the kernel's index maps consume: pooled
    planes flatten (num_blocks, KV, ...) to row ``block*KV + head``, so the
    head offset folds into the table once, outside the kernel."""
    b, maxb = table.shape
    rows = (table[:, None, :] * kv_heads
            + jnp.arange(kv_heads, dtype=table.dtype)[None, :, None])
    return rows.reshape(b * kv_heads, maxb)


def paged_to_dense(cache: dict) -> dict:
    """Gather the dense per-slot view back out of a paged cache dict —
    ``pool[table]`` per plane. The jnp reference path (non-TPU backends)
    runs the UNCHANGED dense reference math over this view, so paged ref
    results are bitwise identical to dense by construction; it is also the
    bit-parity oracle the paged kernel is tested against."""
    nb, kvh, bs, _ = cache["k"].shape
    tbl = cache["table"]

    def g(leaf):  # (NB, KV, BS, X) -> (B, KV, MAXB*BS, X)
        x = jnp.swapaxes(leaf[tbl], 1, 2)  # (B, KV, MAXB, BS, X)
        return x.reshape(x.shape[0], kvh, -1, x.shape[-1])

    return {key: g(cache[key]) for key in ("k", "v", "k_scale", "v_scale")}


def decode_attn_q8(
    q: jax.Array,            # (B, KV, G, 1, HD) UNROTATED queries
    cache: dict,             # {"k","v": int8 (B,KV,T,HD); "k_scale","v_scale": (B,KV,T,1)}
    k_tok: tuple[jax.Array, jax.Array],  # encoded current-token K: (codes (B,KV,1,HD), scale (B,KV,1,1))
    v_tok: tuple[jax.Array, jax.Array],  # encoded current-token V
    kv_len: jax.Array,       # (B,) int32 — valid cached positions (== pos)
    *,
    backend: str = "auto",
    interpret: bool | None = None,
    tt: int | None = None,
    early_exit: bool = True,
) -> jax.Array:
    """Single-token decode attention against the rotated-int8 cache.

    The current token rides OUTSIDE the cache (same discipline as the fp
    ``_sdpa_decode_token``): its K/V arrive already encoded through the same
    codec that will write them to the cache, so the self term sees exactly
    the values every later step will read back — greedy streams match the
    dequantize-then-attend reference bit-for-decision.

    A PAGED cache dict (extra ``"table"`` key; planes laid out
    (num_blocks, KV, block_size, HD|1) — serve/paged.py) routes through the
    same kernel with the block table as a third scalar-prefetch operand, or
    through the dense reference over the gathered :func:`paged_to_dense`
    view.

    Returns (B, KV, G, 1, HD) f32."""
    from repro.kernels.ops import auto_interpret  # local: avoid import cycle

    if interpret is None:
        interpret = auto_interpret()
    b, kv, g, _, hd = q.shape
    sm_scale = 1.0 / math.sqrt(hd)
    use_kernel = _use_kernel(backend, hd, interpret=interpret)
    q_rot = fwht(q[..., 0, :].astype(jnp.float32))  # (B, KV, G, HD)
    paged = "table" in cache

    if use_kernel:
        cache_len = (cache["table"].shape[1] * cache["k"].shape[2]
                     if paged else cache["k"].shape[2])
        if tt is None:
            # autotune-cache lookup keyed on (cache length, head_dim,
            # kv heads); deterministic defaults in interpret mode
            from repro.kernels.autotune import get_attn_tiles
            _, tt = get_attn_tiles(cache_len, hd, kv, interpret=interpret)
        r = b * kv
        if paged:
            nb, _, bs, _ = cache["k"].shape
            pool_rows = nb * kv
            acc, m, l = attn_decode_q8_pallas(
                q_rot.reshape(r, g, hd),
                cache["k"].reshape(pool_rows, bs, hd),
                cache["k_scale"].reshape(pool_rows, bs),
                cache["v"].reshape(pool_rows, bs, hd),
                cache["v_scale"].reshape(pool_rows, bs),
                jnp.broadcast_to(kv_len[:, None], (b, kv)).reshape(r),
                paged_row_table(cache["table"], kv),
                sm_scale=sm_scale, tt=tt, interpret=interpret,
                early_exit=early_exit, block_size=bs)
        else:
            acc, m, l = attn_decode_q8_pallas(
                q_rot.reshape(r, g, hd),
                cache["k"].reshape(r, -1, hd), cache["k_scale"].reshape(r, -1),
                cache["v"].reshape(r, -1, hd), cache["v_scale"].reshape(r, -1),
                jnp.broadcast_to(kv_len[:, None], (b, kv)).reshape(r),
                sm_scale=sm_scale, tt=tt, interpret=interpret,
                early_exit=early_exit)
        acc = acc.reshape(b, kv, g, hd)
        m = m.reshape(b, kv, g, 1)
        l = l.reshape(b, kv, g, 1)
    else:
        dc = paged_to_dense(cache) if paged else cache
        acc, m, l = decode_attn_q8_ref(
            q_rot, dc["k"], dc["k_scale"], dc["v"],
            dc["v_scale"], kv_len, sm_scale=sm_scale)

    kc_tok, ks_tok = k_tok
    vc_tok, vs_tok = v_tok
    # self score through the SAME dequantize-free formula: (Hq).codes * scale
    s_self = jnp.einsum("bkgd,bkd->bkg", q_rot,
                        kc_tok[..., 0, :].astype(jnp.float32))[..., None]
    s_self = s_self * (ks_tok[..., 0, :].astype(jnp.float32)[:, :, None]
                       * sm_scale)
    # codes * scale recovers the ROTATED V row (H v); it stays rotated here
    v_self = (vc_tok.astype(jnp.float32)
              * vs_tok.astype(jnp.float32))  # (B, KV, 1, HD)
    out = _merge_self_token(acc, m, l, s_self, v_self)
    # The cache holds H v, so the weighted sum is sum_t w_t (H v_t)
    # = H (sum_t w_t v_t): the rotation commutes with the convex combination
    # and ONE inverse FWHT per step — outside the key-tile loop, outside the
    # kernel — undoes it for every cached token at once.
    out = fwht(out)
    return out[..., None, :]  # (B, KV, G, 1, HD)


def prefill_attn_q8(
    q: jax.Array,          # (B, KV, G, TQ, HD) UNROTATED queries
    cache: dict,           # {"k","v": int8 (B,KV,T,HD); "k_scale","v_scale": (B,KV,T,1)}
    kv_len: jax.Array,     # (B,) int32 — valid cached positions (incl. span)
    q_offset: jax.Array,   # (B,) int32 — absolute position of the span's query 0
    *,
    backend: str = "auto",
    interpret: bool | None = None,
    tq: int | None = None,
    tt: int | None = None,
    early_exit: bool = True,
) -> jax.Array:
    """Query-span (chunked-prefill) attention against the rotated-int8
    cache — the q-tile counterpart of :func:`decode_attn_q8`.

    Unlike decode, the in-flight span's K/V codes are already WRITTEN into
    the cache at ``q_offset..q_offset+TQ-1`` (``attention_apply`` encodes
    and writes the span before attending), so the causal mask
    ``q_offset + qpos >= kpos`` merges the span's self-attention block into
    the cache pass itself — no separate self term, and the cache buffer is
    never dequantized. Every query row sees at least its own position, so
    the online-softmax denominator is strictly positive.

    Returns (B, KV, G, TQ, HD) f32 (rotation already inverted: one inverse
    FWHT over the whole span, outside the kernel)."""
    from repro.kernels.ops import auto_interpret  # local: avoid import cycle

    if interpret is None:
        interpret = auto_interpret()
    b, kv, g, tq_total, hd = q.shape
    sm_scale = 1.0 / math.sqrt(hd)
    use_kernel = _use_kernel(backend, hd, interpret=interpret)
    q_rot = fwht(jnp.swapaxes(q, 2, 3).astype(jnp.float32))  # (B,KV,TQ,G,HD)
    paged = "table" in cache

    if use_kernel:
        cache_len = (cache["table"].shape[1] * cache["k"].shape[2]
                     if paged else cache["k"].shape[2])
        if tq is None or tt is None:
            from repro.kernels.autotune import SPEC_QWIDTH_MAX, get_attn_tiles
            # Narrow spans (speculative K+1 verify windows) have their own
            # tile family: a tq tuned for 512-wide prefill is useless when
            # the span is 5 rows. Wide spans fall through to the base key.
            qw = tq_total if tq_total <= SPEC_QWIDTH_MAX else None
            tuned_tq, tuned_tt = get_attn_tiles(
                cache_len, hd, kv, interpret=interpret, q_width=qw)
            tq = tq if tq else tuned_tq
            tt = tt if tt else tuned_tt
        r = b * kv
        if paged:
            nb, _, bs, _ = cache["k"].shape
            pool_rows = nb * kv
            acc, m, l = attn_q8_pallas(
                q_rot.reshape(r, tq_total, g, hd),
                cache["k"].reshape(pool_rows, bs, hd),
                cache["k_scale"].reshape(pool_rows, bs),
                cache["v"].reshape(pool_rows, bs, hd),
                cache["v_scale"].reshape(pool_rows, bs),
                jnp.broadcast_to(kv_len[:, None], (b, kv)).reshape(r),
                jnp.broadcast_to(q_offset[:, None], (b, kv)).reshape(r),
                paged_row_table(cache["table"], kv),
                sm_scale=sm_scale, causal=True, tq=tq, tt=tt,
                interpret=interpret, early_exit=early_exit, block_size=bs)
        else:
            acc, m, l = attn_q8_pallas(
                q_rot.reshape(r, tq_total, g, hd),
                cache["k"].reshape(r, -1, hd), cache["k_scale"].reshape(r, -1),
                cache["v"].reshape(r, -1, hd), cache["v_scale"].reshape(r, -1),
                jnp.broadcast_to(kv_len[:, None], (b, kv)).reshape(r),
                jnp.broadcast_to(q_offset[:, None], (b, kv)).reshape(r),
                sm_scale=sm_scale, causal=True, tq=tq, tt=tt,
                interpret=interpret, early_exit=early_exit)
        acc = jnp.swapaxes(acc.reshape(b, kv, tq_total, g, hd), 2, 3)
        l = jnp.swapaxes(l.reshape(b, kv, tq_total, g, 1), 2, 3)
    else:
        dc = paged_to_dense(cache) if paged else cache
        acc, m, l = prefill_attn_q8_ref(
            jnp.swapaxes(q_rot, 2, 3), dc["k"], dc["k_scale"],
            dc["v"], dc["v_scale"], kv_len, q_offset,
            sm_scale=sm_scale, causal=True, chunk=tq if tq else DEFAULT_TQ)
    out = acc / l
    # one inverse FWHT per query span — outside the tile loops, outside the
    # kernel — undoes the rotation for every cached token at once
    return fwht(out)
