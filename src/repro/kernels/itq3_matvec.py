"""Pallas TPU kernel: decode-shaped (small-M) fused ITQ3_S matvec.

Low-bit decode is weight-streaming-bound (TWLA, TernaryLLM): at M = a few
slots the matmul grid machinery of ``itq3_matmul_pallas`` — M tiling, M
padding, an (TM, 256) x-tile stream per grid cell — is pure overhead, and
what matters is draining the packed planes from HBM at full bandwidth.

This kernel is the memory-side specialization for M <= ``MATVEC_MAX_M``:

* **No M grid.** The grid is (NB, KB) — output strips N-major, reduction
  innermost — so the packed planes of each strip stream contiguously and
  exactly once; there is no M loop to re-stream them for.
* **No x-tile machinery.** x rides along as one thin (M, 256) block per
  reduction step; the whole activation row set stays VREG-resident.
* **(M, TN) register-tile accumulator.** One f32 scratch tile accumulates
  across KB and flushes once per strip.

The weight-tile expansion is byte-for-byte the tiled kernel's
``dequant_rotate_tile`` (same chunk order, same MXU slices, K ascending),
so results are **bit-identical** to ``itq3_matmul_pallas`` for every format
in the ternary family — ``qmatmul`` dispatches between them purely by shape
(see kernels/ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.fwht import hadamard_matrix
from repro.kernels.itq3_matmul import (
    BLOCK, _accumulate_int8, decode_wint_tile, dequant_rotate_tile,
    pad_packed_n,
)

__all__ = ["itq3_matvec_pallas", "itq3_matvec_int8_pallas", "MATVEC_MAX_M"]

MATVEC_MAX_M = 16  # decode / small-batch regime; above this, tile the M dim


def _itq3_matvec_kernel(
    h_ref,    # (256, 256) f32 — Hadamard (only read when rotate_weights)
    x_ref,    # (M, 256) — reduction block k of the activations
    p2_ref,   # (TN, 1, 64) uint8
    p1_ref,   # (TN, 1, 32) uint8
    sc_ref,   # (TN, 1) f32  |  (TN, 1, SUB) f32
    zp_ref,   # (TN, 1) f32
    o_ref,    # (M, TN)
    acc_ref,  # scratch (M, TN) f32
    *,
    rotate_weights: bool,
    fivelevel: bool,
    sub_blocks: int,
    kb: int,
):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = dequant_rotate_tile(h_ref, p2_ref[:, 0, :], p1_ref[:, 0, :],
                            sc_ref, zp_ref, rotate_weights=rotate_weights,
                            fivelevel=fivelevel, sub_blocks=sub_blocks)
    x = x_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(k == kb - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("rotate_weights", "fivelevel", "sub_blocks", "tn",
                     "interpret", "out_dtype"),
)
def itq3_matvec_pallas(
    x: jax.Array,        # (M, K_pad), M <= MATVEC_MAX_M
    plane2: jax.Array,   # (N, KB, 64) uint8
    plane1: jax.Array,   # (N, KB, 32) uint8
    scales: jax.Array,   # (N, KB) f16/f32  |  (N, KB, SUB)
    zps: jax.Array,      # (N, KB) f16/f32
    *,
    rotate_weights: bool = True,
    fivelevel: bool = False,
    sub_blocks: int = 0,
    tn: int = 256,
    interpret: bool = True,
    out_dtype=jnp.float32,
) -> jax.Array:
    """Decode-shaped fused matvec: returns ``x @ W_hat`` of shape (M, N)."""
    m, kpad = x.shape
    n, kb = plane2.shape[0], plane2.shape[1]
    if m > MATVEC_MAX_M:
        raise ValueError(f"matvec kernel is for M <= {MATVEC_MAX_M}, got {m}")
    if kpad != kb * BLOCK:
        raise ValueError(f"x K dim {kpad} != KB*256 = {kb * BLOCK}")

    tn = max(1, min(tn, n))
    plane2, plane1, scales, zps = pad_packed_n(
        (-n) % tn, plane2, plane1, scales, zps)
    np_ = plane2.shape[0]

    scales = scales.astype(jnp.float32)
    zps = zps.astype(jnp.float32)
    h = hadamard_matrix(BLOCK, dtype=jnp.float32)

    if sub_blocks:
        sc_spec = pl.BlockSpec((tn, 1, sub_blocks), lambda j, k: (j, k, 0))
    else:
        sc_spec = pl.BlockSpec((tn, 1), lambda j, k: (j, k))

    kernel = functools.partial(
        _itq3_matvec_kernel,
        rotate_weights=rotate_weights,
        fivelevel=fivelevel,
        sub_blocks=sub_blocks,
        kb=kb,
    )
    out = pl.pallas_call(
        kernel,
        grid=(np_ // tn, kb),
        in_specs=[
            pl.BlockSpec((BLOCK, BLOCK), lambda j, k: (0, 0)),  # H resident
            pl.BlockSpec((m, BLOCK), lambda j, k: (0, k)),
            pl.BlockSpec((tn, 1, BLOCK // 4), lambda j, k: (j, k, 0)),
            pl.BlockSpec((tn, 1, BLOCK // 8), lambda j, k: (j, k, 0)),
            sc_spec,
            pl.BlockSpec((tn, 1), lambda j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((m, tn), lambda j, k: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((m, tn), jnp.float32)],
        interpret=interpret,
    )(h, x, plane2, plane1, scales, zps)
    return out[:, :n]


def _itq3_matvec_int8_kernel(
    x_ref,    # (M, 256) int8 — reduction block k of the activation codes
    xs_ref,   # (M, 1) f32 — per-row activation scale
    p2_ref,   # (TN, 1, 64) uint8
    p1_ref,   # (TN, 1, 32) uint8
    sc_ref,   # (TN, 1) f32  |  (TN, 1, SUB) f32
    zp_ref,   # (TN, 1) f32 (integer-valued)
    o_ref,    # (M, TN)
    acc_ref,  # scratch (M, TN) f32
    *,
    fivelevel: bool,
    sub_blocks: int,
    kb: int,
):
    """W3A8 decode matvec: same (NB, KB) streaming grid, but the per-strip
    work drops to unpack + integer zero-point fold + one int8 dot — no
    Hadamard operand, no IFWHT MXU passes. Decode is weight-streaming
    bound, so the win is dual: fewer VPU/MXU ops per tile AND 4x fewer
    activation bytes re-read per strip."""
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = decode_wint_tile(p2_ref[:, 0, :], p1_ref[:, 0, :], zp_ref,
                         fivelevel=fivelevel, sub_blocks=sub_blocks)
    _accumulate_int8(acc_ref, x_ref[...], w, sc_ref, sub_blocks=sub_blocks)

    @pl.when(k == kb - 1)
    def _flush():
        o_ref[...] = (acc_ref[...] * xs_ref[...]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("fivelevel", "sub_blocks", "tn", "interpret",
                     "out_dtype"),
)
def itq3_matvec_int8_pallas(
    xq: jax.Array,       # (M, K_pad) int8, M <= MATVEC_MAX_M
    xscale: jax.Array,   # (M, 1) f32
    plane2: jax.Array,   # (N, KB, 64) uint8
    plane1: jax.Array,   # (N, KB, 32) uint8
    scales: jax.Array,   # (N, KB) f16/f32  |  (N, KB, SUB)
    zps: jax.Array,      # (N, KB) f16/f32 (integer-valued)
    *,
    fivelevel: bool = False,
    sub_blocks: int = 0,
    tn: int = 256,
    interpret: bool = True,
    out_dtype=jnp.float32,
) -> jax.Array:
    """Decode-shaped W3A8 matvec (int8 codes in, (M, N) out); the integer
    counterpart of :func:`itq3_matvec_pallas` with the same grid and
    accumulation order as ``itq3_matmul_int8_pallas`` (bit-identical
    dispatch, see kernels/ops.py)."""
    m, kpad = xq.shape
    n, kb = plane2.shape[0], plane2.shape[1]
    if m > MATVEC_MAX_M:
        raise ValueError(f"matvec kernel is for M <= {MATVEC_MAX_M}, got {m}")
    if xq.dtype != jnp.int8:
        raise ValueError(f"int8 kernel expects int8 codes, got {xq.dtype}")
    if kpad != kb * BLOCK:
        raise ValueError(f"xq K dim {kpad} != KB*256 = {kb * BLOCK}")

    tn = max(1, min(tn, n))
    plane2, plane1, scales, zps = pad_packed_n(
        (-n) % tn, plane2, plane1, scales, zps)
    np_ = plane2.shape[0]

    xscale = xscale.astype(jnp.float32)
    scales = scales.astype(jnp.float32)
    zps = zps.astype(jnp.float32)

    if sub_blocks:
        sc_spec = pl.BlockSpec((tn, 1, sub_blocks), lambda j, k: (j, k, 0))
    else:
        sc_spec = pl.BlockSpec((tn, 1), lambda j, k: (j, k))

    kernel = functools.partial(
        _itq3_matvec_int8_kernel,
        fivelevel=fivelevel,
        sub_blocks=sub_blocks,
        kb=kb,
    )
    out = pl.pallas_call(
        kernel,
        grid=(np_ // tn, kb),
        in_specs=[
            pl.BlockSpec((m, BLOCK), lambda j, k: (0, k)),
            pl.BlockSpec((m, 1), lambda j, k: (0, 0)),
            pl.BlockSpec((tn, 1, BLOCK // 4), lambda j, k: (j, k, 0)),
            pl.BlockSpec((tn, 1, BLOCK // 8), lambda j, k: (j, k, 0)),
            sc_spec,
            pl.BlockSpec((tn, 1), lambda j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((m, tn), lambda j, k: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((m, tn), jnp.float32)],
        interpret=interpret,
    )(xq, xscale, plane2, plane1, scales, zps)
    return out[:, :n]
