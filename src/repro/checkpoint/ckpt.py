"""Sharded numpy/msgpack checkpoints with async save and elastic restore.

Layout per checkpoint:

    <dir>/step_000123/
        meta.json          step, leaf paths, shapes, dtypes
        <leafpath>.npy     one file per pytree leaf (path-flattened)
        _COMMITTED         atomic-rename marker written last

Design points for the 1000-node posture:
  * **Atomicity** — writes go to ``step_N.tmp`` and are renamed only after
    every leaf + marker is durably written; a crashed save can never be
    mistaken for a valid checkpoint (restore scans for _COMMITTED).
  * **Async** — ``save_async`` snapshots leaves to host memory and writes on
    a daemon thread so the train loop only blocks for the device->host copy.
  * **Elastic restore** — leaves are loaded host-side and ``device_put`` with
    whatever sharding the *new* mesh prescribes, so restarting on a
    different topology (fewer hosts after failure, more after scale-up) is
    the same code path as a plain resume.
  * On a real multi-host cluster each host writes only the shards it owns
    (addressable_shards); in this single-process container that reduces to
    whole-leaf writes, but the layout/commit protocol is the deployable one.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save", "save_async", "restore", "latest_step", "wait_pending"]

_SEP = "__"
_pending: list[threading.Thread] = []


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", p))))
            for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3) -> str:
    flat = _flatten(tree)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    meta = {"step": step, "leaves": {}}
    for key, arr in flat.items():
        np.save(os.path.join(tmp, key + ".npy"), arr)
        meta["leaves"][key] = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    with open(os.path.join(tmp, "_COMMITTED"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep)
    return final


def save_async(ckpt_dir: str, step: int, tree, *, keep: int = 3) -> threading.Thread:
    """Snapshot to host, then write on a background thread."""
    host_tree = jax.tree.map(lambda x: np.asarray(x), tree)  # blocking D2H only
    th = threading.Thread(target=save, args=(ckpt_dir, step, host_tree),
                          kwargs={"keep": keep}, daemon=True)
    th.start()
    _pending.append(th)
    return th


def wait_pending():
    while _pending:
        _pending.pop().join()


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(_committed_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)


def _committed_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "_COMMITTED")):
                out.append(int(name[5:]))
    return out


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = _committed_steps(ckpt_dir)
    return max(steps) if steps else None


def restore(ckpt_dir: str, template, *, step: Optional[int] = None,
            shardings=None):
    """Rebuild ``template``-shaped pytree from disk. ``shardings`` (optional
    pytree of NamedSharding matching template) enables elastic restore onto
    a new mesh: leaves are device_put directly into the new layout."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    flat_template, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(flat_template))
    leaves = []
    for (path, leaf), shard in zip(flat_template, shard_leaves):
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", p))))
            for p in path)
        arr = np.load(os.path.join(d, key + ".npy"))
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        leaves.append(jax.device_put(arr, shard) if shard is not None else arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), step
