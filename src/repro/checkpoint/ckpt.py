"""Sharded numpy/msgpack checkpoints with async save and elastic restore.

Layout per checkpoint:

    <dir>/step_000123/
        meta.json          step, leaf paths, shapes, dtypes, QTensor metas
        <leafpath>.npy     one file per pytree leaf (path-flattened)
        _COMMITTED         atomic-rename marker written last

Design points for the 1000-node posture:
  * **Atomicity** — writes go to ``step_N.tmp`` and are renamed only after
    every leaf + marker is durably written; a crashed save can never be
    mistaken for a valid checkpoint (restore scans for _COMMITTED).
  * **Async** — ``save_async`` snapshots leaves to host memory and writes on
    a daemon thread so the train loop only blocks for the device->host copy.
  * **Elastic restore** — leaves are loaded host-side and ``device_put`` with
    whatever sharding the *new* mesh prescribes, so restarting on a
    different topology (fewer hosts after failure, more after scale-up) is
    the same code path as a plain resume.
  * **Quantized params are first-class** — a
    :class:`~repro.core.quantize.QTensor` leaf is stored as its packed
    ``data`` arrays (``<leafpath>__Q__<key>.npy``) plus its static
    :class:`~repro.core.quantize.QMeta` serialized into ``meta.json``, and
    restored to an identical pytree. ``restore`` rebuilds QTensors even when
    the template holds the full-precision weight (quantize -> save ->
    serve-from-disk never re-runs Algorithm 1), and ``restore_tree``
    rebuilds a params tree with **no template at all** — what a serving node
    booting from a bare checkpoint directory needs.
  * On a real multi-host cluster each host writes only the shards it owns
    (addressable_shards); in this single-process container that reduces to
    whole-leaf writes, but the layout/commit protocol is the deployable one.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

from repro.core.quantize import QMeta, QTensor

__all__ = ["save", "save_async", "restore", "restore_tree", "restore_params",
           "latest_step", "wait_pending"]

_SEP = "__"
_QMARK = _SEP + "Q" + _SEP  # <leafpath>__Q__<datakey>.npy
_pending: list[threading.Thread] = []


def _pathkey(path) -> str:
    return _SEP.join(
        str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", p))))
        for p in path)


def _is_qtensor(x) -> bool:
    return isinstance(x, QTensor)


def _flatten(tree) -> tuple[dict[str, np.ndarray], dict[str, dict]]:
    """Path-flatten ``tree``; QTensor leaves expand to their packed arrays
    plus a JSON-able meta record."""
    flat: dict[str, np.ndarray] = {}
    qmetas: dict[str, dict] = {}
    pairs = jax.tree_util.tree_flatten_with_path(tree, is_leaf=_is_qtensor)[0]
    for path, leaf in pairs:
        key = _pathkey(path)
        if _is_qtensor(leaf):
            qmetas[key] = {"meta": leaf.meta.to_dict(),
                           "keys": sorted(leaf.data)}
            for dkey in leaf.data:
                flat[key + _QMARK + dkey] = np.asarray(leaf.data[dkey])
        else:
            flat[key] = np.asarray(leaf)
    return flat, qmetas


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3) -> str:
    flat, qmetas = _flatten(tree)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    meta: dict[str, Any] = {"step": step, "leaves": {}, "qtensors": qmetas}
    for key, arr in flat.items():
        np.save(os.path.join(tmp, key + ".npy"), arr)
        meta["leaves"][key] = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    with open(os.path.join(tmp, "_COMMITTED"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep)
    return final


def save_async(ckpt_dir: str, step: int, tree, *, keep: int = 3) -> threading.Thread:
    """Snapshot to host, then write on a background thread."""
    host_tree = jax.tree.map(lambda x: np.asarray(x), tree)  # blocking D2H only
    th = threading.Thread(target=save, args=(ckpt_dir, step, host_tree),
                          kwargs={"keep": keep}, daemon=True)
    th.start()
    _pending.append(th)
    return th


def wait_pending():
    while _pending:
        _pending.pop().join()


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(_committed_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)


def _committed_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "_COMMITTED")):
                out.append(int(name[5:]))
    return out


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = _committed_steps(ckpt_dir)
    return max(steps) if steps else None


def _step_dir(ckpt_dir: str, step: Optional[int]) -> tuple[str, int]:
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {ckpt_dir}")
    return os.path.join(ckpt_dir, f"step_{step:08d}"), step


def _load_qtensor(d: str, key: str, rec: dict) -> QTensor:
    data = {dkey: np.load(os.path.join(d, key + _QMARK + dkey + ".npy"))
            for dkey in rec["keys"]}
    return QTensor(data, QMeta.from_dict(rec["meta"]))


def _put_qtensor(qt: QTensor, shard) -> QTensor:
    """Device_put a restored QTensor into the prescribed layout. ``shard``
    is whatever the shardings pytree holds at the QTensor's template slot: a
    single (Named)Sharding applied to every packed array, a dict keyed like
    ``qt.data``, a QTensor-of-shardings (tree_map over a QTensor template
    produces one), or None (host arrays, caller places them)."""
    if shard is None:
        return qt
    per = shard.data if isinstance(shard, QTensor) else shard
    if not isinstance(per, dict):
        per = {k: per for k in qt.data}
    return QTensor({k: jax.device_put(v, per[k]) for k, v in qt.data.items()},
                   qt.meta)


def restore(ckpt_dir: str, template, *, step: Optional[int] = None,
            shardings=None):
    """Rebuild ``template``-shaped pytree from disk. ``shardings`` (optional
    pytree of NamedSharding matching template) enables elastic restore onto
    a new mesh: leaves are device_put directly into the new layout.

    A leaf saved as a QTensor is rebuilt as a QTensor (its QMeta comes from
    meta.json) whether the template holds a QTensor or the original
    full-precision array — restoring a quantized checkpoint into an fp
    param template yields the quantized tree, ready to serve."""
    d, step = _step_dir(ckpt_dir, step)
    with open(os.path.join(d, "meta.json")) as f:
        qmetas = json.load(f).get("qtensors", {})
    flat_template, treedef = jax.tree_util.tree_flatten_with_path(
        template, is_leaf=_is_qtensor)
    # flatten_up_to keeps shardings aligned one-to-one with template leaves
    # even when a QTensor leaf spans a whole sharding subtree.
    shard_leaves = (treedef.flatten_up_to(shardings)
                    if shardings is not None else [None] * len(flat_template))
    leaves = []
    for (path, leaf), shard in zip(flat_template, shard_leaves):
        key = _pathkey(path)
        if key in qmetas:
            leaves.append(_put_qtensor(_load_qtensor(d, key, qmetas[key]), shard))
            continue
        arr = np.load(os.path.join(d, key + ".npy"))
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        leaves.append(jax.device_put(arr, shard) if shard is not None else arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), step


def restore_tree(ckpt_dir: str, *, step: Optional[int] = None,
                 shardings=None):
    """Template-free restore: rebuild a nested-dict pytree purely from
    ``meta.json`` (params trees are string-keyed dicts all the way down).
    QTensor leaves are reconstructed from their packed planes + stored
    QMeta — this is how a serving process boots a quantized model from a
    bare checkpoint directory (see ServeEngine.from_checkpoint).

    ``shardings``, when given, is a **callable** ``(dotted_key, leaf) ->
    placement`` consulted per leaf as it loads (there is no template to
    align a sharding pytree against). For a QTensor leaf the returned
    placement may be a single sharding, a dict keyed like ``.data``, or
    None; for array leaves a sharding or None. Leaves are ``device_put``
    immediately, so each device only ever materializes its own shard of a
    packed plane — restore-to-sharding for serving TP."""
    d, step = _step_dir(ckpt_dir, step)
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    qmetas = meta.get("qtensors", {})

    tree: dict[str, Any] = {}

    def insert(key: str, value):
        parts = key.split(_SEP)
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = value

    def place(key: str, leaf):
        if shardings is None:
            return leaf
        shard = shardings(key.replace(_SEP, "."), leaf)
        if shard is None:
            return leaf
        if isinstance(leaf, QTensor):
            return _put_qtensor(leaf, shard)
        return jax.device_put(leaf, shard)

    for key, rec in qmetas.items():
        insert(key, place(key, _load_qtensor(d, key, rec)))
    owned = {k + _QMARK + dk for k, rec in qmetas.items() for dk in rec["keys"]}
    for key in meta["leaves"]:
        if key not in owned:
            insert(key, place(key, np.load(os.path.join(d, key + ".npy"))))
    return tree, step


def restore_params(ckpt_dir: str, *, step: Optional[int] = None,
                   shardings=None):
    """Template-free restore of a servable params tree: a bare params
    checkpoint is returned as-is, a TrainState checkpoint is unwrapped to
    its ``params`` member. The one entrypoint for serving-from-disk
    (ServeEngine.from_checkpoint and the serve launcher both use it).
    ``shardings`` is the per-leaf placement callable of
    :func:`restore_tree` (dotted keys include the leading ``params.`` for
    TrainState checkpoints; serve/tp's callable strips it)."""
    tree, step = restore_tree(ckpt_dir, step=step, shardings=shardings)
    if isinstance(tree, dict) and "params" in tree:
        tree = tree["params"]
    return tree, step
