"""Deterministic sharded synthetic-token pipeline.

A fixed Markov-Zipf "language": a seeded transition table gives every token
a small set of likely successors (bigram structure a model can learn), with
occasional resets to a Zipf-distributed unigram draw. Generation is
*stateless* — batch contents are a pure function of (seed, step, shard) —
which gives the fault-tolerance layer exact replay after restart and makes
host sharding trivially disjoint (shard = data-parallel host index).

The same pipeline provides the held-out eval stream for the quantization
quality benchmarks (paper Table 1/3 proxies): train a model on this corpus,
quantize it into each format, and compare eval losses.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["SyntheticCorpus"]


@dataclasses.dataclass
class SyntheticCorpus:
    vocab_size: int
    seed: int = 0
    branching: int = 4
    reset_prob: float = 0.05

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = self.vocab_size
        # transition structure: each token's successor menu (Zipf-biased)
        zipf_p = 1.0 / np.arange(1, v + 1)
        zipf_p /= zipf_p.sum()
        self._perm = rng.permutation(v)  # rank->token map for Zipf draws
        self._zipf_cdf = np.cumsum(zipf_p)
        self._table = rng.integers(0, v, size=(v, self.branching), dtype=np.int64)

    def _zipf_draw(self, u: np.ndarray) -> np.ndarray:
        idx = np.searchsorted(self._zipf_cdf, u, side="right")
        return self._perm[np.clip(idx, 0, self.vocab_size - 1)]

    def batch(self, step: int, batch_size: int, seq_len: int,
              shard: int = 0, num_shards: int = 1) -> dict:
        """Returns {"tokens": (B, T) int32, "labels": (B, T) int32}; the
        (step, shard) pair fully determines the contents."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, shard, num_shards]))
        b, t = batch_size, seq_len
        seq = np.empty((b, t + 1), dtype=np.int64)
        u0 = rng.random(b)
        seq[:, 0] = self._zipf_draw(u0)
        resets = rng.random((b, t)) < self.reset_prob
        choice = rng.integers(0, self.branching, size=(b, t))
        uz = rng.random((b, t))
        zipf_next = self._zipf_draw(uz.reshape(-1)).reshape(b, t)
        for i in range(t):
            nxt = self._table[seq[:, i], choice[:, i]]
            seq[:, i + 1] = np.where(resets[:, i], zipf_next[:, i], nxt)
        return {
            "tokens": seq[:, :-1].astype(np.int32),
            "labels": seq[:, 1:].astype(np.int32),
        }

    def eval_batches(self, n: int, batch_size: int, seq_len: int):
        """Held-out stream: steps are drawn from a disjoint range."""
        for i in range(n):
            yield self.batch(10_000_000 + i, batch_size, seq_len)
