"""Blocked Fast Walsh-Hadamard Transform (FWHT).

The paper's rotation primitive (§2.3): the normalized Walsh-Hadamard matrix

    H_n = (1/sqrt(n)) * [[H_{n/2}, H_{n/2}], [H_{n/2}, -H_{n/2}]],  H_1 = [1]

is symmetric and involutory (H @ H = I), so the transform is its own
inverse. We provide two computation forms:

  * ``fwht``          -- O(n log n) butterfly network (the paper's Algorithm 2
                         structure, vectorized over leading axes). Used for
                         offline quantization and as the CPU reference.
  * ``hadamard_matrix`` -- explicit H_n for the MXU-matmul form used inside
                         the Pallas kernels (TPU adaptation, DESIGN.md §2).

Both operate *blockwise*: an array whose trailing dimension is a multiple of
``block`` is transformed independently per contiguous 256-element (by
default) block, matching the ITQ3_S block structure (§4.1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "fwht",
    "blocked_fwht",
    "hadamard_matrix",
    "is_pow2",
]


def is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


@functools.lru_cache(maxsize=32)
def _hadamard_np(n: int) -> np.ndarray:
    """Unnormalized +-1 Hadamard matrix of size n (Sylvester construction)."""
    if not is_pow2(n):
        raise ValueError(f"Hadamard size must be a power of two, got {n}")
    h = np.array([[1.0]], dtype=np.float64)
    while h.shape[0] < n:
        h = np.block([[h, h], [h, -h]])
    return h


@functools.lru_cache(maxsize=32)
def _hadamard_jnp(n: int, dtype_name: str, normalized: bool) -> jax.Array:
    h = _hadamard_np(n)
    if normalized:
        h = h / np.sqrt(n)
    # first call may happen inside a jit trace: materialize eagerly so the
    # cache holds a committed device constant, never a tracer
    with jax.ensure_compile_time_eval():
        return jnp.asarray(h, dtype=dtype_name)


def hadamard_matrix(n: int, dtype=jnp.float32, normalized: bool = True) -> jax.Array:
    """Normalized (or raw +-1) Walsh-Hadamard matrix H_n.

    ``H_n @ H_n = I`` when normalized. Symmetric: ``H_n.T == H_n``.

    The device constant is cached per (n, dtype, normalized): every kernel
    trace closes over the same committed array instead of rebuilding and
    re-staging the 256x256 constant per trace.
    """
    return _hadamard_jnp(n, np.dtype(dtype).name, normalized)


def fwht(x: jax.Array, *, normalized: bool = True) -> jax.Array:
    """FWHT along the last axis. Last dim must be a power of two.

    Butterfly decomposition (paper Eq. 4): log2(n) stages of
    (u, v) -> (u + v, u - v) on disjoint pairs. Vectorized over all leading
    axes. Self-inverse when ``normalized=True``.
    """
    n = x.shape[-1]
    if not is_pow2(n):
        raise ValueError(f"fwht requires power-of-two trailing dim, got {n}")
    orig_dtype = x.dtype
    # Accumulate in f32 at minimum: n=256 butterflies add 8 bits of dynamic
    # range; bf16 accumulation would hit the Theorem-2 epsilon_FWHT term hard.
    x = x.astype(jnp.promote_types(orig_dtype, jnp.float32))
    shape = x.shape
    h = 1
    while h < n:
        x = x.reshape(*shape[:-1], n // (2 * h), 2, h)
        a = x[..., 0, :]
        b = x[..., 1, :]
        x = jnp.stack([a + b, a - b], axis=-2)
        h *= 2
    x = x.reshape(shape)
    if normalized:
        x = x * (1.0 / np.sqrt(n))
    return x.astype(orig_dtype)


def blocked_fwht(x: jax.Array, block: int = 256, *, normalized: bool = True) -> jax.Array:
    """Apply an independent ``block``-point FWHT to each contiguous block of
    the trailing dimension (ITQ3_S §4.1 block structure).

    Trailing dim must be divisible by ``block``.
    """
    n = x.shape[-1]
    if n % block != 0:
        raise ValueError(f"trailing dim {n} not divisible by block {block}")
    shape = x.shape
    x = x.reshape(*shape[:-1], n // block, block)
    x = fwht(x, normalized=normalized)
    return x.reshape(shape)
