"""ITQ3_S blockwise quantization — paper Algorithm 1 and Eq. (10).

Pipeline per 256-element block ``w`` (taken along the *reduction* dimension
of each weight matrix):

    w'  = FWHT(w)                               # rotation-domain smoothing
    d_k = c_rule * std(w')                      # optimal ternary scale, §3.3
    z_k = -round(mean(w') / d_k)                # zero-point offset
    q   = clamp(round(w'/d_k) + z_k, -1, 1)     # ternary codes
    store(pack3b(q + 1), d_k, z_k)              # planar 3-bit planes

Dequantization (paper Prop. 1): ``w_hat = FWHT(d_k * (q - z_k))`` — exact up
to grid error because H is involutory and isometric (Theorem 2).

This module provides the block-level primitives plus the :class:`QTensor`
pytree container used by every format in :mod:`repro.core.formats`. Weight
tensors are shaped ``(..., K, N)`` (reduction-major, matching ``x @ W``);
blocks tile K; internal storage is output-major ``(..., N, KB, block)`` so a
row of packed bytes is one output feature's weight stream (GGUF-style).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import grids, packing
from repro.core.fwht import fwht

__all__ = [
    "QMeta",
    "QTensor",
    "quantize_blocks_ternary",
    "dequantize_blocks_ternary",
    "pad_reduction_dim",
    "pad_last_dim",
    "to_blocks",
    "from_blocks",
    "decode_values",
    "decode_wint",
]

DEFAULT_BLOCK = 256


@dataclasses.dataclass(frozen=True)
class QMeta:
    """Static (hashable) metadata for a quantized tensor."""

    fmt: str
    shape: tuple[int, ...]  # original (unpadded) shape (..., K, N)
    block: int
    rule: str = "paper"
    rotate: bool = True
    sub_blocks: int = 0  # 0 = single block scale; 8 = paper sub-block variant
    fivelevel: bool = False
    bits_per_weight: float = 3.125
    # Per-path W3A8 eligibility: may this weight's matmul run the integer
    # activation-quantized path when Runtime.act_quant is on? Default True
    # (checkpoints predating the field opt in); a QuantPolicy rule can pin
    # sensitive paths (e.g. lm_head) back to the float contraction.
    act_quant: bool = True

    @property
    def k(self) -> int:
        return self.shape[-2]

    @property
    def n(self) -> int:
        return self.shape[-1]

    @property
    def k_padded(self) -> int:
        return -(-self.k // self.block) * self.block

    @property
    def kb(self) -> int:
        return self.k_padded // self.block

    def to_dict(self) -> dict[str, Any]:
        """JSON-safe representation (checkpoint meta.json)."""
        d = dataclasses.asdict(self)
        d["shape"] = list(d["shape"])
        return d

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "QMeta":
        d = dict(d)
        d["shape"] = tuple(d["shape"])
        return cls(**d)


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["data"],
    meta_fields=["meta"],
)
@dataclasses.dataclass
class QTensor:
    """A quantized weight tensor: dict of packed arrays + static meta.

    ``data`` keys depend on the format; for the ITQ3_S family:
      plane2  (..., N, KB, block//4) uint8   2-bit payload plane
      plane1  (..., N, KB, block//8) uint8   1-bit selector plane
      scales  (..., N, KB) f16 — or (..., N, KB, sub) for the sub variant
      zps     (..., N, KB) f16 (integer-valued)
      dsign   (block,) int8 — only for quip3 (random sign diagonal)
    """

    data: dict[str, jax.Array]
    meta: QMeta

    @property
    def fmt(self) -> str:
        return self.meta.fmt

    @property
    def shape(self) -> tuple[int, ...]:
        return self.meta.shape

    def nbytes(self) -> int:
        return sum(int(np.prod(v.shape)) * v.dtype.itemsize for v in self.data.values())


# ---------------------------------------------------------------------------
# Shape plumbing: (..., K, N) <-> output-major blocks (..., N, KB, block)
# ---------------------------------------------------------------------------

def pad_reduction_dim(w: jax.Array, block: int) -> jax.Array:
    """Zero-pad axis -2 (the reduction dim K) up to a multiple of ``block``
    (paper §8, non-power-of-two layers)."""
    k = w.shape[-2]
    pad = (-k) % block
    if pad == 0:
        return w
    widths = [(0, 0)] * w.ndim
    widths[-2] = (0, pad)
    return jnp.pad(w, widths)


def pad_last_dim(x: jax.Array, to: int) -> jax.Array:
    """Zero-pad the last axis up to a multiple of ``to`` (activation-side
    counterpart of :func:`pad_reduction_dim`; shared by the ref and kernel
    matmul wrappers)."""
    pad = (-x.shape[-1]) % to
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[-1] = (0, pad)
    return jnp.pad(x, widths)


def to_blocks(w: jax.Array, block: int) -> jax.Array:
    """(..., K, N) -> (..., N, KB, block); pads K as needed."""
    w = pad_reduction_dim(w, block)
    *lead, kp, n = w.shape
    w = w.reshape(*lead, kp // block, block, n)
    return jnp.moveaxis(w, -1, -3)  # (..., N, KB, block)


def from_blocks(wb: jax.Array, k_orig: int) -> jax.Array:
    """(..., N, KB, block) -> (..., K, N), trimming the K padding."""
    *lead, n, kb, block = wb.shape
    w = jnp.moveaxis(wb, -3, -1)  # (..., KB, block, N)
    w = w.reshape(*lead, kb * block, n)
    return w[..., :k_orig, :]


# ---------------------------------------------------------------------------
# Block-level ternary quantization (Algorithm 1) and its inverse
# ---------------------------------------------------------------------------

def _rotate(wb: jax.Array, dsign: jax.Array | None) -> jax.Array:
    if dsign is not None:
        wb = wb * dsign.astype(wb.dtype)
    return fwht(wb)


def quantize_blocks_ternary(
    wb: jax.Array,
    *,
    rotate: bool = True,
    rule: str = "paper",
    sub_blocks: int = 0,
    fivelevel: bool = False,
    dsign: jax.Array | None = None,
) -> dict[str, jax.Array]:
    """Quantize blocks ``wb`` (..., block) -> packed planes + scales + zps.

    Follows Algorithm 1 exactly for the default arguments; ``rotate=False``
    gives the IQ3_S-style no-rotation baseline, ``sub_blocks=8`` the §4.1
    sub-block-scale variant, ``fivelevel=True`` the beyond-paper escape grid.
    """
    wb = wb.astype(jnp.float32)
    if rotate:
        wb = _rotate(wb, dsign)
    block = wb.shape[-1]

    if sub_blocks:
        sub = wb.reshape(*wb.shape[:-1], sub_blocks, block // sub_blocks)
        sigma = jnp.std(sub, axis=-1)  # (..., sub)
        alpha = grids.FIVELEVEL_ALPHA if fivelevel else grids.SCALE_RULES[rule]
        d_sub = (alpha * sigma).astype(jnp.float16).astype(jnp.float32)
        d_full = jnp.repeat(d_sub, block // sub_blocks, axis=-1)
        d_block = jnp.mean(d_sub, axis=-1)  # stored block scale (compat)
        zp = jnp.zeros_like(d_block)  # symmetric (paper: z absorbed)
        scales = d_sub
        d_for_codes = d_full
        z_for_codes = 0.0
    else:
        sigma = jnp.std(wb, axis=-1)
        alpha = grids.FIVELEVEL_ALPHA if fivelevel else grids.SCALE_RULES[rule]
        d_block = (alpha * sigma).astype(jnp.float16).astype(jnp.float32)
        mu = jnp.mean(wb, axis=-1)
        safe_d = jnp.where(d_block > 0, d_block, 1.0)
        zmax = 2.0 if fivelevel else 1.0
        zp = jnp.clip(-jnp.round(mu / safe_d), -zmax, zmax)
        scales = d_block
        d_for_codes = d_block[..., None]
        z_for_codes = zp[..., None]

    safe_d = jnp.where(d_for_codes > 0, d_for_codes, 1.0)
    if fivelevel:
        q = jnp.clip(jnp.round(wb / safe_d) + z_for_codes, -2, 2)
        codes3 = _fivelevel_to_codes3(q.astype(jnp.int8))
    else:
        q = jnp.clip(jnp.round(wb / safe_d) + z_for_codes, -1, 1)
        # Payload {0,1,2}; selector plane carries the interleave parity bit
        # (paper Eq. 9's high nibble bit — informational, not value-bearing).
        payload = (q + 1).astype(jnp.uint8)
        parity = (jnp.arange(block, dtype=jnp.uint8) & 1) * jnp.ones_like(payload)
        codes3 = payload | (parity << 2)

    plane2, plane1 = packing.pack_codes(codes3)
    out = {
        "plane2": plane2,
        "plane1": plane1,
        "scales": scales.astype(jnp.float16),
        "zps": zp.astype(jnp.float16),
    }
    if dsign is not None:
        out["dsign"] = dsign.astype(jnp.int8)
    return out


def _fivelevel_to_codes3(q: jax.Array) -> jax.Array:
    """q in {-2..2} -> 3-bit code: payload = clip(q,-1,1)+1, sel = |q|==2."""
    payload = (jnp.clip(q, -1, 1) + 1).astype(jnp.uint8)
    sel = (jnp.abs(q) == 2).astype(jnp.uint8)
    return payload | (sel << 2)


def _codes3_to_fivelevel(codes3: jax.Array) -> jax.Array:
    payload = (codes3 & 0x3).astype(jnp.int8) - 1
    sel = ((codes3 >> 2) & 0x1).astype(jnp.int8)
    return payload * (1 + sel)


def decode_values(
    plane2: jax.Array,
    plane1: jax.Array,
    *,
    fivelevel: bool = False,
) -> jax.Array:
    """Packed planes -> integer grid values q~ (..., block):
    {-1,0,1} (ternary) or {-2..2} (fivelevel). Shared by ref paths and the
    Pallas kernels' interpret-mode oracle."""
    codes3 = packing.unpack_codes(plane2, plane1)
    if fivelevel:
        return _codes3_to_fivelevel(codes3)
    return (codes3 & 0x3).astype(jnp.int8) - 1


def decode_wint(
    plane2: jax.Array,
    plane1: jax.Array,
    zps: jax.Array,
    *,
    fivelevel: bool = False,
    sub_blocks: int = 0,
) -> jax.Array:
    """Packed planes -> exact int8 integer weights ``wint = q - z``
    (..., block). The stored zero-point is integer-valued by construction
    (clipped round, |z| <= 1 ternary / 2 fivelevel) so the subtraction is
    exact in int8; sub-block formats store z = 0 (symmetric). Value range
    {-2..2} ternary / {-4..4} fivelevel — the integer compute path (W3A8)
    contracts these directly against int8 activation codes with no separate
    zero-point correction term."""
    qv = decode_values(plane2, plane1, fivelevel=fivelevel)
    if sub_blocks:
        return qv  # symmetric: z absorbed at quantization time
    return qv - zps.astype(jnp.int8)[..., None]


def dequantize_blocks_ternary(
    data: dict[str, jax.Array],
    *,
    rotate: bool = True,
    sub_blocks: int = 0,
    fivelevel: bool = False,
    dtype=jnp.float32,
) -> jax.Array:
    """Inverse of :func:`quantize_blocks_ternary` (paper Algorithm 2 math):
    unpack -> dequantize on the grid -> inverse FWHT (self-inverse) ->
    undo sign diagonal. Returns (..., block)."""
    qv = decode_values(data["plane2"], data["plane1"], fivelevel=fivelevel).astype(jnp.float32)
    block = qv.shape[-1]
    if sub_blocks:
        d_sub = data["scales"].astype(jnp.float32)
        d_full = jnp.repeat(d_sub, block // sub_blocks, axis=-1)
        vals = d_full * qv
    else:
        d = data["scales"].astype(jnp.float32)[..., None]
        z = data["zps"].astype(jnp.float32)[..., None]
        vals = d * (qv - z)
    if rotate:
        vals = fwht(vals)  # H is self-inverse (normalized)
        dsign = data.get("dsign")
        if dsign is not None:
            vals = vals * dsign.astype(vals.dtype)
    return vals.astype(dtype)
