"""Quantization format registry.

Every format the paper's experiment tables mention is implemented here so
Table 1/2/3 can be reproduced as like-for-like comparisons:

  fp16 / bf16    identity casts (the FP16 baseline row)
  q8_0           GGUF-style: 32-elem blocks, int8 absmax, fp16 scale (8.5 bpw)
  q4_0           GGUF-style: 32-elem blocks, int4 absmax packed nibbles (4.5 bpw)
  iq3_s          3-bit ternary *without* rotation — the paper's 3-bit baseline
  quip3          random-sign diagonal + FWHT (QuIP#-3bit analogue), ternary
  itq3_s         THE PAPER: FWHT rotation + optimal-scale ternary (3.125 bpw)
  itq3_s_sub     §4.1 sub-block-scale variant (3.625 bpw)
  itq3_x         beyond-paper: 5-level magnitude-escape grid, same 3.125 bpw

All quantize along the reduction dim (axis -2) of ``(..., K, N)`` weights.
``quantize(w, fmt)`` / ``dequantize(qt)`` are the public API; formats are
simple singletons in ``FORMATS``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing
from repro.core.quantize import (
    DEFAULT_BLOCK,
    QMeta,
    QTensor,
    dequantize_blocks_ternary,
    from_blocks,
    quantize_blocks_ternary,
    to_blocks,
)

__all__ = ["FORMATS", "quantize", "dequantize", "bits_per_weight", "Format"]


@dataclasses.dataclass(frozen=True)
class Format:
    name: str
    bits_per_weight: float
    block: int
    rotate: bool = False
    sub_blocks: int = 0
    fivelevel: bool = False
    sign_diag: bool = False  # quip3: random Rademacher diagonal before H
    is_float: bool = False
    float_dtype: str = "bfloat16"


FORMATS: dict[str, Format] = {
    "fp16": Format("fp16", 16.0, block=1, is_float=True, float_dtype="float16"),
    "bf16": Format("bf16", 16.0, block=1, is_float=True, float_dtype="bfloat16"),
    "q8_0": Format("q8_0", 8.5, block=32),
    "q4_0": Format("q4_0", 4.5, block=32),
    "iq3_s": Format("iq3_s", 3.125, block=DEFAULT_BLOCK, rotate=False),
    "quip3": Format("quip3", 3.125, block=DEFAULT_BLOCK, rotate=True, sign_diag=True),
    "itq3_s": Format("itq3_s", 3.125, block=DEFAULT_BLOCK, rotate=True),
    "itq3_s_sub": Format("itq3_s_sub", 3.625, block=DEFAULT_BLOCK, rotate=True, sub_blocks=8),
    "itq3_x": Format("itq3_x", 3.125, block=DEFAULT_BLOCK, rotate=True, fivelevel=True),
}

_TERNARY_FAMILY = {"iq3_s", "quip3", "itq3_s", "itq3_s_sub", "itq3_x"}


def bits_per_weight(fmt: str) -> float:
    return FORMATS[fmt].bits_per_weight


def _rademacher(seed: int, n: int) -> jax.Array:
    key = jax.random.PRNGKey(seed)
    return (jax.random.bernoulli(key, 0.5, (n,)).astype(jnp.int8) * 2 - 1)


def quantize(
    w: jax.Array,
    fmt: str = "itq3_s",
    *,
    rule: str = "paper",
    seed: int = 0,
) -> QTensor:
    """Quantize ``w`` (..., K, N) into format ``fmt``."""
    spec = FORMATS[fmt]
    shape = tuple(w.shape)

    if spec.is_float:
        meta = QMeta(fmt, shape, block=1, rule=rule, rotate=False,
                     bits_per_weight=spec.bits_per_weight)
        return QTensor({"w": w.astype(spec.float_dtype)}, meta)

    if fmt in _TERNARY_FAMILY:
        wb = to_blocks(w, spec.block)  # (..., N, KB, block)
        dsign = _rademacher(seed, spec.block) if spec.sign_diag else None
        data = quantize_blocks_ternary(
            wb,
            rotate=spec.rotate,
            rule=rule,
            sub_blocks=spec.sub_blocks,
            fivelevel=spec.fivelevel,
            dsign=dsign,
        )
        meta = QMeta(fmt, shape, block=spec.block, rule=rule, rotate=spec.rotate,
                     sub_blocks=spec.sub_blocks, fivelevel=spec.fivelevel,
                     bits_per_weight=spec.bits_per_weight)
        return QTensor(data, meta)

    if fmt == "q8_0":
        wb = to_blocks(w, 32).astype(jnp.float32)
        amax = jnp.max(jnp.abs(wb), axis=-1)
        scale = (amax / 127.0).astype(jnp.float16).astype(jnp.float32)
        safe = jnp.where(scale > 0, scale, 1.0)
        q = jnp.clip(jnp.round(wb / safe[..., None]), -127, 127).astype(jnp.int8)
        meta = QMeta(fmt, shape, block=32, rotate=False, bits_per_weight=8.5)
        return QTensor({"q": q, "scales": scale.astype(jnp.float16)}, meta)

    if fmt == "q4_0":
        wb = to_blocks(w, 32).astype(jnp.float32)
        amax = jnp.max(jnp.abs(wb), axis=-1)
        scale = (amax / 7.0).astype(jnp.float16).astype(jnp.float32)
        safe = jnp.where(scale > 0, scale, 1.0)
        q = jnp.clip(jnp.round(wb / safe[..., None]), -7, 7).astype(jnp.int8)
        # offset-8 nibble packing, two values per byte
        u = (q + 8).astype(jnp.uint8)
        lo, hi = u[..., 0::2], u[..., 1::2]
        packed = lo | (hi << 4)
        meta = QMeta(fmt, shape, block=32, rotate=False, bits_per_weight=4.5)
        return QTensor({"q": packed, "scales": scale.astype(jnp.float16)}, meta)

    raise ValueError(f"unknown format {fmt!r}; options {sorted(FORMATS)}")


def dequantize(qt: QTensor, dtype=jnp.bfloat16) -> jax.Array:
    """Reconstruct the (..., K, N) weight from any format."""
    m = qt.meta
    spec = FORMATS[m.fmt]

    if spec.is_float:
        return qt.data["w"].astype(dtype)

    if m.fmt in _TERNARY_FAMILY:
        wb = dequantize_blocks_ternary(
            qt.data,
            rotate=m.rotate,
            sub_blocks=m.sub_blocks,
            fivelevel=m.fivelevel,
            dtype=jnp.float32,
        )
        return from_blocks(wb, m.k).astype(dtype)

    if m.fmt == "q8_0":
        vals = qt.data["q"].astype(jnp.float32) * qt.data["scales"].astype(jnp.float32)[..., None]
        return from_blocks(vals, m.k).astype(dtype)

    if m.fmt == "q4_0":
        p = qt.data["q"]
        lo = (p & 0xF).astype(jnp.int8) - 8
        hi = ((p >> 4) & 0xF).astype(jnp.int8) - 8
        q = jnp.stack([lo, hi], axis=-1).reshape(*p.shape[:-1], p.shape[-1] * 2)
        vals = q.astype(jnp.float32) * qt.data["scales"].astype(jnp.float32)[..., None]
        return from_blocks(vals, m.k).astype(dtype)

    raise ValueError(f"unknown format {m.fmt!r}")
