"""Pluggable quantization-format registry.

Every format the paper's experiment tables mention is implemented here so
Table 1/2/3 can be reproduced as like-for-like comparisons:

  fp16 / bf16    identity casts (the FP16 baseline row)
  q8_0           GGUF-style: 32-elem blocks, int8 absmax, fp16 scale (8.5 bpw)
  q4_0           GGUF-style: 32-elem blocks, int4 absmax packed nibbles (4.5 bpw)
  iq3_s          3-bit ternary *without* rotation — the paper's 3-bit baseline
  quip3          random-sign diagonal + FWHT (QuIP#-3bit analogue), ternary
  itq3_s         THE PAPER: FWHT rotation + optimal-scale ternary (3.125 bpw)
  itq3_s_sub     §4.1 sub-block-scale variant (~3.6 bpw)
  itq3_x         beyond-paper: 5-level magnitude-escape grid, same 3.125 bpw

A :class:`Format` is an object with three capabilities:

  ``quantize_blocks``    block-major weights -> packed ``data`` dict
  ``dequantize_blocks``  packed ``data`` dict -> block-major weights
  ``contract``           the reference ``x @ W_hat`` contraction for that
                         storage layout (what ``qmatmul(backend="ref")`` runs)

plus tensor-level ``quantize``/``dequantize`` wrappers that own the
``(..., K, N) <-> (..., N, KB, block)`` shape plumbing and :class:`QMeta`
construction. New formats plug in via :func:`register_format`:

    @register_format
    class MyFormat(TernaryFormat):
        def __init__(self):
            super().__init__("my_fmt", rotate=True, sub_blocks=4)

All formats quantize along the reduction dim (axis -2) of ``(..., K, N)``
weights. ``quantize(w, fmt)`` / ``dequantize(qt)`` remain as module-level
shims so existing call sites keep working.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantize import (
    DEFAULT_BLOCK,
    QMeta,
    QTensor,
    dequantize_blocks_ternary,
    decode_values,
    decode_wint,
    from_blocks,
    pad_last_dim,
    quantize_blocks_ternary,
    to_blocks,
)
from repro.core.fwht import fwht

__all__ = [
    "FORMATS", "Format", "TernaryFormat", "FloatFormat", "AbsmaxFormat",
    "register_format", "get_format", "quantize", "dequantize",
    "bits_per_weight",
]


class Format:
    """Base class: a named storage format for matmul weights.

    Subclasses implement the three-method contract below. ``supports_fused``
    marks formats the Pallas ITQ3 kernel can consume directly (packed
    ternary planes) — the single source of truth that used to be duplicated
    as string allowlists in ``core/qlinear.py`` and ``kernels/ops.py``.
    """

    name: str = ""
    bits_per_weight: float = 16.0
    block: int = 1
    is_float: bool = False
    supports_fused: bool = False

    # --- block-level contract -------------------------------------------
    def quantize_blocks(self, wb: jax.Array, *, rule: str = "paper",
                        seed: int = 0) -> dict[str, jax.Array]:
        raise NotImplementedError

    def dequantize_blocks(self, data: dict[str, jax.Array]) -> jax.Array:
        raise NotImplementedError

    def contract(self, x: jax.Array, qt: QTensor, *, mode: str = "dequant",
                 compute_dtype=jnp.bfloat16) -> jax.Array:
        """Reference ``x (..., K) @ W_hat (K, N)``. The base implementation
        materializes the weight; ternary formats override with the fused
        weight-/activation-rotation contractions."""
        w = self.dequantize(qt, dtype=compute_dtype)
        return jnp.matmul(x.astype(compute_dtype), w)

    # --- tensor-level wrappers ------------------------------------------
    def make_meta(self, shape: tuple[int, ...], *, rule: str = "paper") -> QMeta:
        return QMeta(self.name, shape, block=self.block, rule=rule,
                     rotate=False, bits_per_weight=self.bits_per_weight)

    def quantize(self, w: jax.Array, *, rule: str = "paper",
                 seed: int = 0) -> QTensor:
        wb = to_blocks(w, self.block)
        data = self.quantize_blocks(wb, rule=rule, seed=seed)
        return QTensor(data, self.make_meta(tuple(w.shape), rule=rule))

    def dequantize(self, qt: QTensor, dtype=jnp.bfloat16) -> jax.Array:
        wb = self.dequantize_blocks(qt.data)
        return from_blocks(wb, qt.meta.k).astype(dtype)


FORMATS: dict[str, Format] = {}


def register_format(fmt):
    """Register a :class:`Format` (instance or zero-arg class) under its
    ``name``. Usable as a decorator; re-registration overwrites, so formats
    can be patched in tests or downstream packages."""
    inst = fmt() if isinstance(fmt, type) else fmt
    if not inst.name:
        raise ValueError(f"format {inst!r} has no name")
    FORMATS[inst.name] = inst
    return fmt


def get_format(name: str) -> Format:
    try:
        return FORMATS[name]
    except KeyError:
        raise ValueError(
            f"unknown format {name!r}; options {sorted(FORMATS)}") from None


# ---------------------------------------------------------------------------
# Float identity formats (the FP16/BF16 baseline rows)
# ---------------------------------------------------------------------------

class FloatFormat(Format):
    is_float = True

    def __init__(self, name: str, dtype: str):
        self.name = name
        self.float_dtype = dtype
        self.bits_per_weight = 16.0
        self.block = 1

    def quantize(self, w: jax.Array, *, rule: str = "paper",
                 seed: int = 0) -> QTensor:
        meta = self.make_meta(tuple(w.shape), rule=rule)
        return QTensor({"w": w.astype(self.float_dtype)}, meta)

    def dequantize(self, qt: QTensor, dtype=jnp.bfloat16) -> jax.Array:
        return qt.data["w"].astype(dtype)

    def quantize_blocks(self, wb, *, rule="paper", seed=0):
        return {"w": wb.astype(self.float_dtype)}

    def dequantize_blocks(self, data):
        return data["w"]


# ---------------------------------------------------------------------------
# GGUF-style absmax integer formats (q8_0 / q4_0 baselines)
# ---------------------------------------------------------------------------

class AbsmaxFormat(Format):
    """Blockwise absmax scaling to a symmetric int grid; q4_0 packs two
    offset-8 nibbles per byte."""

    def __init__(self, name: str, qbits: int, bits_per_weight: float):
        self.name = name
        self.qbits = qbits
        self.bits_per_weight = bits_per_weight
        self.block = 32
        self.qmax = float(2 ** (qbits - 1) - 1)

    def quantize_blocks(self, wb, *, rule="paper", seed=0):
        wb = wb.astype(jnp.float32)
        amax = jnp.max(jnp.abs(wb), axis=-1)
        scale = (amax / self.qmax).astype(jnp.float16).astype(jnp.float32)
        safe = jnp.where(scale > 0, scale, 1.0)
        q = jnp.clip(jnp.round(wb / safe[..., None]),
                     -self.qmax, self.qmax).astype(jnp.int8)
        if self.qbits == 4:
            u = (q + 8).astype(jnp.uint8)
            lo, hi = u[..., 0::2], u[..., 1::2]
            q = lo | (hi << 4)
        return {"q": q, "scales": scale.astype(jnp.float16)}

    def dequantize_blocks(self, data):
        q = data["q"]
        if self.qbits == 4:
            lo = (q & 0xF).astype(jnp.int8) - 8
            hi = ((q >> 4) & 0xF).astype(jnp.int8) - 8
            q = jnp.stack([lo, hi], axis=-1).reshape(*q.shape[:-1],
                                                     q.shape[-1] * 2)
        return q.astype(jnp.float32) * data["scales"].astype(jnp.float32)[..., None]


# ---------------------------------------------------------------------------
# The ternary family (iq3_s / quip3 / itq3_s / itq3_s_sub / itq3_x)
# ---------------------------------------------------------------------------

class TernaryFormat(Format):
    """Rotation-domain ternary storage (paper Algorithm 1). Parameterized by
    the rotation/scale-structure knobs; per-call ``sub_blocks`` overrides are
    honoured so a :class:`~repro.serve.quantized.QuantPolicy` rule can
    request finer scales on selected layers."""

    supports_fused = True

    def __init__(self, name: str, *, rotate: bool = True, sub_blocks: int = 0,
                 fivelevel: bool = False, sign_diag: bool = False,
                 block: int = DEFAULT_BLOCK):
        self.name = name
        self.rotate = rotate
        self.sub_blocks = sub_blocks
        self.fivelevel = fivelevel
        self.sign_diag = sign_diag
        self.block = block
        self.bits_per_weight = self._bpw(sub_blocks)

    def _bpw(self, sub_blocks: int) -> float:
        # 3-bit planes + fp16 scale metadata per block: scale+zp, or one
        # scale per sub-block plus the zp in the §4.1 variant.
        scale_bits = 16 * (sub_blocks + 1 if sub_blocks else 2)
        return 3.0 + scale_bits / self.block

    def _dsign(self, seed: int) -> jax.Array | None:
        if not self.sign_diag:
            return None
        key = jax.random.PRNGKey(seed)
        return (jax.random.bernoulli(key, 0.5, (self.block,)).astype(jnp.int8)
                * 2 - 1)

    def make_meta(self, shape, *, rule="paper", sub_blocks=None) -> QMeta:
        sub = self.sub_blocks if sub_blocks is None else sub_blocks
        return QMeta(self.name, shape, block=self.block, rule=rule,
                     rotate=self.rotate, sub_blocks=sub,
                     fivelevel=self.fivelevel, bits_per_weight=self._bpw(sub))

    def quantize_blocks(self, wb, *, rule="paper", seed=0, sub_blocks=None):
        sub = self.sub_blocks if sub_blocks is None else sub_blocks
        return quantize_blocks_ternary(
            wb, rotate=self.rotate, rule=rule, sub_blocks=sub,
            fivelevel=self.fivelevel, dsign=self._dsign(seed))

    def dequantize_blocks(self, data, *, sub_blocks=None):
        sub = self.sub_blocks if sub_blocks is None else sub_blocks
        return dequantize_blocks_ternary(
            data, rotate=self.rotate, sub_blocks=sub,
            fivelevel=self.fivelevel, dtype=jnp.float32)

    def quantize(self, w, *, rule="paper", seed=0, sub_blocks=None) -> QTensor:
        wb = to_blocks(w, self.block)
        data = self.quantize_blocks(wb, rule=rule, seed=seed,
                                    sub_blocks=sub_blocks)
        return QTensor(data, self.make_meta(tuple(w.shape), rule=rule,
                                            sub_blocks=sub_blocks))

    def dequantize(self, qt: QTensor, dtype=jnp.bfloat16) -> jax.Array:
        wb = self.dequantize_blocks(qt.data, sub_blocks=qt.meta.sub_blocks)
        return from_blocks(wb, qt.meta.k).astype(dtype)

    # --- reference contractions (oracles for the Pallas kernel) ---------
    def contract(self, x, qt, *, mode="dequant", compute_dtype=jnp.bfloat16):
        """Three execution paths, all computing ``y = x @ W_hat``:

        * ``dequant``     — materialize W_hat then matmul (base class).
        * ``weights``     — paper-faithful: per weight tile, unpack ->
          dequantize -> inverse-FWHT the *weights*, then matmul; the pure-JAX
          oracle of the fused Pallas kernel.
        * ``activations`` — dual-domain (DESIGN.md §2): H is involutory and
          blocks tile the reduction dim, so

              y_n = sum_b (H (d_b (q_b - z_b 1))) . x_b
                  = sum_b d_b (q_b - z_b 1) . (H x_b)

          rotate each *activation* block once (O(K) transforms per row of x,
          independent of N) and contract the **int8** integer weights
          ``wint = q - z`` directly — exact because the stored zero-point is
          integer-valued (see :func:`~repro.core.quantize.decode_wint`), so
          no correction term and no dequantized weight tensor: the only
          full-weight-size float tensor is the convert XLA fuses into the
          dot, closing the PR 5 ref-path cast-traffic leftover. The block
          scale ``d`` lands on the (..., N, KB) partials.

        All paths are bit-identical in exact arithmetic (tested); they
        differ only in where the rotation FLOPs land.
        """
        if mode == "dequant":
            return super().contract(x, qt, compute_dtype=compute_dtype)

        m = qt.meta
        block, kb, n = m.block, m.kb, m.n

        if mode == "weights":
            qv = decode_values(qt.data["plane2"], qt.data["plane1"],
                               fivelevel=m.fivelevel)
            if m.sub_blocks:
                d = qt.data["scales"].astype(jnp.float32)  # (N, KB, sub)
                d = jnp.repeat(d, block // m.sub_blocks, axis=-1)
                vals = d * qv.astype(jnp.float32)
            else:
                d = qt.data["scales"].astype(jnp.float32)[..., None]
                z = qt.data["zps"].astype(jnp.float32)[..., None]
                vals = d * (qv.astype(jnp.float32) - z)
            if m.rotate:
                vals = fwht(vals)
                dsign = qt.data.get("dsign")
                if dsign is not None:
                    vals = vals * dsign.astype(vals.dtype)
            w = vals.reshape(n, kb * block).T.astype(compute_dtype)  # (K_pad, N)
            xp = pad_last_dim(x, block).astype(compute_dtype)
            return jnp.matmul(xp, w)

        if mode != "activations":
            raise ValueError(f"unknown contraction mode {mode!r}")

        xp = pad_last_dim(x, block).astype(jnp.float32)
        *lead, kp = xp.shape
        xb = xp.reshape(*lead, kb, block)
        if m.rotate:
            dsign = qt.data.get("dsign")
            if dsign is not None:
                xb = xb * dsign.astype(xb.dtype)  # w = D H v => w.x = v.(H D x)
            xb = fwht(xb)
        xr = xb.astype(compute_dtype)  # (..., KB, block)

        wint = decode_wint(qt.data["plane2"], qt.data["plane1"],
                           qt.data["zps"], fivelevel=m.fivelevel,
                           sub_blocks=m.sub_blocks)  # (N, KB, block) int8
        # Fold the per-block scale into the integer weights with ONE fused
        # scale-and-cast — the only weight-size float materialization on
        # this path (the old code decoded, subtracted the zero point, and
        # carried a separate correction contraction) — so the reduction
        # stays a single full-K GEMM.
        d = qt.data["scales"].astype(compute_dtype)
        if m.sub_blocks:
            d = jnp.repeat(d, block // m.sub_blocks, axis=-1)  # (N, KB, block)
            wq = d * wint
        else:
            wq = d[..., None] * wint  # (N, KB, block)
        return jnp.einsum("...kb,nkb->...n", xr, wq).astype(compute_dtype)

    def contract_int8(self, x, qt, *, compute_dtype=jnp.bfloat16):
        """W3A8 reference: quantize the rotated activations to int8
        (:func:`repro.core.act_quant.act_encode`) and contract against the
        int8 integer weights —

            y[m, n] = s_m * sum_b d_{n,b} * ( xq[m, b] . wint[n, b] )

        The block MACs are integer-exact even though this path carries them
        in f32: |xq * wint| <= 127 * 4 and a 256-wide block sum stays below
        2**24, so f32 accumulation returns the same integers as the kernels'
        int32 accumulators while XLA:CPU gets a BLAS batched GEMM instead of
        a scalar int32 loop (the strict-int32 oracle the kernel tests
        compare against lives in :func:`repro.kernels.ref.itq3_matmul_int8_ref`).
        Scale-application order (d on block partials, s_m once at the end)
        matches the kernels' flush exactly."""
        from repro.core.act_quant import act_encode  # local: tiny module

        m = qt.meta
        block, kb, n = m.block, m.kb, m.n
        xp = pad_last_dim(x, block)
        xq, xs = act_encode(xp, block=block, rotate=m.rotate,
                            dsign=qt.data.get("dsign"))
        *lead, kp = xq.shape
        xqb = xq.reshape(*lead, kb, block).astype(jnp.float32)
        wint = decode_wint(qt.data["plane2"], qt.data["plane1"],
                           qt.data["zps"], fivelevel=m.fivelevel,
                           sub_blocks=m.sub_blocks)
        d = qt.data["scales"].astype(jnp.float32)
        if m.sub_blocks:
            per = block // m.sub_blocks
            xsub = xqb.reshape(*lead, kb, m.sub_blocks, per)
            wsub = wint.reshape(n, kb, m.sub_blocks, per)
            part = jnp.einsum("...ksp,nksp->...nks", xsub, wsub,
                              preferred_element_type=jnp.float32)
            y = jnp.einsum("...nks,nks->...n", part, d)
        else:
            part = jnp.einsum("...kb,nkb->...nk", xqb, wint,
                              preferred_element_type=jnp.float32)
            y = jnp.einsum("...nk,nk->...n", part, d)
        return (y * xs).astype(compute_dtype)


register_format(FloatFormat("fp16", "float16"))
register_format(FloatFormat("bf16", "bfloat16"))
register_format(AbsmaxFormat("q8_0", qbits=8, bits_per_weight=8.5))
register_format(AbsmaxFormat("q4_0", qbits=4, bits_per_weight=4.5))
register_format(TernaryFormat("iq3_s", rotate=False))
register_format(TernaryFormat("quip3", rotate=True, sign_diag=True))
register_format(TernaryFormat("itq3_s", rotate=True))
register_format(TernaryFormat("itq3_s_sub", rotate=True, sub_blocks=8))
register_format(TernaryFormat("itq3_x", rotate=True, fivelevel=True))


# ---------------------------------------------------------------------------
# Module-level shims (the original string-keyed API; kept indefinitely)
# ---------------------------------------------------------------------------

def bits_per_weight(fmt: str) -> float:
    return get_format(fmt).bits_per_weight


def quantize(w: jax.Array, fmt: str = "itq3_s", *, rule: str = "paper",
             seed: int = 0, **overrides) -> QTensor:
    """Quantize ``w`` (..., K, N) into format ``fmt`` (registry lookup)."""
    return get_format(fmt).quantize(w, rule=rule, seed=seed, **overrides)


def dequantize(qt: QTensor, dtype=jnp.bfloat16) -> jax.Array:
    """Reconstruct the (..., K, N) weight from any registered format."""
    return get_format(qt.meta.fmt).dequantize(qt, dtype=dtype)
