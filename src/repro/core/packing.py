"""ITQ3_S bit-plane packing (paper §4.2, adapted for TPU — DESIGN.md §2).

Storage per 256-element block is exactly 96 bytes = 3 bits/weight:

  * ``plane2`` — 64 bytes: the 2-bit payload plane. Byte ``i`` holds the
    codes of elements ``{i, 64+i, 128+i, 192+i}`` in bit-pairs
    (an *interleaved* layout: unpacking yields four contiguous 64-lane
    vectors, each extracted with one uniform shift+mask — the VREG-lane
    analogue of the paper's DP4A nibble interleave).
  * ``plane1`` — 32 bytes: the 1-bit selector plane. Byte ``i`` holds the
    selector bits of elements ``{i, 32+i, ..., 224+i}``.

For the faithful ternary format the payload is the code q+z in {0,1,2} and
the selector plane carries the interleave parity (paper Eq. 9's high nibble
bit); for the ``itq3_x`` 5-level extension the selector is the magnitude
escape bit, making the full 3-bit code ``sel*? ...`` — see formats.py.

All functions are shape-polymorphic: they act on the trailing axis, which
must equal the block size for ``pack_*``/planes for ``unpack_*``; leading
axes are batched. Everything is pure jnp → usable under jit/pjit and inside
Pallas interpret-mode reference paths.

A byte-faithful implementation of the paper's Eq. (9) nibble codec is
provided as ``pack_nibbles_reference``/``unpack_nibbles_reference`` for
documentation and cross-tests (it costs 4 bits/value — the paper's own
96-byte figure is only achievable with the planar layout above, which is one
of the quiet corrections recorded in DESIGN.md §7).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "pack_plane2",
    "unpack_plane2",
    "pack_plane1",
    "unpack_plane1",
    "pack_codes",
    "unpack_codes",
    "pack_nibbles_reference",
    "unpack_nibbles_reference",
]


def pack_plane2(codes2: jax.Array) -> jax.Array:
    """Pack 2-bit values (trailing axis length n, n % 4 == 0, values 0..3)
    into n//4 bytes, interleaved: byte i <- codes2[..., [i, q+i, 2q+i, 3q+i]]
    where q = n//4."""
    n = codes2.shape[-1]
    if n % 4 != 0:
        raise ValueError(f"plane2 pack needs trailing dim % 4 == 0, got {n}")
    q = n // 4
    c = codes2.astype(jnp.uint8).reshape(*codes2.shape[:-1], 4, q)
    return (
        c[..., 0, :]
        | (c[..., 1, :] << 2)
        | (c[..., 2, :] << 4)
        | (c[..., 3, :] << 6)
    )


def unpack_plane2(plane2: jax.Array) -> jax.Array:
    """Inverse of :func:`pack_plane2`: n//4 bytes -> n 2-bit values."""
    p = plane2.astype(jnp.uint8)
    parts = [(p >> (2 * k)) & 0x3 for k in range(4)]
    out = jnp.stack(parts, axis=-2)
    return out.reshape(*plane2.shape[:-1], plane2.shape[-1] * 4)


def pack_plane1(codes1: jax.Array) -> jax.Array:
    """Pack 1-bit values (trailing n, n % 8 == 0) into n//8 bytes,
    interleaved with stride n//8."""
    n = codes1.shape[-1]
    if n % 8 != 0:
        raise ValueError(f"plane1 pack needs trailing dim % 8 == 0, got {n}")
    q = n // 8
    c = codes1.astype(jnp.uint8).reshape(*codes1.shape[:-1], 8, q)
    out = c[..., 0, :]
    for k in range(1, 8):
        out = out | (c[..., k, :] << k)
    return out


def unpack_plane1(plane1: jax.Array) -> jax.Array:
    """Inverse of :func:`pack_plane1`."""
    p = plane1.astype(jnp.uint8)
    parts = [(p >> k) & 0x1 for k in range(8)]
    out = jnp.stack(parts, axis=-2)
    return out.reshape(*plane1.shape[:-1], plane1.shape[-1] * 8)


def pack_codes(codes3: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Split 3-bit codes (0..7, trailing axis = block) into the two planes.

    Returns ``(plane2, plane1)`` with trailing dims n//4 and n//8 bytes."""
    return pack_plane2(codes3 & 0x3), pack_plane1((codes3 >> 2) & 0x1)


def unpack_codes(plane2: jax.Array, plane1: jax.Array) -> jax.Array:
    """Reassemble 3-bit codes from the two planes."""
    lo = unpack_plane2(plane2)
    hi = unpack_plane1(plane1)
    return (lo | (hi << 2)).astype(jnp.uint8)


# ---------------------------------------------------------------------------
# Paper Eq. (9) nibble codec — byte-faithful reference (4 bits/value storage).
# ---------------------------------------------------------------------------

def pack_nibbles_reference(codes3: jax.Array) -> jax.Array:
    """Paper Eq. (9): 8 nibbles per 32-bit word; low 2 bits = q mod 4, high
    bit = interleave selector (code bit 2). Trailing axis n % 8 == 0; output
    is uint32 words, n//8 per row."""
    n = codes3.shape[-1]
    if n % 8 != 0:
        raise ValueError("nibble pack needs trailing dim % 8 == 0")
    c = codes3.astype(jnp.uint32)
    nib = (c & 0x3) | ((c >> 2) << 3)  # bit layout: s _ b b
    nib = nib.reshape(*codes3.shape[:-1], n // 8, 8)
    word = jnp.zeros(nib.shape[:-1], dtype=jnp.uint32)
    for j in range(8):
        word = word | (nib[..., j] << (4 * j))
    return word


def unpack_nibbles_reference(words: jax.Array) -> jax.Array:
    """Inverse of :func:`pack_nibbles_reference`."""
    w = words.astype(jnp.uint32)
    nibs = [(w >> (4 * j)) & 0xF for j in range(8)]
    nib = jnp.stack(nibs, axis=-1)
    codes = (nib & 0x3) | (((nib >> 3) & 0x1) << 2)
    return codes.reshape(*words.shape[:-1], words.shape[-1] * 8).astype(jnp.uint8)
