"""Quantized linear forward — the online half of ITQ3_S.

Three execution paths, all computing ``y = x @ W_hat`` for a QTensor W_hat:

  * ``mode="dequant"``    — materialize W_hat then matmul. The reference
    path (what a naive integration would do); used as the oracle in tests
    and as the non-fused baseline in the perf log.

  * ``mode="weights"``    — paper-faithful fused path: per weight tile,
    unpack -> dequantize -> inverse-FWHT the *weights*, then matmul. On TPU
    this runs inside the Pallas kernel (kernels/itq3_matmul); the pure-JAX
    expression here is its oracle and the CPU/dry-run lowering.

  * ``mode="activations"`` — beyond-paper dual-domain path (DESIGN.md §2):
    since H is symmetric/involutory and blocks tile the reduction dim,

        y_n = sum_b  (H (d_b (q_b - z_b 1))) . x_b
            = sum_b  d_b q_b . (H x_b)  -  d_b z_b sqrt(block) * x_b[0]

    (using H 1 = sqrt(block) e_0), so we rotate each *activation* block once
    (O(K) transforms per row of x, independent of N) and contract against
    the raw ternary codes; the zero-point correction costs one multiply per
    block. For the sub-block-scale variant the elementwise scale lives in
    the rotated domain so it folds into the same contraction with no
    correction (z=0 there).

All paths are bit-identical in exact arithmetic (tested); they differ only
in where the rotation FLOPs land — the core of EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import formats as fmt_mod
from repro.core.fwht import fwht
from repro.core.quantize import QTensor, decode_values

__all__ = ["qmatmul", "QLINEAR_MODES"]

QLINEAR_MODES = ("dequant", "weights", "activations", "auto")


def _pad_last(x: jax.Array, to: int) -> jax.Array:
    pad = (-x.shape[-1]) % to
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[-1] = (0, pad)
    return jnp.pad(x, widths)


def qmatmul(
    x: jax.Array,
    qt: QTensor,
    *,
    mode: str = "activations",
    compute_dtype=jnp.bfloat16,
) -> jax.Array:
    """``x (..., K) @ W_hat (K, N) -> (..., N)`` for a quantized weight.

    Non-ternary formats (fp16/bf16/q8_0/q4_0) always take the dequant path.
    """
    m = qt.meta
    if len(m.shape) != 2:
        raise ValueError(f"qmatmul expects 2-D weights, got shape {m.shape}")
    if mode not in QLINEAR_MODES:
        raise ValueError(f"mode {mode!r} not in {QLINEAR_MODES}")
    if mode == "auto":
        # side-adaptive rotation (EXPERIMENTS §Perf): H is involutory, so the
        # transform can land on either operand — put it on the SMALLER side.
        # Decode (few rows) rotates activations; prefill/training-width
        # batches rotate the weight tiles.
        rows = 1
        for d in x.shape[:-1]:
            rows *= d
        mode = "activations" if rows <= m.n else "weights"
    ternary = m.fmt in ("iq3_s", "quip3", "itq3_s", "itq3_s_sub", "itq3_x")

    if mode == "dequant" or not ternary:
        w = fmt_mod.dequantize(qt, dtype=compute_dtype)
        return jnp.matmul(x.astype(compute_dtype), w)

    block, kb, n = m.block, m.kb, m.n
    qv = decode_values(qt.data["plane2"], qt.data["plane1"], fivelevel=m.fivelevel)
    qv = qv.astype(compute_dtype)  # (N, KB, block)

    if mode == "weights":
        # Paper path: reconstruct rotated-domain values per tile, inverse-FWHT
        # the weights, contract. (The Pallas kernel fuses exactly this.)
        if m.sub_blocks:
            d = qt.data["scales"].astype(jnp.float32)  # (N, KB, sub)
            d = jnp.repeat(d, block // m.sub_blocks, axis=-1)
            vals = d * qv.astype(jnp.float32)
        else:
            d = qt.data["scales"].astype(jnp.float32)[..., None]
            z = qt.data["zps"].astype(jnp.float32)[..., None]
            vals = d * (qv.astype(jnp.float32) - z)
        if m.rotate:
            vals = fwht(vals)
            dsign = qt.data.get("dsign")
            if dsign is not None:
                vals = vals * dsign.astype(vals.dtype)
        w = vals.reshape(n, kb * block).T.astype(compute_dtype)  # (K_pad, N)
        xp = _pad_last(x, block).astype(compute_dtype)
        return jnp.matmul(xp, w)

    # mode == "activations": rotate x blockwise once, contract vs codes.
    xp = _pad_last(x, block).astype(jnp.float32)
    *lead, kp = xp.shape
    xb = xp.reshape(*lead, kb, block)
    if m.rotate:
        dsign = qt.data.get("dsign")
        if dsign is not None:
            xb = xb * dsign.astype(xb.dtype)  # w = D H v => w.x = v.(H D x)
        xr = fwht(xb).astype(compute_dtype)  # (..., KB, block)
        # zero-point correction factor: H 1 = sqrt(block) e_0  ->  x_b[0]
        x0 = (xb[..., 0] * jnp.sqrt(jnp.float32(block))).astype(compute_dtype)
    else:
        # iq3_s no-rotation baseline: contract codes against raw x; the
        # zero-point couples to sum(x_b) instead.
        xr = xb.astype(compute_dtype)
        x0 = jnp.sum(xb, axis=-1).astype(compute_dtype)

    if m.sub_blocks:
        d = qt.data["scales"].astype(compute_dtype)  # (N, KB, sub)
        d = jnp.repeat(d, block // m.sub_blocks, axis=-1)  # (N, KB, block)
        wq = d * qv  # scale lives in rotated domain -> fold into codes
        y = jnp.einsum("...kb,nkb->...n", xr, wq)
        return y.astype(compute_dtype)

    d = qt.data["scales"].astype(compute_dtype)  # (N, KB)
    z = qt.data["zps"].astype(compute_dtype)  # (N, KB)
    # Main term: sum_b d_b * (q_b . xr_b)
    wq = d[..., None] * qv  # (N, KB, block)
    y = jnp.einsum("...kb,nkb->...n", xr, wq)
    # Zero-point correction: - sum_b d_b z_b * x0_b (see above for x0).
    corr = jnp.einsum("...k,nk->...n", x0, d * z)
    return (y - corr).astype(compute_dtype)
