"""Quantized linear forward — the online half of ITQ3_S.

:func:`qmatmul` is the ONE entrypoint for ``y = x @ W_hat`` on a QTensor.
It owns two orthogonal dispatch decisions:

**mode** — where the rotation FLOPs land (see
:meth:`repro.core.formats.TernaryFormat.contract` for the math):

  * ``"dequant"``      — materialize W_hat then matmul (oracle / baseline).
  * ``"weights"``      — paper-faithful fused path: unpack -> dequantize ->
    inverse-FWHT the *weight* tiles, then matmul.
  * ``"activations"``  — dual-domain path: rotate each activation block once
    and contract against the raw ternary codes.
  * ``"auto"``         — side-adaptive: H is involutory, so the transform can
    land on either operand — put it on the SMALLER side. Decode (few rows)
    rotates activations; prefill/training-width batches rotate weight tiles.

**backend** — which implementation runs the chosen contraction:

  * ``"ref"``     — the pure-JAX expression (``Format.contract``); CPU/GPU
    portable, and the oracle the kernels are tested against.
  * ``"pallas"``  — the fused Pallas TPU kernel (kernels/ops.py); formats
    without a fused kernel (fp16/bf16/q8_0/q4_0) and ``mode="dequant"`` fall
    back to ``"ref"`` so mixed-precision trees serve through one code path.
  * ``"auto"``    — ``"pallas"`` on real TPU hardware for fused-capable
    formats, ``"ref"`` everywhere else.

All modes are bit-identical in exact arithmetic (tested); ref and pallas
agree within kernel tolerance for every registered ternary format.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import formats as fmt_mod
from repro.core.quantize import QTensor

__all__ = ["qmatmul", "resolve_mode", "QLINEAR_MODES", "QMATMUL_BACKENDS"]

QLINEAR_MODES = ("dequant", "weights", "activations", "auto")
QMATMUL_BACKENDS = ("auto", "ref", "pallas")


def resolve_mode(x: jax.Array, m, mode: str) -> str:
    """Resolve mode="auto" side-adaptively: rotate the smaller operand."""
    if mode != "auto":
        return mode
    rows = 1
    for d in x.shape[:-1]:
        rows *= d
    return "activations" if rows <= m.n else "weights"


def qmatmul(
    x: jax.Array,
    qt: QTensor,
    *,
    mode: str = "activations",
    backend: str = "auto",
    compute_dtype=jnp.bfloat16,
    tm: int | None = None,
    tn: int | None = None,
    interpret: bool | None = None,
    act_quant: bool = False,
) -> jax.Array:
    """``x (..., K) @ W_hat (K, N) -> (..., N)`` for a quantized weight.

    ``tm``/``tn``/``interpret`` only affect the Pallas backend (tile sizes
    and interpret-mode override for CPU testing). ``tm=None``/``tn=None``
    resolve through :mod:`repro.kernels.autotune`: the cached per-device
    winner for this shape if one exists, deterministic defaults otherwise
    (always, in interpret mode). The kernel wrapper additionally dispatches
    small-M calls to the decode-shaped matvec kernel by shape.

    ``act_quant=True`` selects the W3A8 integer compute path: activations
    are rotated + int8-quantized (core/act_quant.py) and contracted against
    the int8 integer weights with int32 accumulation — no per-tile weight
    rotation at all. It is honoured only where it makes sense: fused-capable
    (ternary) formats whose :class:`~repro.core.quantize.QMeta` opts in
    (``meta.act_quant``, settable per path via QuantPolicy), and never for
    an explicit ``mode="dequant"`` oracle call. Everything else falls back
    to the float contraction, so mixed trees serve through one entrypoint
    and ``act_quant=False`` stays bit-identical to the historical streams.
    """
    m = qt.meta
    if len(m.shape) != 2:
        raise ValueError(f"qmatmul expects 2-D weights, got shape {m.shape}")
    if mode not in QLINEAR_MODES:
        raise ValueError(f"mode {mode!r} not in {QLINEAR_MODES}")
    if backend not in QMATMUL_BACKENDS:
        raise ValueError(f"backend {backend!r} not in {QMATMUL_BACKENDS}")

    spec = fmt_mod.get_format(m.fmt)
    mode = resolve_mode(x, m, mode)
    if not spec.supports_fused or mode == "dequant":
        backend = "ref"
        if not spec.supports_fused:
            mode = "dequant"  # non-ternary formats only store dense values
    elif backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"

    act = (act_quant and spec.supports_fused and m.act_quant
           and mode != "dequant")
    if backend == "pallas":
        from repro.kernels.ops import qmatmul_kernel  # lazy: core<->kernels

        return qmatmul_kernel(x, qt, mode=mode, act_quant=act, tm=tm, tn=tn,
                              interpret=interpret, out_dtype=compute_dtype)
    if act:
        return spec.contract_int8(x, qt, compute_dtype=compute_dtype)
    return spec.contract(x, qt, mode=mode, compute_dtype=compute_dtype)
