"""The paper's primary contribution: rotation-domain ternary quantization.

fwht (blocked Walsh-Hadamard), grids (optimal ternary scale theory),
packing (planar 3-bit planes, 96 B / 256 weights), quantize (Algorithm 1 +
QTensor pytree), formats (registry incl. every baseline the paper compares
against), qlinear (dequant / weight-rotation / activation-rotation / auto
execution paths).
"""
