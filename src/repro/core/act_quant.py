"""Rotation-domain activation codec — the W3A8 online half.

The weights are stored as ternary codes of the *rotated* tensor:
``W_hat = H (d (q - z))`` per 256-block. H is involutory and symmetric, so
each block contributes

    x_b . H (d (q - z))_b  =  (H x_b) . (d (q - z))_b,

i.e. rotating the *activation* block once replaces the per-tile inverse
FWHT on the weight side entirely (the same isometry the attention kernels
exploit). This module quantizes ``H x`` to int8 with one absmax scale per
row (per token), so the contraction against the ternary codes can run as
pure int8 x int8 -> int32 MACs:

    y[m, n] = s_m * sum_b d_{n,b} * ( xq[m, b] . wint[n, b] )

where ``wint = q - z`` is *exactly* representable in int8 because the
stored zero-point is integer-valued (clipped round, |z| <= 1 ternary / 2
fivelevel) — there is no separate zero-point correction term on the
integer path. The per-block weight scale ``d`` cannot be folded into the
row scale (it varies per (n, b)), so it is applied to the int32 partial of
each reduction block; ``s_m`` is applied once at flush.

Scale safety follows the kv_quant fp16 lessons even though the activation
scale stays f32: all-zero (or padding-only) rows get scale 1.0 and all-zero
codes instead of a 0/0 NaN, and the dequantization error is bounded by
``amax / (2*127)`` per element regardless of magnitude.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.fwht import blocked_fwht

__all__ = ["ACT_QMAX", "act_encode", "act_decode"]

ACT_QMAX = 127.0  # symmetric int8 grid


def act_encode(
    x: jax.Array,
    *,
    block: int = 256,
    rotate: bool = True,
    dsign: jax.Array | None = None,
    fwht_fn=None,
) -> tuple[jax.Array, jax.Array]:
    """Rotate + int8-quantize activations for the integer compute path.

    ``x`` is ``(..., K_pad)`` with K_pad a multiple of ``block`` (callers
    pad first — same contract as the kernels). Returns ``(codes, scale)``:
    int8 codes of the same shape and one f32 absmax scale per row
    ``(..., 1)``. ``dsign`` (quip3) is applied before the rotation, mirroring
    the weight-side ``W_hat = D H v`` factorization. ``fwht_fn`` lets the
    kernel wrapper substitute the Pallas blocked FWHT; the default is the
    jnp reference (bit-identical math, see core/fwht.py).
    """
    xf = x.astype(jnp.float32)
    if rotate:
        if dsign is not None:
            lead, k = xf.shape[:-1], xf.shape[-1]
            xb = xf.reshape(*lead, k // block, block) * dsign.astype(jnp.float32)
            xf = xb.reshape(*lead, k)
        fn = fwht_fn if fwht_fn is not None else blocked_fwht
        xf = fn(xf, block)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    safe = jnp.where(amax > 0, amax / ACT_QMAX, 1.0)
    codes = jnp.clip(jnp.round(xf / safe), -ACT_QMAX, ACT_QMAX).astype(jnp.int8)
    scale = jnp.where(amax > 0, amax / ACT_QMAX, 0.0).astype(jnp.float32)
    return codes, scale


def act_decode(codes: jax.Array, scale: jax.Array) -> jax.Array:
    """Rotation-domain reconstruction ``H x ~= scale * codes`` (f32). The
    round trip back to the original domain is one more (self-inverse) FWHT;
    tests verify ``ifwht(act_decode(act_encode(x)))`` against ``x``."""
    return codes.astype(jnp.float32) * scale.astype(jnp.float32)
