"""Quantization grids and the optimal ternary scale theory (paper §3.3, App. A).

Grid convention (paper Eq. 10 / §4.2): stored codes q in {0,1,2} with
zero-point z=1; reconstruction is d_k * (q - 1) in {-d_k, 0, +d_k}; encoding
is round-to-nearest (floor(x/d + 0.5)) so decision boundaries sit at
+-d_k/2.

Scale-rule discrepancy in the paper (documented in DESIGN.md / EXPERIMENTS):
the paper states alpha* = sqrt(2)*erfinv(2/3)*sigma and, repeatedly, the
number alpha* ~= 0.798*sigma. These disagree — sqrt(2)*erfinv(2/3) = 0.9674,
while 0.7979 = sqrt(2/pi) = E|x| for x~N(0,sigma=1). Moreover, for the
paper's own round-to-nearest encoder (Eq. 10) the true MSE-optimal scale is
the Lloyd-Max 3-level value alpha ~= 1.2240*sigma (threshold 0.612*sigma).
We therefore expose three scale rules:

    "paper"  -> d = 0.7979 * sigma   (the paper's stated number; faithful default)
    "erfinv" -> d = 0.9674 * sigma   (the paper's stated formula)
    "lloyd"  -> d = 1.2240 * sigma   (true optimum for Eq. 10; beyond-paper fix)

``ternary_mse`` is the closed-form MSE(alpha) oracle used by tests to verify
which rule actually minimizes error (it is "lloyd", by ~28% MSE vs "paper").

The 5-level extension grid (``itq3_x``, beyond-paper, DESIGN.md §7.6) uses
the third stored bit as a magnitude escape: levels {-2d,-d,0,+d,+2d}.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "ALPHA_PAPER",
    "ALPHA_ERFINV",
    "ALPHA_LLOYD",
    "FIVELEVEL_ALPHA",
    "SCALE_RULES",
    "scale_from_std",
    "optimal_ternary_scale",
    "ternary_quantize_codes",
    "ternary_mse",
    "fivelevel_quantize_codes",
    "fivelevel_mse",
]


def _erfinv(y: float) -> float:
    # Newton iteration on erf(x) - y = 0; for module-level constants only.
    x = 0.5
    for _ in range(80):
        err = math.erf(x) - y
        deriv = 2.0 / math.sqrt(math.pi) * math.exp(-x * x)
        x -= err / deriv
    return x


def _phi(t):
    return np.exp(-0.5 * np.asarray(t, dtype=np.float64) ** 2) / math.sqrt(2.0 * math.pi)


def _Phi(t):
    t = np.asarray(t, dtype=np.float64)
    return 0.5 * (1.0 + np.vectorize(math.erf)(t / math.sqrt(2.0)))


def ternary_mse(alpha, sigma: float = 1.0):
    """Closed-form MSE of the round-to-nearest ternary quantizer with levels
    {-a, 0, +a} (boundaries at +-a/2) for x ~ N(0, sigma^2):

        MSE(a) = sigma^2 - 4a * sigma*phi(a/2sigma) + 2a^2 * (1 - Phi(a/2sigma))
    """
    a = np.asarray(alpha, dtype=np.float64)
    s = float(sigma)
    t = a / (2.0 * s)
    return s * s - 4.0 * a * s * _phi(t) + 2.0 * a * a * (1.0 - _Phi(t))


def _optimize_scalar(fn, lo: float, hi: float, iters: int = 200) -> float:
    gr = (math.sqrt(5.0) - 1.0) / 2.0
    c = hi - gr * (hi - lo)
    d = lo + gr * (hi - lo)
    for _ in range(iters):
        if fn(c) < fn(d):
            hi = d
        else:
            lo = c
        c = hi - gr * (hi - lo)
        d = lo + gr * (hi - lo)
    return 0.5 * (lo + hi)


#: The paper's stated numeric value (Eq. 8, App. A): alpha*/sigma ~= 0.798.
ALPHA_PAPER: float = 0.7979
#: The paper's stated *formula* sqrt(2)*erfinv(2/3) (which != 0.798).
ALPHA_ERFINV: float = math.sqrt(2.0) * _erfinv(2.0 / 3.0)
#: True MSE-optimum for the paper's Eq.-10 round-to-nearest encoder
#: (Lloyd-Max 3-level for a Gaussian), solved numerically from the oracle.
ALPHA_LLOYD: float = _optimize_scalar(lambda a: float(ternary_mse(a)), 0.5, 2.5)

SCALE_RULES = {
    "paper": ALPHA_PAPER,
    "erfinv": ALPHA_ERFINV,
    "lloyd": ALPHA_LLOYD,
}


def _fivelevel_mse_scalar(a: float, sigma: float = 1.0) -> float:
    """MSE of the 5-level grid {-2a..+2a} (round-to-nearest) under
    N(0, sigma^2), by dense trapezoid (module-load one-time cost)."""
    xs = np.linspace(-8.0 * sigma, 8.0 * sigma, 100_001)
    f = _phi(xs / sigma) / sigma
    q = np.clip(np.round(xs / a), -2, 2) * a
    return float(np.trapezoid((xs - q) ** 2 * f, xs))


#: Optimal base scale (alpha/sigma) for the 5-level escape grid (~0.800).
FIVELEVEL_ALPHA: float = _optimize_scalar(_fivelevel_mse_scalar, 0.2, 1.5)


def fivelevel_mse(alpha: float, sigma: float = 1.0) -> float:
    return _fivelevel_mse_scalar(alpha, sigma)


def scale_from_std(block_std: jax.Array, rule: str = "paper") -> jax.Array:
    """d_k from the empirical std of the rotated block (Algorithm 1 line 3).

    ``rule`` selects the alpha/sigma constant; see module docstring."""
    try:
        c = SCALE_RULES[rule]
    except KeyError:
        raise ValueError(f"unknown scale rule {rule!r}; options {sorted(SCALE_RULES)}")
    return (c * block_std).astype(block_std.dtype)


# Backwards-friendly alias used throughout core/.
optimal_ternary_scale = scale_from_std


def ternary_quantize_codes(x: jax.Array, scale: jax.Array) -> jax.Array:
    """Round-to-nearest onto {-1,0,+1}*scale (paper Eq. 10); returns codes in
    {0,1,2} (zero-point z=1). ``scale`` broadcasts against ``x``."""
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(x / safe), -1, 1)
    return (q + 1).astype(jnp.uint8)


def fivelevel_quantize_codes(x: jax.Array, scale: jax.Array) -> jax.Array:
    """Round-to-nearest onto {-2..+2}*scale; returns codes in {0..4}
    (zero-point z=2). Used by the beyond-paper ``itq3_x`` format."""
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(x / safe), -2, 2)
    return (q + 2).astype(jnp.uint8)
