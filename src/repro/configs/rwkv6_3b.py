"""RWKV6 "Finch" 3B [arXiv:2404.05892; hf] — attention-free, data-dependent
decay. 32L, d_model 2560 (40 heads of 64), channel-mix d_ff 8960, vocab 65536.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=40,       # d_model / 64
    num_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    norm="layernorm",
    activation="relu2",  # rwkv channel-mix uses relu^2
    tie_embeddings=False,
)
