"""Phi-3-vision 4.2B [hf:microsoft/Phi-3-vision-128k-instruct; hf].

phi3-mini backbone (32L, d_model 3072, 32H MHA, d_ff 8192, vocab 32064)
+ CLIP vision frontend — STUBBED per assignment: input_specs() provides
precomputed patch embeddings (frontend_dim x frontend_len), projected into
the token stream by a learned linear.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    norm="rmsnorm",
    activation="swiglu",
    frontend="vision",
    frontend_dim=1024,   # CLIP-L/14 patch embedding width
    frontend_len=576,    # 24x24 patches
    tie_embeddings=False,
)
