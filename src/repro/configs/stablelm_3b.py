"""StableLM-3B [hf:stabilityai/stablelm-2-1_6b family; unverified].

32L, d_model 2560, 32H MHA, d_ff 6912, vocab 50304, LayerNorm,
partial rotary (25%).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    num_layers=32,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=6912,
    vocab_size=50304,
    norm="layernorm",
    activation="swiglu",
    rotary_pct=0.25,
    tie_embeddings=False,
)
