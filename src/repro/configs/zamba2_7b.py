"""Zamba2-7B [arXiv:2411.15242; unverified] — Mamba2 backbone + shared
attention blocks.

81 Mamba2 layers (d_model 3584, ssm_state 64, expand 2), with a single
SHARED full-attention block (32H MHA, d_ff 14336 MLP) applied every 6th
layer. vocab 32000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    norm="rmsnorm",
    activation="swiglu",
    ssm_state=64,
    ssm_expand=2,
    ssm_conv=4,
    attn_every=6,
    tie_embeddings=True,
)
