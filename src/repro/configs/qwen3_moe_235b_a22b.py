"""Qwen3-MoE 235B-A22B [hf:Qwen/Qwen3-30B-A3B family; hf].

94L, d_model 4096, 64 heads (GQA kv=4, head_dim 128), per-expert d_ff 1536,
vocab 151936, 128 experts top-8 (22B active of 235B total).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab_size=151936,
    num_experts=128,
    experts_per_token=8,
    norm="rmsnorm",
    activation="swiglu",
    rope_theta=1_000_000.0,
    tie_embeddings=False,
)
