"""Model/shape configuration system.

Each assigned architecture gets one module in this package defining
``CONFIG: ModelConfig``; the registry below resolves ``--arch <id>`` names
(dashes allowed) to configs. ``reduced()`` produces the CPU-smoke-test
version of any config (same family/topology, tiny dims).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

__all__ = ["ModelConfig", "ShapeConfig", "get_config", "reduced", "ARCH_IDS",
           "SHAPES", "runnable_cells", "mixed_precision_recipe",
           "kv_cache_bytes_per_token"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    # --- norm / act / proj details ---
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    activation: str = "swiglu"  # swiglu | gelu | relu2
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    rotary_pct: float = 1.0
    # --- ssm / hybrid ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    attn_every: int = 0  # hybrid: shared attention block period (zamba2)
    # --- enc-dec ---
    encoder_layers: int = 0
    is_encoder_decoder: bool = False
    # --- modality frontend stub ---
    frontend: Optional[str] = None  # "vision" | "audio"
    frontend_dim: int = 0  # provided patch/frame embedding width
    frontend_len: int = 0  # provided patch/frame count
    tie_embeddings: bool = True
    # --- serving ---
    eos_token_id: Optional[int] = None  # engine finishes a request on this
    #   token unless its SamplingParams sets ignore_eos (None: no EOS)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm" and self.attn_every == 0

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch serve 500k-token contexts (O(1)/O(T) decode state)?

        Per the assignment, long_500k runs only for SSM/hybrid families."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Approximate parameter count (matmul weights + embeddings)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        qo = d * self.num_heads * hd * 2
        kv = d * self.num_kv_heads * hd * 2
        attn = qo + kv
        if self.activation == "swiglu":
            mlp = 3 * d * f
        else:
            mlp = 2 * d * f
        if self.num_experts:
            mlp = self.num_experts * 3 * d * f + d * self.num_experts
        if self.family == "ssm":  # rwkv6-style block
            mlp = 2 * d * (int(3.5 * d)) if f == 0 else int(1.5 * d * f)
            attn = 6 * d * d
        per_layer = attn + mlp
        total = self.num_layers * per_layer + v * d * (1 if self.tie_embeddings else 2)
        if self.is_encoder_decoder:
            total += self.encoder_layers * per_layer
        return total


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}

ARCH_IDS = [
    "qwen3-moe-235b-a22b",
    "olmoe-1b-7b",
    "rwkv6-3b",
    "phi-3-vision-4.2b",
    "seamless-m4t-medium",
    "qwen1.5-0.5b",
    "nemotron-4-15b",
    "smollm-135m",
    "stablelm-3b",
    "zamba2-7b",
]


def _module_name(arch_id: str) -> str:
    return arch_id.replace("-", "_").replace(".", "_")


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in ARCH_IDS:
        raise ValueError(f"unknown arch {arch_id!r}; options {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_module_name(arch_id)}")
    return mod.CONFIG


def runnable_cells() -> list[tuple[str, str]]:
    """All (arch, shape) dry-run cells, honouring the assignment's skip
    rules (long_500k only for sub-quadratic families)."""
    cells = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            if shape.name == "long_500k" and not cfg.sub_quadratic:
                continue  # documented skip: full-attention arch
            cells.append((arch, shape.name))
    return cells


def mixed_precision_recipe(cfg: ModelConfig, *, head_fmt: str = "q8_0",
                           mlp_fmt: str = "itq3_s_sub",
                           rest_fmt: str = "itq3_s") -> dict:
    """Default mixed-precision serving recipe for ``cfg``, as a
    :class:`~repro.serve.quantized.QuantPolicy` dict (JSON-safe, usable from
    configs, examples, and benchmarks):

      * the LM head (quality-critical output projection) at 8-bit;
        tied-embedding models project through ``embed.T``, so the head rule
        targets the table there instead,
      * MLP/expert projections at the sub-block-scale ternary variant,
      * every other matmul projection at plain ITQ3_S,
      * router/norms/biases fp via the policy's no-match default.
    """
    from repro.serve.quantized import MATMUL_LEAVES  # leaf-name vocabulary

    head_pattern = r"(^|\.)embed$" if cfg.tie_embeddings else r"(^|\.)lm_head$"
    return {"rules": [
        {"pattern": head_pattern, "fmt": head_fmt},
        {"pattern": r"(^|\.)(gate|up|down)$", "fmt": mlp_fmt},
        {"pattern": MATMUL_LEAVES, "fmt": rest_fmt},
    ]}


def kv_cache_bytes_per_token(cfg: ModelConfig, *, kv_quant: bool = False,
                             fp_bytes: int = 2) -> int:
    """Attention KV-cache bytes per cached token position across all
    attention layers (the long-context serving cost model, and the number
    ``Runtime.kv_quant`` shrinks).

    fp layout: 2 planes (K, V) x num_kv_heads x head_dim x fp_bytes.
    Rotated-int8 layout (serve/kv_quant.py): head_dim int8 codes + one fp16
    scale per vector = head_dim + 2 bytes — ~0.52x of bf16 for the zoo's
    head dims. SSM families cache O(1) state, not per-token KV: 0."""
    if cfg.family == "ssm":
        return 0
    if cfg.family == "hybrid":
        n_attn = cfg.num_layers // cfg.attn_every + (
            1 if cfg.num_layers % cfg.attn_every else 0)
    else:
        n_attn = cfg.num_layers
    hd = cfg.resolved_head_dim
    per_vector = (hd + 2) if kv_quant else hd * fp_bytes
    return 2 * n_attn * cfg.num_kv_heads * per_vector


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests: few layers, small dims,
    few experts — topology preserved (GQA ratio, MoE top-k, hybrid period,
    enc-dec, frontends)."""
    kv_ratio = max(1, cfg.num_heads // max(cfg.num_kv_heads, 1))
    heads = 4
    return dataclasses.replace(
        cfg,
        num_layers=min(cfg.num_layers, 4 if cfg.attn_every == 0 else 7),
        d_model=128,
        num_heads=heads,
        num_kv_heads=max(1, heads // kv_ratio),
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        num_experts=min(cfg.num_experts, 8) if cfg.num_experts else 0,
        experts_per_token=min(cfg.experts_per_token, 2) if cfg.num_experts else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        attn_every=min(cfg.attn_every, 3) if cfg.attn_every else 0,
        encoder_layers=min(cfg.encoder_layers, 2),
        frontend_dim=64 if cfg.frontend else 0,
        frontend_len=8 if cfg.frontend else 0,
    )
