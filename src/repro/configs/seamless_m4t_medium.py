"""SeamlessM4T-medium [arXiv:2308.11596; hf] — encoder-decoder, multimodal.

12L encoder + 12L decoder, d_model 1024, 16H MHA, d_ff 4096, vocab 256206.
Audio frontend STUBBED per assignment: input_specs() provides precomputed
speech frame embeddings fed to the encoder.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    num_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    norm="layernorm",
    activation="gelu",
    encoder_layers=12,
    is_encoder_decoder=True,
    frontend="audio",
    frontend_dim=160,   # fbank-ish frame features
    frontend_len=1024,  # speech frames per utterance (stub)
    tie_embeddings=True,
)
