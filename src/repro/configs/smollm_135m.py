"""SmolLM-135M [hf:HuggingFaceTB/SmolLM-135M; hf] — llama-arch small.

30L, d_model 576, 9H (GQA kv=3), d_ff 1536, vocab 49152.
d_model 576 is NOT a multiple of 256 -> exercises the ITQ3_S pad-to-block
path (paper §8).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m",
    family="dense",
    num_layers=30,
    d_model=576,
    num_heads=9,
    num_kv_heads=3,
    d_ff=1536,
    vocab_size=49152,
    norm="rmsnorm",
    activation="swiglu",
    tie_embeddings=True,
)
