"""Fault-tolerance layer: heartbeats, straggler detection, elastic re-mesh.

On a real cluster this wraps the multi-controller runtime (heartbeats over
the coordination service; each host runs the same driver). The logic —
what counts as a straggler, when to declare a host dead, how to rebuild
the mesh and resume — is hardware-independent and fully tested here with
simulated clocks; ``examples/fault_tolerant_train.py`` drives an actual
train loop through failure + elastic-restart on CPU.

Policies:
  * **Straggler**: host step latency > ``straggler_factor`` x rolling median
    of the fleet -> flagged; the driver's response is configurable (log,
    or exclude at the next re-mesh — "leave the slow host behind" is the
    standard mitigation when checkpoints are cheap).
  * **Failure**: no heartbeat for ``timeout_s`` -> host declared dead ->
    ``ElasticPlan`` computes the largest viable (data, model) mesh from the
    survivors (model axis preserved — TP degree is baked into weight
    layouts; data axis shrinks), and the driver restores the latest
    committed checkpoint onto the new mesh (checkpoint/ckpt.py handles the
    resharding) and replays the data stream deterministically from the
    restored step (data/pipeline.py is keyed by step).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

__all__ = ["HeartbeatMonitor", "ElasticPlan", "plan_remesh"]


@dataclasses.dataclass
class HostState:
    last_beat: float
    last_step: int = -1
    step_times: deque = dataclasses.field(default_factory=lambda: deque(maxlen=16))


class HeartbeatMonitor:
    """Tracks per-host liveness and step latency."""

    def __init__(self, num_hosts: int, *, timeout_s: float = 60.0,
                 straggler_factor: float = 2.0, clock=time.monotonic):
        self.num_hosts = num_hosts
        self.timeout_s = timeout_s
        self.straggler_factor = straggler_factor
        self.clock = clock
        now = clock()
        self.hosts = {h: HostState(last_beat=now) for h in range(num_hosts)}
        self.excluded: set[int] = set()

    def beat(self, host: int, step: int, now: Optional[float] = None):
        now = self.clock() if now is None else now
        st = self.hosts.get(host)
        if st is None:
            # A host beating after an elastic re-mesh (rejoin, or a driver
            # monitoring a dynamic member set) must not crash the monitor:
            # auto-register it as of this beat. Its first step-latency
            # sample starts from here, like any fresh host. A stale
            # exclusion from before the re-mesh is cleared — a rejoining
            # host is alive by definition. (Hosts the driver explicitly
            # excluded and that keep beating stay excluded: only the
            # never-seen path re-admits.)
            st = self.hosts[host] = HostState(last_beat=now)
            self.num_hosts = max(self.num_hosts, len(self.hosts))
            self.excluded.discard(host)
        if st.last_step >= 0 and step > st.last_step:
            st.step_times.append((now - st.last_beat) / max(1, step - st.last_step))
        st.last_beat = now
        st.last_step = step

    def _median_step_time(self) -> Optional[float]:
        times = sorted(
            t for h, st in self.hosts.items() if h not in self.excluded
            for t in st.step_times)
        return times[len(times) // 2] if times else None

    def stragglers(self) -> list[int]:
        med = self._median_step_time()
        if med is None:
            return []
        out = []
        for h, st in self.hosts.items():
            if h in self.excluded or not st.step_times:
                continue
            mine = sorted(st.step_times)[len(st.step_times) // 2]
            if mine > self.straggler_factor * med:
                out.append(h)
        return out

    def failed(self, now: Optional[float] = None) -> list[int]:
        now = self.clock() if now is None else now
        return [h for h, st in self.hosts.items()
                if h not in self.excluded and now - st.last_beat > self.timeout_s]

    def exclude(self, hosts):
        self.excluded.update(hosts)

    def alive(self) -> list[int]:
        return [h for h in self.hosts if h not in self.excluded]


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    """A re-mesh decision after failures/exclusions."""

    data: int
    model: int
    pod: int = 1
    dropped_hosts: tuple = ()

    @property
    def devices(self) -> int:
        return self.pod * self.data * self.model


def plan_remesh(alive_devices: int, *, model: int, prefer_pods: int = 1,
                min_data: int = 1) -> Optional[ElasticPlan]:
    """Largest mesh from survivors, preserving the TP degree.

    TP (model) is baked into weight layouts, so we keep it fixed and shrink
    data (and pods, if a whole pod is unusable). Returns None if survivors
    cannot host even (min_data x model)."""
    if alive_devices < min_data * model:
        return None
    for pods in range(prefer_pods, 0, -1):
        per_pod = alive_devices // pods
        data = per_pod // model
        if data >= min_data:
            # data axes must be uniform across pods
            return ElasticPlan(data=data, model=model, pod=pods)
    return None
