"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state; callers (dryrun.py,
train.py) decide when devices are materialized. The production topology is
a TPU v5e pod of 16 x 16 = 256 chips; multi-pod doubles along a leading
"pod" axis (2 x 256 = 512 chips) reserved for DCN-tolerant data
parallelism.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

__all__ = ["make_production_mesh", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over however many (host/CPU) devices exist — used by
    tests and the local examples."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, n // data)
    return jax.make_mesh((data, model), ("data", "model"))
