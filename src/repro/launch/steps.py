"""Step builders + input specs for every (arch x shape) cell.

``build_cell(arch, shape, mesh)`` returns everything the dry-run, trainer
and server need: the jittable step function, ShapeDtypeStruct stand-ins for
its inputs (weak-type-correct, no allocation), and in/out shardings —
one coherent definition reused by launch/dryrun.py, launch/train.py and
launch/serve.py.

Cell kinds:
  train   -> train_step(TrainState, batch)            [bf16 fwd, f32 optim]
  prefill -> serve_prefill(qparams, tokens[, frames]) -> logits
  decode  -> serve_decode(qparams, tokens, cache, pos) -> (logits, cache)

Serving cells consume ITQ3_S-quantized parameter trees (the paper's
deployment path); training cells consume full-precision params. Both are
built abstractly via jax.eval_shape so a 235B config costs nothing to
stage.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig, get_config, SHAPES
from repro.models import lm
from repro.models.layers import Runtime
from repro.serve.quantized import quantize_params
from repro.sharding import rules as rules_mod
from repro.train import loop as train_loop

__all__ = ["Cell", "build_cell", "input_specs"]


def input_specs(arch: str, shape_name: str) -> tuple:
    """ShapeDtypeStruct stand-ins for every input of the (arch, shape) cell
    — weak-type-correct, shardable, no device allocation (the dry-run
    contract). For training that's (TrainState, {tokens, labels[,
    frontend]}); for prefill (qparams, batch); for decode (qparams, tokens,
    cache, pos)."""
    import jax as _jax

    mesh = _jax.sharding.Mesh(
        np.asarray(_jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    return build_cell(arch, shape_name, mesh).args_sds


@dataclasses.dataclass
class Cell:
    arch: str
    shape: ShapeConfig
    cfg: ModelConfig
    mesh: Mesh
    rules: Any
    step_fn: Any  # jittable
    args_sds: tuple  # ShapeDtypeStructs (pytrees)
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple = ()

    def lower(self):
        jitted = jax.jit(self.step_fn, in_shardings=self.in_shardings,
                         out_shardings=self.out_shardings,
                         donate_argnums=self.donate_argnums)
        with self.mesh:
            return jitted.lower(*self.args_sds)


def _named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree, is_leaf=lambda x: isinstance(x, P))


def _runtime(cfg, rules, mesh, *, quant_mode="activations") -> Runtime:
    return Runtime(compute_dtype=jnp.bfloat16, quant_mode=quant_mode,
                   use_kernel=False, attn_chunk=256, rules=rules, mesh=mesh)


def _batch_sds(cfg, shape: ShapeConfig, *, with_labels: bool):
    gb, t = shape.global_batch, shape.seq_len
    out = {"tokens": jax.ShapeDtypeStruct((gb, t), jnp.int32)}
    if with_labels:
        out["labels"] = jax.ShapeDtypeStruct((gb, t), jnp.int32)
    if cfg.frontend:
        out["frontend"] = jax.ShapeDtypeStruct(
            (gb, cfg.frontend_len, cfg.frontend_dim), jnp.bfloat16)
    return out


def _batch_axis_for(n_rows: int, rules, mesh):
    """Largest prefix of the (pod, data) batch axes that divides n_rows —
    long_500k has global_batch=1 (a single 500k-token stream), which simply
    cannot data-shard; it falls back to replicated-batch + model-parallel."""
    b = rules.assignments["batch"]
    if b is None:
        return None
    axes = b if isinstance(b, tuple) else (b,)
    keep = []
    size = 1
    for a in axes:
        if n_rows % (size * mesh.shape[a]) == 0:
            keep.append(a)
            size *= mesh.shape[a]
    if not keep:
        return None
    return tuple(keep) if len(keep) > 1 else keep[0]


def _batch_specs(batch_sds, rules, mesh):
    def spec(leaf):
        b = _batch_axis_for(leaf.shape[0], rules, mesh)
        return P(*([b] + [None] * (len(leaf.shape) - 1)))
    return jax.tree.map(spec, batch_sds)


def _cache_pspec(leaf, rules, mesh) -> P:
    """(L, B, ...) cache leaves: batch on dim 1; model on the first trailing
    dim it divides (kv heads or sequence per the adaptive rule)."""
    msize = rules.mesh.shape.get("model", 1)
    kv_ax = rules.assignments.get("kv_heads")
    seq_ax = rules.assignments.get("kv_seq")
    dims = list(leaf.shape)
    spec = [None, _batch_axis_for(dims[1], rules, mesh)] + [None] * (len(dims) - 2)
    if len(dims) >= 5:  # (L, B, KV, T, HD) attention cache
        if kv_ax and dims[2] % msize == 0:
            spec[2] = kv_ax
        elif seq_ax and dims[3] % msize == 0:
            spec[3] = seq_ax
    elif len(dims) >= 3 and msize > 1:
        for i in range(2, len(dims)):
            if dims[i] % msize == 0 and dims[i] >= msize:
                spec[i] = "model"
                break
    return P(*spec)


def build_cell(arch: str, shape_name: str, mesh: Mesh, *,
               quant_fmt: str = "itq3_s", quant_rule: str = "paper",
               quant_mode: str = "activations",
               num_micro: int = 1) -> Cell:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rules = rules_mod.make_rules(mesh, cfg)
    rt = _runtime(cfg, rules, mesh, quant_mode=quant_mode)
    key = jax.random.PRNGKey(0)

    if shape.kind == "train":
        state_sds = jax.eval_shape(
            functools.partial(train_loop.init_train_state, cfg=cfg), key)
        pspecs = rules_mod.param_pspecs(state_sds.params, cfg, rules)
        # optimizer moments share the param specs (ZeRO-sharded by construction)
        from repro.train.optim import OptState
        state_specs = train_loop.TrainState(
            params=pspecs, opt=OptState(mu=pspecs, nu=pspecs, step=P()),
            step=P())
        batch_sds = _batch_sds(cfg, shape, with_labels=True)
        batch_specs = _batch_specs(batch_sds, rules, mesh)
        step_fn = train_loop.make_train_step(cfg, rt, num_micro=num_micro)
        in_sh = (_named(mesh, state_specs), _named(mesh, batch_specs))
        out_sh = (_named(mesh, state_specs), None)
        return Cell(arch, shape, cfg, mesh, rules, step_fn,
                    (state_sds, batch_sds), in_sh, out_sh,
                    donate_argnums=(0,))

    # ---- serving cells: quantized params ----
    params_sds = jax.eval_shape(functools.partial(lm.init_params, cfg=cfg), key)
    qparams_sds = jax.eval_shape(
        functools.partial(quantize_params, fmt=quant_fmt, rule=quant_rule),
        params_sds)
    qspecs = rules_mod.param_pspecs(qparams_sds, cfg, rules)

    if shape.kind == "prefill":
        batch_sds = _batch_sds(cfg, shape, with_labels=False)
        batch_specs = _batch_specs(batch_sds, rules, mesh)

        def prefill_step(params, batch):
            # serving prefill: head over the last position only (the full
            # (B, 32k, V) logits tensor is never wanted in deployment)
            logits, _, _ = lm.forward(params, batch["tokens"], rt, cfg,
                                      frontend_feats=batch.get("frontend"),
                                      last_only=True)
            return logits

        in_sh = (_named(mesh, qspecs), _named(mesh, batch_specs))
        return Cell(arch, shape, cfg, mesh, rules, prefill_step,
                    (qparams_sds, batch_sds), in_sh, None)

    # ---- decode ----
    gb = shape.global_batch
    cache_sds = jax.eval_shape(
        functools.partial(lm.init_cache, cfg, gb, shape.seq_len,
                          dtype=jnp.bfloat16))
    cache_specs = jax.tree.map(lambda l: _cache_pspec(l, rules, mesh), cache_sds)
    tok_sds = jax.ShapeDtypeStruct((gb, 1), jnp.int32)
    pos_sds = jax.ShapeDtypeStruct((gb,), jnp.int32)

    def decode_fn(params, tokens, cache, pos):
        return lm.decode_step(params, tokens, cache, pos, rt, cfg)

    b = _batch_axis_for(gb, rules, mesh)
    in_sh = (_named(mesh, qspecs), NamedSharding(mesh, P(b, None)),
             _named(mesh, cache_specs), NamedSharding(mesh, P(b)))
    out_sh = (None, _named(mesh, cache_specs))
    return Cell(arch, shape, cfg, mesh, rules, decode_fn,
                (qparams_sds, tok_sds, cache_sds, pos_sds), in_sh, out_sh,
                donate_argnums=(2,))
