"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 200 --batch 8 --seq 256 --reduced --ckpt-dir /tmp/ckpt

Runs the full production loop on whatever devices exist (CPU for local
runs; the same driver binary works per-host on a cluster): sharded
train_step under the mesh, deterministic data pipeline, async checkpoints,
heartbeat/straggler monitor, and resume-from-latest — including *elastic*
resume onto a different mesh (see --data/--model).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import ckpt as ckpt_mod
from repro.configs.base import get_config, reduced as reduced_cfg
from repro.data.pipeline import SyntheticCorpus
from repro.ft.monitor import HeartbeatMonitor
from repro.launch.mesh import make_host_mesh
from repro.models.layers import Runtime
from repro.sharding import rules as rules_mod
from repro.train import loop as train_loop
from repro.train.optim import OptState


def build_trainer(cfg, mesh, *, num_micro=1, lr=3e-4, total_steps=1000):
    rules = rules_mod.make_rules(mesh, cfg)
    rt = Runtime(compute_dtype=jnp.float32 if jax.default_backend() == "cpu"
                 else jnp.bfloat16,
                 rules=rules, mesh=mesh, capacity_factor=2.0)
    step_fn = train_loop.make_train_step(cfg, rt, lr_peak=lr,
                                         total_steps=total_steps,
                                         num_micro=num_micro)
    pspecs = rules_mod.param_pspecs(
        jax.eval_shape(lambda k: train_loop.init_train_state(k, cfg).params,
                       jax.random.PRNGKey(0)), cfg, rules)
    state_specs = train_loop.TrainState(
        params=pspecs, opt=OptState(mu=pspecs, nu=pspecs, step=P()), step=P())
    named = jax.tree.map(lambda s: NamedSharding(mesh, s), state_specs,
                         is_leaf=lambda x: isinstance(x, P))
    batch_spec = NamedSharding(mesh, P(rules.assignments["batch"]))
    jitted = jax.jit(step_fn,
                     in_shardings=(named, jax.tree.map(lambda _: batch_spec,
                                                       {"tokens": 0, "labels": 0})),
                     out_shardings=(named, None), donate_argnums=(0,))
    return jitted, named, rules


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--micro", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--data", type=int, default=1, help="data mesh axis")
    ap.add_argument("--model", type=int, default=1, help="model mesh axis")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_cfg(cfg)
    mesh = make_host_mesh(args.data, args.model)
    print(f"arch={cfg.name} mesh={dict(mesh.shape)} devices={len(jax.devices())}")

    jitted, state_shardings, rules = build_trainer(
        cfg, mesh, num_micro=args.micro, lr=args.lr, total_steps=args.steps)

    with mesh:
        state = train_loop.init_train_state(jax.random.PRNGKey(0), cfg)
        state = jax.device_put(state, state_shardings)
        start = 0
        if args.ckpt_dir and ckpt_mod.latest_step(args.ckpt_dir) is not None:
            state, start = ckpt_mod.restore(args.ckpt_dir, state,
                                            shardings=state_shardings)
            print(f"resumed from step {start} (elastic onto {dict(mesh.shape)})")

        corpus = SyntheticCorpus(cfg.vocab_size, seed=17)
        monitor = HeartbeatMonitor(num_hosts=jax.process_count())
        t0 = time.time()
        for step in range(start, args.steps):
            batch = corpus.batch(step, args.batch, args.seq,
                                 shard=jax.process_index(),
                                 num_shards=max(jax.process_count(), 1))
            state, metrics = jitted(state, {k: jnp.asarray(v)
                                            for k, v in batch.items()})
            monitor.beat(jax.process_index(), step)
            if step % args.log_every == 0 or step == args.steps - 1:
                m = jax.tree.map(float, metrics)
                print(f"step {step:5d} loss {m['loss']:.4f} gnorm {m['gnorm']:.3f} "
                      f"lr {m['lr']:.2e} ({(time.time()-t0):.1f}s)", flush=True)
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                ckpt_mod.save_async(args.ckpt_dir, step + 1, state)
        if args.ckpt_dir:
            ckpt_mod.save(args.ckpt_dir, args.steps, state)
            ckpt_mod.wait_pending()
        if monitor.stragglers():
            print("stragglers detected:", monitor.stragglers())
    print("done.")


if __name__ == "__main__":
    main()
