"""Static analysis of compiled (SPMD-partitioned) HLO text.

``analyze_hlo`` walks the module's computation graph from ENTRY and
accumulates, per §Roofline:

  * matmul FLOPs (dot ops: 2 * prod(result_dims) * contraction_size, with
    operand shapes resolved through a per-computation symbol table — the
    optimized HLO does not annotate operand shapes inline)
  * collective bytes by kind (all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute) — operand sizes of each op
  * a coarse bytes-accessed estimate (operands + results of tensor ops)

Crucially, ``while`` bodies are multiplied by their trip count, recovered
from constants in the loop condition — XLA's built-in cost analysis counts
scan bodies once, which under-counts a 94-layer scanned transformer by
~94x. Fusion/call/map ops are charged via their called computations.

This is a *structural* profile (the dry-run substitute for a wall-clock
trace): exact for matmul FLOPs and collective bytes up to control flow we
cannot bound (dynamic trip counts default to 1 and are counted in
``dynamic_whiles``).
"""
from __future__ import annotations

import dataclasses
import re

__all__ = ["analyze_hlo", "HloStats"]

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*\(?\s*(\w+)\[([0-9,]*)\]")
_OP_RE = re.compile(r"\b([a-z][a-z0-9\-\._$]*)\(")
_CALL_RE = re.compile(r"(?:to_apply=|calls=|body=|condition=)%?([\w\.\-]+)")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# ops whose operands we charge to bytes_accessed (elementwise/copy fusions
# are charged through their fused computations instead)
#
# Pure dtype/layout ops (convert/copy/transpose/broadcast/reshape) are
# SKIPPED: on TPU they fuse into adjacent computation or are elided by
# buffer aliasing; the CPU backend materializes them (it legalizes bf16
# compute to f32 and double-buffers loop carries), which would otherwise
# swamp the memory term with backend artifacts. What remains — dots,
# slices/updates, scatters, collectives, element-wise math, reduces — is a
# close "fused TPU" HBM-traffic model, still an upper bound (element-wise
# chains count each op).
_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "while", "conditional", "call", "fusion",
               "convert", "copy", "transpose", "broadcast", "reshape",
               "iota", "reverse", "pad"}


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: dict = dataclasses.field(default_factory=dict)
    collective_counts: dict = dataclasses.field(default_factory=dict)
    dot_count: float = 0.0
    dynamic_whiles: int = 0

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    def scaled(self, k: float) -> "HloStats":
        return HloStats(
            flops=self.flops * k,
            bytes_accessed=self.bytes_accessed * k,
            collective_bytes={kk: v * k for kk, v in self.collective_bytes.items()},
            collective_counts={kk: v * k for kk, v in self.collective_counts.items()},
            dot_count=self.dot_count * k,
            dynamic_whiles=self.dynamic_whiles,
        )

    def add(self, other: "HloStats"):
        self.flops += other.flops
        self.bytes_accessed += other.bytes_accessed
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] = self.collective_bytes.get(k, 0.0) + v
        for k, v in other.collective_counts.items():
            self.collective_counts[k] = self.collective_counts.get(k, 0.0) + v
        self.dot_count += other.dot_count
        self.dynamic_whiles += other.dynamic_whiles


def _nbytes(dtype: str, dims: list[int]) -> float:
    n = 1
    for d in dims:
        n *= d
    return n * _DTYPE_BYTES.get(dtype, 0)


def _split_computations(text: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur: str | None = None
    depth = 0
    for line in text.splitlines():
        if cur is None:
            if "{" in line and "->" in line and ("(" in line):
                m = re.match(r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(", line)
                if m:
                    cur = m.group(1)
                    comps[cur] = []
                    depth = line.count("{") - line.count("}")
                    if depth <= 0:
                        cur = None
        else:
            depth += line.count("{") - line.count("}")
            comps[cur].append(line)
            if depth <= 0:
                cur = None
    return comps


def _symtab(lines: list[str]) -> dict[str, tuple[str, list[int]]]:
    tab = {}
    for line in lines:
        m = _DEF_RE.match(line)
        if m:
            dims = [int(d) for d in m.group(3).split(",")] if m.group(3) else []
            tab[m.group(1)] = (m.group(2), dims)
    return tab


def _trip_count(cond_lines: list[str]) -> int | None:
    consts = [int(m.group(1)) for line in cond_lines
              for m in re.finditer(r"constant\((\d+)\)", line)]
    return max(consts) if consts else None


def _op_bytes(op: str, operand_bytes: list[float], result_bytes: float) -> float:
    """Aliasing-aware per-op HBM traffic model.

    dynamic-update-slice / scatter write *in place* on TPU (XLA aliases the
    scan-carried buffer): traffic is the update slice (read + write), not
    the whole buffer. dynamic-slice reads only the slice it produces.
    Everything else: operands + result (unfused upper bound)."""
    if op == "dynamic-update-slice":
        upd = operand_bytes[1] if len(operand_bytes) > 1 else 0.0
        return 2.0 * upd
    if op == "scatter":
        upd = operand_bytes[-1] if operand_bytes else 0.0
        return 2.0 * upd + (operand_bytes[1] if len(operand_bytes) > 2 else 0.0)
    if op in ("dynamic-slice", "slice"):
        return 2.0 * result_bytes
    return sum(operand_bytes) + result_bytes


def _analyze_comp(name: str, comps: dict[str, list[str]],
                  cache: dict[str, HloStats]) -> HloStats:
    if name in cache:
        return cache[name]
    cache[name] = HloStats()  # cycle guard
    stats = HloStats()
    lines = comps.get(name, [])
    tab = _symtab(lines)

    for line in lines:
        s = line.strip()
        if "=" not in s or s.startswith("//"):
            continue
        mdef = _DEF_RE.match(s)
        rhs = s.split("=", 1)[1]
        opm = _OP_RE.search(rhs)
        op = opm.group(1) if opm else ""

        if op == "while":
            bm = re.search(r"body=%?([\w\.\-]+)", rhs)
            cm = re.search(r"condition=%?([\w\.\-]+)", rhs)
            trips = _trip_count(comps.get(cm.group(1), [])) if cm else None
            inner = _analyze_comp(bm.group(1), comps, cache) if bm else HloStats()
            if trips is None:
                stats.dynamic_whiles += 1
                trips = 1
            stats.add(inner.scaled(trips))
            continue

        if op == "conditional":
            branches = re.findall(
                r"(?:true_computation=|false_computation=|branch_computations=\{)"
                r"%?([\w\.\-]+)", rhs)
            if branches:
                subs = [_analyze_comp(b, comps, cache) for b in branches]
                stats.add(max(subs, key=lambda st: st.flops))
            continue

        for cname in _CALL_RE.findall(rhs):
            stats.add(_analyze_comp(cname, comps, cache))

        # operand + result bytes
        argm = re.search(r"\(([^)]*)\)", rhs)
        operand_list: list[float] = []
        lhs_shape: list[int] = []
        if argm:
            for i, ref in enumerate(_OPERAND_RE.findall(argm.group(1))):
                if ref in tab:
                    dt, dims = tab[ref]
                    operand_list.append(_nbytes(dt, dims))
                    if i == 0:
                        lhs_shape = dims
        operand_bytes = sum(operand_list)
        result_bytes = 0.0
        if mdef:
            dims = [int(d) for d in mdef.group(3).split(",")] if mdef.group(3) else []
            result_bytes = _nbytes(mdef.group(2), dims)
            result_dims = dims
        else:
            result_dims = []

        if op == "dot":
            contraction = 1
            cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rhs)
            if cm and lhs_shape:
                for d in cm.group(1).split(","):
                    if d and int(d) < len(lhs_shape):
                        contraction *= lhs_shape[int(d)]
            n = 1
            for d in result_dims:
                n *= d
            stats.flops += 2.0 * n * max(contraction, 1)
            stats.dot_count += 1
            stats.bytes_accessed += operand_bytes + result_bytes
            continue

        coll = next((c for c in COLLECTIVES
                     if op == c or op == c + "-start"), None)
        if coll:
            stats.collective_bytes[coll] = (
                stats.collective_bytes.get(coll, 0.0) + operand_bytes)
            stats.collective_counts[coll] = (
                stats.collective_counts.get(coll, 0.0) + 1)
            stats.bytes_accessed += operand_bytes + result_bytes
            continue

        if op and op not in _SKIP_BYTES:
            stats.bytes_accessed += _op_bytes(op, operand_list, result_bytes)

    cache[name] = stats
    return stats


def top_ops(text: str, k: int = 25) -> list[tuple[str, float, float]]:
    """Rank (op, total_bytes, count) across the module with while-trip
    multipliers — the dry-run profiler for the §Perf hypothesis loop.
    Groups by opcode + metadata op_name prefix when present."""
    comps = _split_computations(text)
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = re.search(r"ENTRY\s+%?([\w\.\-]+)", line)
            entry = m.group(1) if m else None
            break
    agg: dict[str, list[float]] = {}

    def visit(name: str, mult: float, seen: set):
        if name in seen or name not in comps:
            return
        seen = seen | {name}
        lines = comps[name]
        tab = _symtab(lines)
        for line in lines:
            s = line.strip()
            if "=" not in s:
                continue
            rhs = s.split("=", 1)[1]
            opm = _OP_RE.search(rhs)
            op = opm.group(1) if opm else ""
            if op == "while":
                bm = re.search(r"body=%?([\w\.\-]+)", rhs)
                cm = re.search(r"condition=%?([\w\.\-]+)", rhs)
                trips = _trip_count(comps.get(cm.group(1), [])) if cm else None
                if bm:
                    visit(bm.group(1), mult * (trips or 1), seen)
                continue
            for cname in _CALL_RE.findall(rhs):
                visit(cname, mult, seen)
            if not op or op in _SKIP_BYTES:
                continue
            mdef = _DEF_RE.match(s)
            operand_list = []
            argm = re.search(r"\(([^)]*)\)", rhs)
            if argm:
                for ref in _OPERAND_RE.findall(argm.group(1)):
                    if ref in tab:
                        operand_list.append(_nbytes(*tab[ref]))
            result_bytes = 0.0
            if mdef:
                dims = [int(d) for d in mdef.group(3).split(",")] if mdef.group(3) else []
                result_bytes = _nbytes(mdef.group(2), dims)
            nbytes = _op_bytes(op, operand_list, result_bytes)
            tag = op
            mm = re.search(r'op_name="([^"]{0,120})', s)
            if mm:
                frag = mm.group(1).split("/")
                tag = op + " @ " + "/".join(frag[-3:])
            cur = agg.setdefault(tag, [0.0, 0.0])
            cur[0] += nbytes * mult
            cur[1] += mult

    visit(entry or max(comps, key=lambda c: len(comps[c])), 1.0, set())
    ranked = sorted(((t, v[0], v[1]) for t, v in agg.items()),
                    key=lambda x: -x[1])
    return ranked[:k]


def analyze_hlo(text: str) -> HloStats:
    comps = _split_computations(text)
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = re.search(r"ENTRY\s+%?([\w\.\-]+)", line)
            if m:
                entry = m.group(1)
            break
    if entry is None or entry not in comps:
        entry = max(comps, key=lambda c: len(comps[c])) if comps else None
        if entry is None:
            return HloStats()
    return _analyze_comp(entry, comps, {})
