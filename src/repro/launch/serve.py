"""Serving launcher: quantize a model with a format or QuantPolicy and run
batched inference through the continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
        --fmt itq3_s --requests 8

Mixed-precision serving via a policy (the arch's default recipe, or any
JSON file with {"rules": [{"pattern": ..., "fmt": ...}, ...]}):

    ... --policy mixed                 # configs.base.mixed_precision_recipe
    ... --policy recipes/my_policy.json

The quantized tree can be checkpointed and served straight from disk
(packed planes + QMeta; Algorithm 1 runs once, offline):

    ... --policy mixed --save-quantized /tmp/qckpt     # quantize + save
    ... --load-quantized /tmp/qckpt                    # boot from planes

Optionally restores trained weights from a checkpoint directory (as written
by launch/train.py) before quantizing — the full offline pipeline of the
paper: train/load fp weights -> Algorithm 1 -> deploy packed planes.

Request-lifecycle serving (PR 4): per-request sampling knobs
(``--temperature/--top-k/--top-p/--sampling-seed/--stop-token``), pluggable
admission policy (``--scheduler fifo|priority|sjf``), and ``--stream`` to
print StreamEvents (finish reason, TTFT, queue wait) as requests complete
instead of waiting for the closed batch.

Failure-hardened serving (PR 7): ``--max-queue``/``--shed-policy`` bound
admission (overflow -> terminal ``rejected`` events), ``--deadline-ms``
arms per-request deadlines, ``--watchdog-timeout-s`` counts stalled decode
steps, and ``--chaos`` runs the whole thing under a seeded
``serve/faults.py`` FaultPlan (KV-scale poison + clock skip + stall) to
demo that every failure mode drains to a terminal finish reason:

    ... --reduced --kv-quant --chaos --stream --scheduler priority \
        --max-queue 4 --shed-policy shed_lowest

Speculative decoding (PR 10): ``--draft-depth N`` serves with an N-layer
self-draft (a prefix of the target sharing embedding/head weights) and a
``--num-draft-tokens``-wide propose/verify/commit window per decode step;
the run report adds acceptance rate and mean committed tokens/step:

    ... --reduced --kv-quant --draft-depth 2 --num-draft-tokens 4
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt as ckpt_mod
from repro.configs.base import get_config, mixed_precision_recipe, reduced as reduced_cfg
from repro.models import lm
from repro.models.layers import Runtime
from repro.serve.engine import Request, SamplingParams, ServeEngine
from repro.serve.quantized import (
    QuantPolicy, describe_quantized, quantize_params, quantized_bytes,
)
from repro.serve.scheduler import SCHEDULERS
from repro.train import loop as train_loop


def _load_policy(spec: str, cfg) -> QuantPolicy:
    if spec == "mixed":
        return QuantPolicy.from_dict(mixed_precision_recipe(cfg))
    with open(spec) as f:
        return QuantPolicy.from_dict(json.load(f))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--fmt", default="itq3_s")
    ap.add_argument("--rule", default="paper")
    ap.add_argument("--policy", default=None,
                    help="'mixed' or path to a QuantPolicy JSON; overrides --fmt")
    ap.add_argument("--quant-mode", default="activations",
                    choices=["activations", "weights", "dequant", "auto"])
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "ref", "pallas"])
    ap.add_argument("--ckpt-dir", default=None,
                    help="restore fp train-state weights before quantizing")
    ap.add_argument("--save-quantized", default=None,
                    help="write the quantized param tree as a checkpoint")
    ap.add_argument("--load-quantized", default=None,
                    help="serve a previously saved quantized checkpoint")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy (default); >0 samples on device")
    ap.add_argument("--top-k", type=int, default=0,
                    help="top-k filter (0 = disabled)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus (top-p) filter (1.0 = disabled)")
    ap.add_argument("--sampling-seed", type=int, default=None,
                    help="per-request PRNG seed base (request i uses seed+i); "
                         "default derives deterministic keys from rid")
    ap.add_argument("--stop-token", type=int, action="append", default=None,
                    help="stop-token id finishing a request early "
                         "(repeatable)")
    ap.add_argument("--scheduler", default="fifo", choices=sorted(SCHEDULERS),
                    help="admission policy: fifo | priority (Request."
                         "priority, demoed with rid%%3) | sjf "
                         "(shortest-prompt-first)")
    ap.add_argument("--stream", action="store_true",
                    help="print StreamEvents as tokens arrive instead of "
                         "waiting for the closed batch")
    ap.add_argument("--autotune", action="store_true",
                    help="benchmark kernel tile sizes for this model's "
                         "shapes on boot (TPU only; no-op in interpret mode)")
    ap.add_argument("--tile-m", type=int, default=None,
                    help="explicit Pallas tile override (else autotune cache)")
    ap.add_argument("--tile-n", type=int, default=None)
    ap.add_argument("--sample-on-host", action="store_true",
                    help="pre-overhaul per-slot host argmax (baseline mode)")
    ap.add_argument("--kv-quant", action="store_true",
                    help="rotated-int8 KV cache (8.25 bits/element; fused "
                         "Pallas decode attention on TPU, einsum fallback "
                         "elsewhere)")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache: block-pool allocator + per-slot "
                         "block table over the rotated-int8 planes "
                         "(requires --kv-quant; concurrency bounded by live "
                         "tokens instead of slots x max_len reservation)")
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="pool size for --paged (default: enough for every "
                         "slot to reach max_len, i.e. dense-equivalent)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="tokens per pool block for --paged")
    ap.add_argument("--act-quant", action="store_true",
                    help="W3A8 integer compute path: quantize activations "
                         "to int8 in the rotation domain and contract "
                         "against ternary codes with int32 accumulation "
                         "(QuantPolicy act_quant=False pins paths to float)")
    ap.add_argument("--mesh", default=None, metavar="DATA,MODEL",
                    help="tensor-parallel serving over a data,model device "
                         "mesh (e.g. --mesh 1,2: packed ITQ3_S planes "
                         "column-sharded and KV cache head-sharded over the "
                         "model axis; clamped to available devices)")
    ap.add_argument("--tp-shard-map", action="store_true",
                    help="force explicit shard_map over the quantized "
                         "kernels instead of GSPMD-partitioned jit (the "
                         "automatic default on real TPU, where GSPMD cannot "
                         "split a pallas_call)")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bound the waiting queue; overflow follows "
                         "--shed-policy (terminal 'rejected' events)")
    ap.add_argument("--shed-policy", default="reject",
                    choices=["reject", "shed_lowest"],
                    help="queue-overflow policy: turn the newcomer away, or "
                         "drop the lowest-priority waiting request instead")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request submit->done deadline; expired "
                         "requests finish with finish_reason='deadline'")
    ap.add_argument("--watchdog-timeout-s", type=float, default=None,
                    help="arm the decode-step watchdog: steps slower than "
                         "this are counted in stats()['stalled_steps']")
    ap.add_argument("--chaos", action="store_true",
                    help="serve under a seeded FaultPlan (KV-scale poison + "
                         "clock skip + stall): demos quarantine/deadline/"
                         "watchdog draining to terminal events")
    ap.add_argument("--chaos-seed", type=int, default=0)
    ap.add_argument("--draft-depth", type=int, default=0,
                    help="speculative decoding with a self-draft: serve "
                         "with an N-layer prefix of the target as the "
                         "draft model (0 = off). The decode tick becomes "
                         "propose/verify/commit; greedy streams stay "
                         "bit-identical to non-speculative serving")
    ap.add_argument("--num-draft-tokens", type=int, default=4,
                    help="speculative window size K: draft proposes K "
                         "tokens per slot per step, one batched target "
                         "pass verifies all K+1 positions")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_cfg(cfg)
    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_host_mesh
        d, m = (int(x) for x in args.mesh.split(","))
        mesh = make_host_mesh(d, m)
        print(f"serving mesh: {dict(mesh.shape)} "
              f"({mesh.devices.size} devices)")
    rt = Runtime(compute_dtype=jnp.float32, quant_mode=args.quant_mode,
                 backend=args.backend, autotune=args.autotune,
                 tile_m=args.tile_m, tile_n=args.tile_n,
                 kv_quant=args.kv_quant, act_quant=args.act_quant)

    if args.load_quantized:
        t0 = time.time()
        shardings = None
        if mesh is not None:
            # restore-to-sharding: each packed plane goes straight to its
            # column shard as it loads off disk
            from repro.serve import tp as tp_mod
            shardings = tp_mod.restore_shardings(cfg, mesh)
        params, step = ckpt_mod.restore_params(args.load_quantized,
                                               shardings=shardings)
        print(f"loaded quantized step-{step} tree from {args.load_quantized} "
              f"in {time.time()-t0:.1f}s ({quantized_bytes(params)/1e6:.1f}MB)")
    else:
        key = jax.random.PRNGKey(0)
        params = lm.init_params(key, cfg)
        if args.ckpt_dir:
            state = train_loop.init_train_state(key, cfg)
            state, step = ckpt_mod.restore(args.ckpt_dir, state)
            params = state.params
            print(f"restored step-{step} weights from {args.ckpt_dir}")

        fp_bytes = sum(np.prod(x.shape) * 2 for x in jax.tree.leaves(params))
        t0 = time.time()
        if args.policy:
            policy = _load_policy(args.policy, cfg)
            params = quantize_params(params, policy)
            fmts = sorted(set(describe_quantized(params).values()))
            print(f"policy quantized ({len(policy.rules)} rules -> {fmts})")
        elif args.fmt not in ("fp16", "bf16"):
            params = quantize_params(params, args.fmt, rule=args.rule)
        qb = quantized_bytes(params)
        print(f"quantized in {time.time()-t0:.1f}s: "
              f"{qb/1e6:.1f}MB vs bf16 {fp_bytes/1e6:.1f}MB "
              f"({fp_bytes/max(qb,1):.2f}x smaller)")
        if args.save_quantized:
            path = ckpt_mod.save(args.save_quantized, 0, params)
            print(f"saved quantized tree to {path}")

    faults = None
    if args.chaos:
        from repro.serve.faults import Fault, FaultPlan
        faults = FaultPlan([
            Fault("kv_nan", step=3, slot=0,
                  plane="k_scale" if args.kv_quant else "k"),
            Fault("clock_skip", step=6, dt=1.0),
            Fault("stall", step=6, dt=2.0),
        ], seed=args.chaos_seed)
        if args.watchdog_timeout_s is None:
            args.watchdog_timeout_s = 0.5
        if args.deadline_ms is None:
            args.deadline_ms = 400.0
        print(f"chaos mode: {len(faults.faults)} seeded faults armed "
              f"(seed {args.chaos_seed}, deterministic clock)")
    draft_kw = {}
    if args.draft_depth:
        from repro.serve import spec as spec_mod
        dparams, dcfg = spec_mod.draft_from_params(params, cfg,
                                                   args.draft_depth)
        draft_kw = dict(draft_params=dparams, draft_cfg=dcfg,
                        num_draft_tokens=args.num_draft_tokens)
        print(f"speculative decoding: {args.draft_depth}-layer self-draft, "
              f"K={args.num_draft_tokens} tokens/window")
    eng = ServeEngine(params, cfg, slots=args.slots, max_len=args.max_len,
                      rt=rt, temperature=args.temperature,
                      sample_on_host=args.sample_on_host,
                      scheduler=args.scheduler, mesh=mesh,
                      tp_shard_map=True if args.tp_shard_map else None,
                      max_queue=args.max_queue, shed_policy=args.shed_policy,
                      watchdog_timeout_s=args.watchdog_timeout_s,
                      faults=faults, paged=args.paged,
                      num_blocks=args.num_blocks, block_size=args.block_size,
                      **draft_kw)
    if args.kv_quant:
        print(f"kv_quant cache: {eng.cache_bytes/1e6:.1f}MB "
              f"({eng.stats()['cache_bytes_per_token']:.0f} B/token)")
    if args.paged:
        st0 = eng.stats()
        print(f"paged pool: {st0['pool_blocks']} blocks x "
              f"{st0['block_size']} tokens "
              f"({st0['cache_bytes_reserved']/1e6:.2f}MB reserved)")
    if args.act_quant:
        print("act_quant: W3A8 integer compute path "
              "(int8 rotation-domain activations, int32 accumulation)")
    if mesh is not None:
        st0 = eng.stats()
        print(f"tp cache: {st0['cache_bytes_per_device']/1e6:.2f}MB/device "
              f"x {st0['devices']} devices "
              f"(shard_map={'on' if st0['tp_shard_map'] else 'off'})")
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        sp = SamplingParams(
            temperature=args.temperature, top_k=args.top_k, top_p=args.top_p,
            seed=None if args.sampling_seed is None else args.sampling_seed + i,
            stop=tuple(args.stop_token or ()))
        reqs.append(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab_size, size=8 + i % 5),
            max_new=args.max_new, sampling=sp,
            priority=i % 3 if args.scheduler == "priority" else 0,
            deadline_ms=args.deadline_ms))
    t0 = time.time()
    if args.stream:
        for ev in eng.generate(reqs):
            if ev.finished:
                st = ev.stats or {}
                print(f"  rid={ev.rid} finished [{ev.finish_reason}] "
                      f"{st.get('tokens', 0)} tokens, "
                      f"ttft {st.get('ttft_s', float('nan'))*1e3:.0f}ms, "
                      f"queue {st.get('queue_wait_s', 0)*1e3:.0f}ms")
        done = reqs
    else:
        done = eng.run(reqs)
    dt = time.time() - t0
    total_tokens = sum(len(r.out) for r in done)
    st = eng.stats()
    print(f"served {len(done)} requests / {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s on {jax.default_backend()}, "
          f"{st['syncs_per_token']:.2f} host syncs/token, "
          f"scheduler={st['scheduler']}, "
          f"cache bytes moved {st['cache_bytes_moved']})")
    if args.draft_depth:
        print(f"speculation: acceptance {st['acceptance_rate']:.1%} "
              f"({st['draft_accepted']}/{st['draft_proposed']} drafts), "
              f"{st['tokens_per_step']:.2f} tokens/step over "
              f"{st['spec_steps']} windows")
    resil = {k: st[k] for k in ("quarantined", "deadline_expired",
                                "requests_rejected", "requests_shed",
                                "preemptions", "stalled_steps") if st.get(k)}
    if resil or args.chaos:
        from collections import Counter
        reasons = Counter(r.finish_reason for r in done)
        print(f"resilience: {resil or 'no faults fired'}; "
              f"finish reasons {dict(reasons)}")
        if faults is not None:
            print(f"fault log: {faults.log}")
    for r in done[:3]:
        print(f"  rid={r.rid} -> {r.out[:10]}")


if __name__ == "__main__":
    main()
