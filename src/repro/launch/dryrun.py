"""Multi-pod dry-run: prove every (architecture x input-shape x mesh) cell
lowers, SPMD-partitions, and compiles on the production topology — and
extract the roofline terms from the compiled artifact.

The two lines above MUST precede any jax-importing code: jax locks the
device count at first backend init, and only this entry point should see
512 placeholder devices (tests/benches see the real host).

Per cell we record into a JSON report (EXPERIMENTS.md §Dry-run reads it):
  * compile wall time, per-device HLO memory analysis (when the backend
    provides it) + analytic params/cache bytes per device,
  * cost_analysis() FLOPs and our while-aware HLO reparse (flops,
    collective bytes by kind — scan bodies multiplied by trip count),
  * the §Roofline three terms against TPU v5e constants.

Usage:
    python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
    python -m repro.launch.dryrun --all --mesh both --out reports/dryrun
"""
from __future__ import annotations

# The dry-run (and ONLY the dry-run) sees 512 placeholder devices; this must
# run before ANY other import — jax locks the device count on first init.
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import dataclasses
import json
import time
import traceback

import jax
import numpy as np

# v5e-class hardware constants (per chip) for the roofline terms.
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link (assume 1 usable link/collective)


def _analytic_param_bytes(sds_tree, spec_tree, mesh) -> float:
    """Per-device bytes for a spec'd pytree (sum leaf_bytes / shard_count)."""
    from jax.sharding import PartitionSpec as P

    leaves = jax.tree_util.tree_leaves(sds_tree)
    specs = jax.tree_util.tree_leaves(spec_tree,
                                      is_leaf=lambda x: isinstance(x, P))
    total = 0.0
    for leaf, spec in zip(leaves, specs):
        n = float(np.prod(leaf.shape)) if leaf.shape else 1.0
        nbytes = n * leaf.dtype.itemsize
        denom = 1
        if isinstance(spec, P):
            for ax in spec:
                if ax is None:
                    continue
            for ax in spec:
                for a in (ax if isinstance(ax, tuple) else (ax,)):
                    if a is not None:
                        denom *= mesh.shape[a]
        total += nbytes / denom
    return total


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             quant_mode: str = "activations", quant_rule: str = "paper",
             quant_fmt: str = "itq3_s", skip_analysis: bool = False) -> dict:
    from repro.launch.hlo_analysis import analyze_hlo
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_cell
    from repro.models.lm import model_flops

    mesh = make_production_mesh(multi_pod=multi_pod)
    nchips = int(np.prod(list(mesh.shape.values())))
    rec: dict = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.shape.values()),
        "chips": nchips, "quant_mode": quant_mode, "status": "started",
    }
    t0 = time.time()
    cell = build_cell(arch, shape_name, mesh, quant_mode=quant_mode,
                      quant_rule=quant_rule, quant_fmt=quant_fmt)
    lowered = cell.lower()
    rec["lower_s"] = round(time.time() - t0, 1)

    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 1)
    rec["status"] = "compiled"

    # --- memory ---
    try:
        ma = compiled.memory_analysis()
        rec["memory_analysis"] = {
            k: getattr(ma, k) for k in dir(ma)
            if not k.startswith("_") and isinstance(getattr(ma, k), (int, float))
        }
    except Exception as e:  # CPU backend may not implement it
        rec["memory_analysis"] = f"unavailable: {type(e).__name__}"
    rec["param_bytes_per_device"] = _analytic_param_bytes(
        cell.args_sds[0], cell.in_shardings[0] and jax.tree.map(
            lambda s: s.spec, cell.in_shardings[0],
            is_leaf=lambda x: hasattr(x, "spec")), mesh)

    # --- cost analysis (XLA) ---
    try:
        ca = compiled.cost_analysis()
        if ca:
            rec["xla_flops"] = float(ca.get("flops", 0.0))
            rec["xla_bytes"] = float(ca.get("bytes accessed", 0.0))
    except Exception:
        pass

    # --- while-aware HLO reparse ---
    if not skip_analysis:
        t2 = time.time()
        stats = analyze_hlo(compiled.as_text())
        rec["analysis_s"] = round(time.time() - t2, 1)
        rec["hlo_flops"] = stats.flops
        rec["hlo_bytes"] = stats.bytes_accessed
        rec["collective_bytes"] = stats.collective_bytes
        rec["collective_counts"] = stats.collective_counts
        rec["dynamic_whiles"] = stats.dynamic_whiles

        # --- roofline terms (per device, seconds) ---
        flops_dev = stats.flops  # HLO is already per-partition under SPMD
        bytes_dev = stats.bytes_accessed
        coll_dev = stats.total_collective_bytes
        rec["roofline"] = {
            "compute_s": flops_dev / PEAK_FLOPS,
            "memory_s": bytes_dev / HBM_BW,
            "collective_s": coll_dev / ICI_BW,
        }
        dom = max(rec["roofline"], key=rec["roofline"].get)
        rec["bottleneck"] = dom
        mf = model_flops(cell.cfg, cell.shape.seq_len, cell.shape.global_batch,
                         decode=cell.shape.is_decode)
        if cell.shape.kind == "train":
            mf *= 3.0  # fwd + bwd
        rec["model_flops_global"] = mf
        rec["model_flops_per_device"] = mf / nchips
        rec["useful_flops_frac"] = (mf / nchips) / max(stats.flops, 1.0)
    rec["status"] = "ok"
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--quant-mode", default="activations",
                    choices=["activations", "weights", "dequant", "auto"])
    ap.add_argument("--quant-rule", default="paper")
    ap.add_argument("--quant-fmt", default="itq3_s")
    ap.add_argument("--out", default="reports/dryrun")
    ap.add_argument("--skip-analysis", action="store_true")
    args = ap.parse_args()

    from repro.configs.base import runnable_cells

    if args.all:
        cells = runnable_cells()
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    for arch, shape in cells:
        for multi in meshes:
            tag = f"{arch}_{shape}_{'multi' if multi else 'single'}_{args.quant_mode}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print(f"[skip] {tag} (exists)")
                continue
            print(f"[run ] {tag}", flush=True)
            try:
                rec = run_cell(arch, shape, multi, quant_mode=args.quant_mode,
                               quant_rule=args.quant_rule,
                               quant_fmt=args.quant_fmt,
                               skip_analysis=args.skip_analysis)
            except Exception as e:
                rec = {"arch": arch, "shape": shape,
                       "mesh": "multi" if multi else "single",
                       "status": "error", "error": f"{type(e).__name__}: {e}",
                       "trace": traceback.format_exc()[-2000:]}
            with open(path, "w") as f:
                json.dump(rec, f, indent=2, default=float)
            print(f"       -> {rec['status']}"
                  + (f" compile={rec.get('compile_s')}s"
                     f" bottleneck={rec.get('bottleneck')}" if rec["status"] == "ok" else
                     f" {rec.get('error', '')[:200]}"), flush=True)


if __name__ == "__main__":
    main()
