"""Training step: loss, remat, microbatching, optimizer — pjit-ready.

``make_train_step(cfg, rules, ...)`` returns a pure function
``train_step(state, batch) -> (state, metrics)`` suitable for
``jax.jit(..., in_shardings=..., out_shardings=...)`` on any mesh. The
layer stack is rematerialized (configurable policy) and the vocab-sharded
cross-entropy uses a stable logsumexp whose reductions the SPMD partitioner
turns into model-axis collectives.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.layers import Runtime
from repro.train import optim
from repro.train.grad import accumulate_grads

__all__ = ["TrainState", "make_train_step", "init_train_state", "softmax_xent"]


@dataclasses.dataclass
class TrainState:
    params: Any
    opt: optim.OptState
    step: jax.Array


jax.tree_util.register_dataclass(TrainState, data_fields=["params", "opt", "step"],
                                 meta_fields=[])


def init_train_state(key, cfg) -> TrainState:
    params = lm.init_params(key, cfg)
    return TrainState(params=params, opt=optim.adamw_init(params),
                      step=jnp.zeros((), jnp.int32))


def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token cross-entropy; logits may be vocab-sharded (logsumexp
    reductions become model-axis all-reduces under SPMD)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll)


def make_train_step(
    cfg,
    rt: Runtime,
    *,
    lr_peak: float = 3e-4,
    warmup: int = 100,
    total_steps: int = 10_000,
    num_micro: int = 1,
    aux_weight: float = 0.01,
    remat: bool = True,
    remat_policy: Optional[str] = "dots",
):
    """Build the jittable train step for one architecture."""

    # remat is applied PER LAYER inside the scan bodies (lm._maybe_remat):
    # backward re-runs each layer, so 32k-context attention internals are
    # never all live — the flash-attention memory discipline.
    rt = dataclasses.replace(rt, remat=remat,
                             remat_policy=remat_policy or "none")

    def loss_fn(params, batch):
        loss, aux = lm.forward_xent(params, batch["tokens"], batch["labels"],
                                    rt, cfg,
                                    frontend_feats=batch.get("frontend"))
        return loss + aux_weight * aux, aux

    def train_step(state: TrainState, batch):
        lr = optim.cosine_lr(state.step, peak=lr_peak, warmup=warmup,
                             total=total_steps)
        if num_micro > 1:
            mb = jax.tree.map(
                lambda x: x.reshape(num_micro, x.shape[0] // num_micro, *x.shape[1:]),
                batch)
            loss, grads, aux = accumulate_grads(loss_fn, state.params, mb,
                                                num_micro=num_micro)
        else:
            (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                state.params, batch)
        new_params, new_opt, gnorm = optim.adamw_update(
            grads, state.opt, state.params, lr)
        metrics = {"loss": loss, "gnorm": gnorm, "lr": lr, "moe_aux": aux}
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step
