"""AdamW optimizer, pure JAX, ZeRO-sharded by construction.

Optimizer moments inherit the parameter partition specs — with FSDP rules
active, params (and thus mu/nu) are stored sharded over the `data` axis, so
the optimizer is ZeRO-1/3 automatically: no replicated f32 state anywhere.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["OptState", "adamw_init", "adamw_update", "cosine_lr"]


@dataclasses.dataclass
class OptState:
    mu: Any
    nu: Any
    step: jax.Array


jax.tree_util.register_dataclass(OptState, data_fields=["mu", "nu", "step"], meta_fields=[])


def adamw_init(params) -> OptState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return OptState(
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
        step=jnp.zeros((), jnp.int32),
    )


def adamw_update(
    grads,
    state: OptState,
    params,
    lr: jax.Array | float,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: float = 1.0,
):
    """Returns (new_params, new_state, grad_norm)."""
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / c1
        vh = v / c2
        new_p = p.astype(jnp.float32) - lr * (mh / (jnp.sqrt(vh) + eps)
                                              + weight_decay * p.astype(jnp.float32))
        return new_p.astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, OptState(new_mu, new_nu, step), gnorm


def cosine_lr(step, *, peak: float, warmup: int, total: int, floor: float = 0.1):
    s = step.astype(jnp.float32)
    warm = peak * s / max(warmup, 1)
    prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = floor * peak + (1 - floor) * peak * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(s < warmup, warm, cos)
