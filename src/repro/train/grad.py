"""Distributed-gradient machinery: microbatch accumulation with overlapped
reduction, and int8 gradient compression with error feedback for the
pod-crossing (DCN) all-reduce.

Under pjit, intra-pod gradient averaging is implicit (SPMD inserts
reduce-scatters against the FSDP/ZeRO sharding). What we add here:

  * ``accumulate_grads`` — lax.scan over microbatches; each microbatch's
    backward finishes with its partial gradients already laid out in the
    sharded spec, so the per-microbatch reduce-scatter overlaps the next
    microbatch's compute under XLA's async collectives.
  * ``compressed_pod_allreduce`` — explicit shard_map over the ``pod`` axis:
    1-byte quantized gradient exchange with error-feedback buffers
    (e_{t+1} = x - Q(x); the quantization residual is replayed into the
    next step), cutting DCN bytes 4x vs f32 with no convergence penalty at
    pod counts this small.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["accumulate_grads", "compressed_pod_allreduce", "zeros_error_buf"]


def accumulate_grads(loss_fn, params, batches, *, num_micro: int):
    """batches: pytree with leading [num_micro, ...] axis. Returns
    (mean_loss, mean_grads, aux_mean)."""
    def one(carry, mb):
        (loss, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        gsum, lsum, asum = carry
        gsum = jax.tree.map(jnp.add, gsum, g)
        return (gsum, lsum + loss, asum + aux), None

    gz = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (gsum, lsum, asum), _ = jax.lax.scan(
        one, (gz, jnp.zeros(()), jnp.zeros(())), batches, length=num_micro)
    inv = 1.0 / num_micro
    return lsum * inv, jax.tree.map(lambda g: g * inv, gsum), asum * inv


def zeros_error_buf(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_pod_allreduce(grads, error_buf, mesh, *, axis: str = "pod"):
    """int8 + error-feedback all-reduce over the 'pod' mesh axis.

    Contract: every leaf carries a LEADING pod axis — ``grads[leaf]`` is
    (npod, ...) holding each pod's partial (intra-pod-reduced) gradient;
    this is how the manual-DP driver stages the DCN exchange. Each pod
    quantizes (g + e) to int8 against a pod-shared absmax scale, psums the
    1-byte payload (4x fewer DCN bytes than f32), and keeps its local
    residual for the next step (error feedback: the quantization error is
    replayed, so the time-averaged update is unbiased).

    Returns (reduced_mean with the same leading axis (identical across
    pods), new_error_buf)."""
    if axis not in mesh.shape or mesh.shape[axis] == 1:
        return grads, error_buf
    npod = mesh.shape[axis]

    def leaf_reduce(g, e):
        x = g.astype(jnp.float32) + e  # (1, ...) local slice
        amax = jax.lax.pmax(jnp.max(jnp.abs(x)), axis)
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(x / scale), -127, 127)
        new_e = x - q * scale  # local residual (error feedback)
        tot = jax.lax.psum(q.astype(jnp.int32), axis).astype(jnp.float32)
        return (tot * scale / npod).astype(g.dtype), new_e

    def body(gs, es):
        out = jax.tree.map(leaf_reduce, gs, es)
        new_g = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_e = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_g, new_e

    from jax.experimental.shard_map import shard_map

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(axis), P(axis)), out_specs=(P(axis), P(axis)),
                   check_rep=False)
    return fn(grads, error_buf)
