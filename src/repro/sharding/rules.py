"""Logical-axis sharding rules: DP / TP / EP / SP / FSDP over the
(pod, data, model) production mesh.

Strategy (1000-node posture, DESIGN.md §5):

  * ``pod``   — pure data parallelism. Only gradient/weight-reduction
    collectives cross pods (DCN-tolerant); TP/EP stay intra-pod.
  * ``data``  — data parallelism + FSDP/ZeRO weight sharding (params are
    stored sharded over `data` and all-gathered at use; optimizer states
    stay sharded — ZeRO-1/3 hybrid).
  * ``model`` — tensor parallelism (heads / ffn / vocab / experts) chosen
    *adaptively per architecture*: a logical dim is model-sharded only when
    divisible by the mesh axis; GQA KV heads that don't divide fall back to
    sequence-sharded KV (flash-decode style — softmax reductions over the
    sharded length are handled by the SPMD partitioner).

``Rules`` resolves logical names to mesh axes once per (config, mesh);
``constrain`` applies with_sharding_constraint, silently dropping axes that
don't divide (so the same model code runs on 1-device CPU and 512-way pods).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["Rules", "make_rules", "param_pspecs", "batch_pspec"]


@dataclasses.dataclass(frozen=True)
class Rules:
    mesh: Mesh
    assignments: dict  # logical name -> mesh axis | tuple | None

    def axis_for(self, name: Optional[str]):
        if name is None:
            return None
        return self.assignments.get(name)

    def spec(self, names: tuple) -> P:
        return P(*[self.axis_for(n) for n in names])

    def constrain(self, x: jax.Array, names: tuple, mesh=None) -> jax.Array:
        mesh = mesh or self.mesh
        axes = []
        used: set = set()
        for dim, n in enumerate(names):
            ax = self.axis_for(n)
            if ax is None:
                axes.append(None)
                continue
            ax_tuple = ax if isinstance(ax, tuple) else (ax,)
            if any(a in used for a in ax_tuple):
                axes.append(None)  # a mesh axis can shard only one dim
                continue
            size = int(np.prod([mesh.shape[a] for a in ax_tuple]))
            if dim < x.ndim and x.shape[dim] % size == 0 and x.shape[dim] > 0:
                axes.append(ax)
                used.update(ax_tuple)
            else:
                axes.append(None)
        while len(axes) < x.ndim:
            axes.append(None)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*axes[: x.ndim])))


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def make_rules(mesh: Mesh, cfg, *, fsdp: bool = True) -> Rules:
    """Resolve logical axes for one (arch, mesh)."""
    axes = dict(mesh.shape)
    model = "model" if "model" in axes else None
    msize = axes.get("model", 1)
    batch_axes = tuple(a for a in ("pod", "data") if a in axes) or None
    if batch_axes and len(batch_axes) == 1:
        batch_axes = batch_axes[0]

    kv_ok = _div(cfg.num_kv_heads, msize)
    assignments = {
        "batch": batch_axes,
        "seq": None,  # SP applied selectively via "seq_sp"
        "seq_sp": model,
        "ffn": model if _div(cfg.d_ff, msize) else None,
        "heads": model if _div(cfg.num_heads * cfg.resolved_head_dim, msize) else None,
        "kv_heads": model if kv_ok else None,
        # flash-decode fallback: shard the KV length when heads can't shard
        "kv_seq": None if kv_ok else model,
        "experts": model if _div(cfg.num_experts, msize) else None,
        "vocab": model if _div(cfg.vocab_size, msize) else None,
        "embed": model if _div(cfg.d_model, msize) else None,
        "fsdp": "data" if (fsdp and "data" in axes) else None,
    }
    return Rules(mesh=mesh, assignments=assignments)


# ---------------------------------------------------------------------------
# Parameter partition specs (walk the param tree by path)
# ---------------------------------------------------------------------------

_COL = re.compile(r"(wq|wk|wv|wg|wr|gate|up|wz|wx|lm_head|frontend_proj|w_lora_a)$")
_ROW = re.compile(r"(wo|down|out_proj|cm_v|w_lora_b)$")
_REPL = re.compile(r"(scale|bias|mu|cm_mu|A_log|dt_bias|conv_\w+|router|w_base|u|D)$")


def _leaf_spec(path: str, shape: tuple, rules: Rules, msize: int, dsize: int,
               stacked: int) -> P:
    """Spec for one parameter leaf. ``stacked`` = number of leading stacked
    layer dims (never sharded)."""
    lead = [None] * stacked
    dims = shape[stacked:]
    model = rules.assignments.get("heads") and "model"  # mesh has model axis?
    model = "model" if rules.mesh.shape.get("model", 1) > 1 else None
    fsdp = rules.assignments.get("fsdp")

    def div(d, k):
        return k > 1 and d % k == 0

    name = path.split("/")[-1]
    if len(dims) == 0:
        return P(*lead) if lead else P()

    if _REPL.search(name) and "embed" not in path:
        return P(*(lead + [None] * len(dims)))

    if name == "embed":  # (V, D): fsdp on vocab rows, TP on embed dim
        spec = [fsdp if div(dims[0], dsize) else None,
                model if div(dims[1], msize) else None]
        return P(*(lead + spec))

    if "moe" in path and name in ("gate", "up", "down"):
        # (E, K, N): experts over model (EP); fsdp the K dim
        e, k, n = dims
        return P(*(lead + [model if div(e, msize) else None,
                           fsdp if div(k, dsize) else None, None]))

    if _COL.search(name) and len(dims) == 2:
        k, n = dims
        return P(*(lead + [fsdp if div(k, dsize) else None,
                           model if div(n, msize) else None]))
    if _ROW.search(name) and len(dims) == 2:
        k, n = dims
        return P(*(lead + [model if div(k, msize) else None,
                           fsdp if div(n, dsize) else None]))
    # default: fsdp the largest divisible dim
    spec = [None] * len(dims)
    order = sorted(range(len(dims)), key=lambda i: -dims[i])
    for i in order:
        if div(dims[i], dsize):
            spec[i] = fsdp
            break
    return P(*(lead + spec))


def _stack_depth(path_parts: tuple) -> int:
    """Leading stacked dims: 1 for layer stacks, 2 for hybrid macroblocks."""
    parts = [getattr(p, "key", getattr(p, "name", str(p))) for p in path_parts]
    if "mamba_blocks" in parts:
        return 2
    for tag in ("layers", "encoder", "mamba_tail"):
        if tag in parts:
            return 1
    return 0


_QDATA = {"plane2", "plane1", "scales", "zps", "q", "w", "dsign"}


def _qtensor_leaf_spec(path: str, name: str, shape: tuple, rules: Rules,
                       msize: int, stacked: int) -> P:
    """Specs for packed QTensor data leaves (serving).

    plane2/plane1 are (..., N, KB, bytes); scales/zps (..., N, KB[, sub]).
    The output-feature dim N is the TP dim (matches the matmul's
    model-sharded output); the packed reduction stream is replicated —
    3.125 bpw makes that cheap, and it keeps decode free of weight
    all-gathers. MoE expert stacks shard the expert dim instead (EP)."""
    if name == "dsign":
        return P(*([None] * len(shape)))
    lead = [None] * stacked
    dims = list(shape[stacked:])
    model = "model" if msize > 1 else None
    spec = [None] * len(dims)
    if "moe" in path and stacked >= 1:
        # expert dim sits right after the layer stack: (L, E, ...)
        lead2 = [None] * (stacked - 1)
        edim = shape[stacked - 1] if stacked >= 1 else 0
        # re-derive: leaf = (L, E, N, ...); stacked counted only the L dim
        if len(dims) >= 1 and model and shape[stacked] % msize == 0:
            spec[0] = model  # E over model (EP)
        return P(*(lead + spec))
    if model and len(dims) >= 1 and dims[0] % msize == 0:
        spec[0] = model  # N over model
    return P(*(lead + spec))


_RWKV_TMIX = {"wr", "wk", "wv", "wg", "wo"}


def param_pspecs(params, cfg, rules: Rules):
    """PartitionSpec pytree matching ``params`` (arrays or QTensor leaves)."""
    msize = rules.mesh.shape.get("model", 1)
    dsize = rules.mesh.shape.get("data", 1)

    def spec_of(path_parts, leaf):
        parts = [getattr(p, "key", getattr(p, "name", str(p))) for p in path_parts]
        path = "/".join(str(p) for p in parts)
        stacked = _stack_depth(path_parts)
        if not hasattr(leaf, "shape"):
            return P()
        name = parts[-1]
        if "data" in parts and name in _QDATA:
            return _qtensor_leaf_spec(path, name, tuple(leaf.shape), rules,
                                      msize, stacked)
        # NB (perf log C3, refuted): replicating the RWKV time-mix
        # projections (to avoid the SPMD involuntary-remat reshard at the
        # (B,T,2560)->(B,T,40,64) head split) costs 16x per-device matmul +
        # elementwise work — strictly worse. The real fix is padding
        # 40 heads -> 48 so heads tile the model axis (future work); until
        # then TP + reshard wins.
        return _leaf_spec(path, tuple(leaf.shape), rules, msize, dsize, stacked)

    return jax.tree_util.tree_map_with_path(spec_of, params)


def batch_pspec(rules: Rules) -> P:
    return P(rules.assignments["batch"])
