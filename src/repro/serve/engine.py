"""Serving engine: a typed request lifecycle over batched prefill/decode
with continuous batching.

``ServeEngine`` owns a fixed slot-batched KV cache (B slots x max_len) and
admits requests continuously: free slots are prefilled with new prompts
(left-aligned, their own position counters) while other slots keep decoding
— the standard continuous-batching discipline (vLLM-style, static slots
instead of paged blocks; pages are unnecessary when max_len is fixed per
deployment, and static layouts are what TPU SPMD wants).

The request lifecycle (this module's public surface):

* :class:`Request` carries a prompt plus :class:`SamplingParams`
  (temperature/top-k/top-p, per-request PRNG seed, stop tokens, output
  budget) and a ``priority`` for the scheduler.
* A pluggable :class:`~repro.serve.scheduler.Scheduler` owns the waiting
  queue; the engine asks it for admission waves whenever slots free up.
* :meth:`ServeEngine.generate` streams :class:`StreamEvent`s — one per
  emitted token, terminal events carrying the finish reason (``stop`` /
  ``length`` / ``cancelled``) and lifecycle stats (queue wait, TTFT,
  decode tok/s). :meth:`ServeEngine.cancel` evicts a live slot or a queued
  request mid-stream.
* :meth:`ServeEngine.run` remains as a thin closed-batch shim over
  ``generate`` (the benchmarks' token-parity baseline).

Hot-path discipline (the decode loop is the product):

* **One device->host transfer per step.** Sampling runs inside the jitted
  ``decode`` under PER-SLOT device vectors (temperature/top-k/top-p and a
  (slots, 2) batch of PRNG keys), so heterogeneous requests — greedy next
  to nucleus-sampled — batch in one compiled step; ``step()`` fetches a
  single (slots,) int32 vector. Each slot's key is its request's own
  (derived from the request seed, folded with the request-local token
  index), making batched streams bit-identical to running each request
  alone. An all-greedy batch drops to a PRNG-free argmax trace.
  ``sample_on_host=True`` restores the pre-overhaul per-slot host argmax —
  kept as the measured baseline for benchmarks/serve_bench.py.
  ``host_syncs`` counts every transfer either way.
* **Donated cache buffers.** The jitted prefill/decode donate the cache
  operand (``donate_argnums``), so XLA writes the new cache in place
  instead of functionally copying ~cache_bytes every step;
  ``cache_bytes_moved`` counts any step where donation did NOT engage
  (asserted zero in benchmarks/serve_bench.py).
* **One compiled call per admission wave.** All free slots are admitted
  together: prompts are padded to one shared ``prompt_pad`` bucket and
  prefilled in a single jitted call that also ZEROES the admitted slots'
  cache/state (no separate reset pass) and samples each prompt's first
  token from its true last-real-token logits.
* **Bounded compile shapes for recurrent archs.** SSM/hybrid states
  integrate every fed token, so pad tokens would pollute them; instead of
  compiling one prefill per exact prompt length, prompts are fed in a
  power-of-two chunk ladder (``prompt_chunk``, then halves) with state
  threaded between calls — at most log2(prompt_chunk)+1 compiled shapes
  ever, regardless of traffic.

Resilience layer (every failure mode ends in a terminal StreamEvent with a
specific ``finish_reason`` — never a hang, a crash, or a corrupted
neighbor stream):

* **Deadlines.** ``Request.deadline_ms`` (submit -> done wall budget) and
  ``Request.decode_timeout_ms`` (first token -> done) are enforced in
  ``_tick`` against an injectable ``clock``: queued requests past deadline
  are shed at pop time, live slots finish with ``finish_reason="deadline"``
  before decoding another token.
* **Backpressure.** ``max_queue`` bounds the waiting queue. Overflow
  follows ``shed_policy``: ``"reject"`` turns the newcomer away
  (``submit_request`` returns False, terminal ``"rejected"`` event);
  ``"shed_lowest"`` drops the lowest-priority waiting request instead —
  unless the newcomer IS lowest, in which case it is rejected itself.
* **Numeric quarantine.** The jitted decode folds a per-slot finiteness
  check over the logits into the step and encodes failure as a ``-1``
  sentinel in the token vector — riding the step's single device->host
  transfer, so the 1 host sync/step discipline is preserved. A poisoned
  slot (inf/NaN logits — e.g. a degenerate KV scale plane) finishes with
  ``finish_reason="error"`` and its cache rows are re-zeroed; healthy
  slots' streams are bit-identical to a fault-free run (their rows pass
  through the check untouched; batch rows are independent).
* **Mid-flight preemption + swap/resume.** :meth:`preempt` extracts a
  live slot's cache rows (``_take_slots`` -> host copy) plus its stream
  state into a swap pool and requeues the request with the scheduler; on
  re-admission the rows are scattered back (``_put_slots``) and decoding
  continues bit-identically — no re-prefill. Schedulers may drive this via
  the optional ``should_preempt`` hook (PriorityScheduler evicts the
  lowest-priority live request when strictly higher-priority work waits).
* **Watchdog.** ``watchdog_timeout_s`` arms an ``ft.monitor``-based
  heartbeat over decode steps: a step whose wall gap exceeds the timeout
  is counted in ``stats()["stalled_steps"]`` (the training watchdog policy
  reused for serving).
* **Fault injection.** ``faults=`` accepts a ``serve/faults.py``
  :class:`FaultPlan`; the engine calls its ``before_decode`` hook each
  step, and adopts its deterministic clock when no explicit ``clock`` is
  given — every policy above is exercised by seeded, reproducible tests
  and ``launch/serve.py --chaos``.

Speculative decoding (``draft_params``/``draft_cfg``/``num_draft_tokens``):
the one-token decode tick generalizes to a **propose/verify/commit**
window. A cheap draft model (often a layer-sliced prefix of the target —
``serve/spec.py:draft_from_params``) decodes K candidates per slot from
its own dense KV cache; ONE batched ``lm.score_tokens`` pass runs the
target over all K+1 window positions (under ``kv_quant`` that is one fused
``prefill_attn_q8`` q-tile call against the rotated-int8 cache — dense or
paged); ``spec.verify_commit`` decides the accepted prefix + one
window-end token per slot on device. Every hot-path invariant survives
with "1 token/slot/step" generalized to "1..K+1 tokens/slot/window": ONE
device->host transfer moves the (S, K+1) token window + commit counts,
both caches donate in place, quarantine rides the same ``_POISONED``
sentinel, deadlines/cancel/preempt land at window boundaries, and paged
slots pre-extend their block chains by the window lookahead
(``paged.blocks_needed``). Greedy streams are bitwise identical to the
non-speculative engine; sampled streams follow Leviathan-style rejection
sampling under tagged per-request PRNG streams (``draft_tokens=0`` /
``draft=False`` slots stay bit-identical too — they ride the same window
machinery with kvec=0). SSM/hybrid targets are rejected: rolling back a
rejected window needs positional cache indexing, which recurrent state
lacks (ROADMAP leftover).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Iterable, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models.layers import Runtime
from repro.serve.sampling import (
    FINISH_CANCELLED, FINISH_DEADLINE, FINISH_ERROR, FINISH_LENGTH,
    FINISH_REJECTED, FINISH_STOP, SamplingParams, StreamEvent,
)
from repro.serve.scheduler import Scheduler, get_scheduler

__all__ = ["Request", "ServeEngine", "SamplingParams", "StreamEvent"]

# In-band numeric-health sentinel: the jitted decode replaces a poisoned
# slot's sampled token with this (token ids are always >= 0), so quarantine
# detection rides the step's one device->host token transfer.
_POISONED = -1


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (L,) int32
    max_new: int = 32  # output budget (SamplingParams.max_new overrides)
    sampling: Optional[SamplingParams] = None  # None -> engine default
    priority: int = 0  # PriorityScheduler: higher admits first
    # --- SLO knobs (None disables; both measured on the engine clock) ---
    deadline_ms: Optional[float] = None  # submit -> done wall budget;
    #   queued requests past it are shed at pop time, live ones finish
    #   with finish_reason="deadline" before decoding another token
    decode_timeout_ms: Optional[float] = None  # first token -> done budget
    #   (covers time spent swapped out by preemption, by design: the SLO
    #   is the caller's wall clock, not the slot's)
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    finish_reason: Optional[str] = None
    preemptions: int = 0  # times this request was swapped out mid-flight
    # --- speculative-decoding accounting (filled by the engine) ---
    drafted: int = 0       # draft tokens proposed on this request's behalf
    accepted: int = 0      # of those, tokens the verifier committed
    spec_windows: int = 0  # propose/verify/commit windows executed
    # --- lifecycle stamps (perf_counter seconds, filled by the engine) ---
    t_submit: Optional[float] = None
    t_admit: Optional[float] = None
    t_first: Optional[float] = None
    t_done: Optional[float] = None

    def stats(self) -> dict:
        """Lifecycle stats (present on the terminal StreamEvent)."""
        n = len(self.out)
        out: dict = {"tokens": n, "finish_reason": self.finish_reason}
        if self.t_submit is not None and self.t_admit is not None:
            out["queue_wait_s"] = self.t_admit - self.t_submit
        if self.t_submit is not None and self.t_first is not None:
            out["ttft_s"] = self.t_first - self.t_submit
        if self.t_first is not None and self.t_done is not None and n > 1:
            dt = self.t_done - self.t_first
            out["decode_tok_s"] = (n - 1) / dt if dt > 0 else float("inf")
        if self.preemptions:
            out["preemptions"] = self.preemptions
        if self.drafted:
            out["draft_proposed"] = self.drafted
            out["draft_accepted"] = self.accepted
            out["acceptance_rate"] = self.accepted / self.drafted
        return out


class ServeEngine:
    def __init__(self, params, cfg, *, slots: int = 4, max_len: int = 256,
                 rt: Optional[Runtime] = None, prompt_pad: int = 64,
                 prompt_chunk: int = 16, temperature: float = 0.0,
                 seed: int = 0, sample_on_host: bool = False,
                 cache_dtype=jnp.float32,
                 sampling: Optional[SamplingParams] = None,
                 scheduler: "str | Scheduler | None" = None,
                 eos_id: Optional[int] = None,
                 mesh=None, tp_shard_map: Optional[bool] = None,
                 clock=None, max_queue: Optional[int] = None,
                 shed_policy: str = "reject",
                 watchdog_timeout_s: Optional[float] = None,
                 faults=None, paged: bool = False,
                 num_blocks: Optional[int] = None, block_size: int = 16,
                 draft_params=None, draft_cfg=None,
                 draft_rt: Optional[Runtime] = None,
                 num_draft_tokens: int = 4):
        self.cfg = cfg
        self.rt = rt or Runtime(compute_dtype=jnp.float32)
        self.mesh = mesh
        if mesh is not None and mesh.shape.get("data", 1) > 1:
            # the serving layout head-shards the KV planes over "model" and
            # keeps the slot batch whole on every device — nothing below
            # partitions over "data", so a multi-way data axis would place
            # every "replicated" leaf wrong silently. Name the limitation
            # instead (ROADMAP: data-parallel serving is future work).
            raise ValueError(
                f"ServeEngine assumes a serving mesh with a trivial 'data' "
                f"axis (data=1); got data={mesh.shape['data']}. The slot "
                f"batch is not data-sharded — reshape the mesh so all "
                f"devices sit on the 'model' axis for tensor-parallel "
                f"serving.")
        if mesh is not None:
            # Tensor-parallel serving (serve/tp.py): derive the serving
            # Rules, place the packed planes column-sharded (and fp leaves
            # replicated) over the mesh, and thread rules/mesh into the
            # Runtime so shard_hint constraints steer GSPMD inside the
            # jitted prefill/decode. tp_shard_map defaults on for real TPU,
            # where GSPMD cannot partition a pallas_call and the kernels
            # must be shard_mapped explicitly.
            from repro.serve import tp as tp_mod  # lazy: optional subsystem
            rules = tp_mod.serve_rules(mesh, cfg)
            if tp_shard_map is None:
                tp_shard_map = jax.default_backend() == "tpu"
            self.rt = dataclasses.replace(self.rt, rules=rules, mesh=mesh,
                                          tp_shard_map=bool(tp_shard_map))
            params = tp_mod.shard_params(params, cfg, rules)
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.prompt_pad = prompt_pad
        self.prompt_chunk = prompt_chunk
        self.seed = int(seed)
        self.sample_on_host = sample_on_host
        # --- speculative decoding (propose/verify/commit; serve/spec.py) ---
        self.spec = draft_params is not None
        self.draft_cfg = draft_cfg
        if self.spec:
            if draft_cfg is None:
                raise ValueError("draft_params needs a draft_cfg")
            if sample_on_host:
                raise ValueError(
                    "sample_on_host is the measured pre-overhaul baseline; "
                    "speculative decoding needs on-device sampling (the "
                    "accept/commit decision rides the window's one token "
                    "transfer)")
            if num_draft_tokens < 1:
                raise ValueError(
                    f"num_draft_tokens must be >= 1, got {num_draft_tokens}")
            for c, role in ((cfg, "target"), (draft_cfg, "draft")):
                if c.family not in ("dense", "vlm", "moe"):
                    raise ValueError(
                        f"speculative decoding needs pure-attention "
                        f"families (dense/vlm/moe); the {role} is "
                        f"{c.family!r} — recurrent state cannot roll back "
                        f"a rejected window (positional cache indexing is "
                        f"what makes rejection free)")
            if draft_cfg.vocab_size != cfg.vocab_size:
                raise ValueError(
                    f"draft vocab {draft_cfg.vocab_size} != target vocab "
                    f"{cfg.vocab_size}: acceptance compares distributions "
                    f"over the same token ids")
            self._spec_k = int(num_draft_tokens)
            drt = draft_rt or self.rt
            if mesh is not None:
                from repro.serve import tp as tp_mod
                drt = dataclasses.replace(
                    drt, tp_shard_map=self.rt.tp_shard_map)
                draft_params, drt = tp_mod.place_draft(
                    draft_params, draft_cfg, mesh, drt)
            self.draft_rt = drt
            self.draft_params = draft_params
        else:
            self._spec_k = 0
            self.draft_rt = None
            self.draft_params = None
        # engine-default sampling for requests that don't carry their own;
        # the legacy ``temperature`` knob folds into it (and stays live via
        # the ``temperature`` property below)
        self.default_sampling = sampling or SamplingParams(
            temperature=float(temperature))
        self.scheduler: Scheduler = get_scheduler(scheduler)
        self.eos_id = eos_id if eos_id is not None else getattr(
            cfg, "eos_token_id", None)
        # --- resilience layer (see module docstring) ---
        self.faults = faults
        if clock is None and faults is not None:
            clock = getattr(faults, "clock", None)  # deterministic test time
        self._clock = clock or time.perf_counter
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        if shed_policy not in ("reject", "shed_lowest"):
            raise ValueError(
                f"shed_policy must be 'reject' or 'shed_lowest', "
                f"got {shed_policy!r}")
        self.max_queue = max_queue
        self.shed_policy = shed_policy
        # rid -> {"cache": host pytree, "pos": int, "next_tok": int} for
        # requests swapped out mid-flight by preempt()
        self._swapped: dict[int, dict] = {}
        self.watchdog = None
        if watchdog_timeout_s is not None:
            from repro.ft.monitor import HeartbeatMonitor  # lazy: ft layer
            self.watchdog = HeartbeatMonitor(
                1, timeout_s=float(watchdog_timeout_s), clock=self._clock)
        # --- resilience counters (surfaced via stats()) ---
        self.requests_rejected = 0  # backpressure: newcomer turned away
        self.requests_shed = 0      # backpressure: waiting victim dropped
        self.requests_invalid = 0   # malformed (empty prompt) at submit/admit
        self.deadline_expired = 0   # queued or live deadline/timeout expiries
        self.quarantined = 0        # slots evicted by the numeric-health check
        self.preemptions = 0        # live slots swapped out mid-flight
        self.resumes = 0            # swapped requests scattered back in
        self.stalled_steps = 0      # decode steps slower than the watchdog
        # --- paged-pool counters (zero for dense engines) ---
        self.blocks_swapped = 0     # blocks host-swapped by preemption
        self.pool_exhausted = 0     # slots error-finished on a dry pool
        self.max_concurrent = 0     # peak simultaneously-decoding requests
        # Runtime.kv_quant lays the attention cache out as rotated-int8
        # codes + fp16 scales (serve/kv_quant.py); cache_dtype is the fp
        # cache element type otherwise (f32 default keeps CPU tests exact,
        # bf16 is the deployment baseline the bytes ratio is quoted against)
        self.paged = bool(paged)
        if self.paged:
            # paged pool (serve/paged.py): cache positions come from a
            # shared ref-counted block pool instead of a per-slot max_len
            # reservation — admission is bounded by LIVE tokens, not slots
            from repro.serve import paged as paged_mod
            if not self.rt.kv_quant:
                raise ValueError(
                    "paged=True requires Runtime(kv_quant=True): the block "
                    "pool is laid out over the rotated-int8 codes + scale "
                    "planes")
            # +_spec_k: a speculative verify writes K+1 positions starting
            # at pos <= max_len - 2, so the address space must reach
            # max_len - 2 + K (zero when speculation is off — exact old
            # shapes, byte parity)
            n_pos = (max_len + self._spec_k
                     + (cfg.frontend_len if cfg.frontend else 0))
            self.block_size = int(block_size)
            # per-slot table width: enough entries to address every logical
            # position a slot can reach
            self._maxb = -(-n_pos // self.block_size)
            if num_blocks is None:
                # default: dense-equivalent capacity (every slot could run
                # to max_len) + the reserved null block — callers shrink it
                # to realize the memory win
                num_blocks = slots * self._maxb + 1
            self.num_blocks = int(num_blocks)
            self.pool = paged_mod.BlockPool(self.num_blocks, self.block_size)
            self._table = np.zeros((slots, self._maxb), np.int32)
            self._slot_blocks: list[list[int]] = [[] for _ in range(slots)]
            self.cache = paged_mod.init_paged_cache(
                cfg, self.num_blocks, self.block_size)
        else:
            self.block_size = None
            self.num_blocks = None
            self.pool = None
            # +_spec_k for the speculative write horizon (0 when off)
            self.cache = lm.init_cache(cfg, slots, max_len + self._spec_k,
                                       dtype=cache_dtype,
                                       kv_quant=self.rt.kv_quant)
        if mesh is not None:
            # per-device KV-cache shards from step 0: codes + scale planes
            # head-sharded over `model` (replicated when GQA doesn't divide)
            from repro.serve import tp as tp_mod
            self.cache = tp_mod.shard_cache(self.cache, cfg, self.rt.rules)
        if self.spec:
            # the draft's own KV cache: always dense slot-batched (the
            # draft is small by construction, so paging it buys nothing),
            # same +K horizon so a fully-accepted window's final proposal
            # is cached with no stale hole
            self.draft_cache = lm.init_cache(
                draft_cfg, slots, max_len + self._spec_k, dtype=cache_dtype,
                kv_quant=self.draft_rt.kv_quant)
            if mesh is not None:
                from repro.serve import tp as tp_mod
                self.draft_cache = tp_mod.shard_cache(
                    self.draft_cache, draft_cfg, self.draft_rt.rules)
        else:
            self.draft_cache = None
        self._cache_nbytes = self.cache_bytes  # fixed for the engine's life
        self.pos = np.zeros(slots, dtype=np.int32)  # next write index per slot
        self.active: list[Optional[Request]] = [None] * slots
        self._next_tok = np.zeros(slots, dtype=np.int32)
        # --- per-slot sampling state, packed to device vectors each step ---
        self._temp = np.zeros(slots, np.float32)
        self._top_k = np.zeros(slots, np.int32)
        self._top_p = np.ones(slots, np.float32)
        self._keys = np.zeros((slots, 2), np.uint32)
        self._slot_stop: list[frozenset[int]] = [frozenset()] * slots
        self._slot_max_new: list[int] = [0] * slots
        # per-slot speculative window size (0 = one-token decode; set at
        # install from SamplingParams.draft/draft_tokens, always 0 on
        # non-speculative engines)
        self._slot_draft_k = np.zeros(slots, np.int32)
        self._pending_events: list[StreamEvent] = []
        # --- perf counters (read by benchmarks/serve_bench.py and tests) ---
        self.host_syncs = 0       # device->host transfers
        self.tokens_decoded = 0   # tokens emitted by step()
        self.decode_steps = 0     # jitted decode calls
        self.cache_bytes_moved = 0  # bytes functionally copied (donation off)
        self.cache_donated = False  # did the last decode donate in place?
        # --- speculative counters ---
        self.spec_steps = 0       # propose/verify/commit windows executed
        self.draft_proposed = 0   # draft tokens offered for verification
        self.draft_accepted = 0   # of those, tokens committed
        self._jit_prefill = jax.jit(self._prefill_impl,
                                    static_argnames=("plen", "fresh"),
                                    donate_argnums=(1,))
        self._jit_decode = jax.jit(self._decode_impl, donate_argnums=(1,))
        self._jit_decode_logits = jax.jit(self._decode_logits_impl,
                                          donate_argnums=(1,))
        if self.spec:
            self._jit_draft_prefill = jax.jit(self._draft_prefill_impl,
                                              donate_argnums=(1,))
            self._jit_propose = jax.jit(self._propose_impl,
                                        donate_argnums=(1,))
            self._jit_verify = jax.jit(self._verify_impl,
                                       donate_argnums=(1,))
            # a speculative engine prices SJF admission by expected slot
            # OCCUPANCY (prefill + decode STEPS), not prompt length alone:
            # a draft-enabled request frees its slot up to (K+1)x faster
            set_cost = getattr(self.scheduler, "set_cost", None)
            if set_cost is not None:
                set_cost(self._admission_cost)
        if self.rt.autotune:
            from repro.kernels import autotune as autotune_mod
            # no-op on CPU/interpret; on TPU, pre-tunes every QTensor matmul
            # shape at decode batch = slots so the hot loop runs tuned tiles
            autotune_mod.tune_params_shapes(params, slots)
            if self.spec and self.rt.kv_quant:
                # pre-tune the verify pass's NARROW q-width attention shape
                # (K+1 window positions over the full cache) so the first
                # speculative window already runs tuned tiles
                attn = self.cache.get("attn")
                if attn:
                    cl = (self._maxb * self.block_size if self.paged
                          else int(attn["k"].shape[3]))
                    kvh = cfg.num_kv_heads
                    autotune_mod.autotune_attn(
                        cl, cfg.resolved_head_dim, kvh, batch=slots,
                        g=max(1, getattr(cfg, "num_heads", kvh) // kvh),
                        q_width=self._spec_k + 1)

    @property
    def temperature(self) -> float:
        """Legacy knob: the engine-default temperature. Reads/writes route
        through ``default_sampling`` so mutating it between batches still
        takes effect (already-admitted requests keep their resolved
        params)."""
        return self.default_sampling.temperature

    @temperature.setter
    def temperature(self, value: float) -> None:
        self.default_sampling = dataclasses.replace(
            self.default_sampling, temperature=float(value))

    @classmethod
    def from_checkpoint(cls, ckpt_dir: str, cfg, *, step: Optional[int] = None,
                        mesh=None, **kw) -> "ServeEngine":
        """Boot an engine from a bare checkpoint directory — including
        policy-quantized checkpoints, whose QTensor leaves are rebuilt from
        their packed planes without re-running Algorithm 1 (the
        serve-from-disk path of the deployment story).

        With ``mesh``, each leaf is ``device_put`` into its serving TP
        placement AS IT LOADS (restore-to-sharding): packed planes go
        straight to their column shards, so the full plane set never
        materializes on one device — the path that makes 235B-class plane
        sets bootable."""
        from repro.checkpoint import ckpt as ckpt_mod  # lazy: optional dep

        shardings = None
        if mesh is not None:
            from repro.serve import tp as tp_mod
            shardings = tp_mod.restore_shardings(cfg, mesh)
        params, _ = ckpt_mod.restore_params(ckpt_dir, step=step,
                                            shardings=shardings)
        return cls(params, cfg, mesh=mesh, **kw)

    # --- compiled kernels -------------------------------------------------
    def _prefill_impl(self, params, cache, tokens, slots, last_idx, pos0,
                      keys, temp, top_k, top_p, table=None, *, plen, fresh):
        """One admission wave: tokens (G, plen) for slot ids ``slots`` (G,).

        ``fresh=True`` starts each admitted slot from a ZEROED state (the
        old per-slot reset pass folded into this same compiled call);
        ``fresh=False`` continues from the slot's current state (the
        SSM/hybrid chunk ladder). ``keys`` is a (G, 2) batch of per-request
        PRNG keys (None for an all-greedy wave: no PRNG in the trace).

        PAGED engines pass ``table`` (G, MAXB) — the admitted slots' block
        rows. Writes scatter through the table into the shared pool, so
        there is no per-slot gather/zero/scatter: freshly allocated blocks
        may hold a finished request's stale FINITE codes, which the kv_len
        mask zeroes exactly (the finite-garbage invariant; serve/paged.py).
        Returns (cache, sampled (G,) first tokens, last-real-token logits
        (G, V))."""
        if table is not None:
            model_cache = {"attn": cache["attn"], "table": table}
            logits, new_cache, _ = lm.forward(
                params, tokens, self.rt, self.cfg, cache=model_cache,
                pos=pos0, last_idx=last_idx)
            cache = {"attn": new_cache["attn"]}
        else:
            g = tokens.shape[0]
            if fresh:
                slot_cache = _zero_slots_like(cache, g)
            else:
                slot_cache = _take_slots(cache, slots)
            # pad tokens run through the model (masked later via pos), but
            # the head + first sampled token come from the TRUE last prompt
            # position only — one V-row per slot, not V logits per pad
            logits, new_slot_cache, _ = lm.forward(
                params, tokens, self.rt, self.cfg, cache=slot_cache,
                pos=pos0, last_idx=last_idx)
            cache = _put_slots(cache, new_slot_cache, slots)
        last = logits[:, 0]
        tok = _sample_slots(last, keys, jnp.zeros_like(slots), temp,
                            top_k, top_p)
        return cache, tok, last

    def _model_cache(self, cache, table):
        """The cache pytree the model sees: the engine cache, plus the
        block table threaded OUTSIDE it for paged engines — the table rides
        the jitted calls as its own argument so the cache-donation probe
        (``jax.tree.leaves(self.cache)``) never sees it."""
        return cache if table is None else {"attn": cache["attn"],
                                            "table": table}

    def _decode_impl(self, params, cache, tokens, positions, keys, gen,
                     temp, top_k, top_p, table=None):
        """tokens (S, 1); per-slot positions (S,). Sampling stays on device
        under per-slot vectors: the step's only fetch is the (S,) token
        vector. ``gen`` (S,) is each request's own token index — folded
        into its key so row draws don't depend on slot or batchmates."""
        logits, new_cache = lm.decode_step(
            params, tokens, self._model_cache(cache, table), positions,
            self.rt, self.cfg)
        if table is not None:
            new_cache = {"attn": new_cache["attn"]}
        last = logits[:, 0]
        tok = _sample_slots(last, keys, gen, temp, top_k, top_p)
        # numeric-health check folded into the step: a slot whose logits
        # row went non-finite (inf/NaN — e.g. a poisoned KV scale plane)
        # reports the in-band _POISONED sentinel instead of a token, so
        # quarantine costs zero extra host syncs; healthy rows pass through
        # untouched (batch rows are independent -> bit-identical streams)
        ok = lm.finite_rows(last)
        return jnp.where(ok, tok, _POISONED), new_cache

    def _decode_logits_impl(self, params, cache, tokens, positions,
                            table=None):
        """Pre-overhaul decode: ship logits out, sample on host."""
        logits, new_cache = lm.decode_step(
            params, tokens, self._model_cache(cache, table), positions,
            self.rt, self.cfg)
        if table is not None:
            new_cache = {"attn": new_cache["attn"]}
        return logits[:, 0], new_cache

    # --- speculative propose/verify (compiled) ----------------------------
    def _draft_prefill_impl(self, params, cache, tokens, slots, pos0):
        """Admission-wave prefill of the DRAFT cache: zero the admitted
        slots and append the padded prompt bucket. No head, no sampling —
        the target's prefill picks the first token; the draft only needs
        the KV state. Pad positions hold finite garbage behind the kv_len
        mask / under the window's sequential overwrites, exactly like the
        target's bucketed prefill."""
        g = tokens.shape[0]
        new_slot = lm.advance_cache(params, tokens,
                                    _zero_slots_like(cache, g), pos0,
                                    self.draft_rt, self.draft_cfg)
        return _put_slots(cache, new_slot, slots)

    def _propose_impl(self, dparams, dcache, tokens, positions, keys, gen,
                      temp, top_k, top_p):
        """K sequential draft steps + one final cache advance. Returns
        (cand (S, K+1) = [anchor, d_1..d_K], qlog (S, K, V) draft
        scaled+masked logits (None on an all-greedy trace), new draft
        cache). Proposal w is drawn from the slot's DRAFT_TAG PRNG stream
        at generation index gen + w — mirroring ``lm.sample_tokens``'s
        masked-categorical path exactly, so ``qlog`` IS the distribution
        the draw came from (what rejection sampling requires). The final
        ``advance_cache`` writes d_K at pos + K: a fully-accepted window
        leaves no stale hole for the next window to read."""
        from repro.serve import spec as spec_mod
        k = self._spec_k
        cand = [tokens[:, 0]]
        qlogs = []
        cur = tokens
        for w in range(k):
            logits, dcache = lm.decode_step(dparams, cur, dcache,
                                            positions + w, self.draft_rt,
                                            self.draft_cfg)
            last = logits[:, 0].astype(jnp.float32)
            if keys is None:  # all-greedy: argmax proposals, no PRNG
                d = jnp.argmax(last, axis=-1).astype(jnp.int32)
            else:
                scaled = last / jnp.maximum(temp, 1e-6)[:, None]
                if top_k is not None or top_p is not None:
                    scaled = lm.top_mask(scaled, top_k, top_p)
                dk = spec_mod.draft_keys(keys, gen, w)
                sampled = jax.vmap(
                    lambda kk, row: jax.random.categorical(kk, row)
                )(dk, scaled).astype(jnp.int32)
                d = jnp.where(temp > 0, sampled,
                              jnp.argmax(last, axis=-1).astype(jnp.int32))
                qlogs.append(scaled)
            cand.append(jnp.clip(d, 0, self.cfg.vocab_size - 1))
            cur = cand[-1][:, None]
        dcache = lm.advance_cache(dparams, cur, dcache, positions + k,
                                  self.draft_rt, self.draft_cfg)
        qlog = jnp.stack(qlogs, axis=1) if qlogs else None
        return jnp.stack(cand, axis=1), qlog, dcache

    def _verify_impl(self, params, cache, cand, positions, kvec, keys, gen,
                     temp, top_k, top_p, qlog, table=None):
        """One batched target pass over the K+1 window positions
        (``lm.score_tokens`` — under kv_quant a single fused
        ``prefill_attn_q8`` call per layer), then the on-device
        accept/commit decision. Numeric quarantine generalizes: a slot
        whose logits went non-finite at any position its window can USE
        (<= kvec; later rows read lookahead positions past the slot's
        paged allocation, which hold finite-but-meaningless null-block
        garbage) reports a fully _POISONED row with n=1, riding the same
        single transfer."""
        from repro.serve import spec as spec_mod
        logits, new_cache = lm.score_tokens(
            params, cand, self._model_cache(cache, table), positions,
            self.rt, self.cfg)
        if table is not None:
            new_cache = {"attn": new_cache["attn"]}
        out, n = spec_mod.verify_commit(logits, cand, kvec, keys=keys,
                                        gen=gen, temp=temp, top_k=top_k,
                                        top_p=top_p, qlog=qlog)
        used = jnp.arange(cand.shape[1])[None, :] <= kvec[:, None]
        ok = jnp.all(lm.finite_rows(logits) | ~used, axis=1)
        out = jnp.where(ok[:, None], out, _POISONED)
        n = jnp.where(ok, n, 1)
        return out, n, new_cache

    # --- request lifecycle ------------------------------------------------
    def _spec_k_for(self, req: Request) -> int:
        """This request's speculative window size: the engine's
        ``num_draft_tokens``, capped (never raised) by
        ``SamplingParams.draft_tokens``, zeroed by ``draft=False`` — and
        always 0 on a non-speculative engine."""
        if not self.spec:
            return 0
        sp = req.sampling or self.default_sampling
        if sp.draft is False:
            return 0
        if sp.draft_tokens is not None:
            return max(0, min(int(sp.draft_tokens), self._spec_k))
        return self._spec_k

    def _admission_cost(self, req: Request) -> float:
        """SJF job-size estimate under speculation: prefill cost (prompt
        length) plus expected decode STEPS — the output budget amortized
        by the request's window size (a K-draft window commits up to K+1
        tokens per step)."""
        sp = req.sampling or self.default_sampling
        new = sp.max_new if sp.max_new is not None else req.max_new
        return float(len(req.prompt)) + float(new) / (
            1 + self._spec_k_for(req))

    def _resolve(self, req: Request) -> SamplingParams:
        sp = req.sampling or self.default_sampling
        over: dict = {}
        if sp.max_new is None:
            over["max_new"] = req.max_new
        if sp.greedy and (sp.top_k > 0 or sp.top_p < 1.0):
            # argmax ignores the filters by spec — normalize them to the
            # inert values so a greedy request never drags top_mask's
            # full-vocab sort into a mixed batch's decode trace
            over.update(top_k=0, top_p=1.0)
        return dataclasses.replace(sp, **over) if over else sp

    def _terminal(self, req: Request, reason: str) -> StreamEvent:
        """Stamp a request done OFF-slot (rejected / shed / expired while
        queued / invalid) and queue its terminal event for the next tick."""
        if req.t_submit is None:
            req.t_submit = self._clock()
        req.done = True
        req.finish_reason = reason
        req.t_done = self._clock()
        ev = StreamEvent(req.rid, None, len(req.out), finished=True,
                         finish_reason=reason, stats=req.stats())
        self._pending_events.append(ev)
        return ev

    def submit_request(self, req: Request) -> bool:
        """Enqueue a request with the scheduler (stamped for queue-wait).

        Returns False — with a terminal StreamEvent queued for the next
        tick — when the request is turned away instead of enqueued:
        malformed (empty prompt -> ``finish_reason="error"``) or shed by
        backpressure (queue at ``max_queue`` under the ``reject`` policy,
        or under ``shed_lowest`` when the newcomer is itself the
        lowest-priority request waiting -> ``"rejected"``)."""
        if len(req.prompt) == 0 and req.rid not in self._swapped:
            # malformed: reject ALONE, loudly, before it can poison an
            # admission wave (an empty prompt would gather last_idx=-1)
            self.requests_invalid += 1
            self._terminal(req, FINISH_ERROR)
            return False
        if self.max_queue is not None and len(self.scheduler) >= self.max_queue:
            victim = None
            if self.shed_policy == "shed_lowest":
                shed = getattr(self.scheduler, "shed", None)
                if shed is not None:
                    victim = shed(below=int(getattr(req, "priority", 0)))
            if victim is None:
                # reject policy, no shed() hook, or the newcomer doesn't
                # outrank anyone waiting: the newcomer is turned away
                self.requests_rejected += 1
                self._terminal(req, FINISH_REJECTED)
                return False
            self._swapped.pop(victim.rid, None)
            self.requests_shed += 1
            self._terminal(victim, FINISH_REJECTED)
        if req.t_submit is None:
            req.t_submit = self._clock()
        self.scheduler.add(req)
        return True

    def cancel(self, rid: int) -> bool:
        """Evict a live slot or drop a queued request. The terminal
        ``cancelled`` StreamEvent is delivered on the next ``generate``
        tick. Returns False for unknown/finished rids."""
        req = self.scheduler.cancel(rid)
        if req is not None:
            self._swapped.pop(rid, None)  # preempted + requeued, now dead
            req.t_done = self._clock()
            self._pending_events.append(StreamEvent(
                rid, None, len(req.out), finished=True,
                finish_reason=FINISH_CANCELLED, stats=req.stats()))
            return True
        for s, r in enumerate(self.active):
            if r is not None and r.rid == rid:
                self._finish_slot(s, r, FINISH_CANCELLED, token=None)
                return True
        return False

    def preempt(self, rid: int) -> bool:
        """Swap a LIVE request out mid-flight: its slot's cache rows are
        copied to host (int8 codes / fp scales round-trip exactly) together
        with its stream state, the slot is freed, and the request goes back
        to the scheduler. On re-admission :meth:`_admit_group` scatters the
        rows back and decoding continues bit-identically — no re-prefill.
        Returns False for rids that aren't live."""
        for s, req in enumerate(self.active):
            if req is not None and req.rid == rid:
                break
        else:
            return False
        if self.paged:
            # gather the slot's BLOCKS (pool axis) to host, then release
            # them: the swap entry is self-contained, so the blocks can be
            # reused immediately — resume scatters into fresh blocks with
            # bit-identical contents
            blocks = list(self._slot_blocks[s])
            sub = jax.device_get(
                _take_slots(self.cache, jnp.asarray(blocks, jnp.int32)))
            self._swapped[rid] = {"cache": sub, "pos": int(self.pos[s]),
                                  "next_tok": int(self._next_tok[s]),
                                  "nblocks": len(blocks)}
            self.blocks_swapped += len(blocks)
            self._release_blocks(s, zero=False)
        else:
            sub = jax.device_get(
                _take_slots(self.cache, jnp.asarray([s], jnp.int32)))
            self._swapped[rid] = {"cache": sub, "pos": int(self.pos[s]),
                                  "next_tok": int(self._next_tok[s])}
        if self.spec:
            # the draft's slot rows ride the same swap entry, so resume
            # restores BOTH models' state with no draft re-prefill
            self._swapped[rid]["draft"] = jax.device_get(
                _take_slots(self.draft_cache, jnp.asarray([s], jnp.int32)))
        # free the slot WITHOUT finishing the request (no terminal event:
        # the stream simply pauses until resume)
        self.active[s] = None
        self._slot_stop[s] = frozenset()
        self._temp[s] = 0.0
        self._top_k[s] = 0
        self._top_p[s] = 1.0
        self._slot_draft_k[s] = 0
        req.preemptions += 1
        self.preemptions += 1
        self.scheduler.add(req)
        return True

    def _release_blocks(self, s: int, *, zero: bool) -> None:
        """Drop slot ``s``'s references into the block pool and clear its
        table row. ``zero=True`` (quarantine) first zeroes the blocks this
        slot holds EXCLUSIVELY — NaN is the one garbage the kv_len mask
        cannot neutralize (0 * NaN), so poisoned blocks must not reenter
        the free list dirty; shared blocks hold clean prompt codes some
        other holder is still reading."""
        from repro.serve import paged as paged_mod
        blocks = self._slot_blocks[s]
        if zero and blocks:
            exclusive = [b for b in blocks if self.pool.ref[b] == 1]
            if exclusive:
                self.cache = paged_mod.zero_blocks(self.cache, exclusive)
        for b in blocks:
            self.pool.decref(b)
        self._slot_blocks[s] = []
        self._table[s, :] = paged_mod.NULL_BLOCK

    def _resume_slot(self, req: Request, s: int) -> bool:
        """Scatter a swapped request's cache rows back into slot ``s`` and
        rebind its stream state. Lifecycle stamps are NOT reset — queue
        wait and TTFT stay measured from the original submission. Returns
        True when the slot was consumed; paged engines return False when
        the pool cannot supply the blocks right now (request requeued,
        swap entry kept) or the request can never fit (error-finished)."""
        sw = self._swapped[req.rid]
        if self.paged:
            from repro.serve.paged import PoolExhausted
            n = sw["nblocks"]
            if n > self.pool.capacity:
                # can NEVER fit: finish loudly instead of spinning forever
                self._swapped.pop(req.rid)
                self.pool_exhausted += 1
                self._terminal(req, FINISH_ERROR)
                return False  # slot stays free; terminal event queued
            blocks: list[int] = []
            try:
                for _ in range(n):
                    blocks.append(self.pool.alloc())
            except PoolExhausted:
                for b in blocks:
                    self.pool.decref(b)
                self.scheduler.add(req)  # retry when blocks free up
                return False
            self._swapped.pop(req.rid)
            self.cache = _put_slots(
                self.cache, jax.tree.map(jnp.asarray, sw["cache"]),
                jnp.asarray(blocks, jnp.int32))
            self._slot_blocks[s] = blocks
            self._table[s, :] = 0
            self._table[s, :len(blocks)] = blocks
        else:
            self._swapped.pop(req.rid)
            self.cache = _put_slots(
                self.cache, jax.tree.map(jnp.asarray, sw["cache"]),
                jnp.asarray([s], jnp.int32))
        if self.spec and "draft" in sw:
            self.draft_cache = _put_slots(
                self.draft_cache, jax.tree.map(jnp.asarray, sw["draft"]),
                jnp.asarray([s], jnp.int32))
        self._install_slot(s, req, self._resolve(req), pos=sw["pos"],
                           next_tok=sw["next_tok"])
        self.resumes += 1
        return True

    def generate(self, requests: Iterable[Request] = (),
                 ) -> Iterator[StreamEvent]:
        """Stream tokens for ``requests`` (plus anything already queued or
        live) until everything finishes. Yields one :class:`StreamEvent`
        per emitted token; terminal events carry finish reason + stats.
        Call :meth:`submit_request` (or pass more requests to a later
        ``generate``) to keep feeding the engine; call :meth:`cancel`
        between events to evict mid-stream."""
        for r in requests:
            self.submit_request(r)
        while (self._pending_events or len(self.scheduler)
               or any(r is not None for r in self.active)):
            yield from self._tick()

    def _tick(self) -> list[StreamEvent]:
        events = self._pending_events
        self._pending_events = []
        events += self._expire_live()
        self._maybe_preempt()
        events += self._pending_events  # preemption emits no events today,
        self._pending_events = []       # but a custom hook may cancel
        free = sum(r is None for r in self.active)
        if free and len(self.scheduler):
            wave = self._pop_wave(free, events)
            if wave:
                events += self._admit_group(wave)
        if any(r is not None for r in self.active):
            events += self._step_events()
        return events

    def _expired(self, req: Request, now: float) -> bool:
        if (req.deadline_ms is not None and req.t_submit is not None
                and (now - req.t_submit) * 1e3 > req.deadline_ms):
            return True
        return (req.decode_timeout_ms is not None and req.t_first is not None
                and (now - req.t_first) * 1e3 > req.decode_timeout_ms)

    def _expire_live(self) -> list[StreamEvent]:
        """Finish live slots whose deadline/decode-timeout expired —
        BEFORE decoding another token on their behalf."""
        now = self._clock()
        events = []
        for s, req in enumerate(self.active):
            if req is not None and self._expired(req, now):
                self.deadline_expired += 1
                events.append(self._finish_slot(
                    s, req, FINISH_DEADLINE, token=None))
        return events

    def _pop_wave(self, free: int, events: list[StreamEvent]) -> list:
        """Pop the next admission wave, shedding queued requests whose
        deadline already expired (they would only waste a prefill)."""
        now = self._clock()
        wave: list = []
        while len(wave) < free and len(self.scheduler):
            for req in self.scheduler.pop(free - len(wave)):
                if self._expired(req, now):
                    self._swapped.pop(req.rid, None)
                    self.deadline_expired += 1
                    self._terminal(req, FINISH_DEADLINE)
                    events.append(self._pending_events.pop())  # deliver NOW
                else:
                    wave.append(req)
        return wave

    def _maybe_preempt(self) -> None:
        """Let the scheduler evict live work for higher-priority waiting
        work — only when the machine is actually full (free slots admit
        without anyone paying a swap)."""
        hook = getattr(self.scheduler, "should_preempt", None)
        if hook is None or not len(self.scheduler):
            return
        for _ in range(self.slots):
            if any(r is None for r in self.active):
                return
            live = [r for r in self.active if r is not None]
            rid = hook(live)
            if rid is None or not self.preempt(rid):
                return

    # --- admission --------------------------------------------------------
    def submit(self, req: Request) -> bool:
        return self.admit([req]) == 1

    def admit(self, reqs: list[Request]) -> int:
        """Admit as many of ``reqs`` (in order) as there are free slots,
        bypassing the scheduler (the closed-batch / legacy path).
        Returns the number actually admitted (malformed requests are
        rejected with a terminal ``error`` event, not counted)."""
        free = sum(r is None for r in self.active)
        group = reqs[:free]
        if not group:
            return 0
        inv0 = self.requests_invalid
        self._admit_group(group)
        return len(group) - (self.requests_invalid - inv0)

    def _admit_group(self, group: list[Request]) -> list[StreamEvent]:
        free = [s for s in range(self.slots) if self.active[s] is None]
        assert len(group) <= len(free), "scheduler over-popped"
        now = self._clock()
        events: list[StreamEvent] = []
        fresh: list[Request] = []
        for r in group:
            if r.rid in self._swapped:
                # preempted mid-flight: scatter its rows back, no prefill
                # (paged resume can fail allocation — slot stays free)
                if self._resume_slot(r, free[0]):
                    free.pop(0)
            elif len(r.prompt) == 0:
                # malformed: an empty prompt would gather last_idx=-1 (a
                # pad position) in the bucketed path. Reject it ALONE with
                # a terminal event — never abort a wave whose peers are
                # already stamped (this is the direct-admit() screen; the
                # queued path is screened at submit_request)
                self.requests_invalid += 1
                self._terminal(r, FINISH_ERROR)
                events.append(self._pending_events.pop())  # deliver NOW
            else:
                fresh.append(r)
        if self.paged and fresh:
            # allocate each prompt's block chain BEFORE the compiled wave;
            # requests the pool cannot hold right now go back to the
            # scheduler (decode progress frees blocks), and requests that
            # can NEVER fit are error-finished instead of spinning
            admitted: list[Request] = []
            from repro.serve.paged import PoolExhausted
            for r in fresh:
                s = free[len(admitted)]  # the slot zip() will pair r with
                try:
                    blocks = self.pool.alloc_prompt(r.prompt)
                except PoolExhausted:
                    if -(-len(r.prompt) // self.block_size) > \
                            self.pool.capacity:
                        self.pool_exhausted += 1
                        self._terminal(r, FINISH_ERROR)
                        events.append(self._pending_events.pop())
                    else:
                        self.scheduler.add(r)  # retry when blocks free
                    continue
                self._slot_blocks[s] = blocks
                self._table[s, :] = 0
                self._table[s, :len(blocks)] = blocks
                admitted.append(r)
            fresh = admitted
        if not fresh:
            return events
        for r in fresh:
            if r.t_submit is None:
                r.t_submit = now  # direct admit(): no queue wait
            r.t_admit = now
        free = free[: len(fresh)]
        if self.cfg.family in ("ssm", "hybrid"):
            # recurrent state integrates every fed token: no pad buckets;
            # chunk ladder instead (bounded compiled shapes)
            for req, s in zip(fresh, free):
                events += self._admit_chunked(req, s)
            return events
        return events + self._admit_bucketed(fresh, free)

    def _group_sampling(self, group: list[Request]):
        """Per-request device vectors for one admission wave. Returns
        (resolved params, keys (G,2)|None, temp, top_k, top_p) — keys is
        None when the whole wave is greedy (PRNG-free prefill trace), and
        the filter vectors are None when unused (no top_mask in the
        trace)."""
        sps = [self._resolve(r) for r in group]
        if all(sp.greedy for sp in sps):
            return sps, None, None, None, None
        keys = np.stack([sp.key_data(engine_seed=self.seed, rid=r.rid)
                         for sp, r in zip(sps, group)])
        temp = jnp.asarray([sp.temperature for sp in sps], jnp.float32)
        top_k, top_p = self._filter_vectors(
            (sp.top_k for sp in sps), (sp.top_p for sp in sps))
        return sps, jnp.asarray(keys), temp, top_k, top_p

    @staticmethod
    def _filter_vectors(ks, ps):
        """Per-row top-k/top-p device vectors — or None for a filter no
        row is using, keeping it (and its full-vocab sort) out of the
        jitted step. Freed slots are reset to the inert 0 / 1.0, so
        passing every slot's value is safe on the decode path."""
        ks, ps = list(ks), list(ps)
        top_k = jnp.asarray(ks, jnp.int32) if any(k > 0 for k in ks) else None
        top_p = jnp.asarray(ps, jnp.float32) if any(p < 1.0 for p in ps) \
            else None
        return top_k, top_p

    def _bucket(self, max_plen: int) -> int:
        pad = (-max_plen) % self.prompt_pad
        # cap padding so the padded prompt always fits the cache
        return max_plen + min(pad, max(0, self.max_len - 1 - max_plen))

    def _admit_bucketed(self, group: list[Request],
                        free: list[int]) -> list[StreamEvent]:
        """Attention-family admission: every free slot in ONE padded-bucket
        compiled call (zero + prefill + first-token sample fused)."""
        plens = [int(len(r.prompt)) for r in group]
        bucket = self._bucket(max(plens))
        toks = np.stack([np.pad(np.asarray(r.prompt, np.int32),
                                (0, bucket - p))
                         for r, p in zip(group, plens)])
        sps, keys, temp, top_k, top_p = self._group_sampling(group)
        table = jnp.asarray(self._table[free]) if self.paged else None
        self.cache, tok, last = self._jit_prefill(
            self.params, self.cache, jnp.asarray(toks),
            jnp.asarray(free, jnp.int32),
            jnp.asarray([p - 1 for p in plens], jnp.int32),
            jnp.zeros(len(group), jnp.int32),
            keys, temp, top_k, top_p, table, plen=bucket, fresh=True)
        if self.spec:
            # the draft consumes the SAME padded bucket (one compiled
            # shape family per bucket for both models); its pad writes sit
            # behind the kv_len mask like the target's
            self.draft_cache = self._jit_draft_prefill(
                self.draft_params, self.draft_cache, jnp.asarray(toks),
                jnp.asarray(free, jnp.int32),
                jnp.zeros(len(group), jnp.int32))
        return self._finish_admission(group, free, plens, sps, tok, last)

    def _admit_chunked(self, req: Request, s: int) -> list[StreamEvent]:
        """SSM/hybrid admission: exact-length feeding via a power-of-two
        chunk ladder with state threaded between compiled calls."""
        prompt = np.asarray(req.prompt, np.int32)
        plen = int(len(prompt))
        sizes, rem = [], plen
        while rem:
            c = self.prompt_chunk
            while c > rem:
                c //= 2
            sizes.append(c)
            rem -= c
        off, fresh = 0, True
        slot = jnp.asarray([s], jnp.int32)
        sps, keys, temp, top_k, top_p = self._group_sampling([req])
        for c in sizes:
            self.cache, tok, last = self._jit_prefill(
                self.params, self.cache, jnp.asarray(prompt[None, off:off + c]),
                slot, jnp.asarray([c - 1], jnp.int32),
                jnp.asarray([off], jnp.int32),
                keys, temp, top_k, top_p, plen=c, fresh=fresh)
            fresh = False
            off += c
        return self._finish_admission([req], [s], [plen], sps, tok, last)

    def _finish_admission(self, group, free, plens, sps, tok,
                          last) -> list[StreamEvent]:
        if self.sample_on_host:
            firsts = [int(jnp.argmax(last[g])) for g in range(len(group))]
            self.host_syncs += len(group)
        else:
            firsts = np.asarray(tok)
            self.host_syncs += 1
        now = self._clock()
        events = []
        for g, (req, s) in enumerate(zip(group, free)):
            first = int(firsts[g])
            self._install_slot(s, req, sps[g], pos=plens[g], next_tok=first)
            req.out.append(first)
            req.t_first = now
            events.append(self._emit(s, req, first))
        return events

    def _install_slot(self, s: int, req: Request, sp: SamplingParams, *,
                      pos: int, next_tok: int) -> None:
        """Bind a request to a slot: position counter + per-slot sampling
        state (shared by fresh admission and preemption resume)."""
        self.pos[s] = pos
        self.active[s] = req
        self._slot_stop[s] = sp.stop_set(self.eos_id)
        self._slot_max_new[s] = int(sp.max_new)
        self._temp[s] = sp.temperature
        self._top_k[s] = sp.top_k
        self._top_p[s] = sp.top_p
        self._keys[s] = sp.key_data(engine_seed=self.seed, rid=req.rid)
        self._slot_draft_k[s] = self._spec_k_for(req)
        self._next_tok[s] = next_tok

    # --- decode -----------------------------------------------------------
    def _step_events(self) -> list[StreamEvent]:
        """One decode step for every active slot -> one StreamEvent per
        emitted token (terminal events carry finish reason + stats).
        Speculative engines run a propose/verify/commit WINDOW instead of
        a single token; both paths share :meth:`_commit_slot`."""
        if self.spec:
            return self._spec_step_events()
        if self.faults is not None:
            self.faults.before_decode(self)
        events0: list[StreamEvent] = []
        if self.paged:
            # grow block chains for slots whose next write crosses a block
            # boundary (preempting victims on a dry pool); exhaustion can
            # finish slots, so re-check liveness before decoding
            events0 = self._ensure_decode_blocks()
            if not any(r is not None for r in self.active):
                return events0
        n_live = sum(r is not None for r in self.active)
        self.max_concurrent = max(self.max_concurrent, n_live)
        toks = jnp.asarray(self._next_tok[:, None])
        positions = jnp.asarray(self.pos)
        table = jnp.asarray(self._table) if self.paged else None
        probe = jax.tree.leaves(self.cache)
        if self.sample_on_host:
            logits, self.cache = self._jit_decode_logits(
                self.params, self.cache, toks, positions, table)
            tok_np = None
        else:
            live = [s for s, r in enumerate(self.active) if r is not None]
            if all(self._temp[s] <= 0 for s in live):
                keys = gen = temp = top_k = top_p = None  # argmax-only trace
            else:
                gen = jnp.asarray([len(r.out) if r is not None else 0
                                   for r in self.active], jnp.int32)
                keys = jnp.asarray(self._keys)
                temp = jnp.asarray(self._temp)
                # filters stay OUT of the trace when no live slot uses
                # them: a temperature-only batch shouldn't pay top_mask's
                # full-vocab sort+cumsum every step
                top_k, top_p = self._filter_vectors(self._top_k, self._top_p)
            tok_dev, self.cache = self._jit_decode(
                self.params, self.cache, toks, positions,
                keys, gen, temp, top_k, top_p, table)
            tok_np = np.asarray(tok_dev)  # THE step's one transfer
            self.host_syncs += 1
        self.decode_steps += 1
        # EVERY leaf must donate — a partially-donated cache (some planes
        # copied, e.g. mixed int8/fp16/fp32 leaves under kv_quant) still
        # burns bandwidth and must show up in the counter
        self.cache_donated = all(a.is_deleted() for a in probe)
        if not self.cache_donated:  # functional copy happened: count it
            self.cache_bytes_moved += self._cache_nbytes
        if self.watchdog is not None:
            now = self._clock()
            self.stalled_steps += len(self.watchdog.failed(now))
            self.watchdog.beat(0, self.decode_steps, now=now)
        events = events0
        for s, req in enumerate(self.active):
            if req is None:
                continue
            if tok_np is None:
                row = np.asarray(logits[s])  # one transfer per slot
                self.host_syncs += 1
                tok = _POISONED if not np.isfinite(row).all() \
                    else int(np.argmax(row))
            else:
                tok = int(tok_np[s])
            events += self._commit_slot(s, req, [tok])
        return events

    def _spec_step_events(self) -> list[StreamEvent]:
        """One speculative window for every active slot: the draft
        proposes K candidates from its own cache, ONE batched target pass
        verifies all K+1 window positions, and each slot commits its
        accepted prefix plus one window-end token. Every single-token
        invariant generalizes per-slot-variable-count: one device->host
        transfer moves the whole (S, K+1) window + commit counts, both
        caches donate in place, quarantine rides the same _POISONED
        sentinel, and kvec=0 slots (draft opt-out) commit exactly one
        token through the identical machinery."""
        if self.faults is not None:
            self.faults.before_decode(self)
        events0: list[StreamEvent] = []
        if self.paged:
            events0 = self._ensure_decode_blocks()
            if not any(r is not None for r in self.active):
                return events0
        n_live = sum(r is not None for r in self.active)
        self.max_concurrent = max(self.max_concurrent, n_live)
        kvec_np = self._slot_draft_k.copy()
        toks = jnp.asarray(self._next_tok[:, None])
        positions = jnp.asarray(self.pos)
        table = jnp.asarray(self._table) if self.paged else None
        live = [s for s, r in enumerate(self.active) if r is not None]
        if all(self._temp[s] <= 0 for s in live):
            keys = gen = temp = top_k = top_p = None  # argmax-only traces
        else:
            gen = jnp.asarray([len(r.out) if r is not None else 0
                               for r in self.active], jnp.int32)
            keys = jnp.asarray(self._keys)
            temp = jnp.asarray(self._temp)
            top_k, top_p = self._filter_vectors(self._top_k, self._top_p)
        probe = jax.tree.leaves(self.cache)
        dprobe = jax.tree.leaves(self.draft_cache)
        cand, qlog, self.draft_cache = self._jit_propose(
            self.draft_params, self.draft_cache, toks, positions,
            keys, gen, temp, top_k, top_p)
        out_dev, n_dev, self.cache = self._jit_verify(
            self.params, self.cache, cand, positions,
            jnp.asarray(kvec_np), keys, gen, temp, top_k, top_p, qlog,
            table)
        out_np, n_np = jax.device_get((out_dev, n_dev))  # THE one transfer
        self.host_syncs += 1
        self.decode_steps += 1
        self.spec_steps += 1
        # both models' caches must donate for the window to be copy-free
        self.cache_donated = (all(a.is_deleted() for a in probe)
                              and all(a.is_deleted() for a in dprobe))
        if not self.cache_donated:
            self.cache_bytes_moved += self._cache_nbytes
        if self.watchdog is not None:
            now = self._clock()
            self.stalled_steps += len(self.watchdog.failed(now))
            self.watchdog.beat(0, self.decode_steps, now=now)
        events = events0
        for s, req in enumerate(self.active):
            if req is None:
                continue
            n = int(n_np[s])
            window = [int(t) for t in out_np[s, :n]]
            if window[0] != _POISONED:
                # acceptance accounting: n - 1 of the kvec proposals were
                # committed (the window-end token is the engine's, not the
                # draft's), counted even when a stop/length finish inside
                # the window drops the tail of the stream
                kv = int(kvec_np[s])
                self.draft_proposed += kv
                self.draft_accepted += n - 1
                req.drafted += kv
                req.accepted += n - 1
                req.spec_windows += 1
            events += self._commit_slot(s, req, window)
        return events

    def _commit_slot(self, s: int, req: Request,
                     toks: list) -> list[StreamEvent]:
        """Fold committed tokens into one slot's stream state — shared by
        the one-token step (a 1-element window) and the speculative
        window. Stops at the first terminal condition: a _POISONED
        sentinel quarantines the slot (finish_reason="error", cache rows
        re-zeroed), a stop/length finish drops the rest of the window (the
        cache holds a few positions past the stream's end; they are never
        read — kv_len follows ``pos``, which stops advancing)."""
        events: list[StreamEvent] = []
        for tok in toks:
            if tok == _POISONED:
                # numeric quarantine: the slot's logits went non-finite.
                # Finish the stream loudly and re-zero the slot's cache
                # rows so the poison can't leak into a later tenant.
                self.quarantined += 1
                events.append(self._finish_slot(
                    s, req, FINISH_ERROR, token=None))
                self._zero_slot(s)
                break
            req.out.append(tok)
            self._next_tok[s] = tok
            self.pos[s] += 1
            self.tokens_decoded += 1
            ev = self._emit(s, req, tok)
            events.append(ev)
            if ev.finished:
                break
        return events

    def _ensure_decode_blocks(self) -> list[StreamEvent]:
        """Paged decode admission control: before each step, every live
        slot must own the block its next write lands in. On a dry pool,
        preempt a victim (lowest priority, newest admission) to free its
        blocks; when no victim exists the slot itself error-finishes — the
        pool physically cannot hold it."""
        from repro.serve.paged import PoolExhausted, blocks_needed
        events: list[StreamEvent] = []
        for s, req in enumerate(self.active):
            if req is None:
                continue
            # speculative slots pre-extend by their window lookahead: the
            # window can commit (and later read) positions up to
            # pos + kvec. Verify writes BEYOND pos + kvec (up to the
            # engine-wide K) land in the null block — never committed,
            # never read, finite garbage by the paged invariant.
            need = blocks_needed(self.pos[s], self.block_size,
                                 lookahead=int(self._slot_draft_k[s]))
            while len(self._slot_blocks[s]) < need:
                try:
                    blk = self.pool.alloc()
                except PoolExhausted:
                    victim = self._pick_victim(exclude=s)
                    if victim is not None and self.preempt(victim):
                        continue  # victim's blocks are free now: retry
                    self.pool_exhausted += 1
                    events.append(self._finish_slot(
                        s, req, FINISH_ERROR, token=None))
                    break  # _finish_slot released this slot's blocks
                self._slot_blocks[s].append(blk)
                self._table[s, len(self._slot_blocks[s]) - 1] = blk
        return events

    def _pick_victim(self, *, exclude: int) -> Optional[int]:
        """rid of the live request to preempt when the pool runs dry:
        lowest priority first, newest admission breaks ties (it has the
        least sunk prefill work)."""
        best = None
        for s, r in enumerate(self.active):
            if r is None or s == exclude:
                continue
            key = (int(getattr(r, "priority", 0)), -(r.t_admit or 0.0))
            if best is None or key < best[0]:
                best = (key, r.rid)
        return best[1] if best else None

    def _zero_slot(self, s: int) -> None:
        """Eagerly re-zero one slot's cache rows (quarantine cleanup).
        Paged engines already zeroed + freed the poisoned blocks in
        ``_release_blocks`` (via ``_finish_slot``); only the host-side
        counters remain."""
        if not self.paged:
            self.cache = _put_slots(self.cache,
                                    _zero_slots_like(self.cache, 1),
                                    jnp.asarray([s], jnp.int32))
        if self.spec:
            # the draft cache is dense even on paged engines; a poisoned
            # slot's draft rows are re-zeroed for the same reason its
            # target rows are (NaN is the garbage no mask neutralizes)
            self.draft_cache = _put_slots(self.draft_cache,
                                          _zero_slots_like(self.draft_cache,
                                                           1),
                                          jnp.asarray([s], jnp.int32))
        self.pos[s] = 0
        self._next_tok[s] = 0

    def _emit(self, s: int, req: Request, tok: int) -> StreamEvent:
        """Record one emitted token; finishes the slot on stop/length."""
        idx = len(req.out) - 1
        if tok in self._slot_stop[s]:
            return self._finish_slot(s, req, FINISH_STOP, token=tok)
        if (len(req.out) >= self._slot_max_new[s]
                or self.pos[s] >= self.max_len - 1):
            return self._finish_slot(s, req, FINISH_LENGTH, token=tok)
        return StreamEvent(req.rid, tok, idx)

    def _finish_slot(self, s: int, req: Request, reason: str,
                     token: Optional[int]) -> StreamEvent:
        req.done = True
        req.finish_reason = reason
        req.t_done = self._clock()
        if self.paged:
            # blocks return to the pool the moment the stream ends;
            # quarantine (reason="error") zeroes exclusively-held blocks
            # first so NaN never reenters circulation
            self._release_blocks(s, zero=(reason == FINISH_ERROR))
        self.active[s] = None
        self._slot_stop[s] = frozenset()
        self._temp[s] = 0.0
        self._top_k[s] = 0
        self._top_p[s] = 1.0
        self._slot_draft_k[s] = 0
        # tokenless terminal events (cancellation) index PAST the stream:
        # len(out), the position no token will ever fill — so (rid, index)
        # never collides with a real token's event
        idx = len(req.out) - 1 if token is not None else len(req.out)
        ev = StreamEvent(req.rid, token, idx, finished=True,
                         finish_reason=reason, stats=req.stats())
        if reason == FINISH_CANCELLED:
            self._pending_events.append(ev)
        return ev

    def step(self) -> list[tuple[int, int]]:
        """One decode step for every active slot; returns [(rid, token)]
        (legacy view of :meth:`_step_events`)."""
        if not any(r is not None for r in self.active):
            return []
        return [(e.rid, e.token) for e in self._step_events()
                if e.token is not None]

    def run(self, requests: list[Request]) -> list[Request]:
        """Drive all requests to completion with continuous admission —
        the closed-batch shim over :meth:`generate` (FIFO ordering via the
        engine's scheduler; benchmarks use it for token-parity baselines)."""
        for _ in self.generate(requests):
            pass
        return requests

    @property
    def cache_bytes(self) -> int:
        """Total bytes held by the slot cache (KV planes + scale planes +
        recurrent state). Benchmarks and tests assert the rotated-int8
        shrink against this instead of poking cache internals."""
        return int(sum(a.nbytes for a in jax.tree.leaves(self.cache)))

    def stats(self) -> dict:
        """Perf counters for the bench harness. ``cache_bytes_per_token``
        counts only the per-token self-attention KV planes — SSM/hybrid
        recurrent state and the audio cross-attention memory are O(1) in
        decoded tokens, so folding them in would misprice long contexts
        (an attention-free arch reports 0)."""
        attn = self.cache.get("attn", {})
        attn_bytes = sum(a.nbytes for a in jax.tree.leaves(attn))
        if self.paged:
            # pool planes are (L, NB, KV, BS, *): NB * BS addressable
            # positions, shared by every slot
            n_tokens_cap = self.num_blocks * self.block_size
        else:
            # divide by the buffer's REAL position count (frontend archs
            # allocate max_len + frontend_len slots), not max_len, so the
            # vision prefix isn't misbilled as per-decoded-token cost
            n_pos = attn["k"].shape[3] if attn else 1
            n_tokens_cap = self.slots * n_pos
        bytes_per_token = attn_bytes / max(n_tokens_cap, 1)
        # reserved: bytes requests currently CLAIM (a dense engine claims
        # its full B x max_len allocation for the engine's life; a paged
        # engine claims only allocated blocks). live: pos-weighted bytes of
        # tokens actually written — the gap between the two is the
        # reservation waste the paged pool exists to reclaim.
        live_tokens = int(sum(int(self.pos[s])
                              for s, r in enumerate(self.active)
                              if r is not None))
        if self.paged:
            reserved = bytes_per_token * self.pool.used() * self.block_size
        else:
            reserved = attn_bytes
        out = {
            "host_syncs": self.host_syncs,
            "tokens_decoded": self.tokens_decoded,
            "syncs_per_token": (self.host_syncs / self.tokens_decoded
                                if self.tokens_decoded else float("nan")),
            "cache_bytes": self.cache_bytes,
            "cache_bytes_reserved": int(reserved),
            "cache_bytes_live": int(bytes_per_token * live_tokens),
            "cache_bytes_per_token": bytes_per_token,
            "decode_steps": self.decode_steps,
            "cache_donated": self.cache_donated,
            "cache_bytes_moved": self.cache_bytes_moved,
            "scheduler": getattr(self.scheduler, "name",
                                 type(self.scheduler).__name__),
            "waiting": len(self.scheduler),
            # --- resilience counters ---
            "requests_rejected": self.requests_rejected,
            "requests_shed": self.requests_shed,
            "requests_invalid": self.requests_invalid,
            "deadline_expired": self.deadline_expired,
            "quarantined": self.quarantined,
            "preemptions": self.preemptions,
            "resumes": self.resumes,
            "stalled_steps": self.stalled_steps,
            "swapped": len(self._swapped),
            "max_queue": self.max_queue,
            "shed_policy": self.shed_policy,
            # --- compute-path knobs (which numeric paths served this run) ---
            "backend": self.rt.backend,
            "kv_quant": self.rt.kv_quant,
            "act_quant": self.rt.act_quant,
            "max_concurrent": self.max_concurrent,
        }
        if self.spec:
            out.update(
                speculative=True,
                num_draft_tokens=self._spec_k,
                spec_steps=self.spec_steps,
                draft_proposed=self.draft_proposed,
                draft_accepted=self.draft_accepted,
                acceptance_rate=(self.draft_accepted / self.draft_proposed
                                 if self.draft_proposed else float("nan")),
                tokens_per_step=(self.tokens_decoded / self.decode_steps
                                 if self.decode_steps else float("nan")),
                draft_cache_bytes=int(sum(
                    a.nbytes for a in jax.tree.leaves(self.draft_cache))),
            )
        if self.paged:
            out.update(
                paged=True,
                block_size=self.block_size,
                pool_blocks=self.pool.capacity,
                pool_blocks_used=self.pool.used(),
                pool_utilization=round(self.pool.utilization(), 4),
                blocks_swapped=self.blocks_swapped,
                pool_exhausted=self.pool_exhausted,
                prefix_hits=self.pool.prefix_hits,
            )
        if self.mesh is not None:
            from repro.serve import tp as tp_mod
            out["devices"] = self.mesh.devices.size
            out["cache_bytes_per_device"] = tp_mod.cache_bytes_per_device(
                self.cache)
            out["tp_shard_map"] = self.rt.tp_shard_map
        return out


def _sample_slots(last, keys, gen, temp, top_k, top_p):
    """Per-slot sampling inside the jitted step. ``keys`` (G, 2) are the
    requests' BASE keys; each row folds in its own request-local token
    index ``gen`` so the draw depends only on (request seed, token index) —
    never on the slot, the step, or the batchmates (the bit-parity
    contract). ``keys=None`` is the all-greedy fast path: bare argmax, no
    PRNG in the trace."""
    if keys is None:
        return lm.sample_tokens(last)
    step_keys = jax.vmap(jax.random.fold_in)(keys, gen)
    return lm.sample_tokens(last, step_keys, temp, top_k=top_k, top_p=top_p)


# --- slot gather/scatter over heterogeneous cache pytrees -------------------

def _batch_axis(a) -> int:
    """Cache leaves are either (L, B, ...) stacked per layer or (B, ...)."""
    return 1 if a.ndim >= 3 else 0


def _take_slots(cache, slots):
    """Gather the (G,)-slot sub-cache along each leaf's batch axis."""
    return jax.tree.map(
        lambda a: jnp.take(a, slots, axis=_batch_axis(a)), cache)


def _zero_slots_like(cache, g: int):
    """A fresh zero state for G slots (shape of a gathered sub-cache)."""
    def zero(a):
        ax = _batch_axis(a)
        shape = a.shape[:ax] + (g,) + a.shape[ax + 1:]
        return jnp.zeros(shape, a.dtype)
    return jax.tree.map(zero, cache)


def _put_slots(cache, part, slots):
    """Scatter a (G,)-slot sub-cache back into the full cache."""
    def put(full, p):
        ax = _batch_axis(full)
        fm = jnp.moveaxis(full, ax, 0)
        pm = jnp.moveaxis(p.astype(full.dtype), ax, 0)
        return jnp.moveaxis(fm.at[slots].set(pm), 0, ax)
    return jax.tree.map(put, cache, part)
