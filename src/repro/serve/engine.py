"""Serving engine: batched prefill/decode with continuous batching.

``ServeEngine`` owns a fixed slot-batched KV cache (B slots x max_len) and
admits requests continuously: free slots are prefilled with new prompts
(left-aligned, their own position counters) while other slots keep decoding
— the standard continuous-batching discipline (vLLM-style, static slots
instead of paged blocks; pages are unnecessary when max_len is fixed per
deployment, and static layouts are what TPU SPMD wants).

The engine is model-agnostic: any architecture in the zoo works, quantized
(QTensor params) or not. Per-slot position counters mask attention so slots
never see each other's garbage.

Hot-path discipline (the decode loop is the product):

* **One device->host transfer per step.** Sampling (greedy argmax or
  temperature) runs inside the jitted ``decode``; ``step()`` fetches a
  single (slots,) int32 vector. ``sample_on_host=True`` restores the
  pre-overhaul per-slot host argmax — kept as the measured baseline for
  benchmarks/serve_bench.py. ``host_syncs`` counts every transfer either
  way.
* **One compiled call per admission wave.** All free slots are admitted
  together: prompts are padded to one shared ``prompt_pad`` bucket and
  prefilled in a single jitted call that also ZEROES the admitted slots'
  cache/state (no separate reset pass) and samples each prompt's first
  token from its true last-real-token logits.
* **Bounded compile shapes for recurrent archs.** SSM/hybrid states
  integrate every fed token, so pad tokens would pollute them; instead of
  compiling one prefill per exact prompt length, prompts are fed in a
  power-of-two chunk ladder (``prompt_chunk``, then halves) with state
  threaded between calls — at most log2(prompt_chunk)+1 compiled shapes
  ever, regardless of traffic.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models.layers import Runtime

__all__ = ["Request", "ServeEngine"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (L,) int32
    max_new: int = 32
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, params, cfg, *, slots: int = 4, max_len: int = 256,
                 rt: Optional[Runtime] = None, prompt_pad: int = 64,
                 prompt_chunk: int = 16, temperature: float = 0.0,
                 seed: int = 0, sample_on_host: bool = False,
                 cache_dtype=jnp.float32):
        self.params = params
        self.cfg = cfg
        self.rt = rt or Runtime(compute_dtype=jnp.float32)
        self.slots = slots
        self.max_len = max_len
        self.prompt_pad = prompt_pad
        self.prompt_chunk = prompt_chunk
        self.temperature = float(temperature)
        self.sample_on_host = sample_on_host
        # Runtime.kv_quant lays the attention cache out as rotated-int8
        # codes + fp16 scales (serve/kv_quant.py); cache_dtype is the fp
        # cache element type otherwise (f32 default keeps CPU tests exact,
        # bf16 is the deployment baseline the bytes ratio is quoted against)
        self.cache = lm.init_cache(cfg, slots, max_len, dtype=cache_dtype,
                                   kv_quant=self.rt.kv_quant)
        self.pos = np.zeros(slots, dtype=np.int32)  # next write index per slot
        self.active: list[Optional[Request]] = [None] * slots
        self._next_tok = np.zeros(slots, dtype=np.int32)
        self._key = jax.random.PRNGKey(seed)
        self._step_idx = 0
        # --- perf counters (read by benchmarks/serve_bench.py and tests) ---
        self.host_syncs = 0       # device->host transfers
        self.tokens_decoded = 0   # tokens emitted by step()
        self._jit_prefill = jax.jit(self._prefill_impl,
                                    static_argnames=("plen", "fresh"))
        self._jit_decode = jax.jit(self._decode_impl)
        self._jit_decode_logits = jax.jit(self._decode_logits_impl)
        if self.rt.autotune:
            from repro.kernels import autotune as autotune_mod
            # no-op on CPU/interpret; on TPU, pre-tunes every QTensor matmul
            # shape at decode batch = slots so the hot loop runs tuned tiles
            autotune_mod.tune_params_shapes(params, slots)

    @classmethod
    def from_checkpoint(cls, ckpt_dir: str, cfg, *, step: Optional[int] = None,
                        **kw) -> "ServeEngine":
        """Boot an engine from a bare checkpoint directory — including
        policy-quantized checkpoints, whose QTensor leaves are rebuilt from
        their packed planes without re-running Algorithm 1 (the
        serve-from-disk path of the deployment story)."""
        from repro.checkpoint import ckpt as ckpt_mod  # lazy: optional dep

        params, _ = ckpt_mod.restore_params(ckpt_dir, step=step)
        return cls(params, cfg, **kw)

    # --- compiled kernels -------------------------------------------------
    def _prefill_impl(self, params, cache, tokens, slots, last_idx, pos0,
                      key, temperature, *, plen, fresh):
        """One admission wave: tokens (G, plen) for slot ids ``slots`` (G,).

        ``fresh=True`` starts each admitted slot from a ZEROED state (the
        old per-slot reset pass folded into this same compiled call);
        ``fresh=False`` continues from the slot's current state (the
        SSM/hybrid chunk ladder). Returns (cache, sampled (G,) first tokens,
        last-real-token logits (G, V))."""
        g = tokens.shape[0]
        if fresh:
            slot_cache = _zero_slots_like(cache, g)
        else:
            slot_cache = _take_slots(cache, slots)
        # pad tokens run through the model (masked later via pos), but the
        # head + first sampled token come from the TRUE last prompt
        # position only — one V-row per slot, not V logits per pad
        logits, new_slot_cache, _ = lm.forward(
            params, tokens, self.rt, self.cfg, cache=slot_cache, pos=pos0,
            last_idx=last_idx)
        cache = _put_slots(cache, new_slot_cache, slots)
        last = logits[:, 0]
        tok = lm.sample_tokens(last, key, temperature)
        return cache, tok, last

    def _decode_impl(self, params, cache, tokens, positions, key, temperature):
        """tokens (S, 1); per-slot positions (S,). Sampling stays on device:
        the step's only fetch is the (S,) token vector."""
        logits, new_cache = lm.decode_step(
            params, tokens, cache, positions, self.rt, self.cfg)
        tok = lm.sample_tokens(logits[:, 0], key, temperature)
        return tok, new_cache

    def _decode_logits_impl(self, params, cache, tokens, positions):
        """Pre-overhaul decode: ship logits out, sample on host."""
        logits, new_cache = lm.decode_step(
            params, tokens, cache, positions, self.rt, self.cfg)
        return logits[:, 0], new_cache

    # --- scheduler --------------------------------------------------------
    def _next_key(self):
        """Per-call PRNG key — or None when greedy, so the compiled step
        contains no PRNG work at all (sample_tokens traces to bare argmax)."""
        if self.temperature <= 0:
            return None
        self._step_idx += 1
        return jax.random.fold_in(self._key, self._step_idx)

    def submit(self, req: Request) -> bool:
        return self.admit([req]) == 1

    def admit(self, reqs: list[Request]) -> int:
        """Admit as many of ``reqs`` (in order) as there are free slots.
        Returns the number admitted."""
        free = [s for s in range(self.slots) if self.active[s] is None]
        group = reqs[: len(free)]
        if not group:
            return 0
        for r in group:
            # loud here, not garbage later: an empty prompt would gather
            # last_idx=-1 (a pad position) in the bucketed path
            if len(r.prompt) == 0:
                raise ValueError(f"request rid={r.rid} has an empty prompt")
        free = free[: len(group)]
        if self.cfg.family in ("ssm", "hybrid"):
            # recurrent state integrates every fed token: no pad buckets;
            # chunk ladder instead (bounded compiled shapes)
            for req, s in zip(group, free):
                self._admit_chunked(req, s)
            return len(group)
        self._admit_bucketed(group, free)
        return len(group)

    def _bucket(self, max_plen: int) -> int:
        pad = (-max_plen) % self.prompt_pad
        # cap padding so the padded prompt always fits the cache
        return max_plen + min(pad, max(0, self.max_len - 1 - max_plen))

    def _admit_bucketed(self, group: list[Request], free: list[int]) -> None:
        """Attention-family admission: every free slot in ONE padded-bucket
        compiled call (zero + prefill + first-token sample fused)."""
        plens = [int(len(r.prompt)) for r in group]
        bucket = self._bucket(max(plens))
        toks = np.stack([np.pad(np.asarray(r.prompt, np.int32),
                                (0, bucket - p))
                         for r, p in zip(group, plens)])
        self.cache, tok, last = self._jit_prefill(
            self.params, self.cache, jnp.asarray(toks),
            jnp.asarray(free, jnp.int32),
            jnp.asarray([p - 1 for p in plens], jnp.int32),
            jnp.zeros(len(group), jnp.int32),
            self._next_key(), jnp.float32(self.temperature),
            plen=bucket, fresh=True)
        self._finish_admission(group, free, plens, tok, last)

    def _admit_chunked(self, req: Request, s: int) -> None:
        """SSM/hybrid admission: exact-length feeding via a power-of-two
        chunk ladder with state threaded between compiled calls."""
        prompt = np.asarray(req.prompt, np.int32)
        plen = int(len(prompt))
        sizes, rem = [], plen
        while rem:
            c = self.prompt_chunk
            while c > rem:
                c //= 2
            sizes.append(c)
            rem -= c
        off, fresh = 0, True
        slot = jnp.asarray([s], jnp.int32)
        for c in sizes:
            self.cache, tok, last = self._jit_prefill(
                self.params, self.cache, jnp.asarray(prompt[None, off:off + c]),
                slot, jnp.asarray([c - 1], jnp.int32),
                jnp.asarray([off], jnp.int32),
                self._next_key(), jnp.float32(self.temperature),
                plen=c, fresh=fresh)
            fresh = False
            off += c
        self._finish_admission([req], [s], [plen], tok, last)

    def _finish_admission(self, group, free, plens, tok, last) -> None:
        if self.sample_on_host:
            firsts = [int(jnp.argmax(last[g])) for g in range(len(group))]
            self.host_syncs += len(group)
        else:
            firsts = np.asarray(tok)
            self.host_syncs += 1
        for g, (req, s) in enumerate(zip(group, free)):
            self.pos[s] = plens[g]
            first = int(firsts[g])
            req.out.append(first)
            self._next_tok[s] = first
            self.active[s] = req

    def step(self) -> list[tuple[int, int]]:
        """One decode step for every active slot; returns [(rid, token)]."""
        if not any(self.active):
            return []
        toks = jnp.asarray(self._next_tok[:, None])
        positions = jnp.asarray(self.pos)
        if self.sample_on_host:
            logits, self.cache = self._jit_decode_logits(
                self.params, self.cache, toks, positions)
            tok_np = None
        else:
            tok_dev, self.cache = self._jit_decode(
                self.params, self.cache, toks, positions,
                self._next_key(), jnp.float32(self.temperature))
            tok_np = np.asarray(tok_dev)  # THE step's one transfer
            self.host_syncs += 1
        emitted = []
        for s, req in enumerate(self.active):
            if req is None:
                continue
            if tok_np is None:
                tok = int(jnp.argmax(logits[s]))  # one transfer per slot
                self.host_syncs += 1
            else:
                tok = int(tok_np[s])
            req.out.append(tok)
            self._next_tok[s] = tok
            self.pos[s] += 1
            self.tokens_decoded += 1
            emitted.append((req.rid, tok))
            if len(req.out) >= req.max_new or self.pos[s] >= self.max_len - 1:
                req.done = True
                self.active[s] = None
        return emitted

    def run(self, requests: list[Request]) -> list[Request]:
        """Drive all requests to completion with continuous admission."""
        pending = list(requests)
        while pending or any(self.active):
            admitted = self.admit(pending)
            del pending[:admitted]
            self.step()
        return requests

    @property
    def cache_bytes(self) -> int:
        """Total bytes held by the slot cache (KV planes + scale planes +
        recurrent state). Benchmarks and tests assert the rotated-int8
        shrink against this instead of poking cache internals."""
        return int(sum(a.nbytes for a in jax.tree.leaves(self.cache)))

    def stats(self) -> dict:
        """Perf counters for the bench harness. ``cache_bytes_per_token``
        counts only the per-token self-attention KV planes — SSM/hybrid
        recurrent state and the audio cross-attention memory are O(1) in
        decoded tokens, so folding them in would misprice long contexts
        (an attention-free arch reports 0)."""
        attn = self.cache.get("attn", {})
        attn_bytes = sum(a.nbytes for a in jax.tree.leaves(attn))
        # divide by the buffer's REAL position count (frontend archs allocate
        # max_len + frontend_len slots), not max_len, so the vision prefix
        # isn't misbilled as per-decoded-token cost
        n_pos = attn["k"].shape[3] if attn else 1
        return {
            "host_syncs": self.host_syncs,
            "tokens_decoded": self.tokens_decoded,
            "syncs_per_token": (self.host_syncs / self.tokens_decoded
                                if self.tokens_decoded else float("nan")),
            "cache_bytes": self.cache_bytes,
            "cache_bytes_per_token": attn_bytes / (self.slots * n_pos),
        }


# --- slot gather/scatter over heterogeneous cache pytrees -------------------

def _batch_axis(a) -> int:
    """Cache leaves are either (L, B, ...) stacked per layer or (B, ...)."""
    return 1 if a.ndim >= 3 else 0


def _take_slots(cache, slots):
    """Gather the (G,)-slot sub-cache along each leaf's batch axis."""
    return jax.tree.map(
        lambda a: jnp.take(a, slots, axis=_batch_axis(a)), cache)


def _zero_slots_like(cache, g: int):
    """A fresh zero state for G slots (shape of a gathered sub-cache)."""
    def zero(a):
        ax = _batch_axis(a)
        shape = a.shape[:ax] + (g,) + a.shape[ax + 1:]
        return jnp.zeros(shape, a.dtype)
    return jax.tree.map(zero, cache)


def _put_slots(cache, part, slots):
    """Scatter a (G,)-slot sub-cache back into the full cache."""
    def put(full, p):
        ax = _batch_axis(full)
        fm = jnp.moveaxis(full, ax, 0)
        pm = jnp.moveaxis(p.astype(full.dtype), ax, 0)
        return jnp.moveaxis(fm.at[slots].set(pm), 0, ax)
    return jax.tree.map(put, cache, part)
