"""Serving engine: batched prefill/decode with continuous batching.

``ServeEngine`` owns a fixed slot-batched KV cache (B slots x max_len) and
admits requests continuously: a free slot is prefilled with the new prompt
(left-aligned, its own position counter) while other slots keep decoding —
the standard continuous-batching discipline (vLLM-style, static slots
instead of paged blocks; pages are unnecessary when max_len is fixed per
deployment, and static layouts are what TPU SPMD wants).

The engine is model-agnostic: any architecture in the zoo works, quantized
(QTensor params) or not. Per-slot position counters mask attention so slots
never see each other's garbage; SSM/hybrid states are reset per admission.

jit boundaries: one compiled ``prefill`` (padded prompt -> cache insert at
slot) and one compiled ``decode`` (all slots, one token each). Sampling is
greedy or temperature on the host for simplicity of the example drivers.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models.layers import Runtime

__all__ = ["Request", "ServeEngine"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (L,) int32
    max_new: int = 32
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, params, cfg, *, slots: int = 4, max_len: int = 256,
                 rt: Optional[Runtime] = None, prompt_pad: int = 64):
        self.params = params
        self.cfg = cfg
        self.rt = rt or Runtime(compute_dtype=jnp.float32)
        self.slots = slots
        self.max_len = max_len
        self.prompt_pad = prompt_pad
        self.cache = lm.init_cache(cfg, slots, max_len, dtype=jnp.float32)
        self.pos = np.zeros(slots, dtype=np.int32)  # next write index per slot
        self.active: list[Optional[Request]] = [None] * slots
        self._jit_prefill = jax.jit(self._prefill_impl, static_argnames=("plen",))
        self._jit_decode = jax.jit(self._decode_impl)

    @classmethod
    def from_checkpoint(cls, ckpt_dir: str, cfg, *, step: Optional[int] = None,
                        **kw) -> "ServeEngine":
        """Boot an engine from a bare checkpoint directory — including
        policy-quantized checkpoints, whose QTensor leaves are rebuilt from
        their packed planes without re-running Algorithm 1 (the
        serve-from-disk path of the deployment story)."""
        from repro.checkpoint import ckpt as ckpt_mod  # lazy: optional dep

        params, _ = ckpt_mod.restore_params(ckpt_dir, step=step)
        return cls(params, cfg, **kw)

    # --- compiled kernels -------------------------------------------------
    def _prefill_impl(self, params, cache, tokens, slot, *, plen):
        """tokens (1, plen) for one slot; returns (cache, last_logits)."""
        slot_cache = jax.tree.map(lambda a: jax.lax.dynamic_slice_in_dim(
            a, slot, 1, axis=_batch_axis(a)), cache)
        logits, new_slot_cache, _ = lm.forward(
            params, tokens, self.rt, self.cfg, cache=slot_cache, pos=0)
        cache = jax.tree.map(
            lambda full, s: jax.lax.dynamic_update_slice_in_dim(
                full, s.astype(full.dtype), slot, axis=_batch_axis(full)),
            cache, new_slot_cache)
        return cache, logits[:, -1]

    def _decode_impl(self, params, cache, tokens, positions):
        """tokens (S, 1); per-slot positions (S,) — decode_step handles
        ragged per-row positions natively."""
        logits, new_cache = lm.decode_step(
            params, tokens, cache, positions, self.rt, self.cfg)
        return logits[:, 0], new_cache

    # --- scheduler --------------------------------------------------------
    def submit(self, req: Request) -> bool:
        for s in range(self.slots):
            if self.active[s] is None:
                plen = int(len(req.prompt))
                # recurrent-state archs integrate every fed token, so pads
                # would pollute the state: prefill exact-length there. Cap
                # padding so the padded prompt always fits the cache.
                pad = 0 if self.cfg.family in ("ssm", "hybrid") else (-plen % self.prompt_pad)
                pad = min(pad, max(0, self.max_len - 1 - plen))
                toks = np.pad(req.prompt, (0, pad)).astype(np.int32)
                # reset slot state then prefill (padding tokens are masked
                # out by the position counter: we only advance pos by plen)
                self.cache = self._reset_slot(self.cache, s)
                self.cache, last = self._jit_prefill(
                    self.params, self.cache, jnp.asarray(toks[None]),
                    jnp.int32(s), plen=toks.shape[0])
                # padded prefill wrote pad junk past plen; pos tracks real len
                self.pos[s] = plen
                first = int(jnp.argmax(last[0]))
                req.out.append(first)
                self.active[s] = req
                return True
        return False

    def _reset_slot(self, cache, s: int):
        def zap(a):
            ax = _batch_axis(a)
            zeros = jnp.zeros_like(jax.lax.dynamic_slice_in_dim(a, s, 1, axis=ax))
            return jax.lax.dynamic_update_slice_in_dim(a, zeros, s, axis=ax)
        return jax.tree.map(zap, cache)

    def step(self) -> list[tuple[int, int]]:
        """One decode step for every active slot; returns [(rid, token)]."""
        if not any(self.active):
            return []
        toks = np.zeros((self.slots, 1), dtype=np.int32)
        for s, req in enumerate(self.active):
            if req is not None:
                toks[s, 0] = req.out[-1]
        logits, self.cache = self._jit_decode(
            self.params, self.cache, jnp.asarray(toks),
            jnp.asarray(self.pos))
        emitted = []
        for s, req in enumerate(self.active):
            if req is None:
                continue
            tok = int(jnp.argmax(logits[s]))
            req.out.append(tok)
            self.pos[s] += 1
            emitted.append((req.rid, tok))
            if len(req.out) >= req.max_new or self.pos[s] >= self.max_len - 1:
                req.done = True
                self.active[s] = None
        return emitted

    def run(self, requests: list[Request]) -> list[Request]:
        """Drive all requests to completion with continuous admission."""
        pending = list(requests)
        while pending or any(self.active):
            while pending and self.submit(pending[0]):
                pending.pop(0)
            self.step()
        return requests


def _batch_axis(a) -> int:
    """Cache leaves are either (L, B, ...) stacked per layer or (B, ...)."""
    return 1 if a.ndim >= 3 else 0
