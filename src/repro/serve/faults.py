"""Deterministic fault injection for the serving resilience layer.

Real serving failures are timing-dependent and hard to reproduce: a KV
scale plane goes denormal-then-inf under a driver bug, a host GC pause
stalls a decode step, a burst of traffic overruns the queue. This module
makes each of those a SEEDED, REPLAYABLE event so the resilience policies
in :class:`~repro.serve.engine.ServeEngine` (numeric quarantine, deadlines,
backpressure, watchdog) are exercised by ordinary unit tests instead of
luck — the same discipline ``ft/monitor.py`` applies to training failures.

Pieces:

* :class:`FaultClock` — a deterministic engine clock. Each read advances
  by ``tick`` (so engine stamps stay strictly ordered without wall time),
  and :meth:`FaultClock.advance` jumps it — how tests expire deadlines and
  trip the watchdog without sleeping.
* :class:`Fault` — one scheduled event, keyed by the engine's
  ``decode_steps`` counter (the only monotonic notion of "when" the engine
  shares with the plan):

  - ``kind="kv_nan"``: overwrite a slot's KV **scale plane** entries with
    ``value`` (inf/NaN). Scales are the right poison target — the int8
    code planes cannot hold a NaN, and a degenerate scale is exactly how
    real quantized-cache corruption presents (one bad fp16 multiplies a
    whole vector). Only positions **below the slot's write head** are
    poisoned, so detection never depends on how the attention mask treats
    unwritten positions.
  - ``kind="clock_skip"``: advance the plan's :class:`FaultClock` by
    ``dt`` seconds (deadline/timeout expiry).
  - ``kind="stall"``: same clock jump, framed as a stalled step — what the
    engine's watchdog counts.
  - ``kind="cancel"`` / ``kind="preempt"``: call ``engine.cancel(rid)`` /
    ``engine.preempt(rid)`` at the top of the step. Because
    ``before_decode`` fires BETWEEN decode windows, on a speculative
    engine this lands exactly at the propose/verify window boundary — the
    chaos scenario proving a mid-stream eviction emits exactly one
    terminal StreamEvent and frees both target and draft cache state,
    however many tokens the previous window committed. (The engine is
    synchronous, so "mid-window" interruption can only be observed at
    this boundary; the window itself is one atomic jitted step.)

* :class:`FaultPlan` — the ordered fault schedule plus the clock. Pass it
  to ``ServeEngine(faults=...)``: the engine calls :meth:`before_decode`
  at the top of every decode step and (when no explicit ``clock`` is
  given) adopts ``plan.clock``, so one object fully scripts a scenario.
* :func:`burst` — a seeded batch of uniform requests for overflowing
  ``max_queue`` (the backpressure scenario).

Everything is driven by explicit seeds and step indices — two runs of the
same plan produce byte-identical engine behavior, which is what lets tests
assert healthy neighbor streams are *bit-identical* to a fault-free run.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax.numpy as jnp
import numpy as np

__all__ = ["FaultClock", "Fault", "FaultPlan", "inject_kv_nan", "burst"]


class FaultClock:
    """Deterministic time source for the engine's ``clock=`` knob.

    Every read returns the current time then advances it by ``tick``
    (default 1 ms) — strictly monotone, so lifecycle stamps (submit <
    admit < first < done) keep their ordering invariants without any wall
    time. :meth:`advance` jumps the clock by ``dt`` seconds; faults use it
    to expire deadlines and stall steps on demand."""

    def __init__(self, t0: float = 0.0, tick: float = 1e-3):
        self.t = float(t0)
        self.tick = float(tick)

    def __call__(self) -> float:
        now = self.t
        self.t += self.tick
        return now

    def advance(self, dt: float) -> None:
        self.t += float(dt)


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scheduled fault. ``step`` compares against the engine's
    ``decode_steps`` counter with ``>=`` so a fault scheduled for a step
    the engine skipped (e.g. everything finished early) still fires at the
    next opportunity rather than silently never."""

    kind: str  # "kv_nan" | "clock_skip" | "stall" | "cancel" | "preempt"
    step: int  # fires at the first decode step with decode_steps >= step
    slot: int = 0            # kv_nan: which cache slot to poison
    plane: str = "k_scale"   # kv_nan: which attn plane ("k_scale"/"v_scale"
    #   for the quantized cache, "k"/"v" for an fp cache)
    value: float = math.nan  # kv_nan: the poison (nan or +/-inf)
    dt: float = 0.0          # clock_skip/stall: seconds to jump the clock
    rid: Optional[int] = None  # cancel/preempt: target request id

    _KINDS = ("kv_nan", "clock_skip", "stall", "cancel", "preempt")

    def __post_init__(self):
        if self.kind not in self._KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"options {self._KINDS}")
        if self.kind in ("cancel", "preempt") and self.rid is None:
            raise ValueError(f"{self.kind} fault needs a target rid")


class FaultPlan:
    """An ordered, replayable fault schedule threaded through the engine.

    The engine calls :meth:`before_decode` at the top of every decode
    step; each :class:`Fault` fires exactly once (tracked by identity in
    ``_fired``) at the first step whose ``decode_steps`` reaches it.
    ``log`` records ``(decode_steps, kind)`` per firing so tests can
    assert the scenario actually ran."""

    def __init__(self, faults=(), *, seed: int = 0,
                 clock: Optional[FaultClock] = None):
        self.faults = tuple(faults)
        self.seed = int(seed)
        self.clock = clock if clock is not None else FaultClock()
        self.log: list[tuple[int, str]] = []
        self._fired: set[int] = set()  # indices into self.faults

    def before_decode(self, engine) -> None:
        for i, f in enumerate(self.faults):
            if i in self._fired or engine.decode_steps < f.step:
                continue
            self._fired.add(i)
            self.log.append((engine.decode_steps, f.kind))
            if f.kind == "kv_nan":
                inject_kv_nan(engine, slot=f.slot, plane=f.plane,
                              value=f.value)
            elif f.kind == "cancel":
                engine.cancel(f.rid)
            elif f.kind == "preempt":
                engine.preempt(f.rid)
            else:  # clock_skip / stall: both are a deterministic time jump
                self.clock.advance(f.dt)


def inject_kv_nan(engine, *, slot: int = 0, plane: str = "k_scale",
                  value: float = math.nan) -> None:
    """Poison one slot's KV ``plane`` with ``value`` at every position the
    slot has WRITTEN (``< pos[slot]``) — the corruption class the numeric
    quarantine exists for (a degenerate scale multiplies a whole rotated
    vector into inf/NaN, which attention then spreads across the row's
    logits). Raises for integer planes: int8 codes cannot represent a NaN,
    which is exactly why scales are the realistic target."""
    attn = engine.cache.get("attn")
    if not attn or plane not in attn:
        raise KeyError(
            f"cache has no attn plane {plane!r}; have "
            f"{sorted(attn) if attn else 'no attn cache'}")
    leaf = attn[plane]
    if not jnp.issubdtype(leaf.dtype, jnp.floating):
        raise TypeError(
            f"plane {plane!r} is {leaf.dtype}: integer code planes cannot "
            f"hold {value!r}; poison a float scale plane instead")
    upto = max(int(engine.pos[slot]), 1)
    if getattr(engine, "paged", False):
        # paged pool: leaves are (L, NB, KV, BS, ...); route the poison
        # through the slot's block table to the same logical positions the
        # dense fault hits — the corruption a real driver bug would land in
        # whatever blocks the slot happens to own
        bs = engine.block_size
        tbl = np.asarray(engine._table[slot])
        p = np.arange(upto)
        blk = tbl[p // bs]
        attn[plane] = leaf.at[:, blk, :, p % bs].set(value)
    else:
        # leaves are (L, B, H, P, ...): poison every layer/head of `slot`
        # at the positions already written (never the unwritten tail, so
        # the check can't silently pass or fail through mask conventions)
        attn[plane] = leaf.at[:, slot, :, :upto].set(value)


def burst(n: int, vocab: int, *, seed: int = 0, plen: int = 8,
          max_new: int = 8, rid0: int = 0, priority: int = 0,
          **req_kw) -> list:
    """A seeded batch of ``n`` uniform requests — the traffic spike that
    overruns ``max_queue`` in the backpressure tests and ``--chaos``."""
    from repro.serve.engine import Request  # here to avoid a module cycle

    rng = np.random.default_rng(seed)
    return [Request(rid=rid0 + i,
                    prompt=rng.integers(0, vocab, size=plen).astype(np.int32),
                    max_new=max_new, priority=priority, **req_kw)
            for i in range(n)]
