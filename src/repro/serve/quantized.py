"""Whole-model quantization pass: params pytree -> pytree with QTensor
matmul leaves (the offline half of ITQ3_S deployment, paper Algorithm 1
applied model-wide).

Which leaves quantize — and into which format — is decided by a
:class:`QuantPolicy`: an ordered list of :class:`QuantRule` entries matched
against the **full dotted path** of each leaf (``"layers.attn.wq"``,
``"lm_head"``, ...), first match wins. Each rule carries the target format
(``fmt=None`` pins the leaf at full precision) plus optional per-rule
``rule``/``seed``/``sub_blocks`` overrides, so mixed-precision recipes —
TernaryLLM/Tequila-style "quality-critical projections at higher precision,
ternarize the rest" — are one declarative, JSON-round-trippable object:

    policy = QuantPolicy.from_dict({"rules": [
        {"pattern": r"(^|\\.)lm_head$", "fmt": "q8_0"},
        {"pattern": r"(^|\\.)(gate|up|down)$", "fmt": "itq3_s_sub"},
        {"pattern": MATMUL_LEAVES, "fmt": "itq3_s"},
    ]})
    qparams = quantize_params(params, policy)

Safety rails apply regardless of policy: leaves without ``ndim >= 2`` or
with a degenerate reduction dim stay fp (norms, biases, decay vectors,
router — quality-critical, ~0.01% of params). Stacked leaves (layers,
experts) are quantized with nested vmap so block statistics are computed
per-matrix exactly as the paper specifies. The embedding table (gathered,
not matmul'd) is only touched by an explicit ``embed`` rule and is
quantized transposed, as (V, D) blocks.

``quantize_params(params, "itq3_s")`` — the original uniform-format call —
keeps working and is expressed as ``QuantPolicy.uniform``.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Optional

import jax

from repro.core import formats
from repro.core.quantize import QTensor

__all__ = [
    "QuantRule", "QuantPolicy", "quantize_params", "quantized_bytes",
    "describe_quantized", "QUANTIZABLE", "MATMUL_LEAVES", "MIN_REDUCTION",
]

# Leaf names of every matmul projection across the model zoo
# (attention/MLP/MoE projections, LM head, frontend proj), anchored so it
# can be used inside full-path rules.
MATMUL_LEAVES = (r"(^|\.)(wq|wk|wv|wo|wg|wr|wz|wx|gate|up|down|lm_head|"
                 r"out_proj|cm_k|cm_v|frontend_proj)$")
# Back-compat alias: pre-policy code matched this against bare leaf names.
QUANTIZABLE = re.compile(MATMUL_LEAVES)
MIN_REDUCTION = 64  # don't quantize degenerate tiny projections


@dataclasses.dataclass(frozen=True)
class QuantRule:
    """One policy entry: regex over the full dotted leaf path -> format.

    ``fmt=None`` pins matching leaves at full precision (an explicit "keep
    the router fp" is an early ``fmt=None`` rule). ``rule``/``seed``/
    ``sub_blocks`` override the policy-wide defaults for matching leaves;
    ``sub_blocks`` is honoured by the ternary family (finer scale
    granularity on selected layers). ``act_quant`` is the per-path W3A8
    opt-in/out: ``False`` pins matching paths to the float contraction even
    when ``Runtime.act_quant`` turns the integer path on (e.g. keep
    ``lm_head`` full-fidelity), ``True``/``None`` leave the runtime knob in
    charge (QMeta defaults to eligible)."""

    pattern: str
    fmt: Optional[str]
    rule: Optional[str] = None  # scale rule: "paper" | "lloyd"
    seed: Optional[int] = None
    sub_blocks: Optional[int] = None
    act_quant: Optional[bool] = None

    def __post_init__(self):
        re.compile(self.pattern)  # fail fast on bad patterns
        if self.fmt is not None:
            spec = formats.get_format(self.fmt)  # fail fast on unknown formats
            if self.sub_blocks is not None and not isinstance(
                    spec, formats.TernaryFormat):
                raise ValueError(
                    f"rule {self.pattern!r}: sub_blocks override requires a "
                    f"ternary format, got {self.fmt!r}")

    def matches(self, path: str) -> bool:
        return re.search(self.pattern, path) is not None

    def to_dict(self) -> dict[str, Any]:
        return {k: v for k, v in dataclasses.asdict(self).items()
                if v is not None or k in ("pattern", "fmt")}

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "QuantRule":
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """Ordered quantization rules; first matching rule decides each leaf.

    Leaves matched by no rule stay full precision. ``rule``/``seed`` are the
    defaults a :class:`QuantRule` can override per-entry."""

    rules: tuple[QuantRule, ...] = ()
    rule: str = "paper"
    seed: int = 0

    def __post_init__(self):
        object.__setattr__(self, "rules", tuple(
            r if isinstance(r, QuantRule)
            else QuantRule(**r) if isinstance(r, dict)
            else QuantRule(*r)
            for r in self.rules))

    # --- construction ---------------------------------------------------
    @classmethod
    def uniform(cls, fmt: str, *, rule: str = "paper", seed: int = 0,
                include_embed: bool = False) -> "QuantPolicy":
        """The pre-policy behavior: every matmul projection -> ``fmt``."""
        rules = [QuantRule(MATMUL_LEAVES, fmt)]
        if include_embed:
            rules.append(QuantRule(r"(^|\.)embed$", fmt))
        return cls(tuple(rules), rule=rule, seed=seed)

    # --- lookup ---------------------------------------------------------
    def match(self, path: str) -> Optional[QuantRule]:
        for r in self.rules:
            if r.matches(path):
                return r
        return None

    # --- serialization --------------------------------------------------
    def to_dict(self) -> dict[str, Any]:
        return {"rules": [r.to_dict() for r in self.rules],
                "rule": self.rule, "seed": self.seed}

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "QuantPolicy":
        return cls(tuple(QuantRule.from_dict(r) for r in d.get("rules", ())),
                   rule=d.get("rule", "paper"), seed=d.get("seed", 0))


def _dotted(path) -> str:
    return ".".join(
        str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", p))))
        for p in path)


def quantize_params(params, fmt: "str | QuantPolicy" = "itq3_s", *,
                    rule: str = "paper", include_embed: bool = False,
                    seed: int = 0):
    """Map over the param tree quantizing leaves per policy.

    ``fmt`` is either a format name (uniform policy over all matmul
    projections — the original API) or a :class:`QuantPolicy`."""
    policy = fmt if isinstance(fmt, QuantPolicy) else QuantPolicy.uniform(
        fmt, rule=rule, seed=seed, include_embed=include_embed)

    def visit(path, leaf):
        if not hasattr(leaf, "ndim"):
            return leaf
        dotted = _dotted(path)
        r = policy.match(dotted)
        if r is None or r.fmt is None:
            return leaf
        spec = formats.get_format(r.fmt)
        kwargs: dict[str, Any] = dict(rule=r.rule or policy.rule,
                                      seed=policy.seed if r.seed is None else r.seed)
        if r.sub_blocks is not None:
            kwargs["sub_blocks"] = r.sub_blocks

        def finish(qt):
            if r.act_quant is None:
                return qt
            return QTensor(qt.data, dataclasses.replace(
                qt.meta, act_quant=r.act_quant))

        is_embed = dotted.split(".")[-1] == "embed"
        if is_embed:
            # table is gathered, not matmul'd: quantize as (V, D) blocks
            if leaf.ndim != 2:
                return leaf
            return finish(spec.quantize(leaf.T, **kwargs))
        if leaf.ndim < 2 or leaf.shape[-2] < MIN_REDUCTION:
            return leaf

        fn = lambda w: spec.quantize(w, **kwargs)
        for _ in range(leaf.ndim - 2):
            fn = jax.vmap(fn)
        return finish(fn(leaf))

    return jax.tree_util.tree_map_with_path(visit, params)


def quantized_bytes(params) -> int:
    total = 0
    for leaf in jax.tree_util.tree_leaves(
            params, is_leaf=lambda x: isinstance(x, QTensor)):
        if isinstance(leaf, QTensor):
            total += leaf.nbytes()
        elif hasattr(leaf, "nbytes"):
            total += leaf.nbytes
    return total


def describe_quantized(params) -> dict[str, str]:
    """{dotted path: format name} for every quantized leaf — the audit view
    of what a policy actually did (examples/benchmarks print this)."""
    out: dict[str, str] = {}

    def visit(path, leaf):
        if isinstance(leaf, QTensor):
            out[_dotted(path)] = leaf.meta.fmt
        return leaf

    jax.tree_util.tree_map_with_path(
        visit, params, is_leaf=lambda x: isinstance(x, QTensor))
    return out
