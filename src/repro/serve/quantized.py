"""Whole-model quantization pass: params pytree -> pytree with QTensor
matmul leaves (the offline half of ITQ3_S deployment, paper Algorithm 1
applied model-wide).

Which leaves quantize: 2-D+ matmul weights (attention/MLP/MoE projections,
LM head, frontend proj). Which stay fp: norms, biases, decay vectors, conv
kernels, router (quality-critical, ~0.01% of params), and by default the
embedding table (gather, not matmul; knob to include it for tied-embedding
models). Stacked leaves (layers, experts) are quantized with nested vmap so
block statistics are computed per-matrix exactly as the paper specifies.
"""
from __future__ import annotations

import re
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import formats
from repro.core.quantize import QTensor

__all__ = ["quantize_params", "quantized_bytes", "QUANTIZABLE"]

QUANTIZABLE = re.compile(
    r"(wq|wk|wv|wo|wg|wr|wz|wx|gate|up|down|lm_head|out_proj|cm_k|cm_v|frontend_proj)$")
MIN_REDUCTION = 64  # don't quantize degenerate tiny projections


def _leaf_name(path) -> str:
    return str(getattr(path[-1], "key", getattr(path[-1], "name", path[-1])))


def quantize_params(params, fmt: str = "itq3_s", *, rule: str = "paper",
                    include_embed: bool = False, seed: int = 0):
    """Map over the param tree quantizing matmul leaves into ``fmt``."""

    def q2d(w):
        return formats.quantize(w, fmt, rule=rule, seed=seed)

    def visit(path, leaf):
        name = _leaf_name(path)
        if not hasattr(leaf, "ndim"):
            return leaf
        if name == "embed" and include_embed:
            # table is gathered, not matmul'd: quantize as (V, D) blocks
            return formats.quantize(leaf.T, fmt, rule=rule, seed=seed)
        if not QUANTIZABLE.search(name):
            return leaf
        if leaf.ndim < 2 or leaf.shape[-2] < MIN_REDUCTION:
            return leaf
        fn = q2d
        for _ in range(leaf.ndim - 2):
            fn = jax.vmap(fn)
        return fn(leaf)

    return jax.tree_util.tree_map_with_path(visit, params)


def quantized_bytes(params) -> int:
    total = 0
    for leaf in jax.tree_util.tree_leaves(
            params, is_leaf=lambda x: isinstance(x, QTensor)):
        if isinstance(leaf, QTensor):
            total += leaf.nbytes()
        elif hasattr(leaf, "nbytes"):
            total += leaf.nbytes
    return total
