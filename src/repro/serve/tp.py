"""Tensor-parallel serving placement: PartitionSpecs and shard_map wrappers
for the packed ITQ3_S planes and the rotated-int8 KV cache.

The serving TP layout is **column-parallel everywhere**:

* Every packed QTensor data plane (``plane2``/``plane1``/``scales``/``zps``)
  is sharded along its leading output-feature dim N over the ``model`` axis
  (`sharding/rules.py` `_qtensor_leaf_spec`). The per-256-block FWHT/IFWHT
  is block-local along K, so N-sharding never splits a transform: each
  device unpacks, dequantizes, and contracts only its own tiles. The packed
  reduction stream (3.125 bpw) is replicated — cheap, and it keeps the
  decode hot loop free of weight all-gathers.
* The rotated-int8 KV cache shards its codes *and* scale planes along the
  kv_heads dim: each device holds the full time axis for its own heads, so
  decode/prefill attention (per-head online softmax) is device-local with
  NO collective inside the softmax. GQA head counts that don't divide the
  ``model`` axis fall back to a **replicated** cache — a too-small KV is
  the one shape where correctness beats memory.
* fp leaves that survive quantization (norms, biases, routers, SSM decay
  vectors) are replicated; the embedding table shards its D column (the
  gather is exact under column sharding). Row-parallel fp TP (K-sharded
  ``wo``/``down`` + psum) exists on the training side (`R.param_pspecs`);
  serving deliberately avoids it because a psum is a cross-device float
  reduction — the one thing that would break the engine's bit-identical
  token-stream contract. All collectives the serving layout ever needs are
  all-gathers, which are exact.

Two execution paths share these specs:

* **sharding-constrained jit** (default off-TPU): operands carry
  NamedShardings, `shard_hint` constraints steer GSPMD, XLA partitions the
  ref einsums itself.
* **shard_map** (``Runtime.tp_shard_map``, default on real TPU): GSPMD
  cannot partition a ``pallas_call``, so :func:`tp_qmatmul` /
  :func:`tp_decode_attn_q8` / :func:`tp_prefill_attn_q8` explicitly
  shard_map the kernels — each device runs the full fused kernel on its own
  N- (or head-) shard, collective-free.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.qlinear import qmatmul
from repro.core.quantize import QTensor
from repro.kernels.attn_decode import decode_attn_q8, prefill_attn_q8
from repro.sharding import rules as R

__all__ = [
    "serve_rules", "serve_param_pspecs", "param_shardings", "shard_params",
    "cache_pspecs", "shard_cache", "cache_bytes_per_device",
    "restore_shardings", "place_draft", "can_tp_qmatmul", "tp_qmatmul",
    "tp_decode_attn_q8", "tp_prefill_attn_q8",
]


# ---------------------------------------------------------------------------
# Rules / specs
# ---------------------------------------------------------------------------

def serve_rules(mesh: Mesh, cfg) -> R.Rules:
    """Serving variant of :func:`repro.sharding.rules.make_rules`: no FSDP
    (serving weights are read-only), and no sequence-sharded KV — the fused
    attention path runs one online softmax per head, and splitting that
    softmax across devices would put a collective inside the decode loop.
    When GQA kv_heads don't divide the model axis the KV cache is simply
    REPLICATED (``kv_heads=None, kv_seq=None``), trading memory for an
    intact per-head kernel."""
    rules = R.make_rules(mesh, cfg, fsdp=False)
    assignments = dict(rules.assignments)
    assignments["kv_seq"] = None  # never split a serving softmax
    assignments["seq_sp"] = None  # decode is T=1; SP buys nothing here
    return R.Rules(mesh=mesh, assignments=assignments)


def serve_param_pspecs(params, cfg, rules: R.Rules):
    """PartitionSpec pytree for a SERVING params tree (quantized or mixed).

    Packed QTensor planes: N over ``model`` (expert dim for MoE stacks) via
    the shared `_qtensor_leaf_spec`. The embed table column-shards D (exact
    gather). Every other fp leaf is replicated — see the module docstring
    for why serving refuses row-parallel fp psums."""
    msize = rules.mesh.shape.get("model", 1)

    def spec_of(path_parts, leaf):
        parts = [str(getattr(p, "key", getattr(p, "name", p)))
                 for p in path_parts]
        path = "/".join(parts)
        name = parts[-1]
        stacked = R._stack_depth(path_parts)
        if not hasattr(leaf, "shape"):
            return P()
        if "data" in parts and name in R._QDATA:
            return R._qtensor_leaf_spec(path, name, tuple(leaf.shape), rules,
                                        msize, stacked)
        if name == "embed" and leaf.ndim == 2:
            dshard = msize > 1 and leaf.shape[1] % msize == 0
            return P(None, "model" if dshard else None)
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(spec_of, params)


def param_shardings(params, cfg, rules: R.Rules):
    """NamedSharding pytree matching ``params`` leaf-for-leaf (including
    the arrays inside each QTensor)."""
    specs = serve_param_pspecs(params, cfg, rules)
    return jax.tree.map(lambda s: NamedSharding(rules.mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def shard_params(params, cfg, rules: R.Rules):
    """Place a (host or device) params tree into the serving TP layout."""
    return jax.device_put(params, param_shardings(params, cfg, rules))


def cache_pspecs(cache, cfg, rules: R.Rules):
    """Specs for a serving cache pytree (`lm.init_cache` layout).

    Attention K/V planes — int8 codes AND their fp16 scale planes, or the
    fp cache — are (L, B, KV, T, HD[|1]): kv_heads over ``model`` when they
    divide, else fully replicated (the GQA fallback). SSM/RWKV recurrent
    states stay replicated (head-sharding them is a named leftover —
    they're O(1) in decoded tokens, so the KV planes dominate)."""
    msize = rules.mesh.shape.get("model", 1)
    kv_ax = rules.assignments.get("kv_heads")

    def spec_of(path_parts, leaf):
        parts = [str(getattr(p, "key", getattr(p, "name", p)))
                 for p in path_parts]
        if not hasattr(leaf, "ndim"):
            return P()
        if parts and parts[0] in ("attn", "xattn") and leaf.ndim == 5:
            ax = kv_ax if (kv_ax and leaf.shape[2] % msize == 0) else None
            return P(None, None, ax, None, None)
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(spec_of, cache)


def shard_cache(cache, cfg, rules: R.Rules):
    specs = cache_pspecs(cache, cfg, rules)
    shardings = jax.tree.map(lambda s: NamedSharding(rules.mesh, s), specs,
                             is_leaf=lambda x: isinstance(x, P))
    return jax.device_put(cache, shardings)


def place_draft(draft_params, draft_cfg, mesh: Mesh, draft_rt):
    """Place a speculative DRAFT model into the same serving TP layout as
    the target: its own rules (head/column splits follow the draft's shape,
    which may differ from the target's), threaded into the draft Runtime so
    shard_hint / shard_map dispatch inside the propose loop matches the
    target path's. Returns ``(sharded_params, draft_rt_with_rules)``."""
    rules = serve_rules(mesh, draft_cfg)
    draft_rt = dataclasses.replace(draft_rt, rules=rules, mesh=mesh)
    return shard_params(draft_params, draft_cfg, rules), draft_rt


def cache_bytes_per_device(cache) -> int:
    """Max bytes any single device holds for this cache — the number that
    actually binds a deployment (replicated leaves count fully on every
    device; head-sharded planes count 1/msize)."""
    per: dict[Any, int] = {}
    for leaf in jax.tree.leaves(cache):
        shards = getattr(leaf, "addressable_shards", None)
        if shards is None:  # host array (tests): bill it whole
            per[None] = per.get(None, 0) + int(leaf.nbytes)
            continue
        for s in shards:
            key = s.device.id
            per[key] = per.get(key, 0) + int(s.data.nbytes)
    return max(per.values()) if per else 0


def restore_shardings(cfg, mesh: Mesh) -> Callable[[str, Any], Any]:
    """Restore-to-sharding callback for :func:`repro.checkpoint.ckpt.
    restore_tree`: maps each loaded leaf (by dotted path) to its serving
    placement so a checkpoint's packed planes are ``device_put`` shard-by-
    shard AT LOAD TIME — a 235B plane set never materializes as one
    device-resident tree. QTensor leaves return a per-data-key dict of
    NamedShardings (`_put_qtensor` consumes it)."""
    rules = serve_rules(mesh, cfg)
    msize = mesh.shape.get("model", 1)

    def place(dotted: str, leaf):
        parts = dotted.split(".")
        if parts and parts[0] == "params":  # TrainState checkpoints
            parts = parts[1:]
        path = "/".join(parts)
        stacked = R._stack_depth(parts)
        if isinstance(leaf, QTensor):
            return {k: NamedSharding(mesh, R._qtensor_leaf_spec(
                        path, k, tuple(v.shape), rules, msize, stacked))
                    for k, v in leaf.data.items()}
        if not hasattr(leaf, "shape"):
            return None
        if parts[-1] == "embed" and leaf.ndim == 2:
            dshard = msize > 1 and leaf.shape[1] % msize == 0
            return NamedSharding(mesh, P(None, "model" if dshard else None))
        return NamedSharding(mesh, P(*([None] * leaf.ndim)))

    return place


# ---------------------------------------------------------------------------
# shard_map wrappers over the fused kernels
# ---------------------------------------------------------------------------
# GSPMD partitions einsums but not pallas_call: on real TPU the quantized
# matmul/attention kernels must be shard_mapped explicitly. Each device runs
# the UNMODIFIED kernel on its own column (N) or head shard — the layout is
# chosen so no wrapper ever needs a psum; the only collective shard_map
# introduces is the (exact) gather of a replicated-in_spec operand.

def can_tp_qmatmul(qt: QTensor, mesh: Mesh) -> bool:
    """Column-parallel eligibility: 2-D weight, N divides the model axis,
    and every N-carrying plane row-divides too (dsign is replicated)."""
    msize = mesh.shape.get("model", 1)
    if msize <= 1 or len(qt.meta.shape) != 2 or qt.meta.n % msize:
        return False
    return all(v.shape[0] % msize == 0
               for k, v in qt.data.items() if k != "dsign")


def _qdata_specs(qt: QTensor, msize: int):
    """QTensor-shaped pytree of PartitionSpecs: leading N dim over model."""
    def spec(key, v):
        if key != "dsign" and v.shape[0] % msize == 0:
            return P(*(["model"] + [None] * (v.ndim - 1)))
        return P(*([None] * v.ndim))
    return QTensor({k: spec(k, v) for k, v in qt.data.items()}, qt.meta)


def tp_qmatmul(x: jax.Array, qt: QTensor, rules: R.Rules, *, mode: str,
               backend: str, compute_dtype, tm=None, tn=None,
               act_quant: bool = False) -> jax.Array:
    """Column-parallel ``x @ W_hat`` under shard_map: planes N-sharded, x
    replicated (shard_map gathers it exactly if it arrives sharded), each
    device runs the full qmatmul/itq3_matvec dispatch on its N/msize shard.
    Output is N-sharded; ineligible shapes fall through to plain qmatmul
    (replicated planes). ``act_quant`` composes freely with column
    parallelism: the activation codec depends only on x (replicated), so
    every device quantizes identically and contracts its own N shard."""
    mesh = rules.mesh
    if not can_tp_qmatmul(qt, mesh):
        return qmatmul(x, qt, mode=mode, backend=backend,
                       compute_dtype=compute_dtype, tm=tm, tn=tn,
                       act_quant=act_quant)
    msize = mesh.shape["model"]
    k, n = qt.meta.shape
    local_meta = dataclasses.replace(qt.meta, shape=(k, n // msize))

    def local_fn(xs, q_local):
        q_local = QTensor(q_local.data, local_meta)
        return qmatmul(xs, q_local, mode=mode, backend=backend,
                       compute_dtype=compute_dtype, tm=tm, tn=tn,
                       act_quant=act_quant)

    out_spec = P(*([None] * (x.ndim - 1) + ["model"]))
    fn = shard_map(local_fn, mesh=mesh,
                   in_specs=(P(), _qdata_specs(qt, msize)),
                   out_specs=out_spec, check_rep=False)
    return fn(x, qt)


def _can_tp_heads(kv_heads: int, mesh: Mesh) -> bool:
    msize = mesh.shape.get("model", 1)
    return msize > 1 and kv_heads % msize == 0


_CACHE_KEYS = ("k", "v", "k_scale", "v_scale")


def tp_decode_attn_q8(q, cache, k_tok, v_tok, kv_len, rules: R.Rules, *,
                      backend: str = "auto", tt=None) -> jax.Array:
    """Head-sharded decode attention: each device runs the fused (or ref)
    decode kernel over its own kv_heads slice of codes + scale planes. The
    per-head online softmax never crosses a device. GQA counts that don't
    divide fall through to the plain (replicated-cache) call."""
    mesh = rules.mesh
    if not _can_tp_heads(q.shape[1], mesh):
        return decode_attn_q8(q, cache, k_tok, v_tok, kv_len,
                              backend=backend, tt=tt)
    hq = P(None, "model", None, None, None)   # q (B, KV, G, 1, HD)
    # cache planes: dense (B, KV, T, HD|1) or paged pool (NB, KV, BS, HD|1)
    # — the kv_heads axis is axis 1 either way, so one spec covers both.
    hc = P(None, "model", None, None)
    cache_spec = {key: hc for key in _CACHE_KEYS}
    cache_arg = {key: cache[key] for key in _CACHE_KEYS}
    if "table" in cache:
        # block table (B, MAXB): replicated — block ids index the pool's
        # block axis, which is unsharded; each shard gathers its own heads.
        cache_spec["table"] = P(None, None)
        cache_arg["table"] = cache["table"]
    fn = shard_map(
        lambda q_, c_, kt_, vt_, kl_: decode_attn_q8(
            q_, c_, kt_, vt_, kl_, backend=backend, tt=tt),
        mesh=mesh,
        in_specs=(hq, cache_spec, (hc, hc), (hc, hc), P(None)),
        out_specs=hq, check_rep=False)
    return fn(q, cache_arg, k_tok, v_tok, kv_len)


def tp_prefill_attn_q8(q, cache, kv_len, q_offset, rules: R.Rules, *,
                       backend: str = "auto", tq=None, tt=None) -> jax.Array:
    """Head-sharded prefill counterpart (q is (B, KV, G, TQ, HD))."""
    mesh = rules.mesh
    if not _can_tp_heads(q.shape[1], mesh):
        return prefill_attn_q8(q, cache, kv_len, q_offset,
                               backend=backend, tq=tq, tt=tt)
    hq = P(None, "model", None, None, None)
    hc = P(None, "model", None, None)
    cache_spec = {key: hc for key in _CACHE_KEYS}
    cache_arg = {key: cache[key] for key in _CACHE_KEYS}
    if "table" in cache:
        cache_spec["table"] = P(None, None)
        cache_arg["table"] = cache["table"]
    fn = shard_map(
        lambda q_, c_, kl_, off_: prefill_attn_q8(
            q_, c_, kl_, off_, backend=backend, tq=tq, tt=tt),
        mesh=mesh,
        in_specs=(hq, cache_spec, P(None), P(None)),
        out_specs=hq, check_rep=False)
    return fn(q, cache_arg, kv_len, q_offset)
