"""Paged rotated-int8 KV cache: a block-pool allocator over the quantized
code + scale planes.

The dense engine reserves ``slots x max_len`` cache positions for the
lifetime of every request — concurrency is capped by RESERVATION, not by
live tokens. This module converts the rotated-int8 cache's byte savings
into served capacity the way vLLM's PagedAttention converts fp16 savings:
one shared pool of ``num_blocks`` fixed-size blocks, a per-slot int32 block
table mapping logical position ``p`` to pool block ``table[slot, p // BS]``
offset ``p % BS``, and a free-list allocator with ref-counted blocks.

Layout
------
Pool planes are ``(L, num_blocks, KV, block_size, HD)`` int8 codes and
``(L, num_blocks, KV, block_size, 1)`` fp16 scales — the dense
``(L, B, KV, T, *)`` layout with the (batch, position) axes re-cut into
(block, offset). Same rank means the engine's ``_take_slots``/``_put_slots``
host-swap plumbing gathers/scatters BLOCKS (axis 1) verbatim, and
``serve/tp.py`` head-sharding specs (kv_heads at axis 2) apply unchanged.

**Block 0 is the reserved null block**: empty table entries point at it,
and padded-bucket prefill writes for positions past a slot's allocation
land there. It accumulates finite garbage that is never read (attention is
masked by ``kv_len``), which is what makes admission zero-free: a freshly
allocated block may hold a finished request's stale codes, but stale
FINITE values behind the mask contribute exactly 0 — the engine only
zeroes blocks when quarantining a numerically poisoned slot, because NaN
is the one kind of garbage the mask cannot neutralize (``0 * NaN = NaN``).

Prefix sharing
--------------
Requests whose prompts share a prefix of FULL blocks share those pool
blocks via refcounts. Keys are CHAIN hashes (each block's hash folds in
its predecessor's), because the K/V written at position ``p`` depend on
every earlier token through causal attention — a content hash of one
block alone would alias different contexts. Only full blocks are shared;
the partial tail block is always private. Admission still prefills the
whole prompt — a shared block is rewritten with bit-identical values
(causal-prefix determinism), so sharing dedups MEMORY without touching
the compiled path, and streams stay bit-identical to the dense engine.
"""
from __future__ import annotations

import hashlib
from typing import Iterable, Optional

import jax.numpy as jnp
import numpy as np

__all__ = ["BlockPool", "PoolExhausted", "init_paged_cache", "zero_blocks",
           "blocks_needed", "NULL_BLOCK"]

# Block 0 never leaves the pool: empty table entries and pad writes target
# it, so a table row of zeros is always safe to gather/scatter through.
NULL_BLOCK = 0


def blocks_needed(pos: int, block_size: int, lookahead: int = 0) -> int:
    """Blocks a slot must own before a decode window starting at ``pos``:
    enough to cover every position the window can COMMIT — up to
    ``pos + lookahead`` inclusive (a speculative window of K drafts commits
    at most K+1 tokens, landing the last write at ``pos + K``). Speculative
    writes past what the verifier later accepts land in allocated blocks
    and are overwritten by the next window; writes the table doesn't cover
    would land in the null block, which is only safe for positions the mask
    provably never reads — committed positions are read, hence the
    lookahead term."""
    return (int(pos) + int(lookahead)) // int(block_size) + 1


class PoolExhausted(RuntimeError):
    """Raised by :meth:`BlockPool.alloc` when no free block remains. The
    engine turns this into admission backoff (requeue) or victim
    preemption — never a crash mid-wave."""


class BlockPool:
    """Host-side free-list allocator with ref-counted blocks.

    Pure bookkeeping — it never touches device memory. The engine owns the
    device planes; this class decides which block ids are free, which are
    shared (refcount > 1), and which prefix hashes map to which blocks.
    """

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError(f"need >= 2 blocks (block 0 is the reserved "
                             f"null block), got {num_blocks}")
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.num_blocks = int(num_blocks)
        self.block_size = int(block_size)
        self.ref = np.zeros(num_blocks, np.int32)
        self.ref[NULL_BLOCK] = 1  # pinned forever
        # LIFO free list: most-recently-freed block is reallocated first
        # (its planes are warmest in whatever cache hierarchy exists)
        self._free = list(range(num_blocks - 1, NULL_BLOCK, -1))
        # chain hash of a FULL prompt block -> block id holding it, and the
        # inverse (to unregister on free)
        self._prefix: dict[bytes, int] = {}
        self._block_key: dict[int, bytes] = {}
        # counters (surfaced through engine stats)
        self.prefix_hits = 0
        self.allocs = 0

    # --- capacity ---------------------------------------------------------
    @property
    def capacity(self) -> int:
        """Usable blocks (the null block is not allocatable)."""
        return self.num_blocks - 1

    def available(self) -> int:
        return len(self._free)

    def used(self) -> int:
        return self.capacity - len(self._free)

    def utilization(self) -> float:
        return self.used() / self.capacity

    # --- alloc / refcount -------------------------------------------------
    def alloc(self) -> int:
        """Pop a free block with refcount 1. Raises :class:`PoolExhausted`
        when the pool is dry."""
        if not self._free:
            raise PoolExhausted(
                f"block pool dry: {self.capacity} blocks all referenced")
        blk = self._free.pop()
        assert self.ref[blk] == 0, f"free-list block {blk} has refs"
        self.ref[blk] = 1
        self.allocs += 1
        return blk

    def incref(self, blk: int) -> None:
        if blk == NULL_BLOCK:
            return
        assert self.ref[blk] > 0, f"incref on unallocated block {blk}"
        self.ref[blk] += 1

    def decref(self, blk: int) -> bool:
        """Drop one reference; returns True when the block was freed."""
        if blk == NULL_BLOCK:
            return False
        assert self.ref[blk] > 0, f"double free of block {blk}"
        self.ref[blk] -= 1
        if self.ref[blk] == 0:
            key = self._block_key.pop(blk, None)
            if key is not None:
                self._prefix.pop(key, None)
            self._free.append(blk)
            return True
        return False

    # --- prefix sharing ---------------------------------------------------
    @staticmethod
    def chain_hashes(prompt: np.ndarray, block_size: int) -> list[bytes]:
        """Chain hash per FULL block of ``prompt``: hash(i) covers tokens
        [0, (i+1)*BS) — block i's content folds in every predecessor, so
        two prompts share hash(i) iff their first (i+1)*BS tokens are
        identical (the causal-attention sharing condition)."""
        toks = np.asarray(prompt, np.int32)
        out, h = [], b""
        for i in range(len(toks) // block_size):
            chunk = toks[i * block_size:(i + 1) * block_size]
            h = hashlib.sha1(h + chunk.tobytes()).digest()
            out.append(h)
        return out

    def lookup_prefix(self, key: bytes) -> Optional[int]:
        """Live block holding this chain hash, or None."""
        return self._prefix.get(key)

    def register_prefix(self, key: bytes, blk: int) -> None:
        """Publish ``blk`` as the holder of chain hash ``key`` (no-op if a
        holder already exists — first writer wins; both wrote identical
        bytes anyway)."""
        if key not in self._prefix:
            self._prefix[key] = blk
            self._block_key[blk] = key

    def alloc_prompt(self, prompt: np.ndarray) -> list[int]:
        """Allocate the block chain for a prompt of ``len(prompt)`` tokens:
        full prefix blocks are shared through the chain-hash map when a
        live holder exists (incref, no new block), everything else is a
        fresh allocation. All-or-nothing: on :class:`PoolExhausted` every
        block taken so far is released before re-raising."""
        n = len(prompt)
        nblk = -(-n // self.block_size)  # ceil
        keys = self.chain_hashes(prompt, self.block_size)
        blocks: list[int] = []
        try:
            for i in range(nblk):
                shared = self.lookup_prefix(keys[i]) if i < len(keys) else None
                if shared is not None:
                    self.incref(shared)
                    self.prefix_hits += 1
                    blocks.append(shared)
                else:
                    blk = self.alloc()
                    if i < len(keys):  # full block: publish for sharers
                        self.register_prefix(keys[i], blk)
                    blocks.append(blk)
        except PoolExhausted:
            for blk in blocks:
                self.decref(blk)
            raise
        return blocks

    # --- invariants (property tests) --------------------------------------
    def check(self, tables: Iterable[Iterable[int]] = ()) -> None:
        """Assert allocator consistency: refcounts match the live tables,
        the free list is disjoint from referenced blocks, and no block
        leaked (referenced by nothing yet absent from the free list)."""
        counts = np.zeros(self.num_blocks, np.int64)
        for row in tables:
            for blk in row:
                if blk != NULL_BLOCK:
                    counts[blk] += 1
        free = set(self._free)
        assert len(free) == len(self._free), "free list holds duplicates"
        assert NULL_BLOCK not in free, "null block escaped into free list"
        assert self.ref[NULL_BLOCK] >= 1, "null block lost its pin"
        for blk in range(1, self.num_blocks):
            r = int(self.ref[blk])
            assert r >= 0, f"negative refcount on block {blk}"
            assert (blk in free) == (r == 0), (
                f"block {blk}: ref={r} but free-list membership "
                f"{blk in free}")
            assert r >= counts[blk], (
                f"block {blk}: {counts[blk]} table references exceed "
                f"refcount {r}")
        for key, blk in self._prefix.items():
            assert self.ref[blk] > 0, f"prefix map points at freed block {blk}"
            assert self._block_key.get(blk) == key, "prefix maps diverged"


def init_paged_cache(cfg, num_blocks: int, block_size: int):
    """Zero-initialized paged pool pytree: ``{"attn": {k, v, k_scale,
    v_scale}}`` with planes (L, num_blocks, KV, block_size, HD|1) — the
    paged analogue of ``lm.init_cache(..., kv_quant=True)``. The block
    table lives OUTSIDE this tree (it rides the jitted calls as an explicit
    argument so cache-buffer donation probes stay exact)."""
    from repro.core.fwht import is_pow2

    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    if not is_pow2(hd):
        raise ValueError(f"paged kv cache needs a power-of-two head_dim, "
                         f"got {hd}")
    if cfg.family not in ("dense", "vlm", "moe"):
        raise ValueError(
            f"paged KV cache supports pure-attention families "
            f"(dense/vlm/moe); {cfg.family!r} carries recurrent or "
            f"cross-attention state that has no block structure")
    n_layers = cfg.num_layers
    shape = (n_layers, num_blocks, kvh, block_size)
    return {"attn": {
        "k": jnp.zeros(shape + (hd,), jnp.int8),
        "v": jnp.zeros(shape + (hd,), jnp.int8),
        "k_scale": jnp.zeros(shape + (1,), jnp.float16),
        "v_scale": jnp.zeros(shape + (1,), jnp.float16),
    }}


def zero_blocks(cache, blocks) -> dict:
    """Zero the given pool blocks across every layer/plane — quarantine
    cleanup for numerically poisoned blocks before they return to the free
    list (stale FINITE garbage is harmless behind the kv_len mask; NaN is
    not)."""
    idx = jnp.asarray(list(blocks), jnp.int32)

    def z(leaf):
        shape = (leaf.shape[0], idx.shape[0]) + leaf.shape[2:]
        return leaf.at[:, idx].set(jnp.zeros(shape, leaf.dtype))

    return {"attn": {k: z(v) for k, v in cache["attn"].items()}}
