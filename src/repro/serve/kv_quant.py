"""Rotated int8 KV-cache quantization — the paper's §7.2 future work.

"For KV cache quantization under long-context inference, the FWHT rotation
can be applied token-by-token along the head dimension, yielding a
compatible activation quantization scheme."

Implemented exactly that way: each cached K/V vector (head_dim-long, one
per token per KV head) is rotated by H_{head_dim} and quantized to int8
with a per-vector fp16 absmax scale. head_dim is 32..128 across the zoo —
all powers of two, so no padding is needed. Because H is an isometry the
attention scores can even skip the inverse transform on the K side:

    q . k  =  (H q) . (H k)

so decode attends with *rotated* queries against *rotated-int8* keys —
dequantize-free score computation (the V side dequantizes after the
softmax-weighted sum... which must stay exact, so V dequantizes per tile).

Storage: 8.25 bits/element vs 16 (bf16) — halves the long_500k cache.
Quality: rotation spreads per-vector outliers before the int8 grid, the
same Theorem-1 mechanism as the weight format.

This module provides the pure-functional codec + a quantized-cache variant
of the decode attention; wired as ``Runtime.kv_quant = True`` -> used by
``init_cache_q8`` consumers (examples/kv_cache_quant.py, tests).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fwht import fwht, is_pow2

__all__ = ["kv_encode", "kv_decode", "kv_scores", "cache_bytes_ratio"]

# fp16's finite NORMAL range: the per-vector scale is STORED in fp16, so it
# must be clamped into what fp16 can actually hold. Above max the cast
# produces inf (codes collapse to 0 and decode yields 0 * inf = NaN,
# poisoning the whole attention row); below the smallest normal it flushes
# toward 0 (encode saturates at +-127 against an epsilon floor while decode
# multiplies by the stored 0 — codes and scale disagree).
F16_SCALE_MAX = float(np.finfo(np.float16).max)   # 65504
F16_SCALE_MIN = float(np.finfo(np.float16).tiny)  # 2^-14, smallest normal


def kv_encode(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x (..., HD) -> (int8 codes (..., HD), fp16 scales (..., 1)).

    Rotate along head_dim, then per-vector absmax int8. The scale is
    clamped into fp16's finite normal range and the codes are quantized
    against the value ACTUALLY stored, so encode->decode stays finite and
    consistent at both magnitude extremes (huge vectors saturate the code
    grid instead of NaN-ing; tiny vectors round to zero codes instead of
    saturating against a scale that decodes as 0)."""
    hd = x.shape[-1]
    if not is_pow2(hd):
        raise ValueError(f"head_dim {hd} must be a power of two")
    xr = fwht(x.astype(jnp.float32))
    amax = jnp.max(jnp.abs(xr), axis=-1, keepdims=True)
    scale = jnp.clip(amax / 127.0, F16_SCALE_MIN,
                     F16_SCALE_MAX).astype(jnp.float16)
    safe = scale.astype(jnp.float32)  # quantize by the stored value
    q = jnp.clip(jnp.round(xr / safe), -127, 127).astype(jnp.int8)
    return q, scale


def kv_decode(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    """Inverse: dequantize + inverse FWHT (self-inverse)."""
    xr = q.astype(jnp.float32) * scale.astype(jnp.float32)
    return fwht(xr).astype(dtype)


def kv_scores(q_rot: jax.Array, k_codes: jax.Array, k_scale: jax.Array) -> jax.Array:
    """Attention scores WITHOUT dequantizing keys: q.k == (Hq).(Hk).

    q_rot (..., G, Tq, HD) already rotated; k_codes (..., Tk, HD) int8 with
    per-token scales (..., Tk, 1). Returns (..., G, Tq, Tk) f32."""
    s = jnp.einsum("...gqd,...td->...gqt", q_rot.astype(jnp.float32),
                   k_codes.astype(jnp.float32))
    scale = jnp.swapaxes(k_scale.astype(jnp.float32), -1, -2)  # (..., 1, Tk)
    return s * scale[..., None, :, :]


def cache_bytes_ratio(head_dim: int) -> float:
    """bytes per element vs bf16: (HD int8 + 2B scale) / (2*HD)."""
    return (head_dim + 2.0) / (2.0 * head_dim)
