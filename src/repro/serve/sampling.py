"""Per-request sampling controls and the streaming event vocabulary.

:class:`SamplingParams` is the public knob set a request carries through
the serving lifecycle (vLLM-style): temperature, top-k, top-p, a private
PRNG seed, output budget, and stop conditions. The engine packs these into
**per-slot device vectors** so a batch of heterogeneous requests (greedy
next to temperature next to top-k) decodes in ONE jitted step — see
``lm.sample_tokens``'s vectorized path — preserving the one
device->host transfer per step discipline.

Determinism contract: a request's token stream depends only on (params,
prompt, its own SamplingParams/seed) — never on which slot it landed in or
what else is in the batch. Per-request PRNG keys are derived from the
request seed and folded with the request-local token index, so batched
streams are bit-identical to running each request alone (tested in
tests/test_serving_api.py).

:class:`StreamEvent` is what ``ServeEngine.generate`` yields: one event per
emitted token, with the terminal event carrying the finish reason and the
request's lifecycle stats (queue wait, TTFT, decode tok/s).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = [
    "SamplingParams", "StreamEvent",
    "FINISH_STOP", "FINISH_LENGTH", "FINISH_CANCELLED",
    "FINISH_DEADLINE", "FINISH_ERROR", "FINISH_REJECTED",
    "FINISH_REASONS",
]

# Finish reasons (string constants, JSON-friendly)
FINISH_STOP = "stop"            # emitted a stop/EOS token
FINISH_LENGTH = "length"        # hit max_new or the slot's cache horizon
FINISH_CANCELLED = "cancelled"  # evicted by ServeEngine.cancel()
FINISH_DEADLINE = "deadline"    # deadline_ms / decode_timeout_ms expired
FINISH_ERROR = "error"          # numeric quarantine or malformed request
FINISH_REJECTED = "rejected"    # backpressure: queue full (reject/shed)

# The closed vocabulary: EVERY request the engine ever sees terminates with
# exactly one of these on its terminal StreamEvent — the resilience-layer
# contract (no hang, no crash, no silent drop).
FINISH_REASONS = frozenset({
    FINISH_STOP, FINISH_LENGTH, FINISH_CANCELLED,
    FINISH_DEADLINE, FINISH_ERROR, FINISH_REJECTED,
})


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling controls.

    ``temperature <= 0`` means greedy (argmax) regardless of the other
    knobs. ``top_k=0`` / ``top_p=1.0`` disable those filters. ``seed=None``
    derives a deterministic per-request key from the engine seed and the
    request id, so reruns reproduce. ``max_new=None`` defers to the
    request's own ``max_new`` (back-compat with the pre-lifecycle API).
    ``stop`` token ids finish the request the step they are emitted (the
    stop token IS appended to the output, mirroring EOS emission);
    ``ignore_eos`` opts out of the engine/config-level EOS id.

    Speculative decoding (engines booted with a draft model):
    ``draft=None`` follows the engine default (speculate when a draft is
    configured), ``False`` opts this request out (it decodes one token per
    window, stream-identical to a non-speculative engine), ``True``
    documents the opt-in explicitly. ``draft_tokens`` caps this request's
    window below the engine's ``num_draft_tokens`` (clipped, never
    raised). Both are inert on engines without a draft model."""

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: Optional[int] = None
    max_new: Optional[int] = None
    stop: tuple[int, ...] = ()
    ignore_eos: bool = False
    draft: Optional[bool] = None
    draft_tokens: Optional[int] = None

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0 (0 disables), got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.max_new is not None and self.max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {self.max_new}")
        if self.draft_tokens is not None and self.draft_tokens < 0:
            raise ValueError(
                f"draft_tokens must be >= 0 (0 disables speculation), "
                f"got {self.draft_tokens}")
        object.__setattr__(self, "stop", tuple(int(t) for t in self.stop))

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0

    def key_data(self, *, engine_seed: int, rid: int) -> np.ndarray:
        """The (2,) uint32 threefry key this request samples under —
        computed in pure numpy so admission does no device round trip.
        Matches ``jax.random.PRNGKey(seed)``'s (hi, lo) layout."""
        seed = self.seed if self.seed is not None else _derived_seed(
            engine_seed, rid)
        return np.array([(seed >> 32) & 0xFFFFFFFF, seed & 0xFFFFFFFF],
                        dtype=np.uint32)

    def stop_set(self, eos_id: Optional[int]) -> frozenset[int]:
        ids = set(self.stop)
        if eos_id is not None and not self.ignore_eos:
            ids.add(int(eos_id))
        return frozenset(ids)


def _derived_seed(engine_seed: int, rid: int) -> int:
    """Deterministic per-request default seed: a splitmix64-style hash so
    adjacent rids don't get adjacent (correlated) threefry keys."""
    mask = 0xFFFFFFFFFFFFFFFF
    z = (engine_seed * 0x9E3779B97F4A7C15 + rid + 1) & mask
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & mask
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & mask
    return z ^ (z >> 31)


@dataclasses.dataclass(frozen=True)
class StreamEvent:
    """One token (or terminal notice) of a request's output stream.

    ``token`` is None only for a terminal event that emitted no token
    (cancellation of a live or queued request). ``index`` is the 0-based
    position of the token within the request's output; tokenless terminal
    events carry ``index = len(out)`` — one past the stream — so
    ``(rid, index)`` uniquely keys every event. ``stats`` is populated on
    terminal events: ``queue_wait_s`` (submit -> admission), ``ttft_s``
    (submit -> first token), ``decode_tok_s`` (post-first-token
    throughput), ``tokens`` — plus ``draft_proposed`` / ``draft_accepted``
    / ``acceptance_rate`` on speculative engines (the request's own
    rejection-sampling accounting)."""

    rid: int
    token: Optional[int]
    index: int
    finished: bool = False
    finish_reason: Optional[str] = None
    stats: Optional[dict] = None
