"""Pluggable admission scheduling for the serving engine.

A :class:`Scheduler` owns the waiting queue: the engine asks it for the
next admission wave whenever slots free up, and never looks inside. That
separation keeps policy (who goes next) out of the engine mechanics (how a
wave is prefilled in one compiled call), so new policies are a class, not
an engine fork.

Built-ins:

* ``fifo``     — strict arrival order (the pre-lifecycle behavior).
* ``priority`` — highest ``Request.priority`` first, FIFO within a
  priority level; an SLA tier knob.
* ``sjf``      — shortest-prompt-first: minimizes mean queue wait when
  prompt length predicts prefill cost (classic shortest-job-first), FIFO
  among equal lengths.

All built-ins break ties by arrival sequence, so scheduling is
deterministic for a fixed submission order.

Resilience hooks (optional — the engine probes with ``getattr``, so a
custom Scheduler that implements only the core protocol still works):

* ``shed(below=None)`` — drop and return the least-valuable waiting
  request (lowest ``priority``, youngest on ties), for the engine's
  ``shed_lowest`` backpressure policy. ``below`` sheds only a victim with
  priority strictly below it — on a tie the incumbent wins and the
  newcomer is rejected instead (no churn).
* ``should_preempt(active)`` — given the live requests, return the rid of
  one worth evicting mid-flight in favor of the waiting queue's head, or
  None. :class:`PriorityScheduler` preempts the lowest-priority live
  request when a strictly higher-priority request is waiting; the engine
  swaps the victim's cache rows to host and resumes it later without
  re-prefill.
"""
from __future__ import annotations

import heapq
from collections import deque
from typing import Iterable, Optional, Protocol, runtime_checkable

from repro.serve.sampling import FINISH_CANCELLED

__all__ = [
    "Scheduler", "FIFOScheduler", "PriorityScheduler",
    "ShortestPromptFirstScheduler", "SCHEDULERS", "get_scheduler",
]


@runtime_checkable
class Scheduler(Protocol):
    """What the engine needs from an admission policy."""

    def add(self, req) -> None:
        """Enqueue a request (called at submission time)."""

    def pop(self, n: int) -> list:
        """Dequeue up to ``n`` requests for the next admission wave, in
        admission order."""

    def cancel(self, rid: int):
        """Remove a waiting request by id; returns it (marked cancelled)
        or None if unknown/already admitted."""

    def __len__(self) -> int:
        """Number of waiting requests."""


class _QueueBase:
    """Shared cancel/shed/len bookkeeping over lazily-compacted entries.

    Cancellation is keyed by the ENTRY's sequence number, not the rid: a
    client may cancel a queued request and resubmit the same rid, and the
    new entry must survive while only the stale one is dropped at pop
    time (regression-tested in tests/test_serving_api.py)."""

    def __init__(self):
        self._seq = 0
        self._cancelled: set[int] = set()  # cancelled entry seqs
        self._live = 0

    def __len__(self) -> int:
        return self._live

    def _on_add(self) -> int:
        self._seq += 1
        self._live += 1
        return self._seq

    def _claim(self, seq: int, req) -> Optional[object]:
        """Filter popped entries against lazy cancellations."""
        if seq in self._cancelled:
            self._cancelled.discard(seq)
            return None
        self._live -= 1
        return req

    def _entries(self) -> Iterable:
        """All queue entries as (seq, req) pairs, arrival-ordered.
        May include lazily-cancelled entries — callers filter."""
        raise NotImplementedError  # pragma: no cover - abstract

    def _cancel_common(self, rid: int, waiting: Iterable):
        """``waiting`` yields (seq, req) in arrival order; the OLDEST live
        entry for ``rid`` is cancelled."""
        for seq, req in waiting:
            if req.rid == rid and seq not in self._cancelled:
                self._cancelled.add(seq)
                self._live -= 1
                req.done = True
                req.finish_reason = FINISH_CANCELLED
                return req
        return None

    def cancel(self, rid: int):
        return self._cancel_common(rid, self._entries())

    def shed(self, below: Optional[int] = None):
        """Drop and return the least-valuable waiting request: lowest
        ``Request.priority``, youngest entry on ties (LIFO within a level —
        seniority is preserved under sustained overload). ``below`` only
        sheds a victim with priority STRICTLY below it, so a newcomer never
        displaces an equal-priority incumbent. Returns None when nothing
        sheddable. The entry is removed via the same lazy-cancellation
        bookkeeping as :meth:`cancel`, but the request is NOT marked — the
        engine stamps the terminal reason (``rejected``)."""
        best = None
        for seq, req in self._entries():
            if seq in self._cancelled:
                continue
            key = (int(getattr(req, "priority", 0)), -seq)
            if best is None or key < best[0]:
                best = (key, seq, req)
        if best is None:
            return None
        if below is not None and best[0][0] >= below:
            return None
        _, seq, req = best
        self._cancelled.add(seq)
        self._live -= 1
        return req


class FIFOScheduler(_QueueBase):
    name = "fifo"

    def __init__(self):
        super().__init__()
        self._q: deque = deque()  # (seq, req)

    def add(self, req) -> None:
        self._q.append((self._on_add(), req))

    def pop(self, n: int) -> list:
        out = []
        while self._q and len(out) < n:
            req = self._claim(*self._q.popleft())
            if req is not None:
                out.append(req)
        return out

    def _entries(self):
        return iter(self._q)


class _HeapScheduler(_QueueBase):
    """Priority-queue scheduling over a per-request sort key."""

    def __init__(self):
        super().__init__()
        self._heap: list = []  # (key, seq, req)

    def _key(self, req):  # pragma: no cover - abstract
        raise NotImplementedError

    def add(self, req) -> None:
        seq = self._on_add()
        heapq.heappush(self._heap, (self._key(req), seq, req))

    def pop(self, n: int) -> list:
        out = []
        while self._heap and len(out) < n:
            _, seq, req = heapq.heappop(self._heap)
            req = self._claim(seq, req)
            if req is not None:
                out.append(req)
        return out

    def _entries(self):
        return sorted((e[1], e[2]) for e in self._heap)

    def _peek(self):
        """The next request :meth:`pop` would return, without removing it
        (lazily compacts cancelled entries off the heap top)."""
        while self._heap and self._heap[0][1] in self._cancelled:
            _, seq, _ = heapq.heappop(self._heap)
            self._cancelled.discard(seq)
        return self._heap[0][2] if self._heap else None


class PriorityScheduler(_HeapScheduler):
    """Highest ``Request.priority`` admitted first; FIFO within a level."""

    name = "priority"

    def _key(self, req):
        return -int(getattr(req, "priority", 0))

    def should_preempt(self, active: list) -> Optional[int]:
        """Evict a live request when a STRICTLY higher-priority request is
        waiting. The victim is the lowest-priority live request, youngest
        admission on ties (least progress lost). Ties between waiting and
        live go to the live request — no same-priority churn."""
        head = self._peek()
        if head is None or not active:
            return None
        best = int(getattr(head, "priority", 0))
        victim = min(active, key=lambda r: (int(getattr(r, "priority", 0)),
                                            -(r.t_admit or 0.0)))
        if int(getattr(victim, "priority", 0)) < best:
            return victim.rid
        return None


class ShortestPromptFirstScheduler(_HeapScheduler):
    """Shortest job admitted first; FIFO on ties.

    The default job-size estimate is prompt length (prefill-cost SJF, the
    pre-speculative behavior). An engine can install a richer cost model
    via :meth:`set_cost` — ``ServeEngine`` does, pricing a request at
    ``prefill + expected decode steps``, where a speculative request's
    decode is amortized by its window size (a draft-enabled request
    commits up to K+1 tokens per step, so it occupies its slot for fewer
    steps than an equal-budget non-speculative one). The cost is sampled
    at ``add`` time, so installing a model only affects requests enqueued
    afterwards."""

    name = "sjf"

    def __init__(self, cost=None):
        super().__init__()
        self._cost = cost

    def set_cost(self, fn) -> None:
        """Install a ``req -> float`` admission cost model (None resets to
        prompt length)."""
        self._cost = fn

    def _key(self, req):
        if self._cost is not None:
            return float(self._cost(req))
        return len(req.prompt)


SCHEDULERS = {
    "fifo": FIFOScheduler,
    "priority": PriorityScheduler,
    "sjf": ShortestPromptFirstScheduler,
}


def get_scheduler(spec: "str | Scheduler | None") -> Scheduler:
    """Resolve a scheduler name or pass through an instance (None -> fifo)."""
    if spec is None:
        return FIFOScheduler()
    if isinstance(spec, str):
        try:
            return SCHEDULERS[spec]()
        except KeyError:
            raise ValueError(
                f"unknown scheduler {spec!r}; options {sorted(SCHEDULERS)}")
    if not isinstance(spec, Scheduler):
        raise TypeError(f"not a Scheduler: {spec!r}")
    return spec
