"""Speculative-decoding acceptance math: propose/verify/commit primitives.

The engine's decode tick generalizes from "one token per slot per step" to
a K-token speculative window per slot:

* **propose** — a cheap draft model decodes K candidate tokens per slot
  from its own KV cache (engine-side; this module supplies the PRNG-stream
  tags and the draft-model constructor).
* **verify** — the TARGET model scores all K+1 window positions in one
  batched pass (``lm.score_tokens``, which under ``kv_quant`` is one fused
  ``prefill_attn_q8`` call over the rotated-int8 cache).
* **commit** — :func:`verify_commit` turns target logits + candidates into
  (accepted tokens, per-slot commit counts) entirely on device, so the
  engine's 1-host-sync-per-step contract holds: one transfer moves the
  whole window.

Acceptance rules
----------------
Greedy slots (temperature 0) accept draft token ``d_{w+1}`` iff it equals
``argmax`` of the target's logits at window position ``w`` — the committed
stream is therefore **bitwise identical** to non-speculative greedy
decoding (the target's argmax sequence), regardless of draft quality.

Sampled slots use standard speculative rejection sampling (Leviathan et
al.): accept ``d`` with probability ``min(1, p(d)/q(d))`` where ``p`` is
the target's (temperature/top-k/top-p masked) distribution and ``q`` the
draft's; on the first rejection the corrected token is drawn from the
residual ``max(p - q, 0)``. The marginal distribution of every committed
token equals pure target sampling, but the PRNG *stream* differs from the
non-speculative engine (documented; greedy is the parity contract).

PRNG streams per slot key (``SamplingParams.key_data``):

* window-end draw at accepted length ``a``: ``fold_in(key, gen + a)`` —
  the SAME stream the non-speculative engine uses for its one token at
  generation index ``gen + a``, so a slot with ``draft_tokens=0`` commits
  a bit-identical sampled stream too.
* acceptance uniforms: ``fold_in(fold_in(key, ACCEPT_TAG), gen + w)``.
* draft proposal draws: ``fold_in(fold_in(key, DRAFT_TAG), gen + w)``.

The tags split off independent streams so draft draws never correlate
with target draws.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import lm

__all__ = ["ACCEPT_TAG", "DRAFT_TAG", "accept_uniforms", "draft_keys",
           "verify_commit", "draft_from_params"]

# Stream-splitting tags (arbitrary distinct constants folded into the slot
# key before the per-position fold). The natural stream (no tag) is
# reserved for committed-token draws so it stays aligned with the
# non-speculative engine.
ACCEPT_TAG = 0x5EC0_ACCE
DRAFT_TAG = 0x5EC0_D4AF

_EPS = 1e-20


def _fold_vec(keys: jax.Array, tag: int) -> jax.Array:
    """fold_in(key, tag) over a (S, 2) raw-key batch."""
    return jax.vmap(lambda k: jax.random.fold_in(k, tag))(keys)


def accept_uniforms(keys: jax.Array, gen: jax.Array, k: int) -> jax.Array:
    """(S, K) acceptance uniforms: u[s, w] from the slot's ACCEPT stream at
    generation index ``gen[s] + w``."""
    tagged = _fold_vec(keys, ACCEPT_TAG)

    def one(key, g):
        def at(w):
            return jax.random.uniform(jax.random.fold_in(key, g + w))
        return jnp.stack([at(w) for w in range(k)])

    return jax.vmap(one)(tagged, gen)


def draft_keys(keys: jax.Array, gen: jax.Array, w: int) -> jax.Array:
    """(S, 2) per-slot keys for the draft's w-th proposal draw."""
    tagged = _fold_vec(keys, DRAFT_TAG)
    return jax.vmap(lambda k, g: jax.random.fold_in(k, g + w))(tagged, gen)


def _natural_keys(keys: jax.Array, gen: jax.Array, a: jax.Array) -> jax.Array:
    """(S, 2) window-end keys: the untagged per-generation-index stream."""
    return jax.vmap(lambda k, g: jax.random.fold_in(k, g))(keys, gen + a)


def verify_commit(
    logits: jax.Array,          # (S, K+1, V) target logits over the window
    cand: jax.Array,            # (S, K+1) int32: [t0, d_1..d_K]
    kvec: jax.Array,            # (S,) int32: per-slot draft count in [0, K]
    *,
    keys: Optional[jax.Array] = None,    # (S, 2) raw slot keys; None = all-greedy
    gen: Optional[jax.Array] = None,     # (S,) generation index at window start
    temp: Optional[jax.Array] = None,    # (S,) temperature
    top_k: Optional[jax.Array] = None,   # (S,) int32
    top_p: Optional[jax.Array] = None,   # (S,) float32
    qlog: Optional[jax.Array] = None,    # (S, K, V) draft scaled+masked logits
) -> tuple[jax.Array, jax.Array]:
    """Decide the committed tokens for one speculative window.

    ``logits[:, w]`` is the target's next-token distribution after
    consuming window tokens ``cand[:, :w+1]`` (``cand[:, 0]`` is the
    already-emitted anchor token, ``cand[:, 1:]`` the draft proposals).
    Returns ``(out_toks (S, K+1), n_commit (S,))``: slot ``s`` commits
    ``out_toks[s, :n_commit[s]]`` — the accepted draft prefix plus exactly
    one window-end token (correction, residual draw, or bonus token at
    full acceptance). ``1 <= n_commit <= kvec + 1`` always: a window never
    commits zero tokens, so the engine always makes progress.
    """
    s, k1, _ = logits.shape
    k = k1 - 1
    rows = jnp.arange(s)
    gr = jnp.argmax(logits, axis=-1).astype(jnp.int32)       # (S, K+1)
    greedy_acc = cand[:, 1:] == gr[:, :k]                    # (S, K)

    if keys is None:  # whole batch greedy: no distributions needed
        accept = greedy_acc
    else:
        scaled = (logits.astype(jnp.float32)
                  / jnp.maximum(temp, 1e-6)[:, None, None])
        # top-k/top-p masking is (B, V): flatten the window axis and
        # repeat the per-slot filters across the K+1 positions. A None
        # filter stays None — same trace-level specialization as the
        # engine's decode step, which the bit-parity contract needs.
        if top_k is not None or top_p is not None:
            masked = lm.top_mask(
                scaled.reshape(s * k1, -1),
                None if top_k is None else jnp.repeat(top_k, k1),
                None if top_p is None else jnp.repeat(top_p, k1))
            masked = masked.reshape(s, k1, -1)
        else:
            masked = scaled
        p = jax.nn.softmax(masked, axis=-1)                  # (S, K+1, V)
        q = jax.nn.softmax(qlog.astype(jnp.float32), axis=-1)  # (S, K, V)
        d_idx = cand[:, 1:, None]                            # (S, K, 1)
        p_d = jnp.take_along_axis(p[:, :k], d_idx, axis=-1)[..., 0]
        q_d = jnp.take_along_axis(q, d_idx, axis=-1)[..., 0]
        u = accept_uniforms(keys, gen, k)                    # (S, K)
        sampled_acc = u * jnp.maximum(q_d, _EPS) < p_d
        accept = jnp.where(temp[:, None] > 0, sampled_acc, greedy_acc)

    window = accept & (jnp.arange(k)[None, :] < kvec[:, None])
    # leading accepted run: first rejection cuts everything after it
    a = jnp.sum(jnp.cumprod(window.astype(jnp.int32), axis=1), axis=1)

    logits_a = logits[rows, a]                               # (S, V)
    if keys is None:
        end_tok = gr[rows, a]
    else:
        nat = _natural_keys(keys, gen, a)
        # direct draw — bitwise the non-speculative engine's sample for
        # generation index gen + a (same stream, same masking path);
        # handles temp == 0 rows as argmax internally
        direct = lm.sample_tokens(logits_a, nat, temp, top_k=top_k,
                                  top_p=top_p)
        # residual draw for genuine rejections: max(p - q, 0)
        p_a = p[rows, a]
        q_pad = jnp.concatenate([q, jnp.zeros_like(q[:, :1])], axis=1)
        resid = jnp.maximum(p_a - q_pad[rows, a], 0.0)
        res_ok = jnp.sum(resid, axis=-1) > _EPS
        logr = jnp.where(resid > 0, jnp.log(jnp.maximum(resid, 1e-38)),
                         -jnp.inf)
        res_tok = jax.vmap(jax.random.categorical)(nat, logr).astype(
            jnp.int32)
        use_res = (temp > 0) & (a < kvec) & res_ok
        end_tok = jnp.where(use_res, res_tok, direct).astype(jnp.int32)

    # out[:, j] = d_{j+1} for j < a, window-end token at j = a (positions
    # past a are never read: n_commit = a + 1)
    shifted = jnp.concatenate([cand[:, 1:], cand[:, :1]], axis=1)
    out = jnp.where(jnp.arange(k1)[None, :] < a[:, None], shifted,
                    end_tok[:, None]).astype(jnp.int32)
    return out, (a + 1).astype(jnp.int32)


def draft_from_params(params, cfg, n_layers: int):
    """Self-draft constructor: a ``n_layers``-deep prefix of the target
    model sharing the embedding / final-norm / head leaves by reference.
    The stacked ``layers`` pytree is sliced along its leading layer axis
    (QTensor data planes slice the same way — meta describes the per-layer
    logical weight and is unchanged). Only pure-attention stacked families
    qualify (the same families speculative decoding itself supports).

    Returns ``(draft_params, draft_cfg)``."""
    if cfg.family not in ("dense", "vlm", "moe"):
        raise ValueError(f"self-draft needs a stacked pure-attention family "
                         f"(dense/vlm/moe), got {cfg.family!r}")
    if not 1 <= n_layers <= cfg.num_layers:
        raise ValueError(f"draft depth {n_layers} outside "
                         f"[1, {cfg.num_layers}]")
    draft = dict(params)
    draft["layers"] = jax.tree.map(lambda a: a[:n_layers], params["layers"])
    return draft, dataclasses.replace(cfg, num_layers=n_layers)
