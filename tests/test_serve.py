"""Serving engine + whole-model quantization pass."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.models import lm
from repro.models.layers import Runtime
from repro.serve.engine import Request, ServeEngine
from repro.serve.quantized import quantize_params, quantized_bytes
from repro.core.quantize import QTensor

KEY = jax.random.PRNGKey(0)
RT = Runtime(compute_dtype=jnp.float32, capacity_factor=8.0)


def test_continuous_batching_more_requests_than_slots():
    cfg = reduced(get_config("smollm-135m"))
    params = lm.init_params(KEY, cfg)
    eng = ServeEngine(params, cfg, slots=2, max_len=48, rt=RT)
    reqs = [Request(rid=i, prompt=np.arange(4 + i) % cfg.vocab_size, max_new=5)
            for i in range(5)]
    done = eng.run(reqs)
    assert all(r.done for r in done)
    assert all(len(r.out) >= 5 for r in done)


def test_engine_matches_direct_decode():
    """Engine greedy output == hand-rolled prefill+decode."""
    cfg = reduced(get_config("qwen1.5-0.5b"))
    params = lm.init_params(KEY, cfg)
    prompt = np.arange(6) % cfg.vocab_size
    eng = ServeEngine(params, cfg, slots=1, max_len=32, rt=RT, prompt_pad=8)
    [req] = eng.run([Request(rid=0, prompt=prompt, max_new=4)])

    cache = lm.init_cache(cfg, 1, 32, dtype=jnp.float32)
    toks = jnp.asarray(prompt[None].astype(np.int32))
    # engine pads prompts to prompt_pad; replicate exactly
    toks_p = jnp.pad(toks, ((0, 0), (0, 2)))
    logits, cache, _ = lm.forward(params, toks_p, RT, cfg, cache=cache, pos=0)
    out = [int(jnp.argmax(logits[0, -1]))]
    # NB engine reads last REAL logit: recompute via pos masking
    # simpler: compare unpadded path
    cache = lm.init_cache(cfg, 1, 32, dtype=jnp.float32)
    logits, cache, _ = lm.forward(params, toks, RT, cfg, cache=cache, pos=0)
    out = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    for _ in range(3):
        l, cache = lm.decode_step(params, jnp.asarray([[out[-1]]], jnp.int32),
                                  cache, jnp.int32(pos), RT, cfg)
        out.append(int(jnp.argmax(l[0, 0])))
        pos += 1
    assert req.out[:4] == out[:4]


def test_quantize_params_selective():
    cfg = reduced(get_config("olmoe-1b-7b"))
    params = lm.init_params(KEY, cfg)
    q = quantize_params(params, "itq3_s")
    # expert weights quantized (stacked), router and norms untouched
    layer = q["layers"]
    assert isinstance(layer["moe"]["up"], QTensor)
    assert not isinstance(layer["moe"]["router"], QTensor)
    assert not isinstance(layer["ln1"]["scale"], QTensor)
    assert isinstance(layer["attn"]["wq"], QTensor)
    assert quantized_bytes(q) < quantized_bytes(params)


def test_quantized_forward_close_enough():
    cfg = reduced(get_config("stablelm-3b"))
    params = lm.init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 12), 0, cfg.vocab_size)
    lf, _, _ = lm.forward(params, toks, RT, cfg)
    for fmt, tol in [("q8_0", 0.05), ("itq3_s", 1.5)]:
        lq, _, _ = lm.forward(quantize_params(params, fmt), toks, RT, cfg)
        rmse = float(jnp.sqrt(jnp.mean((lf - lq) ** 2)))
        assert rmse < tol, (fmt, rmse)


def test_quantized_serving_all_ternary_formats():
    cfg = reduced(get_config("smollm-135m"))
    params = lm.init_params(KEY, cfg)
    for fmt in ("itq3_s", "itq3_x", "iq3_s"):
        q = quantize_params(params, fmt)
        eng = ServeEngine(q, cfg, slots=1, max_len=24, rt=RT)
        [r] = eng.run([Request(rid=0, prompt=np.arange(4), max_new=3)])
        assert len(r.out) >= 3, fmt


def test_ssm_engine_no_padding():
    cfg = reduced(get_config("rwkv6-3b"))
    params = lm.init_params(KEY, cfg)
    eng = ServeEngine(params, cfg, slots=2, max_len=32, rt=RT)
    done = eng.run([Request(rid=0, prompt=np.arange(5), max_new=4),
                    Request(rid=1, prompt=np.arange(9), max_new=4)])
    assert all(len(r.out) >= 4 for r in done)
