"""Rotated int8 KV cache (paper §7.2 extension)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serve import kv_quant

KEY = jax.random.PRNGKey(0)


def test_roundtrip_error_small():
    x = jax.random.normal(KEY, (4, 8, 128, 64)) * 2.0
    q, s = kv_quant.kv_encode(x)
    xh = kv_quant.kv_decode(q, s)
    rel = float(jnp.linalg.norm(xh - x) / jnp.linalg.norm(x))
    assert rel < 0.01, rel  # int8 on a rotated (smoothed) vector


def test_rotation_helps_outliers():
    """per-vector outliers: rotated-int8 beats plain-int8."""
    x = jax.random.normal(KEY, (64, 64))
    x = x.at[:, 7].mul(30.0)  # channel outlier

    def plain_int8(v):
        s = jnp.max(jnp.abs(v), -1, keepdims=True) / 127.0
        return jnp.round(v / s) * s

    plain_err = float(jnp.linalg.norm(plain_int8(x) - x))
    q, s = kv_quant.kv_encode(x)
    rot_err = float(jnp.linalg.norm(kv_quant.kv_decode(q, s) - x))
    assert rot_err < plain_err * 0.6, (rot_err, plain_err)


def test_dequantize_free_scores():
    """q.k == (Hq).(Hk) up to int8 grid error (isometry)."""
    q = jax.random.normal(KEY, (2, 4, 1, 3, 64))   # (B, KV, G, Tq, HD)
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 16, 64))  # (B,KV,Tk,HD)
    want = jnp.einsum("bkgqd,bktd->bkgqt", q, k)
    from repro.core.fwht import fwht
    q_rot = fwht(q)
    codes, scale = kv_quant.kv_encode(k)
    got = kv_quant.kv_scores(q_rot, codes, scale)
    err = float(jnp.max(jnp.abs(got - want)))
    assert err < 0.05 * float(jnp.max(jnp.abs(want))), err


def test_bytes_ratio():
    assert abs(kv_quant.cache_bytes_ratio(128) - 0.508) < 0.01
    assert kv_quant.cache_bytes_ratio(64) < 0.6


@pytest.mark.parametrize("hd", [32, 64, 128])
def test_codec_roundtrip_config_zoo_head_dims(hd):
    """Every head_dim in the zoo (32..128, all pow2) survives the codec."""
    x = jax.random.normal(KEY, (2, 3, 17, hd)) * 3.0
    q, s = kv_quant.kv_encode(x)
    assert q.dtype == jnp.int8 and q.shape == x.shape
    assert s.dtype == jnp.float16 and s.shape == (2, 3, 17, 1)
    xh = kv_quant.kv_decode(q, s)
    rel = float(jnp.linalg.norm(xh - x) / jnp.linalg.norm(x))
    assert rel < 0.012, (hd, rel)


def test_codec_rejects_non_pow2():
    with pytest.raises(ValueError, match="power of two"):
        kv_quant.kv_encode(jnp.ones((2, 48)))


def test_gqa_head_sharing_scores():
    """One encoded K per KV head serves every query head in its group:
    per-group scores from the shared codes == per-group fp scores."""
    b, kv, g, t, hd = 2, 2, 3, 12, 64
    q = jax.random.normal(KEY, (b, kv, g, 1, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, kv, t, hd))
    from repro.core.fwht import fwht
    codes, scale = kv_quant.kv_encode(k)  # encoded ONCE per KV head
    got = kv_quant.kv_scores(fwht(q), codes, scale)
    want = jnp.einsum("bkgqd,bktd->bkgqt", q, k)
    err = float(jnp.max(jnp.abs(got - want)))
    assert err < 0.05 * float(jnp.max(jnp.abs(want)))
    # every query head in the group read the SAME codes: encoding per query
    # head would change nothing but the bytes
    assert codes.shape == (b, kv, t, hd)


def test_encode_append_decode_ragged_roundtrip():
    """Cache discipline: bulk-encode a prefix, append one token at a ragged
    position, decode the whole buffer — values match per-vector encoding."""
    b, kv, t_max, hd = 2, 1, 19, 32
    prefix_len = 13
    k_prefix = jax.random.normal(KEY, (b, kv, prefix_len, hd))
    k_tok = jax.random.normal(jax.random.PRNGKey(2), (b, kv, 1, hd))

    codes = jnp.zeros((b, kv, t_max, hd), jnp.int8)
    scales = jnp.zeros((b, kv, t_max, 1), jnp.float16)
    cp, sp = kv_quant.kv_encode(k_prefix)
    codes = jax.lax.dynamic_update_slice(codes, cp, (0, 0, 0, 0))
    scales = jax.lax.dynamic_update_slice(scales, sp, (0, 0, 0, 0))
    ct, st = kv_quant.kv_encode(k_tok)
    codes = jax.lax.dynamic_update_slice(codes, ct, (0, 0, prefix_len, 0))
    scales = jax.lax.dynamic_update_slice(scales, st, (0, 0, prefix_len, 0))

    out = kv_quant.kv_decode(codes, scales)
    want = kv_quant.kv_decode(*kv_quant.kv_encode(
        jnp.concatenate([k_prefix, k_tok], axis=2)))
    np.testing.assert_allclose(np.asarray(out[:, :, :prefix_len + 1]),
                               np.asarray(want), atol=1e-6)
    # unwritten tail decodes to exact zeros (zero scale), not garbage
    assert float(jnp.max(jnp.abs(out[:, :, prefix_len + 1:]))) == 0.0
