"""Rotated int8 KV cache (paper §7.2 extension)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.serve import kv_quant

KEY = jax.random.PRNGKey(0)


def test_roundtrip_error_small():
    x = jax.random.normal(KEY, (4, 8, 128, 64)) * 2.0
    q, s = kv_quant.kv_encode(x)
    xh = kv_quant.kv_decode(q, s)
    rel = float(jnp.linalg.norm(xh - x) / jnp.linalg.norm(x))
    assert rel < 0.01, rel  # int8 on a rotated (smoothed) vector


def test_rotation_helps_outliers():
    """per-vector outliers: rotated-int8 beats plain-int8."""
    x = jax.random.normal(KEY, (64, 64))
    x = x.at[:, 7].mul(30.0)  # channel outlier

    def plain_int8(v):
        s = jnp.max(jnp.abs(v), -1, keepdims=True) / 127.0
        return jnp.round(v / s) * s

    plain_err = float(jnp.linalg.norm(plain_int8(x) - x))
    q, s = kv_quant.kv_encode(x)
    rot_err = float(jnp.linalg.norm(kv_quant.kv_decode(q, s) - x))
    assert rot_err < plain_err * 0.6, (rot_err, plain_err)


def test_dequantize_free_scores():
    """q.k == (Hq).(Hk) up to int8 grid error (isometry)."""
    q = jax.random.normal(KEY, (2, 4, 1, 3, 64))   # (B, KV, G, Tq, HD)
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 16, 64))  # (B,KV,Tk,HD)
    want = jnp.einsum("bkgqd,bktd->bkgqt", q, k)
    from repro.core.fwht import fwht
    q_rot = fwht(q)
    codes, scale = kv_quant.kv_encode(k)
    got = kv_quant.kv_scores(q_rot, codes, scale)
    err = float(jnp.max(jnp.abs(got - want)))
    assert err < 0.05 * float(jnp.max(jnp.abs(want))), err


def test_bytes_ratio():
    assert abs(kv_quant.cache_bytes_ratio(128) - 0.508) < 0.01
    assert kv_quant.cache_bytes_ratio(64) < 0.6
