"""Rotated int8 KV cache (paper §7.2 extension)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serve import kv_quant

KEY = jax.random.PRNGKey(0)


def test_roundtrip_error_small():
    x = jax.random.normal(KEY, (4, 8, 128, 64)) * 2.0
    q, s = kv_quant.kv_encode(x)
    xh = kv_quant.kv_decode(q, s)
    rel = float(jnp.linalg.norm(xh - x) / jnp.linalg.norm(x))
    assert rel < 0.01, rel  # int8 on a rotated (smoothed) vector


def test_rotation_helps_outliers():
    """per-vector outliers: rotated-int8 beats plain-int8."""
    x = jax.random.normal(KEY, (64, 64))
    x = x.at[:, 7].mul(30.0)  # channel outlier

    def plain_int8(v):
        s = jnp.max(jnp.abs(v), -1, keepdims=True) / 127.0
        return jnp.round(v / s) * s

    plain_err = float(jnp.linalg.norm(plain_int8(x) - x))
    q, s = kv_quant.kv_encode(x)
    rot_err = float(jnp.linalg.norm(kv_quant.kv_decode(q, s) - x))
    assert rot_err < plain_err * 0.6, (rot_err, plain_err)


def test_dequantize_free_scores():
    """q.k == (Hq).(Hk) up to int8 grid error (isometry)."""
    q = jax.random.normal(KEY, (2, 4, 1, 3, 64))   # (B, KV, G, Tq, HD)
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 16, 64))  # (B,KV,Tk,HD)
    want = jnp.einsum("bkgqd,bktd->bkgqt", q, k)
    from repro.core.fwht import fwht
    q_rot = fwht(q)
    codes, scale = kv_quant.kv_encode(k)
    got = kv_quant.kv_scores(q_rot, codes, scale)
    err = float(jnp.max(jnp.abs(got - want)))
    assert err < 0.05 * float(jnp.max(jnp.abs(want))), err


def test_bytes_ratio():
    assert abs(kv_quant.cache_bytes_ratio(128) - 0.508) < 0.01
    assert kv_quant.cache_bytes_ratio(64) < 0.6


@pytest.mark.parametrize("hd", [32, 64, 128])
def test_codec_roundtrip_config_zoo_head_dims(hd):
    """Every head_dim in the zoo (32..128, all pow2) survives the codec."""
    x = jax.random.normal(KEY, (2, 3, 17, hd)) * 3.0
    q, s = kv_quant.kv_encode(x)
    assert q.dtype == jnp.int8 and q.shape == x.shape
    assert s.dtype == jnp.float16 and s.shape == (2, 3, 17, 1)
    xh = kv_quant.kv_decode(q, s)
    rel = float(jnp.linalg.norm(xh - x) / jnp.linalg.norm(x))
    assert rel < 0.012, (hd, rel)


def test_codec_rejects_non_pow2():
    with pytest.raises(ValueError, match="power of two"):
        kv_quant.kv_encode(jnp.ones((2, 48)))


def test_gqa_head_sharing_scores():
    """One encoded K per KV head serves every query head in its group:
    per-group scores from the shared codes == per-group fp scores."""
    b, kv, g, t, hd = 2, 2, 3, 12, 64
    q = jax.random.normal(KEY, (b, kv, g, 1, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, kv, t, hd))
    from repro.core.fwht import fwht
    codes, scale = kv_quant.kv_encode(k)  # encoded ONCE per KV head
    got = kv_quant.kv_scores(fwht(q), codes, scale)
    want = jnp.einsum("bkgqd,bktd->bkgqt", q, k)
    err = float(jnp.max(jnp.abs(got - want)))
    assert err < 0.05 * float(jnp.max(jnp.abs(want)))
    # every query head in the group read the SAME codes: encoding per query
    # head would change nothing but the bytes
    assert codes.shape == (b, kv, t, hd)


def test_encode_append_decode_ragged_roundtrip():
    """Cache discipline: bulk-encode a prefix, append one token at a ragged
    position, decode the whole buffer — values match per-vector encoding."""
    b, kv, t_max, hd = 2, 1, 19, 32
    prefix_len = 13
    k_prefix = jax.random.normal(KEY, (b, kv, prefix_len, hd))
    k_tok = jax.random.normal(jax.random.PRNGKey(2), (b, kv, 1, hd))

    codes = jnp.zeros((b, kv, t_max, hd), jnp.int8)
    scales = jnp.zeros((b, kv, t_max, 1), jnp.float16)
    cp, sp = kv_quant.kv_encode(k_prefix)
    codes = jax.lax.dynamic_update_slice(codes, cp, (0, 0, 0, 0))
    scales = jax.lax.dynamic_update_slice(scales, sp, (0, 0, 0, 0))
    ct, st = kv_quant.kv_encode(k_tok)
    codes = jax.lax.dynamic_update_slice(codes, ct, (0, 0, prefix_len, 0))
    scales = jax.lax.dynamic_update_slice(scales, st, (0, 0, prefix_len, 0))

    out = kv_quant.kv_decode(codes, scales)
    want = kv_quant.kv_decode(*kv_quant.kv_encode(
        jnp.concatenate([k_prefix, k_tok], axis=2)))
    np.testing.assert_allclose(np.asarray(out[:, :, :prefix_len + 1]),
                               np.asarray(want), atol=1e-6)
    # unwritten tail decodes to exact zeros (zero scale), not garbage
    assert float(jnp.max(jnp.abs(out[:, :, prefix_len + 1:]))) == 0.0


# ---------------------------------------------------------------------------
# fp16-scale extremes: the stored scale must stay finite and consistent
# with the codes (regression for the inf/flush-to-zero codec bug)
# ---------------------------------------------------------------------------

def test_encode_huge_magnitude_scale_stays_finite():
    """amax/127 past fp16 max used to cast to inf: codes collapsed to 0 and
    decode returned 0 * inf = NaN, poisoning the whole attention row."""
    for mag in (1e6, 1e7):
        x = jnp.full((3, 128), mag, jnp.float32)  # Hx peak = sqrt(128)*mag
        q, s = kv_quant.kv_encode(x)
        assert bool(jnp.all(jnp.isfinite(s.astype(jnp.float32)))), mag
        dec = kv_quant.kv_decode(q, s)
        assert bool(jnp.all(jnp.isfinite(dec))), mag
        # saturated but directionally right: the code grid clips, 0 codes
        # would mean the scale overflowed again
        assert int(jnp.max(jnp.abs(q))) == 127


def test_encode_tiny_magnitude_codes_do_not_saturate():
    """Below fp16's smallest normal the scale used to flush to 0 while the
    codes saturated at +-127 against an epsilon floor — decode then
    returned zeros for saturated codes. Now the codes quantize against the
    value actually stored: tiny vectors round to zero codes, consistently."""
    x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 64)) * 1e-7,
                    jnp.float32)
    q, s = kv_quant.kv_encode(x)
    assert float(jnp.min(s.astype(jnp.float32))) > 0.0
    assert int(jnp.max(jnp.abs(q))) == 0  # not +-127 garbage
    dec = kv_quant.kv_decode(q, s)
    assert bool(jnp.all(jnp.isfinite(dec)))
    np.testing.assert_array_equal(np.asarray(dec), 0.0)


def test_zero_vector_roundtrip_exact():
    q, s = kv_quant.kv_encode(jnp.zeros((2, 32)))
    assert float(jnp.min(s.astype(jnp.float32))) > 0.0  # finite, not 0/inf
    np.testing.assert_array_equal(np.asarray(kv_quant.kv_decode(q, s)), 0.0)


def test_decode_attn_finite_with_extreme_cache():
    """encode -> decode_attn_q8 end to end with 1e6/1e-7-magnitude cached
    vectors: every output must be finite (one NaN row used to poison the
    softmax for the whole attention head)."""
    from repro.kernels import attn_decode as ad

    rng = np.random.default_rng(1)
    b, kv, g, hd, t = 2, 2, 2, 128, 12
    k = rng.normal(size=(b, kv, t, hd))
    v = rng.normal(size=(b, kv, t, hd))
    k[:, :, 3], v[:, :, 5] = 1e6, 1e6    # hot rows: scale used to go inf
    k[:, :, 7], v[:, :, 2] = 1e-7, 1e-7  # cold rows: scale used to go 0
    kc, ks = kv_quant.kv_encode(jnp.asarray(k, jnp.float32))
    vc, vs = kv_quant.kv_encode(jnp.asarray(v, jnp.float32))
    cache = {"k": kc, "k_scale": ks, "v": vc, "v_scale": vs}
    q = jnp.asarray(rng.normal(size=(b, kv, g, 1, hd)), jnp.float32)
    ktok = kv_quant.kv_encode(
        jnp.asarray(rng.normal(size=(b, kv, 1, hd)), jnp.float32))
    vtok = kv_quant.kv_encode(
        jnp.asarray(rng.normal(size=(b, kv, 1, hd)), jnp.float32))
    kl = jnp.full((b,), t, jnp.int32)
    out = ad.decode_attn_q8(q, cache, ktok, vtok, kl, backend="ref")
    assert bool(jnp.all(jnp.isfinite(out)))
    qs = jnp.asarray(rng.normal(size=(b, kv, g, 4, hd)), jnp.float32)
    outp = ad.prefill_attn_q8(qs, cache, kl, jnp.full((b,), t - 4, jnp.int32),
                              backend="ref")
    assert bool(jnp.all(jnp.isfinite(outp)))
