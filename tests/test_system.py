"""End-to-end system behaviour: the paper's full lifecycle on CPU.

train (fp) -> checkpoint -> crash -> elastic restore -> resume ->
quantize (ITQ3_S + baselines) -> eval-quality ordering -> serve.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs.base import get_config, reduced
from repro.data.pipeline import SyntheticCorpus
from repro.models import lm
from repro.models.layers import Runtime
from repro.serve.engine import Request, ServeEngine
from repro.serve.quantized import quantize_params
from repro.train import loop as tl

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def trained():
    """Train a tiny model until it clearly learns the synthetic grammar."""
    cfg = reduced(get_config("smollm-135m"))
    rt = Runtime(compute_dtype=jnp.float32)
    step = jax.jit(tl.make_train_step(cfg, rt, warmup=10, total_steps=250,
                                      lr_peak=3e-3))
    state = tl.init_train_state(KEY, cfg)
    corpus = SyntheticCorpus(cfg.vocab_size, seed=3)
    for s in range(250):
        b = corpus.batch(s, 16, 64)
        state, m = step(state, {k: jnp.asarray(v) for k, v in b.items()})
    return cfg, state, corpus, float(m["loss"])


def eval_loss(cfg, params, corpus, n=4, rt=None):
    rt = rt or Runtime(compute_dtype=jnp.float32)
    tot = 0.0
    for b in corpus.eval_batches(n, 8, 64):
        loss, _ = lm.forward_xent(params, jnp.asarray(b["tokens"]),
                                  jnp.asarray(b["labels"]), rt, cfg)
        tot += float(loss)
    return tot / n


def test_training_learned(trained):
    cfg, state, corpus, last_loss = trained
    assert last_loss < 5.0  # well below ln(512)=6.24 uniform entropy


def test_checkpoint_resume_deterministic(trained, tmp_path):
    """Crash/restore: resumed training produces identical loss trajectory."""
    cfg, state, corpus, _ = trained
    rt = Runtime(compute_dtype=jnp.float32)
    step = jax.jit(tl.make_train_step(cfg, rt, warmup=10, total_steps=300))
    d = str(tmp_path)
    ckpt.save(d, int(state.step), state)

    def run(state, start, n):
        out = []
        for s in range(start, start + n):
            b = corpus.batch(s, 16, 64)
            state, m = step(state, {k: jnp.asarray(v) for k, v in b.items()})
            out.append(float(m["loss"]))
        return state, out

    _, direct = run(state, int(state.step), 3)
    restored, rstep = ckpt.restore(d, state)
    _, resumed = run(restored, rstep, 3)
    np.testing.assert_allclose(direct, resumed, rtol=1e-6)


def test_quality_ordering_reproduces_table1(trained):
    """Paper Table 1 proxy: eval-loss deltas must order
    fp < q8 < itq3_s < iq3_s (rotation closes the 3-bit gap)."""
    cfg, state, corpus, _ = trained
    base = eval_loss(cfg, state.params, corpus)
    deltas = {}
    for fmt in ("q8_0", "itq3_s", "iq3_s"):
        q = quantize_params(state.params, fmt)
        deltas[fmt] = eval_loss(cfg, q, corpus) - base
    assert deltas["q8_0"] < 0.05
    assert deltas["itq3_s"] < deltas["iq3_s"], deltas
    assert deltas["itq3_s"] >= -0.05


def test_lloyd_rule_improves_model_quality(trained):
    cfg, state, corpus, _ = trained
    base = eval_loss(cfg, state.params, corpus)
    d = {}
    for rule in ("paper", "lloyd"):
        q = quantize_params(state.params, "itq3_s", rule=rule)
        d[rule] = eval_loss(cfg, q, corpus) - base
    assert d["lloyd"] <= d["paper"] + 0.02, d


def test_serve_trained_quantized(trained):
    cfg, state, corpus, _ = trained
    q = quantize_params(state.params, "itq3_s")
    eng = ServeEngine(q, cfg, slots=2, max_len=48,
                      rt=Runtime(compute_dtype=jnp.float32))
    done = eng.run([Request(rid=i, prompt=np.arange(6 + i), max_new=6)
                    for i in range(3)])
    assert all(len(r.out) >= 6 for r in done)
    assert all(0 <= t < cfg.vocab_size for r in done for t in r.out)
