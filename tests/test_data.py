"""Data pipeline: determinism, shard disjointness, learnable structure."""
import numpy as np

from repro.data.pipeline import SyntheticCorpus


def test_deterministic_replay():
    c1 = SyntheticCorpus(512, seed=7)
    c2 = SyntheticCorpus(512, seed=7)
    b1 = c1.batch(42, 4, 32, shard=1, num_shards=4)
    b2 = c2.batch(42, 4, 32, shard=1, num_shards=4)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    assert np.array_equal(b1["labels"], b2["labels"])


def test_labels_are_shifted_tokens():
    c = SyntheticCorpus(512, seed=0)
    b = c.batch(0, 2, 16)
    assert np.array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_shards_differ():
    c = SyntheticCorpus(512, seed=0)
    a = c.batch(5, 4, 32, shard=0, num_shards=4)["tokens"]
    b = c.batch(5, 4, 32, shard=1, num_shards=4)["tokens"]
    assert not np.array_equal(a, b)


def test_steps_differ():
    c = SyntheticCorpus(512, seed=0)
    assert not np.array_equal(c.batch(1, 2, 16)["tokens"],
                              c.batch(2, 2, 16)["tokens"])


def test_bigram_structure_learnable():
    """Transitions follow the seeded table >= (1 - reset_prob)-ish often."""
    c = SyntheticCorpus(128, seed=9, branching=4, reset_prob=0.05)
    b = c.batch(0, 8, 256)
    toks = np.concatenate([b["tokens"], b["labels"][:, -1:]], axis=1)
    hits = 0
    total = 0
    for row in toks:
        for i in range(len(row) - 1):
            total += 1
            if row[i + 1] in c._table[row[i]]:
                hits += 1
    assert hits / total > 0.85


def test_eval_stream_disjoint_from_train():
    c = SyntheticCorpus(512, seed=0)
    train = c.batch(0, 2, 16)["tokens"]
    ev = next(iter(c.eval_batches(1, 2, 16)))["tokens"]
    assert not np.array_equal(train, ev)
