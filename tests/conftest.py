import signal

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


# --- @pytest.mark.timeout(seconds) ------------------------------------------
# A hard per-test wall clock via SIGALRM (no pytest-timeout dependency).
# The fault-injection suite uses it so a resilience regression that HANGS
# the engine (the exact failure class the suite exists to catch) fails the
# test instead of wedging CI. Unix-only; silently inert where SIGALRM is
# unavailable.

def pytest_runtest_setup(item):
    marker = item.get_closest_marker("timeout")
    if marker is None or not hasattr(signal, "SIGALRM"):
        return
    seconds = int(marker.args[0]) if marker.args else 60

    def _on_alarm(signum, frame):
        raise TimeoutError(
            f"test exceeded its {seconds}s timeout marker (hung?)")

    item._timeout_prev = signal.signal(signal.SIGALRM, _on_alarm)
    signal.alarm(seconds)


def pytest_runtest_teardown(item, nextitem):
    if hasattr(item, "_timeout_prev"):
        signal.alarm(0)
        signal.signal(signal.SIGALRM, item._timeout_prev)
        del item._timeout_prev
