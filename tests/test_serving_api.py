"""Request-lifecycle serving API: per-request SamplingParams batched on
device, streaming generate(), stop/cancel lifecycle, pluggable scheduling,
and the donated-cache no-copy decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.models import lm
from repro.models.layers import Runtime
from repro.serve.engine import Request, SamplingParams, ServeEngine
from repro.serve.sampling import (
    FINISH_CANCELLED, FINISH_LENGTH, FINISH_STOP, StreamEvent,
)
from repro.serve.scheduler import (
    FIFOScheduler, PriorityScheduler, ShortestPromptFirstScheduler,
    get_scheduler,
)

KEY = jax.random.PRNGKey(0)
RT = Runtime(compute_dtype=jnp.float32, capacity_factor=8.0)


@pytest.fixture(scope="module")
def model():
    cfg = reduced(get_config("smollm-135m"))
    return cfg, lm.init_params(KEY, cfg)


def _engine(model, **kw):
    cfg, params = model
    kw.setdefault("slots", 2)
    kw.setdefault("max_len", 48)
    return ServeEngine(params, cfg, rt=RT, **kw)


# ---------------------------------------------------------------------------
# SamplingParams + top-k/top-p masking
# ---------------------------------------------------------------------------

def test_sampling_params_validation():
    with pytest.raises(ValueError, match="temperature"):
        SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError, match="top_k"):
        SamplingParams(top_k=-1)
    for bad_p in (0.0, 1.5):
        with pytest.raises(ValueError, match="top_p"):
            SamplingParams(top_p=bad_p)
    with pytest.raises(ValueError, match="max_new"):
        SamplingParams(max_new=0)
    assert SamplingParams().greedy
    assert not SamplingParams(temperature=0.7).greedy


def test_top_mask_per_row_k_and_p(rng):
    logits = jnp.asarray(rng.normal(size=(3, 32)), jnp.float32)
    # row 0: k=1 keeps exactly the argmax; row 1: disabled; row 2: k=5
    masked = lm.top_mask(logits, top_k=jnp.asarray([1, 0, 5]), top_p=None)
    m = np.asarray(masked)
    assert np.sum(np.isfinite(m[0])) == 1
    assert np.argmax(m[0]) == np.argmax(np.asarray(logits[0]))
    assert np.all(np.isfinite(m[1]))
    assert np.sum(np.isfinite(m[2])) == 5
    # tiny top_p keeps at least (exactly, for a peaked row) the argmax;
    # top_p=1.0 disables
    peaked = jnp.asarray([[0.0, 10.0, 0.1, -1.0]], jnp.float32)
    mp = np.asarray(lm.top_mask(peaked, top_k=None,
                                top_p=jnp.asarray([1e-6])))
    assert np.sum(np.isfinite(mp)) == 1 and np.argmax(mp) == 1
    assert np.all(np.isfinite(np.asarray(
        lm.top_mask(peaked, top_k=None, top_p=jnp.asarray([1.0])))))


def test_sample_tokens_legacy_shapes_still_route_shared_stream():
    """1-D (V,) logits with a single (2,) key must take the legacy shared
    stream, not the vmapped per-row path (regression: the batched-key
    heuristic must key on the key's shape, not the logits rank)."""
    tok = lm.sample_tokens(jnp.arange(100.0), jax.random.PRNGKey(0), 1.0)
    assert 0 <= int(tok) < 100
    toks = lm.sample_tokens(jnp.arange(200.0).reshape(2, 100),
                            jax.random.PRNGKey(0), 1.0)
    assert toks.shape == (2,)


def test_greedy_request_filters_normalized_inert(model):
    """A greedy request carrying top_k/top_p must not drag top_mask's
    full-vocab sort into a mixed batch's decode trace: argmax ignores the
    filters, so resolution normalizes them to the inert 0 / 1.0."""
    eng = _engine(model)
    sp = eng._resolve(Request(
        rid=0, prompt=np.arange(3), max_new=2,
        sampling=SamplingParams(temperature=0.0, top_k=40, top_p=0.5)))
    assert sp.top_k == 0 and sp.top_p == 1.0
    # sampled requests keep theirs
    sp2 = eng._resolve(Request(
        rid=1, prompt=np.arange(3), max_new=2,
        sampling=SamplingParams(temperature=0.5, top_k=40, top_p=0.5)))
    assert sp2.top_k == 40 and sp2.top_p == 0.5


def test_sample_tokens_per_row_keys_row_independent(rng):
    """A row's draw depends only on its own key — not on batch position
    (the property heterogeneous batching parity is built on)."""
    logits = jnp.asarray(rng.normal(size=(4, 64)), jnp.float32)
    keys = jnp.asarray(np.stack([np.asarray(jax.random.PRNGKey(s))
                                 for s in (7, 8, 9, 10)]))
    temp = jnp.ones(4, jnp.float32)
    batched = lm.sample_tokens(logits, keys, temp)
    single = [lm.sample_tokens(logits[i:i + 1], keys[i:i + 1], temp[i:i + 1])
              for i in range(4)]
    assert [int(t[0]) for t in single] == list(np.asarray(batched))


# ---------------------------------------------------------------------------
# Heterogeneous per-request sampling in ONE batched decode
# ---------------------------------------------------------------------------

def _mixed_requests(vocab):
    return [
        Request(rid=0, prompt=np.arange(5) % vocab, max_new=6),  # greedy
        Request(rid=1, prompt=np.arange(7) % vocab, max_new=6,
                sampling=SamplingParams(temperature=0.9, seed=11)),
        Request(rid=2, prompt=np.arange(3) % vocab, max_new=6,
                sampling=SamplingParams(temperature=1.1, top_k=8, seed=5)),
        Request(rid=3, prompt=np.arange(4) % vocab, max_new=6,
                sampling=SamplingParams(temperature=0.7, top_p=0.8, seed=3)),
    ]


def test_heterogeneous_batch_bitwise_matches_sequential(model):
    """Greedy + temperature + top-k + top-p with distinct seeds in ONE
    batched jitted decode == each request run alone (bit-identical)."""
    cfg, _ = model
    batched = _mixed_requests(cfg.vocab_size)
    eng = _engine(model, slots=4)
    eng.run(batched)
    sequential = _mixed_requests(cfg.vocab_size)
    for r in sequential:
        _engine(model, slots=1).run([r])
    assert [r.out for r in batched] == [r.out for r in sequential]
    # the sampled streams genuinely sampled (seeded, non-degenerate): at
    # least one differs from the greedy stream of the same prompt
    greedy_ref = Request(rid=1, prompt=np.arange(7) % cfg.vocab_size,
                         max_new=6)
    _engine(model, slots=1).run([greedy_ref])
    assert batched[1].out != greedy_ref.out


def test_heterogeneous_parity_through_chunk_ladder():
    """Recurrent admission (SSM/hybrid chunk ladder) preserves the same
    batched==sequential bit-parity for per-request sampling."""
    cfg = reduced(get_config("zamba2-7b"))
    params = lm.init_params(KEY, cfg)

    def make():
        return [Request(rid=0, prompt=np.arange(9), max_new=4),
                Request(rid=1, prompt=np.arange(5), max_new=4,
                        sampling=SamplingParams(temperature=1.0, top_k=12,
                                                seed=4))]

    batched = make()
    ServeEngine(params, cfg, slots=2, max_len=32, rt=RT,
                prompt_chunk=8).run(batched)
    sequential = make()
    for r in sequential:
        ServeEngine(params, cfg, slots=1, max_len=32, rt=RT,
                    prompt_chunk=8).run([r])
    assert [r.out for r in batched] == [r.out for r in sequential]


def test_mixed_batch_single_decode_one_sync_per_step(model):
    """Heterogeneous sampling keeps the 1 device->host transfer/step
    discipline: one sync for the admission wave, one per decode step."""
    cfg, _ = model
    eng = _engine(model, slots=4)
    assert eng.admit(_mixed_requests(cfg.vocab_size)) == 4
    assert eng.host_syncs == 1
    for _ in range(4):
        before = eng.host_syncs
        eng.step()
        assert eng.host_syncs - before == 1


# ---------------------------------------------------------------------------
# Stop tokens / EOS
# ---------------------------------------------------------------------------

def test_stop_token_early_finish(model):
    cfg, _ = model
    prompt = np.arange(6) % cfg.vocab_size
    [ref] = _engine(model, slots=1).run([Request(rid=0, prompt=prompt,
                                                 max_new=8)])
    assert ref.finish_reason == FINISH_LENGTH
    stop_tok = ref.out[2]
    cut = ref.out.index(stop_tok)  # first emission of the stop id
    [r] = _engine(model, slots=1).run([
        Request(rid=0, prompt=prompt, max_new=8,
                sampling=SamplingParams(stop=(stop_tok,)))])
    assert r.finish_reason == FINISH_STOP
    assert r.out == ref.out[:cut + 1]  # stop token included, then finish


def test_eos_id_and_ignore_eos(model):
    cfg, _ = model
    prompt = np.arange(6) % cfg.vocab_size
    [ref] = _engine(model, slots=1).run([Request(rid=0, prompt=prompt,
                                                 max_new=8)])
    eos = ref.out[1]
    cut = ref.out.index(eos)
    [r] = _engine(model, slots=1, eos_id=eos).run(
        [Request(rid=0, prompt=prompt, max_new=8)])
    assert r.finish_reason == FINISH_STOP and r.out == ref.out[:cut + 1]
    [r2] = _engine(model, slots=1, eos_id=eos).run(
        [Request(rid=0, prompt=prompt, max_new=8,
                 sampling=SamplingParams(ignore_eos=True))])
    assert r2.finish_reason == FINISH_LENGTH and r2.out == ref.out


# ---------------------------------------------------------------------------
# Streaming generate(): events, stats, cancellation
# ---------------------------------------------------------------------------

def test_generate_streams_one_event_per_token_with_stats(model):
    cfg, _ = model
    reqs = [Request(rid=i, prompt=np.arange(4 + i) % cfg.vocab_size,
                    max_new=5) for i in range(3)]
    eng = _engine(model, slots=2)
    events = list(eng.generate(reqs))
    assert all(isinstance(e, StreamEvent) for e in events)
    total = sum(len(r.out) for r in reqs)
    assert len(events) == total
    finals = [e for e in events if e.finished]
    assert sorted(e.rid for e in finals) == [0, 1, 2]
    for e in finals:
        assert e.finish_reason == FINISH_LENGTH
        assert e.stats["tokens"] == 5
        assert e.stats["ttft_s"] >= e.stats["queue_wait_s"] >= 0.0
        assert e.stats["decode_tok_s"] > 0
    # rid 2 waited for a slot: its queue wait must exceed the first wave's
    waits = {e.rid: e.stats["queue_wait_s"] for e in finals}
    assert waits[2] > max(waits[0], waits[1])


def test_cancel_live_slot_midstream(model):
    cfg, _ = model
    eng = _engine(model, slots=2)
    reqs = [Request(rid=0, prompt=np.arange(5) % cfg.vocab_size, max_new=12),
            Request(rid=1, prompt=np.arange(6) % cfg.vocab_size, max_new=12)]
    events = []
    for e in eng.generate(reqs):
        events.append(e)
        if e.rid == 1 and e.index == 2 and not e.finished:
            assert eng.cancel(1)
    finals = {e.rid: e for e in events if e.finished}
    assert finals[1].finish_reason == FINISH_CANCELLED
    assert reqs[1].done and len(reqs[1].out) == 3
    # the survivor is unaffected and runs to its budget
    assert finals[0].finish_reason == FINISH_LENGTH
    assert len(reqs[0].out) == 12
    assert not eng.cancel(0)  # already finished: nothing to cancel


def test_cancel_queued_request_never_admitted(model):
    cfg, _ = model
    eng = _engine(model, slots=1)
    reqs = [Request(rid=0, prompt=np.arange(5) % cfg.vocab_size, max_new=6),
            Request(rid=1, prompt=np.arange(4) % cfg.vocab_size, max_new=6)]
    events = []
    for e in eng.generate(reqs):
        events.append(e)
        if len(events) == 1:  # rid 1 still waiting in the scheduler
            assert eng.cancel(1)
    finals = {e.rid: e for e in events if e.finished}
    assert finals[1].finish_reason == FINISH_CANCELLED
    assert finals[1].token is None and reqs[1].out == []
    assert len(reqs[0].out) == 6


# ---------------------------------------------------------------------------
# Schedulers
# ---------------------------------------------------------------------------

def test_get_scheduler_resolution():
    assert isinstance(get_scheduler(None), FIFOScheduler)
    assert isinstance(get_scheduler("priority"), PriorityScheduler)
    sched = ShortestPromptFirstScheduler()
    assert get_scheduler(sched) is sched
    with pytest.raises(ValueError, match="unknown scheduler"):
        get_scheduler("lifo")


def _admission_order(model, scheduler, reqs):
    eng = _engine(model, slots=1, scheduler=scheduler)
    list(eng.generate(reqs))
    return [r.rid for r in sorted(reqs, key=lambda r: r.t_admit)]


def test_priority_preempts_fifo_order(model):
    cfg, _ = model
    def make():
        return [Request(rid=0, prompt=np.arange(4) % cfg.vocab_size,
                        max_new=3, priority=0),
                Request(rid=1, prompt=np.arange(4) % cfg.vocab_size,
                        max_new=3, priority=0),
                Request(rid=2, prompt=np.arange(4) % cfg.vocab_size,
                        max_new=3, priority=5)]
    assert _admission_order(model, "fifo", make()) == [0, 1, 2]
    # the late-submitted high-priority request jumps the whole queue
    assert _admission_order(model, "priority", make()) == [2, 0, 1]


def test_shortest_prompt_first_order(model):
    cfg, _ = model
    def make():
        return [Request(rid=0, prompt=np.arange(9) % cfg.vocab_size, max_new=3),
                Request(rid=1, prompt=np.arange(3) % cfg.vocab_size, max_new=3),
                Request(rid=2, prompt=np.arange(6) % cfg.vocab_size, max_new=3)]
    assert _admission_order(model, "fifo", make()) == [0, 1, 2]
    assert _admission_order(model, "sjf", make()) == [1, 2, 0]


def test_scheduler_cancel_then_resubmit_same_rid():
    """Lazy cancellation is keyed by queue ENTRY, not rid: cancelling a
    queued request and resubmitting the same rid must admit the fresh
    request, not the stale cancelled one."""
    sched = PriorityScheduler()
    stale = Request(rid=5, prompt=np.arange(3), max_new=2, priority=0)
    sched.add(stale)
    assert sched.cancel(5) is stale
    fresh = Request(rid=5, prompt=np.arange(3), max_new=2, priority=9)
    sched.add(fresh)
    assert len(sched) == 1
    popped = sched.pop(5)
    assert popped == [fresh] and not fresh.done
    assert len(sched) == 0


@pytest.mark.parametrize("sched_cls", [
    FIFOScheduler, PriorityScheduler, ShortestPromptFirstScheduler])
def test_scheduler_cancel_hits_oldest_live_entry(sched_cls):
    """With duplicate rids queued, cancel() removes the OLDEST live entry
    — on every scheduler, including the heaps (whose _entries() view must
    be arrival-ordered, not heap-ordered)."""
    sched = sched_cls()
    first = Request(rid=3, prompt=np.arange(2), max_new=2, priority=1)
    second = Request(rid=3, prompt=np.arange(5), max_new=2, priority=9)
    sched.add(first)
    sched.add(second)
    assert sched.cancel(3) is first  # oldest, NOT best-keyed
    assert sched.cancel(3) is second
    assert sched.cancel(3) is None
    assert len(sched) == 0 and sched.pop(5) == []


@pytest.mark.parametrize("sched_cls", [
    FIFOScheduler, PriorityScheduler, ShortestPromptFirstScheduler])
def test_scheduler_cancel_resubmit_roundtrip_all_schedulers(sched_cls):
    """cancel -> resubmit same rid -> the FRESH entry pops (entry-keyed
    lazy cancellation), under every built-in scheduler."""
    sched = sched_cls()
    stale = Request(rid=7, prompt=np.arange(4), max_new=2, priority=0)
    sched.add(stale)
    assert sched.cancel(7) is stale
    fresh = Request(rid=7, prompt=np.arange(4), max_new=2, priority=5)
    sched.add(fresh)
    assert sched.pop(5) == [fresh] and not fresh.done
    assert len(sched) == 0


def test_scheduler_shed_lowest_priority_youngest_on_ties():
    sched = PriorityScheduler()
    reqs = [Request(rid=i, prompt=np.arange(3), max_new=2, priority=p)
            for i, p in enumerate([1, 0, 0, 2])]
    for r in reqs:
        sched.add(r)
    # lowest priority wins; among the two p=0 entries the YOUNGER goes
    assert sched.shed() is reqs[2]
    assert sched.shed() is reqs[1]
    # below= only sheds STRICTLY lower priorities
    assert sched.shed(below=1) is None
    assert sched.shed(below=2) is reqs[0]
    assert len(sched) == 1
    assert sched.pop(5) == [reqs[3]]


def test_scheduler_waiting_cancel_bookkeeping(model):
    cfg, _ = model
    sched = PriorityScheduler()
    reqs = [Request(rid=i, prompt=np.arange(3), max_new=2, priority=i)
            for i in range(3)]
    for r in reqs:
        sched.add(r)
    assert len(sched) == 3
    cancelled = sched.cancel(2)
    assert cancelled is reqs[2] and cancelled.done
    assert cancelled.finish_reason == FINISH_CANCELLED
    assert len(sched) == 2
    assert sched.cancel(2) is None  # idempotent
    assert [r.rid for r in sched.pop(5)] == [1, 0]
    assert len(sched) == 0


# ---------------------------------------------------------------------------
# run() shim, 1-sync discipline under generate(), donation
# ---------------------------------------------------------------------------

def test_run_shim_matches_generate(model):
    cfg, _ = model
    def make():
        return [Request(rid=i, prompt=np.arange(3 + i) % cfg.vocab_size,
                        max_new=4) for i in range(4)]
    ran, streamed = make(), make()
    _engine(model, slots=2).run(ran)
    list(_engine(model, slots=2).generate(streamed))
    assert [r.out for r in ran] == [r.out for r in streamed]


def test_one_sync_per_step_under_generate(model):
    cfg, _ = model
    reqs = [Request(rid=0, prompt=np.arange(5) % cfg.vocab_size, max_new=6),
            Request(rid=1, prompt=np.arange(4) % cfg.vocab_size, max_new=6,
                    sampling=SamplingParams(temperature=0.8, seed=2))]
    eng = _engine(model, slots=2)
    list(eng.generate(reqs))
    st = eng.stats()
    # exactly one admission wave + one fetch per decode step, even with
    # mixed greedy/temperature slots
    assert eng.host_syncs == 1 + st["decode_steps"]
    assert st["syncs_per_token"] < 1.0


def test_decode_cache_donation_no_copy(model):
    cfg, _ = model
    eng = _engine(model, slots=2)
    eng.run([Request(rid=i, prompt=np.arange(4 + i) % cfg.vocab_size,
                     max_new=6) for i in range(2)])
    st = eng.stats()
    assert st["decode_steps"] > 0
    assert st["cache_donated"] is True
    assert st["cache_bytes_moved"] == 0
