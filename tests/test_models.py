"""Per-architecture smoke tests (reduced configs, CPU): forward shapes,
no NaNs, decode==full-forward equivalence, family-specific behaviours."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, SHAPES, get_config, reduced, runnable_cells
from repro.models import lm
from repro.models.layers import Runtime

RT = Runtime(compute_dtype=jnp.float32, capacity_factor=64.0)
KEY = jax.random.PRNGKey(0)


def make(arch):
    cfg = reduced(get_config(arch))
    params = lm.init_params(KEY, cfg)
    return cfg, params


def inputs(cfg, b, t):
    tokens = jax.random.randint(KEY, (b, t), 0, cfg.vocab_size)
    ff = None
    if cfg.frontend:
        ff = jax.random.normal(KEY, (b, cfg.frontend_len, cfg.frontend_dim),
                               jnp.float32)
    return tokens, ff


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_smoke(arch):
    cfg, params = make(arch)
    tokens, ff = inputs(cfg, 2, 16)
    logits, _, aux = lm.forward(params, tokens, RT, cfg, frontend_feats=ff)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))
    if cfg.num_experts:
        assert float(aux) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    from repro.train import loop as tl
    cfg = reduced(get_config(arch))
    rt = Runtime(compute_dtype=jnp.float32, capacity_factor=4.0)
    step = jax.jit(tl.make_train_step(cfg, rt, warmup=1, total_steps=10))
    state = tl.init_train_state(KEY, cfg)
    tokens, ff = inputs(cfg, 2, 16)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
    if ff is not None:
        batch["frontend"] = ff
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state.step) == 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_full_forward(arch):
    cfg, params = make(arch)
    T = 12
    tokens, ff = inputs(cfg, 2, T + 1)
    full, _, _ = lm.forward(params, tokens, RT, cfg, frontend_feats=ff)
    cache = lm.init_cache(cfg, 2, 24, dtype=jnp.float32)
    _, cache, _ = lm.forward(params, tokens[:, :T], RT, cfg,
                             frontend_feats=ff, cache=cache, pos=0)
    dpos = T + (cfg.frontend_len if (cfg.frontend and cfg.family != "audio") else 0)
    dec, _ = lm.decode_step(params, tokens[:, T:T + 1], cache,
                            jnp.int32(dpos), RT, cfg)
    err = float(jnp.max(jnp.abs(dec[:, 0] - full[:, T])))
    assert err < 1e-3 * max(float(jnp.max(jnp.abs(full[:, T]))), 1.0), arch


def test_ragged_positions_decode():
    """Per-row cache positions (continuous batching) match row-wise decode."""
    cfg, params = make("smollm-135m")
    toks = jax.random.randint(KEY, (2, 9), 0, cfg.vocab_size)
    # row 0 has 5 ctx tokens, row 1 has 8
    cache = lm.init_cache(cfg, 2, 16, dtype=jnp.float32)
    _, cache, _ = lm.forward(params, toks[:, :8], RT, cfg, cache=cache, pos=0)
    pos = jnp.asarray([5, 8], jnp.int32)
    dec, _ = lm.decode_step(params, toks[:, 8:9], cache, pos, RT, cfg)
    # reference: single-row decode
    for row in range(2):
        c1 = lm.init_cache(cfg, 1, 16, dtype=jnp.float32)
        p = int(pos[row])
        _, c1, _ = lm.forward(params, toks[row:row+1, :p], RT, cfg, cache=c1, pos=0)
        d1, _ = lm.decode_step(params, toks[row:row+1, 8:9], c1,
                               jnp.int32(p), RT, cfg)
        err = float(jnp.max(jnp.abs(d1[0, 0] - dec[row, 0])))
        assert err < 1e-3 * max(float(jnp.max(jnp.abs(d1))), 1.0), row


def test_last_only_prefill():
    cfg, params = make("qwen1.5-0.5b")
    tokens, _ = inputs(cfg, 2, 16)
    full, _, _ = lm.forward(params, tokens, RT, cfg)
    last, _, _ = lm.forward(params, tokens, RT, cfg, last_only=True)
    assert last.shape == (2, 1, cfg.vocab_size)
    np.testing.assert_allclose(np.asarray(last[:, 0]), np.asarray(full[:, -1]),
                               atol=1e-4)


def test_forward_xent_matches_explicit_loss():
    from repro.train.loop import softmax_xent
    cfg, params = make("stablelm-3b")
    tokens, _ = inputs(cfg, 2, 16)
    labels = jnp.roll(tokens, -1, axis=1)
    logits, _, _ = lm.forward(params, tokens, RT, cfg)
    want = float(softmax_xent(logits, labels))
    got, _ = lm.forward_xent(params, tokens, labels, RT, cfg, chunk=8)
    assert abs(float(got) - want) < 1e-3


def test_runnable_cells_accounting():
    cells = runnable_cells()
    assert len(cells) == 32  # 40 assigned minus 8 documented long_500k skips
    assert ("rwkv6-3b", "long_500k") in cells
    assert ("zamba2-7b", "long_500k") in cells
    assert ("nemotron-4-15b", "long_500k") not in cells


def test_model_flops_sane():
    cfg = get_config("smollm-135m")
    f = lm.model_flops(cfg, 4096, 256)
    # ~6ND: N~135M (won't be exact; order check)
    assert 1e14 < f < 1e16
