"""Failure-hardened serving: every failure mode must end in a terminal
StreamEvent with the right finish_reason — never a hang, a crash, or a
corrupted neighbor stream — driven by the seeded fault-injection harness
(serve/faults.py). Each test carries a hard ``timeout`` marker: the
regression class this suite guards against is the engine WEDGING, and a
hung test must fail, not stall CI."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.models import lm
from repro.models.layers import Runtime
from repro.serve.engine import Request, SamplingParams, ServeEngine
from repro.serve.faults import Fault, FaultClock, FaultPlan, burst, \
    inject_kv_nan
from repro.serve.sampling import (
    FINISH_DEADLINE, FINISH_ERROR, FINISH_LENGTH, FINISH_REASONS,
    FINISH_REJECTED,
)

KEY = jax.random.PRNGKey(0)
RT = Runtime(compute_dtype=jnp.float32, capacity_factor=8.0)
RTQ = Runtime(compute_dtype=jnp.float32, kv_quant=True, capacity_factor=8.0)


@pytest.fixture(scope="module")
def model():
    cfg = reduced(get_config("smollm-135m"))
    return cfg, lm.init_params(KEY, cfg)


def _engine(model, **kw):
    cfg, params = model
    kw.setdefault("slots", 2)
    kw.setdefault("max_len", 48)
    kw.setdefault("rt", RT)
    return ServeEngine(params, cfg, **kw)


def _reqs(cfg, n=2, max_new=8, **kw):
    return [Request(rid=i, prompt=(np.arange(4 + i) % cfg.vocab_size
                                   ).astype(np.int32),
                    max_new=max_new, **kw) for i in range(n)]


# ---------------------------------------------------------------------------
# Numeric quarantine
# ---------------------------------------------------------------------------

@pytest.mark.timeout(120)
@pytest.mark.parametrize("value", [math.nan, math.inf])
def test_kv_scale_poison_quarantines_slot_healthy_stream_bit_identical(
        model, value):
    """Poisoning one slot's KV scale plane mid-decode must (a) finish THAT
    stream with finish_reason="error", (b) leave the co-resident stream
    bit-identical to a fault-free run, (c) keep 1 host sync per step."""
    cfg, _ = model
    clean = _reqs(cfg)
    _engine(model, rt=RTQ).run(clean)

    plan = FaultPlan([Fault("kv_nan", step=2, slot=0, plane="k_scale",
                            value=value)])
    eng = _engine(model, rt=RTQ, faults=plan)
    faulted = _reqs(cfg)
    events = list(eng.generate(faulted))

    poisoned, healthy = faulted
    assert poisoned.finish_reason == FINISH_ERROR
    assert 1 <= len(poisoned.out) < poisoned.max_new
    assert healthy.finish_reason == FINISH_LENGTH
    assert healthy.out == clean[1].out  # bit-identical neighbor
    assert eng.quarantined == 1
    assert plan.log and plan.log[0][1] == "kv_nan"
    # quarantine detection rides the step's one token transfer
    assert eng.host_syncs == 1 + eng.decode_steps
    term = [e for e in events if e.finished and e.rid == poisoned.rid]
    assert len(term) == 1 and term[0].token is None
    assert term[0].stats["tokens"] == len(poisoned.out)
    # the poisoned slot's rows were re-zeroed: a NEW tenant of the same
    # slot decodes exactly as in a fresh engine
    again = [Request(rid=10, prompt=np.arange(4, dtype=np.int32), max_new=4)]
    list(eng.generate(again))
    ref = [Request(rid=10, prompt=np.arange(4, dtype=np.int32), max_new=4)]
    _engine(model, rt=RTQ).run(ref)
    assert again[0].out == ref[0].out


@pytest.mark.timeout(120)
def test_fp_cache_poison_quarantines_too(model):
    """The quarantine is cache-layout agnostic: an fp KV cache poisoned
    through its raw "k" plane trips the same finiteness check."""
    cfg, _ = model
    plan = FaultPlan([Fault("kv_nan", step=1, slot=1, plane="k")])
    eng = _engine(model, faults=plan)
    reqs = _reqs(cfg)
    list(eng.generate(reqs))
    assert reqs[1].finish_reason == FINISH_ERROR
    assert reqs[0].finish_reason == FINISH_LENGTH
    assert eng.quarantined == 1


@pytest.mark.timeout(60)
def test_inject_kv_nan_rejects_int_planes_and_unknown_planes(model):
    eng = _engine(model, rt=RTQ)
    eng.run(_reqs(cfg=model[0], n=1, max_new=2))
    with pytest.raises(TypeError, match="int"):
        inject_kv_nan(eng, plane="k")  # int8 codes can't hold a NaN
    with pytest.raises(KeyError, match="no attn plane"):
        inject_kv_nan(eng, plane="bogus")


@pytest.mark.timeout(120)
def test_quarantine_on_host_sampling_path(model):
    """sample_on_host=True fetches logits, not tokens — the host-side
    finiteness check must quarantine there too."""
    cfg, _ = model
    plan = FaultPlan([Fault("kv_nan", step=1, slot=0)])
    eng = _engine(model, rt=RTQ, sample_on_host=True, faults=plan)
    reqs = _reqs(cfg)
    list(eng.generate(reqs))
    assert reqs[0].finish_reason == FINISH_ERROR
    assert reqs[1].finish_reason == FINISH_LENGTH
    assert eng.quarantined == 1


# ---------------------------------------------------------------------------
# Deadlines / timeouts
# ---------------------------------------------------------------------------

@pytest.mark.timeout(120)
def test_live_deadline_expires_midstream(model):
    cfg, _ = model
    clk = FaultClock()
    eng = _engine(model, slots=1, clock=clk)
    req = Request(rid=0, prompt=np.arange(4, dtype=np.int32), max_new=50,
                  deadline_ms=100.0)
    it = eng.generate([req])
    for _ in range(3):
        next(it)
    clk.advance(1.0)  # blow way past the 100ms budget
    tail = list(it)
    assert req.finish_reason == FINISH_DEADLINE
    assert 1 <= len(req.out) < 50
    assert eng.deadline_expired == 1
    assert tail[-1].finished and tail[-1].token is None


@pytest.mark.timeout(120)
def test_queued_deadline_sheds_at_pop_no_prefill(model):
    """A request whose deadline passed while WAITING is shed at pop time —
    terminal "deadline" event, never admitted (no wasted prefill)."""
    cfg, _ = model
    plan = FaultPlan([Fault("clock_skip", step=2, dt=1.0)])
    eng = _engine(model, slots=1, faults=plan)
    a = Request(rid=0, prompt=np.arange(4, dtype=np.int32), max_new=6)
    b = Request(rid=1, prompt=np.arange(5, dtype=np.int32), max_new=6,
                deadline_ms=50.0)
    list(eng.generate([a, b]))
    assert a.finish_reason == FINISH_LENGTH
    assert b.finish_reason == FINISH_DEADLINE
    assert b.t_admit is None and b.out == []  # never prefilled
    assert eng.deadline_expired == 1


@pytest.mark.timeout(120)
def test_decode_timeout_expires_after_first_token(model):
    cfg, _ = model
    plan = FaultPlan([Fault("clock_skip", step=2, dt=1.0)])
    eng = _engine(model, slots=1, faults=plan)
    req = Request(rid=0, prompt=np.arange(4, dtype=np.int32), max_new=50,
                  decode_timeout_ms=50.0)
    list(eng.generate([req]))
    assert req.finish_reason == FINISH_DEADLINE
    assert req.t_first is not None and len(req.out) >= 1
    assert eng.deadline_expired == 1


# ---------------------------------------------------------------------------
# Backpressure
# ---------------------------------------------------------------------------

@pytest.mark.timeout(120)
def test_max_queue_reject_policy(model):
    cfg, _ = model
    eng = _engine(model, slots=1, max_queue=2)
    reqs = burst(5, cfg.vocab_size, max_new=3)
    accepted = [eng.submit_request(r) for r in reqs]
    assert accepted == [True, True, False, False, False]
    assert eng.requests_rejected == 3
    events = list(eng.generate())
    reasons = {r.rid: r.finish_reason for r in reqs}
    assert [reasons[i] for i in range(5)] == [
        FINISH_LENGTH, FINISH_LENGTH,
        FINISH_REJECTED, FINISH_REJECTED, FINISH_REJECTED]
    # rejected requests still got their terminal event through the stream
    term = {e.rid for e in events if e.finished}
    assert term == {0, 1, 2, 3, 4}


@pytest.mark.timeout(120)
def test_shed_lowest_evicts_waiting_victim_not_equal_priority(model):
    cfg, _ = model
    eng = _engine(model, slots=1, max_queue=1, shed_policy="shed_lowest",
                  scheduler="priority")
    low = Request(rid=0, prompt=np.arange(4, dtype=np.int32), max_new=3,
                  priority=0)
    assert eng.submit_request(low)
    high = Request(rid=1, prompt=np.arange(4, dtype=np.int32), max_new=3,
                   priority=5)
    assert eng.submit_request(high)  # displaces the waiting low-priority
    assert low.finish_reason == FINISH_REJECTED
    assert eng.requests_shed == 1 and eng.requests_rejected == 0
    # an EQUAL-priority newcomer never displaces the incumbent (no churn)
    peer = Request(rid=2, prompt=np.arange(4, dtype=np.int32), max_new=3,
                   priority=5)
    assert not eng.submit_request(peer)
    assert peer.finish_reason == FINISH_REJECTED
    assert eng.requests_rejected == 1
    list(eng.generate())
    assert high.finish_reason == FINISH_LENGTH


@pytest.mark.timeout(60)
def test_engine_validates_backpressure_knobs(model):
    with pytest.raises(ValueError, match="max_queue"):
        _engine(model, max_queue=0)
    with pytest.raises(ValueError, match="shed_policy"):
        _engine(model, shed_policy="drop_newest")


# ---------------------------------------------------------------------------
# Malformed requests (empty prompt)
# ---------------------------------------------------------------------------

@pytest.mark.timeout(120)
def test_empty_prompt_rejected_alone_not_whole_wave(model):
    """Regression: an empty prompt used to raise mid-_admit_group AFTER
    its wave peers were stamped, aborting the wave. It must be rejected
    ALONE with a terminal "error" event, peers unaffected."""
    cfg, _ = model
    eng = _engine(model)
    good = Request(rid=0, prompt=np.arange(4, dtype=np.int32), max_new=3)
    bad = Request(rid=1, prompt=np.zeros(0, dtype=np.int32), max_new=3)
    assert eng.admit([good, bad]) == 1
    assert bad.finish_reason == FINISH_ERROR and bad.done
    assert eng.active[0] is good and eng.requests_invalid == 1
    list(eng.generate())
    assert good.finish_reason == FINISH_LENGTH


@pytest.mark.timeout(120)
def test_empty_prompt_screened_at_submit(model):
    cfg, _ = model
    eng = _engine(model)
    bad = Request(rid=0, prompt=np.zeros(0, dtype=np.int32), max_new=3)
    assert not eng.submit_request(bad)
    assert bad.finish_reason == FINISH_ERROR
    assert len(eng.scheduler) == 0 and eng.requests_invalid == 1
    events = list(eng.generate())
    assert len(events) == 1 and events[0].finished
    assert events[0].finish_reason == FINISH_ERROR


# ---------------------------------------------------------------------------
# Watchdog
# ---------------------------------------------------------------------------

@pytest.mark.timeout(120)
def test_watchdog_counts_stalled_steps(model):
    cfg, _ = model
    plan = FaultPlan([Fault("stall", step=2, dt=2.0)])
    eng = _engine(model, slots=1, watchdog_timeout_s=0.5, faults=plan)
    req = Request(rid=0, prompt=np.arange(4, dtype=np.int32), max_new=6)
    list(eng.generate([req]))
    assert req.finish_reason == FINISH_LENGTH  # stall is slow, not fatal
    assert eng.stalled_steps >= 1
    assert eng.stats()["stalled_steps"] == eng.stalled_steps


# ---------------------------------------------------------------------------
# Preemption + swap/resume
# ---------------------------------------------------------------------------

@pytest.mark.timeout(120)
def test_manual_preempt_resume_bit_identical_no_reprefill(model):
    cfg, _ = model
    clean = _reqs(cfg, max_new=8)
    _engine(model).run(clean)

    eng = _engine(model)
    prefills = []
    inner = eng._jit_prefill
    eng._jit_prefill = lambda *a, **k: (prefills.append(1) or inner(*a, **k))
    reqs = _reqs(cfg, max_new=8)
    it = eng.generate(reqs)
    for _ in range(4):
        next(it)
    assert eng.preempt(0)
    assert eng.stats()["swapped"] == 1
    list(it)
    assert [r.out for r in reqs] == [r.out for r in clean]  # bit-identical
    assert reqs[0].preemptions == 1
    assert eng.preemptions == 1 and eng.resumes == 1
    assert len(prefills) == 1  # the initial wave only: resume re-prefills NOTHING
    assert eng.stats()["swapped"] == 0


@pytest.mark.timeout(120)
def test_priority_scheduler_auto_preempts_for_higher_priority(model):
    cfg, _ = model
    alone = Request(rid=0, prompt=np.arange(4, dtype=np.int32), max_new=10)
    _engine(model, slots=1).run([alone])

    eng = _engine(model, slots=1, scheduler="priority")
    low = Request(rid=0, prompt=np.arange(4, dtype=np.int32), max_new=10,
                  priority=0)
    it = eng.generate([low])
    for _ in range(2):
        next(it)
    high = Request(rid=1, prompt=np.arange(5, dtype=np.int32), max_new=4,
                   priority=5)
    eng.submit_request(high)
    events = list(it)
    assert low.finish_reason == FINISH_LENGTH
    assert high.finish_reason == FINISH_LENGTH
    assert low.preemptions == 1 and eng.resumes == 1
    # the high-priority request ran TO COMPLETION before low resumed
    order = [e.rid for e in events if e.finished]
    assert order == [1, 0]
    # the preempted stream is bit-identical to running it alone
    assert low.out == alone.out
    assert low.stats()["preemptions"] == 1


@pytest.mark.timeout(120)
def test_preempt_unknown_rid_and_cancel_swapped(model):
    cfg, _ = model
    eng = _engine(model)
    assert not eng.preempt(99)
    reqs = _reqs(cfg, max_new=8)
    it = eng.generate(reqs)
    next(it)
    assert eng.preempt(1)
    assert eng.cancel(1)  # cancel while swapped out: swap state dropped
    assert eng.stats()["swapped"] == 0
    list(it)
    assert reqs[0].finish_reason == FINISH_LENGTH
    assert reqs[1].finish_reason == "cancelled"


# ---------------------------------------------------------------------------
# Determinism + chaos drain
# ---------------------------------------------------------------------------

def _chaos_run(model, seed):
    cfg, _ = model
    plan = FaultPlan([
        Fault("kv_nan", step=3, slot=0),
        Fault("clock_skip", step=5, dt=1.0),
        Fault("stall", step=5, dt=2.0),  # same step: compound failure
    ], seed=seed)
    eng = _engine(model, rt=RTQ, slots=2, max_queue=3,
                  shed_policy="shed_lowest", scheduler="priority",
                  watchdog_timeout_s=0.5, faults=plan)
    reqs = burst(8, cfg.vocab_size, seed=seed, max_new=6)
    for i, r in enumerate(reqs):
        r.priority = i % 3
        if i % 2:
            r.deadline_ms = 400.0
    for r in reqs:
        eng.submit_request(r)
    events = list(eng.generate())
    return eng, reqs, events, plan


@pytest.mark.timeout(240)
def test_chaos_everything_terminates_with_closed_vocabulary(model):
    """The resilience contract end-to-end: under a combined fault plan,
    EVERY submitted request reaches a terminal event with a finish_reason
    from the closed vocabulary, and the engine drains completely."""
    eng, reqs, events, plan = _chaos_run(model, seed=0)
    assert all(r.done for r in reqs)
    assert all(r.finish_reason in FINISH_REASONS for r in reqs)
    term = [e for e in events if e.finished]
    assert sorted(e.rid for e in term) == sorted(r.rid for r in reqs)
    assert all(e.finish_reason in FINISH_REASONS for e in term)
    # drained: nothing live, queued, swapped, or pending
    assert all(r is None for r in eng.active)
    assert len(eng.scheduler) == 0 and eng.stats()["swapped"] == 0
    assert len(plan.log) == 3


@pytest.mark.timeout(240)
def test_chaos_is_deterministic_under_a_seed(model):
    a = _chaos_run(model, seed=7)
    b = _chaos_run(model, seed=7)
    assert [r.out for r in a[1]] == [r.out for r in b[1]]
    assert [r.finish_reason for r in a[1]] == [r.finish_reason for r in b[1]]
    assert a[3].log == b[3].log
    assert [(e.rid, e.token, e.index, e.finished) for e in a[2]] == \
        [(e.rid, e.token, e.index, e.finished) for e in b[2]]
