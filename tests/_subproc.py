"""Shared helper for multi-device subprocess tests.

Multi-device tests need ``--xla_force_host_platform_device_count`` set
before ``import jax``, so they run in a fresh interpreter. The subprocess
env must INHERIT the parent's platform pins: the long-standing
``test_compressed_pod_allreduce_shardmap`` "hang" (quarantined since PR 3)
was a stripped environment dropping ``JAX_PLATFORMS=cpu``, which sends the
child's ``import jax`` off probing for TPU/GPU runtimes — minutes of stall
on a CPU box before a single test line runs. Inheriting the parent env
(and defaulting the platform to the parent's backend) turns the same
8-device shard_map test into a ~1s pass.
"""
from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_py(script: str, *, devices: int | None = None, timeout: int = 300,
           env: dict | None = None) -> subprocess.CompletedProcess:
    """Run ``script`` in a fresh interpreter with the repo on PYTHONPATH.

    ``devices`` forces the XLA host-platform device count (must be set
    before jax import, hence here and not in the script). The parent env is
    inherited wholesale; JAX_PLATFORMS falls back to the parent's resolved
    backend so the child never platform-probes."""
    full = dict(os.environ)
    if "JAX_PLATFORMS" not in full:
        import jax  # parent has jax initialized already under pytest
        full["JAX_PLATFORMS"] = jax.default_backend()
    full["PYTHONPATH"] = SRC + (
        os.pathsep + full["PYTHONPATH"] if full.get("PYTHONPATH") else "")
    if devices is not None:
        full["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={devices} "
            + full.get("XLA_FLAGS", "")).strip()
    if env:
        full.update(env)
    return subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, timeout=timeout,
                          env=full)
