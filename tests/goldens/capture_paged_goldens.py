"""Capture dense-engine golden token streams for the paged-KV parity test.

Run ONCE against the dense (pre-paging) engine; tests/test_paged.py replays
the same request set through ServeEngine(paged=True) and asserts the token
streams are bit-identical to these committed goldens.

    PYTHONPATH=src python tests/goldens/capture_paged_goldens.py
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduced
from repro.models import lm
from repro.models.layers import Runtime
from repro.serve.engine import Request, ServeEngine
from repro.serve.sampling import SamplingParams


def golden_requests(vocab):
    """Heterogeneous-length burst incl. a shared 16-token prefix pair
    (prefix-sharing coverage) and one sampled request (PRNG parity)."""
    rng = np.random.default_rng(7)
    plens = [3, 9, 17, 5, 12, 24, 7, 2]
    maxn = [6, 10, 4, 8, 5, 12, 9, 7]
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, vocab, size=p).astype(np.int32),
                    max_new=m)
            for i, (p, m) in enumerate(zip(plens, maxn))]
    shared = rng.integers(0, vocab, size=16).astype(np.int32)
    reqs.append(Request(rid=100, prompt=shared.copy(), max_new=6))
    reqs.append(Request(rid=101, prompt=np.concatenate(
        [shared, rng.integers(0, vocab, size=3).astype(np.int32)]),
        max_new=6))
    reqs.append(Request(
        rid=102, prompt=rng.integers(0, vocab, size=6).astype(np.int32),
        sampling=SamplingParams(temperature=0.8, seed=123, max_new=8)))
    return reqs


def main():
    cfg = reduced(get_config("smollm-135m"))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rt = Runtime(compute_dtype=jnp.float32, kv_quant=True)
    eng = ServeEngine(params, cfg, slots=4, max_len=64, prompt_pad=16, rt=rt)
    done = eng.run(golden_requests(cfg.vocab_size))
    streams = {str(r.rid): [int(t) for t in r.out] for r in done}
    path = os.path.join(os.path.dirname(__file__), "paged_dense_streams.json")
    with open(path, "w") as f:
        json.dump(streams, f, indent=1, sort_keys=True)
    print(f"wrote {path}: "
          f"{sum(len(v) for v in streams.values())} tokens over "
          f"{len(streams)} streams")


if __name__ == "__main__":
    main()
