"""Capture pre-speculative-decoding golden engine streams.

Run ONCE against the engine at the commit BEFORE the propose/verify/commit
refactor landed. tests/test_spec_decode.py replays the same request set
through the refactored engine with speculative decoding OFF and asserts the
streams are byte-identical to these goldens (the refactor must be a no-op
when no draft model is configured), and with GREEDY speculative decoding ON
asserts the committed token sequences are identical (lossless
verification).

Covers the three cache layouts the engine serves: dense fp32, dense
rotated-int8 (kv_quant), and the paged block pool — each with greedy and
sampled requests mixed in one burst.

    PYTHONPATH=src python tests/goldens/capture_spec_goldens.py
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduced
from repro.models import lm
from repro.models.layers import Runtime
from repro.serve.engine import Request, ServeEngine
from repro.serve.sampling import SamplingParams


def golden_requests(vocab):
    """Heterogeneous burst: varied prompt/output lengths, greedy and
    sampled (temperature / top-k / top-p) requests, plus a stop-token
    request so stop handling is pinned too."""
    rng = np.random.default_rng(11)
    plens = [4, 9, 17, 6, 12, 21, 3]
    maxn = [7, 10, 5, 9, 6, 12, 8]
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, vocab, size=p).astype(np.int32),
                    max_new=m)
            for i, (p, m) in enumerate(zip(plens, maxn))]
    reqs.append(Request(
        rid=200, prompt=rng.integers(0, vocab, size=8).astype(np.int32),
        sampling=SamplingParams(temperature=0.8, seed=77, max_new=9)))
    reqs.append(Request(
        rid=201, prompt=rng.integers(0, vocab, size=5).astype(np.int32),
        sampling=SamplingParams(temperature=1.1, top_k=20, seed=13,
                                max_new=8)))
    reqs.append(Request(
        rid=202, prompt=rng.integers(0, vocab, size=7).astype(np.int32),
        sampling=SamplingParams(temperature=0.9, top_p=0.85, seed=5,
                                max_new=8)))
    reqs.append(Request(
        rid=203, prompt=rng.integers(0, vocab, size=6).astype(np.int32),
        sampling=SamplingParams(max_new=10, stop=(7, 42))))
    return reqs


def capture(params, cfg, **engine_kw):
    eng = ServeEngine(params, cfg, slots=4, max_len=64, prompt_pad=16,
                      **engine_kw)
    done = eng.run(golden_requests(cfg.vocab_size))
    return {str(r.rid): [int(t) for t in r.out] for r in done}


def main():
    cfg = reduced(get_config("smollm-135m"))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    doc = {
        "dense_fp": capture(params, cfg,
                            rt=Runtime(compute_dtype=jnp.float32)),
        "dense_q8": capture(params, cfg,
                            rt=Runtime(compute_dtype=jnp.float32,
                                       kv_quant=True)),
        "paged_q8": capture(params, cfg,
                            rt=Runtime(compute_dtype=jnp.float32,
                                       kv_quant=True),
                            paged=True),
    }
    path = os.path.join(os.path.dirname(__file__), "spec_decode_streams.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    n = sum(len(v) for layout in doc.values() for v in layout.values())
    print(f"wrote {path}: {n} tokens over "
          f"{sum(len(v) for v in doc.values())} streams x {len(doc)} layouts")


if __name__ == "__main__":
    main()
