"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import formats
from repro.kernels import ops, ref
from repro.kernels.fwht_kernel import fwht_pallas
from repro.kernels.itq3_matmul import itq3_matmul_pallas


@pytest.mark.parametrize("m,k,dtype", [
    (8, 256, jnp.float32), (32, 512, jnp.float32), (7, 256, jnp.float32),
    (16, 1024, jnp.bfloat16), (256, 256, jnp.float32),
])
def test_fwht_kernel_sweep(rng, m, k, dtype):
    x = jnp.asarray(rng.normal(size=(m, k)), dtype)
    got = fwht_pallas(x, interpret=True)
    want = ref.fwht_ref(x)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=3e-2 if dtype == jnp.bfloat16 else 1e-4)


@pytest.mark.parametrize("fmt", ["itq3_s", "iq3_s", "itq3_s_sub", "itq3_x", "quip3"])
@pytest.mark.parametrize("mode", ["weights", "activations"])
def test_itq3_kernel_formats(rng, fmt, mode):
    w = jnp.asarray(rng.standard_t(df=4, size=(512, 320)) * 0.02, jnp.float32)
    x = jnp.asarray(rng.normal(size=(6, 512)), jnp.float32)
    qt = formats.quantize(w, fmt)
    want = ref.itq3_matmul_ref(
        x, qt.data["plane2"], qt.data["plane1"], qt.data["scales"], qt.data["zps"],
        rotate_weights=(qt.meta.rotate and mode == "weights"),
        fivelevel=qt.meta.fivelevel, sub_blocks=qt.meta.sub_blocks,
    ) if False else None
    y0 = np.asarray(jnp.matmul(x, formats.dequantize(qt, jnp.float32)))
    yk = np.asarray(ops.qmatmul_kernel(x, qt, mode=mode, tm=8, tn=128,
                                       interpret=True))
    np.testing.assert_allclose(yk, y0, atol=2e-3)


@pytest.mark.parametrize("m,n,k,tm,tn", [
    (1, 128, 256, 8, 128),       # decode-like (MMVQ path)
    (4, 64, 512, 8, 32),         # small tiles
    (130, 320, 768, 64, 128),    # ragged M/N vs tiles
    (256, 256, 256, 256, 256),   # single tile
])
def test_itq3_kernel_shapes(rng, m, n, k, tm, tn):
    w = jnp.asarray(rng.normal(size=(k, n)) * 0.05, jnp.float32)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    qt = formats.quantize(w, "itq3_s")
    y0 = np.asarray(jnp.matmul(x, formats.dequantize(qt, jnp.float32)))
    yk = np.asarray(ops.qmatmul_kernel(x, qt, mode="weights", tm=tm, tn=tn,
                                       interpret=True))
    np.testing.assert_allclose(yk, y0, atol=3e-3)


def test_kernel_raw_call_matches_ref(rng):
    """Direct pallas_call vs ref.py oracle (no wrapper plumbing)."""
    w = jnp.asarray(rng.normal(size=(512, 128)) * 0.1, jnp.float32)
    x = jnp.asarray(rng.normal(size=(16, 512)), jnp.float32)
    qt = formats.quantize(w, "itq3_s")
    want = np.asarray(ref.itq3_matmul_ref(
        x, qt.data["plane2"], qt.data["plane1"],
        qt.data["scales"], qt.data["zps"], rotate_weights=True))
    got = np.asarray(itq3_matmul_pallas(
        x, qt.data["plane2"], qt.data["plane1"],
        qt.data["scales"], qt.data["zps"],
        rotate_weights=True, tm=8, tn=64, interpret=True))
    np.testing.assert_allclose(got, want, atol=2e-3)


def test_fwht_kernel_involution(rng):
    x = jnp.asarray(rng.normal(size=(12, 512)), jnp.float32)
    y = fwht_pallas(fwht_pallas(x, interpret=True), interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-4)


def test_full_model_through_kernels():
    """Whole smollm forward with every ternary matmul routed through the
    Pallas fused kernel (interpret mode) == reference path."""
    import jax
    from repro.configs.base import get_config, reduced
    from repro.models import lm
    from repro.models.layers import Runtime
    from repro.serve.quantized import quantize_params

    cfg = reduced(get_config("smollm-135m"))
    key = jax.random.PRNGKey(0)
    q = quantize_params(lm.init_params(key, cfg), "itq3_s")
    toks = jax.random.randint(key, (2, 8), 0, cfg.vocab_size)
    l0, _, _ = lm.forward(q, toks, Runtime(compute_dtype=jnp.float32), cfg)
    l1, _, _ = lm.forward(q, toks, Runtime(compute_dtype=jnp.float32,
                                           use_kernel=True), cfg)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l0), atol=1e-3)


def test_quantize_kernel_matches_algorithm1(rng):
    """Offline-quantizer kernel == core.quantize Algorithm 1 (codes+scales)."""
    from repro.core.quantize import quantize_blocks_ternary
    from repro.core.packing import unpack_codes
    from repro.kernels.quantize_kernel import quantize_blocks_pallas

    wb = jnp.asarray(rng.standard_t(df=4, size=(40, 256)) * 0.05, jnp.float32)
    codes_k, d_k, z_k = quantize_blocks_pallas(wb, rule="paper", tm=8)
    ref = quantize_blocks_ternary(wb, rotate=True, rule="paper")
    ref_codes = unpack_codes(ref["plane2"], ref["plane1"]) & 0x3
    np.testing.assert_allclose(np.asarray(d_k, np.float32),
                               np.asarray(ref["scales"], np.float32), rtol=1e-3)
    np.testing.assert_array_equal(np.asarray(z_k), np.asarray(ref["zps"]))
    agree = np.mean(np.asarray(codes_k) == np.asarray(ref_codes))
    assert agree > 0.999, agree  # fp16-grid rounding ties only
