"""Fault-tolerance logic: heartbeats, stragglers, failure, elastic plans."""
from repro.ft.monitor import HeartbeatMonitor, plan_remesh


class FakeClock:
    def __init__(self):
        self.t = 0.0
    def __call__(self):
        return self.t


def test_straggler_detection():
    clk = FakeClock()
    mon = HeartbeatMonitor(4, timeout_s=60, straggler_factor=2.0, clock=clk)
    for step in range(1, 6):
        for h in range(4):
            clk.t = step * 10 + (25 if h == 3 else 0) * step / step
            mon.beat(h, step, now=step * 10 + (step * 25 if h == 3 else 0))
    assert mon.stragglers() == [3]


def test_failure_detection_and_exclusion():
    clk = FakeClock()
    mon = HeartbeatMonitor(3, timeout_s=30, clock=clk)
    clk.t = 10
    for h in (0, 1):
        mon.beat(h, 1)
    clk.t = 35  # host 2 silent since t=0 (>30s); hosts 0/1 fresh (25s)
    assert mon.failed() == [2]
    mon.exclude([2])
    assert mon.failed() == []
    assert mon.alive() == [0, 1]


def test_beat_unknown_host_auto_registers():
    """Regression: beat() from a host the monitor never saw (elastic
    rejoin, or a dynamic member set) raised KeyError. It must auto-register
    the host as of that beat instead of crashing."""
    clk = FakeClock()
    mon = HeartbeatMonitor(2, timeout_s=30, clock=clk)
    clk.t = 5
    mon.beat(7, 1)  # would have raised
    assert 7 in mon.hosts and mon.num_hosts == 3
    assert 7 in mon.alive()
    clk.t = 10
    mon.beat(7, 2)
    assert len(mon.hosts[7].step_times) == 1  # latency tracking works
    clk.t = 50
    assert 7 in mon.failed()  # and liveness tracking too


def test_beat_explicitly_excluded_host_stays_excluded():
    """Only the never-seen path re-admits: a host the driver deliberately
    left behind keeps beating but must not silently rejoin."""
    clk = FakeClock()
    mon = HeartbeatMonitor(3, timeout_s=30, clock=clk)
    mon.exclude([2])
    clk.t = 5
    mon.beat(2, 1)
    assert 2 in mon.excluded and 2 not in mon.alive()


def test_plan_remesh_preserves_tp():
    plan = plan_remesh(240, model=16)
    assert plan.model == 16 and plan.data == 15 and plan.devices == 240


def test_plan_remesh_multi_pod_shrink():
    plan = plan_remesh(srv := 512 - 256, model=16, prefer_pods=2)
    # one whole pod lost -> single pod plan
    assert plan.pod * plan.data * plan.model <= srv
    assert plan.model == 16


def test_plan_remesh_infeasible():
    assert plan_remesh(8, model=16) is None
