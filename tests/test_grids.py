"""Optimal-scale theory (paper §3.3 / App. A) and its documented
discrepancy: the Eq.-10 encoder's true optimum is Lloyd-Max 1.224σ, not
the paper's 0.798σ."""
import numpy as np
import jax.numpy as jnp

from repro.core import grids


def test_alpha_constants():
    assert abs(grids.ALPHA_PAPER - 0.7979) < 1e-4
    assert abs(grids.ALPHA_ERFINV - 0.9674) < 1e-3
    assert abs(grids.ALPHA_LLOYD - 1.2240) < 1e-2


def test_mse_oracle_limits():
    # alpha -> 0 and alpha -> inf both give MSE -> sigma^2
    assert abs(grids.ternary_mse(1e-6) - 1.0) < 1e-3
    assert abs(grids.ternary_mse(50.0) - 1.0) < 1e-3


def test_lloyd_is_stationary_minimum():
    a = grids.ALPHA_LLOYD
    for d in (-0.05, 0.05):
        assert grids.ternary_mse(a + d) > grids.ternary_mse(a)


def test_rule_ordering():
    mses = {r: grids.ternary_mse(c) for r, c in grids.SCALE_RULES.items()}
    assert mses["lloyd"] < mses["erfinv"] < mses["paper"]


def test_empirical_mse_matches_oracle(rng):
    x = rng.normal(size=500_000).astype(np.float32)
    for alpha in (0.8, 1.0, 1.224):
        q = np.clip(np.round(x / alpha), -1, 1) * alpha
        emp = np.mean((x - q) ** 2)
        assert abs(emp - grids.ternary_mse(alpha)) < 5e-3, alpha


def test_fivelevel_beats_ternary():
    assert grids.fivelevel_mse(grids.FIVELEVEL_ALPHA) < grids.ternary_mse(grids.ALPHA_LLOYD)


def test_code_functions(rng):
    x = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
    c3 = grids.ternary_quantize_codes(x, jnp.float32(0.8))
    assert set(np.unique(np.asarray(c3))).issubset({0, 1, 2})
    c5 = grids.fivelevel_quantize_codes(x, jnp.float32(0.8))
    assert set(np.unique(np.asarray(c5))).issubset({0, 1, 2, 3, 4})
