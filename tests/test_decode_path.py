"""Decode-path overhaul: matvec kernel parity, tile autotuner, and the
single-transfer engine hot loop."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.core import formats
from repro.kernels import autotune, ops
from repro.kernels.itq3_matmul import itq3_matmul_pallas
from repro.kernels.itq3_matvec import MATVEC_MAX_M, itq3_matvec_pallas
from repro.models import lm
from repro.models.layers import Runtime
from repro.serve.engine import Request, ServeEngine

KEY = jax.random.PRNGKey(0)
RT = Runtime(compute_dtype=jnp.float32, capacity_factor=8.0)


# ---------------------------------------------------------------------------
# Matvec kernel: bit-identical to the tiled kernel, every format, ragged dims
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", ["itq3_s", "itq3_x", "itq3_s_sub", "iq3_s"])
@pytest.mark.parametrize("m,n,k", [(1, 96, 512), (5, 160, 768), (16, 128, 256)])
def test_matvec_bitwise_matches_tiled(rng, fmt, m, n, k):
    w = jnp.asarray(rng.standard_t(df=4, size=(k, n)) * 0.02, jnp.float32)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    qt = formats.quantize(w, fmt)
    meta = qt.meta
    kw = dict(rotate_weights=meta.rotate, fivelevel=meta.fivelevel,
              sub_blocks=meta.sub_blocks, interpret=True)
    args = (x, qt.data["plane2"], qt.data["plane1"],
            qt.data["scales"], qt.data["zps"])
    y_mv = np.asarray(itq3_matvec_pallas(*args, tn=64, **kw))
    y_mm = np.asarray(itq3_matmul_pallas(*args, tm=m, tn=64, **kw))
    np.testing.assert_array_equal(y_mv, y_mm)
    y0 = np.asarray(jnp.matmul(x, formats.dequantize(qt, jnp.float32)))
    np.testing.assert_allclose(y_mv, y0, atol=3e-3)


def test_qmatmul_auto_dispatches_matvec(rng, monkeypatch):
    """qmatmul routes M <= MATVEC_MAX_M to the matvec kernel by shape."""
    calls = []
    real = ops.itq3_matvec_pallas

    def spy(*a, **kw):
        calls.append(a[0].shape)
        return real(*a, **kw)

    monkeypatch.setattr(ops, "itq3_matvec_pallas", spy)
    w = jnp.asarray(rng.normal(size=(512, 128)) * 0.05, jnp.float32)
    qt = formats.quantize(w, "itq3_s")
    x_small = jnp.asarray(rng.normal(size=(MATVEC_MAX_M, 512)), jnp.float32)
    x_big = jnp.asarray(rng.normal(size=(MATVEC_MAX_M + 1, 512)), jnp.float32)
    y = ops.qmatmul_kernel(x_small, qt, mode="weights", interpret=True)
    assert calls == [(MATVEC_MAX_M, 512)]
    ops.qmatmul_kernel(x_big, qt, mode="weights", interpret=True)
    assert len(calls) == 1  # big M stays on the tiled kernel
    y0 = np.asarray(jnp.matmul(x_small, formats.dequantize(qt, jnp.float32)))
    np.testing.assert_allclose(np.asarray(y), y0, atol=3e-3)


def test_hoisted_grid_bitwise_matches_flat(rng):
    w = jnp.asarray(rng.normal(size=(768, 320)) * 0.05, jnp.float32)
    x = jnp.asarray(rng.normal(size=(130, 768)), jnp.float32)
    qt = formats.quantize(w, "itq3_s")
    args = (x, qt.data["plane2"], qt.data["plane1"],
            qt.data["scales"], qt.data["zps"])
    got = {h: np.asarray(itq3_matmul_pallas(
        *args, rotate_weights=True, tm=64, tn=128, interpret=True, hoist=h))
        for h in (True, False)}
    np.testing.assert_array_equal(got[True], got[False])
    y0 = np.asarray(jnp.matmul(x, formats.dequantize(qt, jnp.float32)))
    np.testing.assert_allclose(got[True], y0, atol=3e-3)


# ---------------------------------------------------------------------------
# Autotuner: deterministic fallback + on-disk cache round trip
# ---------------------------------------------------------------------------

def test_autotune_deterministic_fallback(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "at.json"))
    autotune.clear_memory_cache()
    assert autotune.get_tiles(8, 2048, 2048, "itq3_s", interpret=True) == \
        (autotune.DEFAULT_TM, autotune.DEFAULT_TN)
    # interpret-mode autotune() refuses to benchmark: defaults, no cache file
    assert autotune.autotune(8, 128, 256, interpret=True) == \
        (autotune.DEFAULT_TM, autotune.DEFAULT_TN)
    assert not (tmp_path / "at.json").exists()


def test_autotune_cache_round_trip(tmp_path, monkeypatch):
    path = tmp_path / "at.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
    autotune.clear_memory_cache()
    autotune.record(4, 1024, 512, "itq3_s", 8, 128, interpret=True, us=12.5)
    # fresh process simulation: drop the in-memory cache, re-read from disk
    autotune.clear_memory_cache()
    assert autotune.get_tiles(4, 1024, 512, "itq3_s", interpret=True) == (8, 128)
    # M bucketing: any M in the matvec regime shares the entry
    assert autotune.get_tiles(1, 1024, 512, "itq3_s", interpret=True) == (8, 128)
    # other shapes still fall back
    assert autotune.get_tiles(4, 999, 512, "itq3_s", interpret=True) == \
        (autotune.DEFAULT_TM, autotune.DEFAULT_TN)
    doc = json.loads(path.read_text())
    assert all("tm" in v and "tn" in v for v in doc.values())


def test_autotune_benchmarked_entry_applies(tmp_path, monkeypatch, rng):
    """Forced interpret-mode sweep on a tiny shape: winner lands in the
    cache and qmatmul(tm=None) picks it up and still matches the oracle.

    K=320 is deliberately NOT a multiple of the 256 block: the lookup must
    key on the logical K the tuner recorded, not the block-padded width."""
    path = tmp_path / "at.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
    autotune.clear_memory_cache()
    tm, tn = autotune.autotune(20, 64, 320, "itq3_s", interpret=True,
                               iters=1, force_interpret_bench=True)
    autotune.clear_memory_cache()
    assert autotune.get_tiles(20, 64, 320, "itq3_s", interpret=True) == (tm, tn)
    w = jnp.asarray(rng.normal(size=(320, 64)) * 0.05, jnp.float32)
    x = jnp.asarray(rng.normal(size=(20, 320)), jnp.float32)
    qt = formats.quantize(w, "itq3_s")
    calls = []
    real_get = autotune.get_tiles
    monkeypatch.setattr(
        ops.autotune_mod, "get_tiles",
        lambda *a, **kw: calls.append(a) or real_get(*a, **kw))
    y = ops.qmatmul_kernel(x, qt, mode="weights", interpret=True)
    assert calls and calls[0][2] == 320  # logical K, not padded 512
    y0 = np.asarray(jnp.matmul(x, formats.dequantize(qt, jnp.float32)))
    np.testing.assert_allclose(np.asarray(y), y0, atol=3e-3)


# ---------------------------------------------------------------------------
# Engine: one transfer per step, device sampling == host argmax, admission
# ---------------------------------------------------------------------------

def test_engine_one_transfer_per_step():
    cfg = reduced(get_config("smollm-135m"))
    params = lm.init_params(KEY, cfg)
    eng = ServeEngine(params, cfg, slots=3, max_len=48, rt=RT)
    admitted = eng.admit([Request(rid=i, prompt=np.arange(4 + i), max_new=10)
                          for i in range(3)])
    assert admitted == 3
    assert eng.host_syncs == 1  # batched admission: one fetch for the wave
    for _ in range(4):
        before = eng.host_syncs
        eng.step()
        assert eng.host_syncs - before == 1
    assert eng.stats()["syncs_per_token"] < 0.5  # 3 tokens per sync + prefill


def test_engine_device_sampling_matches_host_argmax():
    cfg = reduced(get_config("smollm-135m"))
    params = lm.init_params(KEY, cfg)
    outs = {}
    for host in (False, True):
        eng = ServeEngine(params, cfg, slots=2, max_len=48, rt=RT,
                          sample_on_host=host)
        done = eng.run([Request(rid=i, prompt=np.arange(3 + 2 * i), max_new=6)
                        for i in range(4)])
        outs[host] = [r.out for r in done]
    assert outs[False] == outs[True]
    # host mode really is the multi-sync baseline
    assert ServeEngine(params, cfg, slots=2, rt=RT).host_syncs == 0


def test_engine_temperature_sampling_runs():
    cfg = reduced(get_config("smollm-135m"))
    params = lm.init_params(KEY, cfg)
    eng = ServeEngine(params, cfg, slots=2, max_len=32, rt=RT,
                      temperature=1.0, seed=7)
    done = eng.run([Request(rid=0, prompt=np.arange(5), max_new=6)])
    assert len(done[0].out) >= 6
    assert all(0 <= t < cfg.vocab_size for t in done[0].out)


def test_engine_batched_admission_matches_sequential():
    """One padded-bucket admission call == admitting slot by slot."""
    cfg = reduced(get_config("smollm-135m"))
    params = lm.init_params(KEY, cfg)
    make = lambda: [Request(rid=i, prompt=np.arange(3 + 3 * i), max_new=5)
                    for i in range(3)]
    reqs_b, reqs_s = make(), make()
    eng_b = ServeEngine(params, cfg, slots=3, max_len=48, rt=RT)
    assert eng_b.admit(reqs_b) == 3  # one wave
    eng_s = ServeEngine(params, cfg, slots=3, max_len=48, rt=RT)
    for r in reqs_s:
        assert eng_s.submit(r)  # one call each
    for eng in (eng_b, eng_s):
        while any(eng.active):
            eng.step()
    assert [r.out for r in reqs_b] == [r.out for r in reqs_s]


@pytest.mark.parametrize("arch", ["rwkv6-3b", "zamba2-7b"])
def test_ssm_chunked_prefill_matches_exact(arch):
    """Chunk-ladder SSM/hybrid prefill == one exact-length prefill + decode."""
    cfg = reduced(get_config(arch))
    params = lm.init_params(KEY, cfg)
    prompt = np.arange(9).astype(np.int32)  # 9 = 8 + 1 exercises the ladder
    eng = ServeEngine(params, cfg, slots=1, max_len=32, rt=RT, prompt_chunk=8)
    [req] = eng.run([Request(rid=0, prompt=prompt, max_new=4)])

    cache = lm.init_cache(cfg, 1, 32, dtype=jnp.float32)
    logits, cache, _ = lm.forward(params, jnp.asarray(prompt[None]), RT, cfg,
                                  cache=cache, pos=0)
    out = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    for _ in range(3):
        l, cache = lm.decode_step(params, jnp.asarray([[out[-1]]], jnp.int32),
                                  cache, jnp.int32(pos), RT, cfg)
        out.append(int(jnp.argmax(l[0, 0])))
        pos += 1
    assert req.out[:4] == out[:4]


def test_engine_rejects_empty_prompt():
    # an empty prompt must NOT raise mid-admission (that used to kill the
    # whole wave): it is rejected alone with a terminal "error" while the
    # valid neighbor admits and decodes normally
    cfg = reduced(get_config("smollm-135m"))
    params = lm.init_params(KEY, cfg)
    eng = ServeEngine(params, cfg, slots=2, max_len=32, rt=RT)
    good = Request(rid=0, prompt=np.arange(4), max_new=2)
    bad = Request(rid=1, prompt=np.array([], np.int32), max_new=2)
    assert eng.admit([good, bad]) == 1
    assert bad.done and bad.finish_reason == "error" and bad.out == []
    eng.run([])
    assert good.done and len(good.out) == 2


def test_bench_doc_schema_validation():
    from benchmarks.common import BENCH_SCHEMA, validate_bench_doc

    good = {"schema": BENCH_SCHEMA, "suite": "kernels", "device": "cpu",
            "records": [{"name": "a", "us_per_call": 1.0, "metrics": {}}]}
    validate_bench_doc(good)
    for bad in (
        {**good, "schema": "nope"},
        {**good, "records": []},
        {**good, "records": [{"metrics": {}}]},
        {**good, "records": [{"name": "a", "us_per_call": "fast"}]},
    ):
        with pytest.raises(ValueError):
            validate_bench_doc(bad)


# ---------------------------------------------------------------------------
# Autotune cache robustness: torn/corrupt files degrade, never kill callers
# ---------------------------------------------------------------------------

def test_autotune_corrupt_cache_falls_back_with_warning(tmp_path, monkeypatch):
    """A torn cache file (the concurrent-writer failure mode) must resolve
    to the deterministic defaults with a warning, and the next record()
    must publish a fresh valid file over the wreckage."""
    path = tmp_path / "at.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
    path.write_text('{"torn": ', encoding="utf-8")  # mid-write interleave
    autotune.clear_memory_cache()
    with pytest.warns(RuntimeWarning, match="autotune cache"):
        tiles = autotune.get_tiles(8, 128, 256, "itq3_s", interpret=True)
    assert tiles == (autotune.DEFAULT_TM, autotune.DEFAULT_TN)
    autotune.record(4, 128, 256, "itq3_s", 8, 64, interpret=True)
    autotune.clear_memory_cache()
    assert autotune.get_tiles(4, 128, 256, "itq3_s", interpret=True) == (8, 64)
    json.loads(path.read_text())  # the rewritten file is valid JSON again


def test_autotune_save_unique_tmp_no_stragglers(tmp_path, monkeypatch):
    """Every _save goes through its own mkstemp name (two concurrent
    processes can no longer interleave into one shared .tmp) and no tmp
    files survive a successful save."""
    import tempfile as tempfile_mod

    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "at.json"))
    autotune.clear_memory_cache()
    names = []
    real = tempfile_mod.mkstemp

    def spy(**kw):
        fd, name = real(**kw)
        names.append(name)
        return fd, name

    monkeypatch.setattr(autotune.tempfile, "mkstemp", spy)
    autotune.record(4, 128, 256, "itq3_s", 8, 64, interpret=True)
    autotune.record(4, 256, 256, "itq3_s", 8, 128, interpret=True)
    assert len(names) == 2 and len(set(names)) == 2
    assert list(tmp_path.glob("*.tmp")) == []


# ---------------------------------------------------------------------------
# Bench trajectory protection: smoke runs land in a sibling file
# ---------------------------------------------------------------------------

def test_bench_smoke_writes_sibling_file_and_forbid_smoke(tmp_path,
                                                          monkeypatch):
    from benchmarks import common

    monkeypatch.setattr(common, "repo_root", lambda: tmp_path)
    full = common.BenchSuite("serve")
    full.add("serve/x", 1.0, tok_s=1)
    smoke = common.BenchSuite("serve", smoke=True)
    smoke.add("serve/x", 1.0, tok_s=1)
    p_full, p_smoke = full.write(), smoke.write()
    # the smoke run must NOT overwrite the committed full trajectory
    assert p_full.name == "BENCH_serve.json"
    assert p_smoke.name == "BENCH_serve.smoke.json"
    common.load_and_validate(p_full, forbid_smoke=True)
    common.load_and_validate(p_smoke)
    with pytest.raises(ValueError, match="smoke"):
        common.load_and_validate(p_smoke, forbid_smoke=True)


def test_committed_bench_docs_are_full_runs():
    """The CI gate, asserted in tier-1 too: the repo-root BENCH_*.json must
    never carry smoke-sized records."""
    from benchmarks.common import load_and_validate, repo_root

    for suite in ("kernels", "serve"):
        load_and_validate(repo_root() / f"BENCH_{suite}.json",
                          forbid_smoke=True)
