"""The roofline analyzer itself: dot flops, while multipliers, collective
bytes, aliasing-aware slice accounting — against a hand-built HLO fixture."""
from repro.launch.hlo_analysis import analyze_hlo, _op_bytes

FIXTURE = """
HloModule test

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %w = f32[16,16]{1,0} constant({...})
  %y = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %r = f32[8,16]{1,0} all-reduce(%y), to_apply=%sum
  ROOT %t = (s32[], f32[8,16]{1,0}) tuple(%i, %r)
}

%cond (p: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], f32[8,16]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (arg: f32[8,16]) -> f32[8,16] {
  %arg = f32[8,16]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,16]{1,0}) tuple(%zero, %arg)
  %loop = (s32[], f32[8,16]{1,0}) while(%init), condition=%cond, body=%body
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%loop), index=1
}
"""


def test_while_multiplied_dot_flops():
    st = analyze_hlo(FIXTURE)
    # dot: 2 * (8*16) * 16 = 4096 flops, x10 trips
    assert st.flops == 4096 * 10, st.flops
    assert st.dot_count == 10


def test_collective_bytes_multiplied():
    st = analyze_hlo(FIXTURE)
    # all-reduce operand: 8*16*4 = 512 B, x10
    assert st.collective_bytes["all-reduce"] == 512 * 10
    assert st.collective_counts["all-reduce"] == 10


def test_op_bytes_aliasing_model():
    # DUS charges 2x the update (2nd operand), not the buffer
    assert _op_bytes("dynamic-update-slice", [1000.0, 10.0], 1000.0) == 20.0
    # dynamic-slice charges 2x the result
    assert _op_bytes("dynamic-slice", [1000.0], 10.0) == 20.0
    # scatter: 2x updates + indices
    assert _op_bytes("scatter", [1000.0, 4.0, 10.0], 1000.0) == 24.0
    # plain op: operands + result
    assert _op_bytes("add", [8.0, 8.0], 8.0) == 24.0


def test_dynamic_while_counted():
    txt = FIXTURE.replace("constant(10)", "parameter(0)").replace(
        "%n = s32[] parameter(0)", "%n = s32[] get-tuple-element(%p), index=0")
    st = analyze_hlo(txt)
    assert st.dynamic_whiles >= 0  # falls back to 1 trip without constants
