"""Tensor-parallel serving (serve/tp.py): placement rules in-process, and
bit-identical token streams / restore-to-sharding in a 2-device subprocess
(this process keeps seeing 1 device per the dry-run isolation rule)."""
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from _subproc import run_py

from repro.configs.base import get_config, reduced
from repro.core import formats
from repro.serve import tp
from repro.sharding import rules as R


class FakeMesh:
    def __init__(self, data=1, model=2):
        self.shape = {"data": data, "model": model}
        self.axis_names = ("data", "model")


def _rules(cfg, model=2):
    mesh = FakeMesh(model=model)
    base = R.make_rules(mesh, cfg, fsdp=False)
    assignments = dict(base.assignments)
    assignments["kv_seq"] = None
    assignments["seq_sp"] = None
    return R.Rules(mesh=mesh, assignments=assignments)


# ---------------------------------------------------------------------------
# Placement rules (pure dict/spec math, no devices needed)
# ---------------------------------------------------------------------------

def test_serve_rules_never_seq_shards_kv():
    """serve_rules drops the training-side kv_seq fallback: a serving
    softmax is never split across devices, whatever make_rules chose."""
    cfg = get_config("nemotron-4-15b")  # kv=8: doesn't divide model=16
    mesh = FakeMesh(data=16, model=16)
    assert R.make_rules(mesh, cfg).assignments["kv_seq"] == "model"
    rules = tp.serve_rules(mesh, cfg)
    assert rules.assignments["kv_seq"] is None
    assert rules.assignments["kv_heads"] is None  # GQA fallback

    cfg2 = get_config("olmoe-1b-7b")  # kv=16 divides
    rules2 = tp.serve_rules(FakeMesh(data=16, model=16), cfg2)
    assert rules2.assignments["kv_heads"] == "model"
    assert rules2.assignments["kv_seq"] is None


def test_cache_pspecs_head_sharding_and_gqa_fallback():
    cfg = reduced(get_config("qwen1.5-0.5b"))  # kv=4: divides 2
    rules = _rules(cfg)
    cache = {"attn": {
        "k": np.zeros((2, 1, 4, 8, 32), np.int8),
        "k_scale": np.zeros((2, 1, 4, 8, 1), np.float16),
    }}
    specs = tp.cache_pspecs(cache, cfg, rules)
    assert specs["attn"]["k"] == P(None, None, "model", None, None)
    assert specs["attn"]["k_scale"] == P(None, None, "model", None, None)

    cfg_g = reduced(get_config("smollm-135m"))  # kv=1: GQA fallback
    rules_g = _rules(cfg_g)
    assert rules_g.assignments["kv_heads"] is None
    cache_g = {"attn": {"k": np.zeros((2, 1, 1, 8, 32), np.int8)}}
    specs_g = tp.cache_pspecs(cache_g, cfg_g, rules_g)
    assert specs_g["attn"]["k"] == P(None, None, None, None, None)


def test_cache_pspecs_ssm_state_replicated():
    cfg = reduced(get_config("zamba2-7b"))
    rules = _rules(cfg)
    cache = {"attn": {"k": np.zeros((2, 1, 4, 8, 32), np.int8)},
             "ssm": {"h": np.zeros((7, 1, 4, 16), np.float32)}}
    specs = tp.cache_pspecs(cache, cfg, rules)
    assert specs["attn"]["k"] == P(None, None, "model", None, None)
    assert specs["ssm"]["h"] == P(None, None, None, None)


def test_can_tp_qmatmul_divisibility_gate(rng):
    w = np.asarray(rng.normal(size=(256, 512)), np.float32)
    qt = formats.quantize(w, "itq3_s")
    assert tp.can_tp_qmatmul(qt, FakeMesh(model=2))
    assert not tp.can_tp_qmatmul(qt, FakeMesh(model=1))  # no model axis
    # N (and every plane's leading dim) must divide the axis
    assert not tp.can_tp_qmatmul(qt, FakeMesh(model=3))


def test_serve_param_pspecs_cover_quantized_tree():
    """Every leaf (packed planes included) gets a spec; QTensor N planes
    shard over model when divisible, fp leaves replicate, embed D-shards."""
    import functools
    from repro.models import lm
    from repro.serve.quantized import quantize_params

    cfg = reduced(get_config("qwen1.5-0.5b"))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    params = quantize_params(params, "itq3_s")
    rules = _rules(cfg)
    specs = tp.serve_param_pspecs(params, cfg, rules)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    flat_l = jax.tree.leaves(params)
    assert len(flat_s) == len(flat_l)
    sharded = sum(1 for s in flat_s
                  if isinstance(s, P) and any(ax == "model" for ax in s))
    assert sharded > 0  # the packed planes actually shard
    for leaf, spec in zip(flat_l, flat_s):
        for dim, ax in enumerate(spec):
            if ax is not None:
                assert leaf.shape[dim] % 2 == 0, (leaf.shape, spec)


# ---------------------------------------------------------------------------
# 2-device execution: bit-identical streams, sharded restore
# ---------------------------------------------------------------------------

TP_PARITY = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import get_config, reduced
    from repro.models import lm
    from repro.models.layers import Runtime
    from repro.serve.engine import ServeEngine, Request
    from repro.serve.quantized import quantize_params
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(1, 2)
    assert dict(mesh.shape) == {"data": 1, "model": 2}

    def streams(arch, kv_quant, mesh_, sm=None):
        cfg = reduced(get_config(arch))
        params = quantize_params(lm.init_params(jax.random.PRNGKey(0), cfg),
                                 "itq3_s")
        rt = Runtime(compute_dtype=jnp.float32, kv_quant=kv_quant)
        eng = ServeEngine(params, cfg, slots=2, max_len=48, rt=rt,
                          mesh=mesh_, tp_shard_map=sm)
        reqs = [Request(rid=i, prompt=(np.arange(6 + i) + 1) % cfg.vocab_size,
                        max_new=6) for i in range(3)]
        eng.run(reqs)
        return [list(r.out) for r in reqs], eng

    # dense (kv=4 divides: head-sharded cache) and hybrid (attn + ssm),
    # quantized and fp cache, BOTH execution paths (GSPMD jit / shard_map)
    for arch in ("qwen1.5-0.5b", "zamba2-7b"):
        for kvq in (True, False):
            base, _ = streams(arch, kvq, None)
            gspmd, _ = streams(arch, kvq, mesh, sm=False)
            smap, eng = streams(arch, kvq, mesh, sm=True)
            assert gspmd == base, (arch, kvq, "gspmd", gspmd, base)
            assert smap == base, (arch, kvq, "shard_map", smap, base)
            st = eng.stats()
            assert st["devices"] == 2
            if kvq or arch == "qwen1.5-0.5b":
                assert st["cache_bytes_per_device"] < st["cache_bytes"], st
    print("DENSE_HYBRID_OK")

    # GQA fallback: reduced smollm has kv=1 -> replicated cache, parity holds
    base, _ = streams("smollm-135m", True, None)
    smap, eng = streams("smollm-135m", True, mesh, sm=True)
    assert smap == base
    st = eng.stats()
    assert st["cache_bytes_per_device"] == st["cache_bytes"], st
    print("GQA_FALLBACK_OK")
""")


def test_tp_engine_bit_identical_streams():
    """ServeEngine(mesh=make_host_mesh(1, 2)) must produce bit-identical
    token streams vs single-device — dense + hybrid, kv_quant on/off,
    GSPMD and shard_map paths, plus the replicated-cache GQA fallback."""
    res = run_py(TP_PARITY, devices=2, timeout=900)
    assert "DENSE_HYBRID_OK" in res.stdout, res.stdout + res.stderr
    assert "GQA_FALLBACK_OK" in res.stdout, res.stdout + res.stderr


TP_RESTORE = textwrap.dedent("""
    import tempfile
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.configs.base import get_config, reduced
    from repro.checkpoint import ckpt
    from repro.models import lm
    from repro.models.layers import Runtime
    from repro.serve import tp
    from repro.serve.engine import ServeEngine, Request
    from repro.serve.quantized import quantize_params
    from repro.launch.mesh import make_host_mesh

    cfg = reduced(get_config("qwen1.5-0.5b"))
    params = quantize_params(lm.init_params(jax.random.PRNGKey(0), cfg),
                             "itq3_s")
    d = tempfile.mkdtemp()
    ckpt.save(d, 0, params)

    mesh = make_host_mesh(1, 2)

    # the restore callback: per-plane dicts for QTensors ('params.' prefix
    # stripped for TrainState checkpoints), replicated fp, None non-arrays
    from repro.core import formats
    place = tp.restore_shardings(cfg, mesh)
    qt = formats.quantize(np.zeros((256, 512), np.float32), "itq3_s")
    # top-level (unstacked) projection; under 'layers.' the same leaf would
    # need its leading L stack dim to shard
    for dotted in ("lm_head", "params.lm_head"):
        shard = place(dotted, qt)
        assert set(shard) == set(qt.data)
        assert shard["plane2"].spec[0] == "model", shard["plane2"].spec
    assert place("layers.ln1", np.zeros((128,), np.float32)).spec == P(None)
    assert place("step", 7) is None
    print("PLACE_OK")
    plain, _ = ckpt.restore_params(d)
    sharded, _ = ckpt.restore_params(
        d, shardings=tp.restore_shardings(cfg, mesh))

    # leaf-for-leaf plane equality: sharded restore changes PLACEMENT only
    eq = jax.tree.map(
        lambda a, b: np.array_equal(np.asarray(a), np.asarray(b)),
        plain, sharded)
    assert all(jax.tree.leaves(eq))
    # ...and the packed planes really are split 2 ways on device
    split = sum(
        1 for leaf in jax.tree.leaves(sharded)
        if hasattr(leaf, "addressable_shards")
        and len({s.device.id for s in leaf.addressable_shards}) == 2
        and leaf.addressable_shards[0].data.shape != leaf.shape)
    assert split > 0, "no leaf was actually sharded"
    print("RESTORE_EQ_OK", split)

    # boot an engine straight from the sharded restore: same streams
    rt = Runtime(compute_dtype=jnp.float32, kv_quant=True)
    def run(eng):
        reqs = [Request(rid=i, prompt=(np.arange(6 + i) + 1) % cfg.vocab_size,
                        max_new=6) for i in range(2)]
        eng.run(reqs)
        return [list(r.out) for r in reqs]
    base = run(ServeEngine(plain, cfg, slots=2, max_len=48,
                           rt=Runtime(compute_dtype=jnp.float32,
                                      kv_quant=True)))
    tp_stream = run(ServeEngine.from_checkpoint(d, cfg, mesh=mesh, slots=2,
                                                max_len=48, rt=rt))
    assert tp_stream == base, (tp_stream, base)
    print("FROM_CKPT_OK")
""")


def test_tp_restore_to_sharding():
    """restore_params(shardings=...) loads each packed plane straight into
    its column shard — values identical to the unsharded restore, and
    ServeEngine.from_checkpoint(mesh=...) serves the same streams."""
    res = run_py(TP_RESTORE, devices=2, timeout=900)
    assert "PLACE_OK" in res.stdout, res.stdout + res.stderr
    assert "RESTORE_EQ_OK" in res.stdout, res.stdout + res.stderr
    assert "FROM_CKPT_OK" in res.stdout, res.stdout + res.stderr
