"""Quantized-KV serving path: fused decode-attention kernel parity, shape
dispatch, and the engine invariants (1 sync/step, cache shrink, token
parity with the dequantize-then-attend reference)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, kv_cache_bytes_per_token, reduced
from repro.core.fwht import fwht
from repro.kernels import attn_decode as ad
from repro.models import lm
from repro.models.layers import Runtime
from repro.serve import kv_quant
from repro.serve.engine import Request, ServeEngine

KEY = jax.random.PRNGKey(0)
RT = Runtime(compute_dtype=jnp.float32, capacity_factor=8.0)
RTQ = Runtime(compute_dtype=jnp.float32, kv_quant=True, capacity_factor=8.0)


def _quant_cache(rng, b, kv, t, hd):
    k = jnp.asarray(rng.normal(size=(b, kv, t, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, kv, t, hd)), jnp.float32)
    kc, ks = kv_quant.kv_encode(k)
    vc, vs = kv_quant.kv_encode(v)
    return {"k": kc, "k_scale": ks, "v": vc, "v_scale": vs}, k, v


# ---------------------------------------------------------------------------
# Kernel: parity with the jnp reference and with dequantized-cache attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,kv,g,hd,t", [
    (2, 1, 4, 32, 48), (1, 3, 2, 64, 33), (2, 2, 1, 128, 17),
])
def test_kernel_matches_ref_backend(rng, b, kv, g, hd, t):
    cache, _, _ = _quant_cache(rng, b, kv, t, hd)
    q = jnp.asarray(rng.normal(size=(b, kv, g, 1, hd)), jnp.float32)
    ktok = kv_quant.kv_encode(
        jnp.asarray(rng.normal(size=(b, kv, 1, hd)), jnp.float32))
    vtok = kv_quant.kv_encode(
        jnp.asarray(rng.normal(size=(b, kv, 1, hd)), jnp.float32))
    kl = jnp.asarray(rng.integers(1, t + 1, size=b), jnp.int32)
    ref = ad.decode_attn_q8(q, cache, ktok, vtok, kl, backend="ref")
    ker = ad.decode_attn_q8(q, cache, ktok, vtok, kl, backend="pallas",
                            interpret=True)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


def test_kernel_online_softmax_tiling_invariant(rng):
    """Multi-tile online softmax == single-pass reference, ragged T."""
    b, kv, g, hd, t = 2, 2, 3, 64, 50
    cache, _, _ = _quant_cache(rng, b, kv, t, hd)
    q = jnp.asarray(rng.normal(size=(b, kv, g, 1, hd)), jnp.float32)
    qr = fwht(q[..., 0, :])
    kl = jnp.asarray([13, 50], jnp.int32)
    sm = 1.0 / np.sqrt(hd)
    r = b * kv
    args = (qr.reshape(r, g, hd),
            cache["k"].reshape(r, t, hd), cache["k_scale"].reshape(r, t),
            cache["v"].reshape(r, t, hd), cache["v_scale"].reshape(r, t),
            jnp.broadcast_to(kl[:, None], (b, kv)).reshape(r))
    acc_r, m_r, l_r = ad.decode_attn_q8_ref(
        qr, cache["k"], cache["k_scale"], cache["v"], cache["v_scale"], kl,
        sm_scale=sm)
    want = np.asarray(acc_r / l_r)
    for tt in (8, 16, 64):  # 50 is ragged for every one of these
        acc, m, l = ad.attn_decode_q8_pallas(*args, sm_scale=sm, tt=tt,
                                             interpret=True)
        got = np.asarray((acc / l).reshape(b, kv, g, hd))
        np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


def test_decode_matches_dequantized_cache_attention(rng):
    """The dequantize-free path == decode the cache, then fp attention."""
    b, kv, g, hd, t = 2, 2, 2, 64, 24
    cache, _, _ = _quant_cache(rng, b, kv, t, hd)
    q = jnp.asarray(rng.normal(size=(b, kv, g, 1, hd)), jnp.float32)
    k_tok_fp = jnp.asarray(rng.normal(size=(b, kv, 1, hd)), jnp.float32)
    v_tok_fp = jnp.asarray(rng.normal(size=(b, kv, 1, hd)), jnp.float32)
    ktok = kv_quant.kv_encode(k_tok_fp)
    vtok = kv_quant.kv_encode(v_tok_fp)
    kl = jnp.asarray([7, 24], jnp.int32)
    got = ad.decode_attn_q8(q, cache, ktok, vtok, kl, backend="ref")

    # reference: roundtrip the cache AND the token through the codec, then
    # ordinary fp attention with the same masking
    kf = kv_quant.kv_decode(cache["k"], cache["k_scale"])
    vf = kv_quant.kv_decode(cache["v"], cache["v_scale"])
    k_tok = kv_quant.kv_decode(*ktok)
    v_tok = kv_quant.kv_decode(*vtok)
    sm = 1.0 / np.sqrt(hd)
    s_c = jnp.einsum("bkgqd,bktd->bkgqt", q, kf) * sm
    mask = jnp.arange(t)[None, None, None, None, :] < kl[:, None, None, None, None]
    s_c = jnp.where(mask, s_c, -1e30)
    s_s = jnp.einsum("bkgqd,bktd->bkgqt", q, k_tok) * sm
    w = jax.nn.softmax(jnp.concatenate([s_c, s_s], -1), axis=-1)
    want = (jnp.einsum("bkgqt,bktd->bkgqd", w[..., :t], vf)
            + w[..., t:] * v_tok[:, :, None])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-4)


def test_kernel_shape_gate():
    assert ad.kernel_supported(128, interpret=False)
    assert not ad.kernel_supported(64, interpret=False)   # lane-partial on HW
    assert ad.kernel_supported(64, interpret=True)
    assert not ad.kernel_supported(48, interpret=True)    # non-pow2: never


# ---------------------------------------------------------------------------
# Model plumbing: quantized cache through forward/decode_step
# ---------------------------------------------------------------------------

def test_decode_step_matches_dequantized_reference():
    """Greedy decode over the int8 cache == decoding the SAME cache to fp
    and running the fp einsum path (the acceptance-criteria reference)."""
    cfg = reduced(get_config("smollm-135m"))
    params = lm.init_params(KEY, cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 11), 0,
                              cfg.vocab_size)
    qc = lm.init_cache(cfg, 2, 32, dtype=jnp.float32, kv_quant=True)
    _, qc, _ = lm.forward(params, toks[:, :10], RTQ, cfg, cache=qc, pos=0)
    fc = {"attn": {
        "k": kv_quant.kv_decode(qc["attn"]["k"], qc["attn"]["k_scale"]),
        "v": kv_quant.kv_decode(qc["attn"]["v"], qc["attn"]["v_scale"])}}
    pos = jnp.int32(10)
    for _ in range(3):
        dq, qc = lm.decode_step(params, toks[:, 10:11], qc, pos, RTQ, cfg)
        df, fc = lm.decode_step(params, toks[:, 10:11], fc, pos, RT, cfg)
        tq, tf = jnp.argmax(dq[:, 0], -1), jnp.argmax(df[:, 0], -1)
        assert bool(jnp.all(tq == tf))
        np.testing.assert_allclose(np.asarray(dq), np.asarray(df), atol=0.05)
        toks = jnp.concatenate([toks[:, :10], tq[:, None]], axis=1)
        pos = pos + 1


def test_hybrid_decode_matches_dequantized_reference():
    """The functional-write decode branch (hybrid's shared attention block
    runs without the scan-carry token cache) uses the same dequantize-free
    path: tokens match the decode-the-cache-then-attend reference."""
    cfg = reduced(get_config("zamba2-7b"))
    params = lm.init_params(KEY, cfg)
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 9), 0,
                              cfg.vocab_size)
    qc = lm.init_cache(cfg, 2, 24, dtype=jnp.float32, kv_quant=True)
    _, qc, _ = lm.forward(params, toks[:, :8], RTQ, cfg, cache=qc, pos=0)
    fc = dict(qc)
    fc["attn"] = {
        "k": kv_quant.kv_decode(qc["attn"]["k"], qc["attn"]["k_scale"]),
        "v": kv_quant.kv_decode(qc["attn"]["v"], qc["attn"]["v_scale"])}
    pos = jnp.int32(8)
    for _ in range(3):
        dq, qc = lm.decode_step(params, toks[:, 8:9], qc, pos, RTQ, cfg)
        df, fc = lm.decode_step(params, toks[:, 8:9], fc, pos, RT, cfg)
        tq, tf = jnp.argmax(dq[:, 0], -1), jnp.argmax(df[:, 0], -1)
        assert bool(jnp.all(tq == tf))
        np.testing.assert_allclose(np.asarray(dq), np.asarray(df), atol=0.05)
        toks = jnp.concatenate([toks[:, :8], tq[:, None]], axis=1)
        pos = pos + 1


def test_stats_per_token_excludes_recurrent_state():
    cfg = reduced(get_config("rwkv6-3b"))
    params = lm.init_params(KEY, cfg)
    eng = ServeEngine(params, cfg, slots=1, max_len=16, rt=RT)
    assert eng.stats()["cache_bytes_per_token"] == 0  # attention-free
    assert eng.cache_bytes > 0  # ...but the recurrent state is counted


def test_init_cache_quant_layout_and_bytes():
    cfg = reduced(get_config("smollm-135m"))
    c = lm.init_cache(cfg, 2, 16, kv_quant=True)["attn"]
    hd = cfg.resolved_head_dim
    assert c["k"].dtype == jnp.int8 and c["k"].shape[-1] == hd
    assert c["k_scale"].dtype == jnp.float16 and c["k_scale"].shape[-1] == 1
    # bytes/token matches the configs helper exactly
    per_tok = sum(a.nbytes for a in c.values()) / (2 * 16)
    assert per_tok == kv_cache_bytes_per_token(cfg, kv_quant=True)
    # ~0.52x of the bf16 layout for pow2 head dims
    ratio = (kv_cache_bytes_per_token(cfg, kv_quant=True)
             / kv_cache_bytes_per_token(cfg, kv_quant=False))
    assert abs(ratio - kv_quant.cache_bytes_ratio(hd)) < 1e-6
    assert 0.5 < ratio < 0.54


def test_init_cache_quant_rejects_odd_head_dim():
    cfg = reduced(get_config("smollm-135m"))
    import dataclasses
    bad = dataclasses.replace(cfg, head_dim=48)
    with pytest.raises(ValueError, match="power-of-two"):
        lm.init_cache(bad, 1, 8, kv_quant=True)


# ---------------------------------------------------------------------------
# Engine: hot-loop invariants under kv_quant
# ---------------------------------------------------------------------------

def test_engine_kv_quant_backend_parity_and_one_sync():
    """pallas(interpret) and ref backends emit identical greedy streams,
    and the 1-transfer-per-step discipline survives quantization."""
    cfg = reduced(get_config("smollm-135m"))
    params = lm.init_params(KEY, cfg)
    outs = {}
    for backend in ("ref", "pallas"):
        rt = Runtime(compute_dtype=jnp.float32, kv_quant=True,
                     backend=backend)
        eng = ServeEngine(params, cfg, slots=2, max_len=32, rt=rt)
        reqs = [Request(rid=i, prompt=np.arange(4 + i) + 1, max_new=5)
                for i in range(2)]
        assert eng.admit(reqs) == 2
        assert eng.host_syncs == 1
        for _ in range(4):
            before = eng.host_syncs
            eng.step()
            assert eng.host_syncs - before == 1
        outs[backend] = [r.out for r in reqs]
    assert outs["ref"] == outs["pallas"]


def test_engine_kv_quant_vs_ssm_noop():
    """kv_quant on an attention-free arch is a no-op (no attn cache)."""
    cfg = reduced(get_config("rwkv6-3b"))
    params = lm.init_params(KEY, cfg)
    eng = ServeEngine(params, cfg, slots=1, max_len=24, rt=RTQ)
    [r] = eng.run([Request(rid=0, prompt=np.arange(5) + 1, max_new=3)])
    assert len(r.out) >= 3


@pytest.mark.parametrize("arch", ["smollm-135m", "zamba2-7b", "olmoe-1b-7b"])
def test_engine_cache_bytes_shrink(arch):
    cfg = reduced(get_config(arch))
    params = lm.init_params(KEY, cfg)
    attn_leaves = lambda e: e.cache.get("attn", {})
    eng_f = ServeEngine(params, cfg, slots=2, max_len=32, rt=RT,
                        cache_dtype=jnp.bfloat16)
    eng_q = ServeEngine(params, cfg, slots=2, max_len=32, rt=RTQ)
    fb = sum(a.nbytes for a in attn_leaves(eng_f).values())
    qb = sum(a.nbytes for a in attn_leaves(eng_q).values())
    ratio = qb / fb
    want = kv_quant.cache_bytes_ratio(cfg.resolved_head_dim)
    assert abs(ratio - want) < 1e-6, (ratio, want)
    assert eng_q.cache_bytes < eng_f.cache_bytes
    assert eng_q.stats()["cache_bytes"] == eng_q.cache_bytes


def test_engine_kv_quant_matches_dequant_reference_rollout():
    """Acceptance: engine greedy stream under kv_quant == hand-rolled
    prefill+decode over the same quantized cache (which tests the whole
    write-encoded / read-quantized plumbing end to end)."""
    cfg = reduced(get_config("qwen1.5-0.5b"))
    params = lm.init_params(KEY, cfg)
    prompt = (np.arange(6) + 1) % cfg.vocab_size
    eng = ServeEngine(params, cfg, slots=1, max_len=32, rt=RTQ, prompt_pad=8)
    [req] = eng.run([Request(rid=0, prompt=prompt, max_new=4)])

    cache = lm.init_cache(cfg, 1, 32, dtype=jnp.float32, kv_quant=True)
    logits, cache, _ = lm.forward(params, jnp.asarray(prompt[None]), RTQ,
                                  cfg, cache=cache, pos=0)
    out = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    for _ in range(3):
        l, cache = lm.decode_step(params, jnp.asarray([[out[-1]]], jnp.int32),
                                  cache, jnp.int32(pos), RTQ, cfg)
        out.append(int(jnp.argmax(l[0, 0])))
        pos += 1
    assert req.out[:4] == out[:4]
