"""Quantized-KV serving path: fused decode-attention kernel parity, shape
dispatch, and the engine invariants (1 sync/step, cache shrink, token
parity with the dequantize-then-attend reference)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, kv_cache_bytes_per_token, reduced
from repro.core.fwht import fwht
from repro.kernels import attn_decode as ad
from repro.models import lm
from repro.models.layers import Runtime
from repro.serve import kv_quant
from repro.serve.engine import Request, ServeEngine

KEY = jax.random.PRNGKey(0)
RT = Runtime(compute_dtype=jnp.float32, capacity_factor=8.0)
RTQ = Runtime(compute_dtype=jnp.float32, kv_quant=True, capacity_factor=8.0)


def _quant_cache(rng, b, kv, t, hd):
    k = jnp.asarray(rng.normal(size=(b, kv, t, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, kv, t, hd)), jnp.float32)
    kc, ks = kv_quant.kv_encode(k)
    vc, vs = kv_quant.kv_encode(v)
    return {"k": kc, "k_scale": ks, "v": vc, "v_scale": vs}, k, v


# ---------------------------------------------------------------------------
# Kernel: parity with the jnp reference and with dequantized-cache attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,kv,g,hd,t", [
    (2, 1, 4, 32, 48), (1, 3, 2, 64, 33), (2, 2, 1, 128, 17),
])
def test_kernel_matches_ref_backend(rng, b, kv, g, hd, t):
    cache, _, _ = _quant_cache(rng, b, kv, t, hd)
    q = jnp.asarray(rng.normal(size=(b, kv, g, 1, hd)), jnp.float32)
    ktok = kv_quant.kv_encode(
        jnp.asarray(rng.normal(size=(b, kv, 1, hd)), jnp.float32))
    vtok = kv_quant.kv_encode(
        jnp.asarray(rng.normal(size=(b, kv, 1, hd)), jnp.float32))
    kl = jnp.asarray(rng.integers(1, t + 1, size=b), jnp.int32)
    ref = ad.decode_attn_q8(q, cache, ktok, vtok, kl, backend="ref")
    ker = ad.decode_attn_q8(q, cache, ktok, vtok, kl, backend="pallas",
                            interpret=True)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


def test_kernel_online_softmax_tiling_invariant(rng):
    """Multi-tile online softmax == single-pass reference, ragged T."""
    b, kv, g, hd, t = 2, 2, 3, 64, 50
    cache, _, _ = _quant_cache(rng, b, kv, t, hd)
    q = jnp.asarray(rng.normal(size=(b, kv, g, 1, hd)), jnp.float32)
    qr = fwht(q[..., 0, :])
    kl = jnp.asarray([13, 50], jnp.int32)
    sm = 1.0 / np.sqrt(hd)
    r = b * kv
    args = (qr.reshape(r, g, hd),
            cache["k"].reshape(r, t, hd), cache["k_scale"].reshape(r, t),
            cache["v"].reshape(r, t, hd), cache["v_scale"].reshape(r, t),
            jnp.broadcast_to(kl[:, None], (b, kv)).reshape(r))
    acc_r, m_r, l_r = ad.decode_attn_q8_ref(
        qr, cache["k"], cache["k_scale"], cache["v"], cache["v_scale"], kl,
        sm_scale=sm)
    want = np.asarray(acc_r / l_r)
    for tt in (8, 16, 64):  # 50 is ragged for every one of these
        acc, m, l = ad.attn_decode_q8_pallas(*args, sm_scale=sm, tt=tt,
                                             interpret=True)
        got = np.asarray((acc / l).reshape(b, kv, g, hd))
        np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


def test_decode_matches_dequantized_cache_attention(rng):
    """The dequantize-free path == decode the cache, then fp attention."""
    b, kv, g, hd, t = 2, 2, 2, 64, 24
    cache, _, _ = _quant_cache(rng, b, kv, t, hd)
    q = jnp.asarray(rng.normal(size=(b, kv, g, 1, hd)), jnp.float32)
    k_tok_fp = jnp.asarray(rng.normal(size=(b, kv, 1, hd)), jnp.float32)
    v_tok_fp = jnp.asarray(rng.normal(size=(b, kv, 1, hd)), jnp.float32)
    ktok = kv_quant.kv_encode(k_tok_fp)
    vtok = kv_quant.kv_encode(v_tok_fp)
    kl = jnp.asarray([7, 24], jnp.int32)
    got = ad.decode_attn_q8(q, cache, ktok, vtok, kl, backend="ref")

    # reference: roundtrip the cache AND the token through the codec, then
    # ordinary fp attention with the same masking
    kf = kv_quant.kv_decode(cache["k"], cache["k_scale"])
    vf = kv_quant.kv_decode(cache["v"], cache["v_scale"])
    k_tok = kv_quant.kv_decode(*ktok)
    v_tok = kv_quant.kv_decode(*vtok)
    sm = 1.0 / np.sqrt(hd)
    s_c = jnp.einsum("bkgqd,bktd->bkgqt", q, kf) * sm
    mask = jnp.arange(t)[None, None, None, None, :] < kl[:, None, None, None, None]
    s_c = jnp.where(mask, s_c, -1e30)
    s_s = jnp.einsum("bkgqd,bktd->bkgqt", q, k_tok) * sm
    w = jax.nn.softmax(jnp.concatenate([s_c, s_s], -1), axis=-1)
    want = (jnp.einsum("bkgqt,bktd->bkgqd", w[..., :t], vf)
            + w[..., t:] * v_tok[:, :, None])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-4)


def test_kernel_shape_gate():
    assert ad.kernel_supported(128, interpret=False)
    assert not ad.kernel_supported(64, interpret=False)   # lane-partial on HW
    assert ad.kernel_supported(64, interpret=True)
    assert not ad.kernel_supported(48, interpret=True)    # non-pow2: never


# ---------------------------------------------------------------------------
# Model plumbing: quantized cache through forward/decode_step
# ---------------------------------------------------------------------------

def test_decode_step_matches_dequantized_reference():
    """Greedy decode over the int8 cache == decoding the SAME cache to fp
    and running the fp einsum path (the acceptance-criteria reference)."""
    cfg = reduced(get_config("smollm-135m"))
    params = lm.init_params(KEY, cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 11), 0,
                              cfg.vocab_size)
    qc = lm.init_cache(cfg, 2, 32, dtype=jnp.float32, kv_quant=True)
    _, qc, _ = lm.forward(params, toks[:, :10], RTQ, cfg, cache=qc, pos=0)
    fc = {"attn": {
        "k": kv_quant.kv_decode(qc["attn"]["k"], qc["attn"]["k_scale"]),
        "v": kv_quant.kv_decode(qc["attn"]["v"], qc["attn"]["v_scale"])}}
    pos = jnp.int32(10)
    for _ in range(3):
        dq, qc = lm.decode_step(params, toks[:, 10:11], qc, pos, RTQ, cfg)
        df, fc = lm.decode_step(params, toks[:, 10:11], fc, pos, RT, cfg)
        tq, tf = jnp.argmax(dq[:, 0], -1), jnp.argmax(df[:, 0], -1)
        assert bool(jnp.all(tq == tf))
        np.testing.assert_allclose(np.asarray(dq), np.asarray(df), atol=0.05)
        toks = jnp.concatenate([toks[:, :10], tq[:, None]], axis=1)
        pos = pos + 1


def test_hybrid_decode_matches_dequantized_reference():
    """The functional-write decode branch (hybrid's shared attention block
    runs without the scan-carry token cache) uses the same dequantize-free
    path: tokens match the decode-the-cache-then-attend reference."""
    cfg = reduced(get_config("zamba2-7b"))
    params = lm.init_params(KEY, cfg)
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 9), 0,
                              cfg.vocab_size)
    qc = lm.init_cache(cfg, 2, 24, dtype=jnp.float32, kv_quant=True)
    _, qc, _ = lm.forward(params, toks[:, :8], RTQ, cfg, cache=qc, pos=0)
    fc = dict(qc)
    fc["attn"] = {
        "k": kv_quant.kv_decode(qc["attn"]["k"], qc["attn"]["k_scale"]),
        "v": kv_quant.kv_decode(qc["attn"]["v"], qc["attn"]["v_scale"])}
    pos = jnp.int32(8)
    for _ in range(3):
        dq, qc = lm.decode_step(params, toks[:, 8:9], qc, pos, RTQ, cfg)
        df, fc = lm.decode_step(params, toks[:, 8:9], fc, pos, RT, cfg)
        tq, tf = jnp.argmax(dq[:, 0], -1), jnp.argmax(df[:, 0], -1)
        assert bool(jnp.all(tq == tf))
        np.testing.assert_allclose(np.asarray(dq), np.asarray(df), atol=0.05)
        toks = jnp.concatenate([toks[:, :8], tq[:, None]], axis=1)
        pos = pos + 1


def test_stats_per_token_excludes_recurrent_state():
    cfg = reduced(get_config("rwkv6-3b"))
    params = lm.init_params(KEY, cfg)
    eng = ServeEngine(params, cfg, slots=1, max_len=16, rt=RT)
    assert eng.stats()["cache_bytes_per_token"] == 0  # attention-free
    assert eng.cache_bytes > 0  # ...but the recurrent state is counted


def test_init_cache_quant_layout_and_bytes():
    cfg = reduced(get_config("smollm-135m"))
    c = lm.init_cache(cfg, 2, 16, kv_quant=True)["attn"]
    hd = cfg.resolved_head_dim
    assert c["k"].dtype == jnp.int8 and c["k"].shape[-1] == hd
    assert c["k_scale"].dtype == jnp.float16 and c["k_scale"].shape[-1] == 1
    # bytes/token matches the configs helper exactly
    per_tok = sum(a.nbytes for a in c.values()) / (2 * 16)
    assert per_tok == kv_cache_bytes_per_token(cfg, kv_quant=True)
    # ~0.52x of the bf16 layout for pow2 head dims
    ratio = (kv_cache_bytes_per_token(cfg, kv_quant=True)
             / kv_cache_bytes_per_token(cfg, kv_quant=False))
    assert abs(ratio - kv_quant.cache_bytes_ratio(hd)) < 1e-6
    assert 0.5 < ratio < 0.54


def test_init_cache_quant_rejects_odd_head_dim():
    cfg = reduced(get_config("smollm-135m"))
    import dataclasses
    bad = dataclasses.replace(cfg, head_dim=48)
    with pytest.raises(ValueError, match="power-of-two"):
        lm.init_cache(bad, 1, 8, kv_quant=True)


# ---------------------------------------------------------------------------
# Engine: hot-loop invariants under kv_quant
# ---------------------------------------------------------------------------

def test_engine_kv_quant_backend_parity_and_one_sync():
    """pallas(interpret) and ref backends emit identical greedy streams,
    and the 1-transfer-per-step discipline survives quantization."""
    cfg = reduced(get_config("smollm-135m"))
    params = lm.init_params(KEY, cfg)
    outs = {}
    for backend in ("ref", "pallas"):
        rt = Runtime(compute_dtype=jnp.float32, kv_quant=True,
                     backend=backend)
        eng = ServeEngine(params, cfg, slots=2, max_len=32, rt=rt)
        reqs = [Request(rid=i, prompt=np.arange(4 + i) + 1, max_new=5)
                for i in range(2)]
        assert eng.admit(reqs) == 2
        assert eng.host_syncs == 1
        for _ in range(4):
            before = eng.host_syncs
            eng.step()
            assert eng.host_syncs - before == 1
        outs[backend] = [r.out for r in reqs]
    assert outs["ref"] == outs["pallas"]


def test_engine_kv_quant_vs_ssm_noop():
    """kv_quant on an attention-free arch is a no-op (no attn cache)."""
    cfg = reduced(get_config("rwkv6-3b"))
    params = lm.init_params(KEY, cfg)
    eng = ServeEngine(params, cfg, slots=1, max_len=24, rt=RTQ)
    [r] = eng.run([Request(rid=0, prompt=np.arange(5) + 1, max_new=3)])
    assert len(r.out) >= 3


@pytest.mark.parametrize("arch", ["smollm-135m", "zamba2-7b", "olmoe-1b-7b"])
def test_engine_cache_bytes_shrink(arch):
    cfg = reduced(get_config(arch))
    params = lm.init_params(KEY, cfg)
    attn_leaves = lambda e: e.cache.get("attn", {})
    eng_f = ServeEngine(params, cfg, slots=2, max_len=32, rt=RT,
                        cache_dtype=jnp.bfloat16)
    eng_q = ServeEngine(params, cfg, slots=2, max_len=32, rt=RTQ)
    fb = sum(a.nbytes for a in attn_leaves(eng_f).values())
    qb = sum(a.nbytes for a in attn_leaves(eng_q).values())
    ratio = qb / fb
    want = kv_quant.cache_bytes_ratio(cfg.resolved_head_dim)
    assert abs(ratio - want) < 1e-6, (ratio, want)
    assert eng_q.cache_bytes < eng_f.cache_bytes
    assert eng_q.stats()["cache_bytes"] == eng_q.cache_bytes


def test_engine_kv_quant_matches_dequant_reference_rollout():
    """Acceptance: engine greedy stream under kv_quant == hand-rolled
    prefill+decode over the same quantized cache (which tests the whole
    write-encoded / read-quantized plumbing end to end)."""
    cfg = reduced(get_config("qwen1.5-0.5b"))
    params = lm.init_params(KEY, cfg)
    prompt = (np.arange(6) + 1) % cfg.vocab_size
    eng = ServeEngine(params, cfg, slots=1, max_len=32, rt=RTQ, prompt_pad=8)
    [req] = eng.run([Request(rid=0, prompt=prompt, max_new=4)])

    cache = lm.init_cache(cfg, 1, 32, dtype=jnp.float32, kv_quant=True)
    logits, cache, _ = lm.forward(params, jnp.asarray(prompt[None]), RTQ,
                                  cfg, cache=cache, pos=0)
    out = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    for _ in range(3):
        l, cache = lm.decode_step(params, jnp.asarray([[out[-1]]], jnp.int32),
                                  cache, jnp.int32(pos), RTQ, cfg)
        out.append(int(jnp.argmax(l[0, 0])))
        pos += 1
    assert req.out[:4] == out[:4]


# ---------------------------------------------------------------------------
# Prefill: fused q-tile kernel vs dequantize-then-attend reference
# ---------------------------------------------------------------------------

def _dequant_prefill_reference(q, cache, kv_len, q_offset):
    """PR-4-era composition: decode the WHOLE cache, then fp attention with
    the same kv_len + causal(q_offset) masks — the oracle the fused q-tile
    path replaces."""
    b, kv, g, span, hd = q.shape
    t = cache["k"].shape[2]
    kf = kv_quant.kv_decode(cache["k"], cache["k_scale"])
    vf = kv_quant.kv_decode(cache["v"], cache["v_scale"])
    sm = 1.0 / np.sqrt(hd)
    s = jnp.einsum("bkgqd,bktd->bkgqt", q, kf) * sm
    kpos = jnp.arange(t)[None, None, None, None, :]
    qpos = (q_offset[:, None] + jnp.arange(span))[:, None, None, :, None]
    mask = (kpos < kv_len[:, None, None, None, None]) & (kpos <= qpos)
    w = jax.nn.softmax(jnp.where(mask, s, -1e30), axis=-1)
    return jnp.einsum("bkgqt,bktd->bkgqd", w, vf)


@pytest.mark.parametrize("b,kv,g,hd,t,span", [
    (2, 1, 4, 32, 48, 7), (1, 3, 2, 64, 33, 16), (2, 2, 1, 128, 24, 24),
])
def test_prefill_matches_dequantize_reference(rng, b, kv, g, hd, t, span):
    """Fused q-tile path == dequantize-the-cache-then-attend, per-row
    ragged offsets, both backends."""
    cache, _, _ = _quant_cache(rng, b, kv, t, hd)
    q = jnp.asarray(rng.normal(size=(b, kv, g, span, hd)), jnp.float32)
    off = jnp.asarray(rng.integers(0, t - span + 1, size=b), jnp.int32)
    kl = off + span
    want = _dequant_prefill_reference(q, cache, kl, off)
    for kwargs in (dict(backend="ref"),
                   dict(backend="pallas", interpret=True)):
        got = ad.prefill_attn_q8(q, cache, kl, off, **kwargs)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=1e-4)


def test_prefill_kernel_tiling_invariant(rng):
    """Multi-tile online softmax over BOTH grid axes == single-pass
    reference: ragged kv width for every (tq, tt) choice."""
    b, kv, g, hd, t, span = 2, 2, 3, 64, 50, 12
    cache, _, _ = _quant_cache(rng, b, kv, t, hd)
    q = jnp.asarray(rng.normal(size=(b, kv, g, span, hd)), jnp.float32)
    off = jnp.asarray([13, 38], jnp.int32)
    kl = off + span
    want = np.asarray(ad.prefill_attn_q8(q, cache, kl, off, backend="ref"))
    for tq in (1, 5, 8, 16):
        for tt in (8, 64):  # 50 keys is ragged for both
            got = ad.prefill_attn_q8(q, cache, kl, off, backend="pallas",
                                     interpret=True, tq=tq, tt=tt)
            np.testing.assert_allclose(np.asarray(got), want,
                                       atol=1e-5, rtol=1e-5)


def test_prefill_causal_boundary_at_span_edge(rng):
    """Row i of the span sees exactly positions <= q_offset + i: a width-1
    span through the prefill entry (post-write cache, causal mask) must
    match the decode entry (pre-write cache + merged self term) on the
    same token."""
    b, kv, g, hd, t = 2, 2, 2, 64, 20
    pos = 9
    cache, k, v = _quant_cache(rng, b, kv, t, hd)
    q = jnp.asarray(rng.normal(size=(b, kv, g, 1, hd)), jnp.float32)
    pos_vec = jnp.full((b,), pos, jnp.int32)
    # decode view: the cache does NOT yet hold the token at `pos`
    ktok = (cache["k"][:, :, pos:pos + 1], cache["k_scale"][:, :, pos:pos + 1])
    vtok = (cache["v"][:, :, pos:pos + 1], cache["v_scale"][:, :, pos:pos + 1])
    dec = ad.decode_attn_q8(q, cache, ktok, vtok, pos_vec, backend="ref")
    # prefill view: same token already written at `pos`, causal mask stops
    # the span at its own edge — positions > pos must contribute nothing
    pre = ad.prefill_attn_q8(q, cache, pos_vec + 1, pos_vec, backend="ref")
    np.testing.assert_allclose(np.asarray(pre), np.asarray(dec),
                               atol=2e-5, rtol=1e-4)
    pre_k = ad.prefill_attn_q8(q, cache, pos_vec + 1, pos_vec,
                               backend="pallas", interpret=True, tq=4, tt=8)
    np.testing.assert_allclose(np.asarray(pre_k), np.asarray(dec),
                               atol=2e-5, rtol=1e-4)


def test_pallas_backend_shape_gate_fails_fast():
    """Forced backend="pallas" on a shape the kernel can't lower raises the
    named gate up front (mirroring qmatmul's dispatch errors) instead of
    dying inside Pallas lowering."""
    rng = np.random.default_rng(0)

    def args(hd, span):
        # raw planes (not kv_encode: the codec itself rejects non-pow2) —
        # the gate must fire before any array math happens
        cache = {
            "k": jnp.asarray(rng.integers(-127, 128, size=(1, 1, 16, hd)),
                             jnp.int8),
            "v": jnp.asarray(rng.integers(-127, 128, size=(1, 1, 16, hd)),
                             jnp.int8),
            "k_scale": jnp.ones((1, 1, 16, 1), jnp.float16),
            "v_scale": jnp.ones((1, 1, 16, 1), jnp.float16),
        }
        q = jnp.asarray(rng.normal(size=(1, 1, 2, span, hd)), jnp.float32)
        return q, cache

    q, cache = args(48, 1)  # non-pow2: never supported
    ktok = (cache["k"][:, :, :1], cache["k_scale"][:, :, :1])
    vtok = (cache["v"][:, :, :1], cache["v_scale"][:, :, :1])
    kl = jnp.asarray([8], jnp.int32)
    with pytest.raises(ValueError, match="power of two"):
        ad.decode_attn_q8(q, cache, ktok, vtok, kl, backend="pallas",
                          interpret=True)
    q, cache = args(48, 4)
    with pytest.raises(ValueError, match="power of two"):
        ad.prefill_attn_q8(q, cache, kl, jnp.asarray([4], jnp.int32),
                           backend="pallas", interpret=True)
    # pow2 but lane-partial on real hardware (interpret=False)
    q, cache = args(64, 4)
    with pytest.raises(ValueError, match="128-wide lanes"):
        ad.prefill_attn_q8(q, cache, kl, jnp.asarray([4], jnp.int32),
                           backend="pallas", interpret=False)
    with pytest.raises(ValueError, match="not in"):
        ad.prefill_attn_q8(q, cache, kl, jnp.asarray([4], jnp.int32),
                           backend="cuda")


# ---------------------------------------------------------------------------
# Model plumbing: prefill over the quantized cache never dequantizes it
# ---------------------------------------------------------------------------

def test_attention_apply_prefill_no_full_cache_dequant(monkeypatch):
    """Acceptance: the prefill branch streams codes — kv_decode over the
    cache buffer is GONE from the model path for every family."""
    import repro.models.layers as layers_mod

    assert not hasattr(layers_mod, "kv_decode")  # the import itself is gone
    monkeypatch.setattr(
        kv_quant, "kv_decode",
        lambda *a, **k: (_ for _ in ()).throw(
            AssertionError("prefill dequantized the cache buffer")))
    for arch in ("smollm-135m", "zamba2-7b"):
        cfg = reduced(get_config(arch))
        params = lm.init_params(KEY, cfg)
        toks = jax.random.randint(jax.random.PRNGKey(2), (2, 9), 0,
                                  cfg.vocab_size)
        cache = lm.init_cache(cfg, 2, 24, dtype=jnp.float32, kv_quant=True)
        logits, cache, _ = lm.forward(params, toks, RTQ, cfg, cache=cache,
                                      pos=0)
        assert bool(jnp.all(jnp.isfinite(logits)))
        # chunked continuation (pos > 0) takes the same fused path
        logits, _, _ = lm.forward(params, toks[:, :4], RTQ, cfg, cache=cache,
                                  pos=9)
        assert bool(jnp.all(jnp.isfinite(logits)))


def test_runtime_attn_tile_knobs_thread_through(rng, monkeypatch):
    """Runtime.attn_tile_q/attn_tile_k REACH the kernel (spied at the
    pallas entry — stream equality alone would also pass if the knobs were
    silently dropped) and forced-pallas streams are identical across tile
    choices."""
    import repro.kernels.attn_decode as ad_mod

    calls = []
    real = ad_mod.attn_q8_pallas

    def spy(*a, **kw):
        calls.append((kw.get("tq"), kw.get("tt"), kw.get("causal")))
        return real(*a, **kw)

    monkeypatch.setattr(ad_mod, "attn_q8_pallas", spy)
    cfg = reduced(get_config("smollm-135m"))
    params = lm.init_params(KEY, cfg)
    outs = {}
    for tiles in (None, (4, 8)):
        rt = Runtime(compute_dtype=jnp.float32, kv_quant=True,
                     backend="pallas",
                     attn_tile_q=None if tiles is None else tiles[0],
                     attn_tile_k=None if tiles is None else tiles[1])
        eng = ServeEngine(params, cfg, slots=2, max_len=32, rt=rt)
        calls.clear()
        reqs = [Request(rid=i, prompt=np.arange(4 + i) + 1, max_new=4)
                for i in range(2)]
        eng.run(reqs)
        outs[tiles] = [r.out for r in reqs]
        want_tq = ad.DEFAULT_TQ if tiles is None else tiles[0]
        want_tt = ad.DEFAULT_TT if tiles is None else tiles[1]
        # the admission wave's prefill call carries the q-tile knobs...
        assert (want_tq, want_tt, True) in calls, calls
        # ...and the decode steps the key-tile knob at tq=1
        assert (1, want_tt, False) in calls, calls
    assert outs[None] == outs[(4, 8)]


# ---------------------------------------------------------------------------
# Engine: prefill streams bit-identical to the PR 4 dequantize-then-attend
# composition (goldens captured at PR 4 HEAD on this CPU image)
# ---------------------------------------------------------------------------

GOLDEN_PR4_DENSE = [[37, 148, 42, 227, 11, 11], [37, 42, 108, 42, 227, 227]]
GOLDEN_PR4_HYBRID = [[141, 272, 453, 227, 314, 430],
                     [499, 77, 314, 299, 272, 77]]


def test_engine_bucketed_prefill_stream_matches_pr4_head():
    cfg = reduced(get_config("smollm-135m"))
    params = lm.init_params(KEY, cfg)
    eng = ServeEngine(params, cfg, slots=2, max_len=48, rt=RTQ, prompt_pad=8)
    reqs = [Request(rid=i, prompt=(np.arange(6 + 3 * i) + 1) % cfg.vocab_size,
                    max_new=6) for i in range(2)]
    eng.run(reqs)
    assert [r.out for r in reqs] == GOLDEN_PR4_DENSE


def test_engine_chunk_ladder_prefill_stream_matches_pr4_head():
    """SSM/hybrid chunk-ladder admission (prompt lengths 11/13 with
    prompt_chunk=8 -> multi-chunk ladders incl. width-1 tail chunks) over
    the quantized cache: token streams bit-identical to PR 4 HEAD's
    whole-cache-dequantize prefill."""
    cfg = reduced(get_config("zamba2-7b"))
    params = lm.init_params(KEY, cfg)
    eng = ServeEngine(params, cfg, slots=2, max_len=48, rt=RTQ,
                      prompt_chunk=8)
    reqs = [Request(rid=i,
                    prompt=(np.arange(11 + 2 * i) + 1) % cfg.vocab_size,
                    max_new=6) for i in range(2)]
    eng.run(reqs)
    assert [r.out for r in reqs] == GOLDEN_PR4_HYBRID


# ---------------------------------------------------------------------------
# Tile-level early exit: ceil(kv_len/TT) clamped index maps (PR 6)
# ---------------------------------------------------------------------------

def test_early_exit_bitwise_matches_full_loop_decode(rng):
    """Decode kernel with clamped key-tile index maps is BITWISE equal to
    the full key loop — skipped tiles are exactly the fully-masked ones,
    so not one float may differ."""
    b, kv, g, hd, t = 1, 3, 2, 32, 640
    cache, _, _ = _quant_cache(rng, b, kv, t, hd)
    q = jnp.asarray(rng.normal(size=(b * kv, g, hd)), jnp.float32)
    r = b * kv
    args = (q, cache["k"].reshape(r, t, hd), cache["k_scale"].reshape(r, t),
            cache["v"].reshape(r, t, hd), cache["v_scale"].reshape(r, t),
            jnp.asarray([5, 300, 640], jnp.int32))  # tiny, mid, full rows
    for tt in (64, 128, 256):
        full = ad.attn_decode_q8_pallas(*args, sm_scale=hd ** -0.5, tt=tt,
                                        interpret=True, early_exit=False)
        fast = ad.attn_decode_q8_pallas(*args, sm_scale=hd ** -0.5, tt=tt,
                                        interpret=True, early_exit=True)
        for a, b_ in zip(full, fast):
            assert np.array_equal(np.asarray(a), np.asarray(b_)), tt


def test_early_exit_bitwise_matches_full_loop_prefill(rng):
    """Causal prefill: the per-query-tile limit (kv_len AND causal bound)
    clamps key tiles; bitwise parity with the unclamped loop across ragged
    offsets and tile widths."""
    r, t, g, hd, tq_total = 3, 512, 2, 32, 96
    kc = jnp.asarray(rng.integers(-127, 128, size=(r, t, hd)), jnp.int8)
    vc = jnp.asarray(rng.integers(-127, 128, size=(r, t, hd)), jnp.int8)
    ks = jnp.asarray(np.abs(rng.normal(size=(r, t))) * 0.02, jnp.float32)
    vs = jnp.asarray(np.abs(rng.normal(size=(r, t))) * 0.02, jnp.float32)
    q = jnp.asarray(rng.normal(size=(r, tq_total, g, hd)), jnp.float32)
    kl = jnp.asarray([100, 300, 512], jnp.int32)
    off = jnp.asarray([4, 204, 416], jnp.int32)  # spans end at kv_len
    for tq, tt in ((32, 64), (96, 128), (64, 256)):
        kw = dict(sm_scale=hd ** -0.5, causal=True, tq=tq, tt=tt,
                  interpret=True)
        full = ad.attn_q8_pallas(q, kc, ks, vc, vs, kl, off,
                                 early_exit=False, **kw)
        fast = ad.attn_q8_pallas(q, kc, ks, vc, vs, kl, off,
                                 early_exit=True, **kw)
        for a, b_ in zip(full, fast):
            assert np.array_equal(np.asarray(a), np.asarray(b_)), (tq, tt)


def test_early_exit_empty_rows(rng):
    """kv_len=0 rows (freshly admitted slots): the clamped index map floors
    at tile 0 and the masked update leaves the init state; the engine's
    self-token merge then owns the whole softmax."""
    r, t, g, hd = 2, 256, 1, 32
    kc = jnp.asarray(rng.integers(-127, 128, size=(r, t, hd)), jnp.int8)
    ks = jnp.asarray(np.abs(rng.normal(size=(r, t))) * 0.02, jnp.float32)
    q = jnp.asarray(rng.normal(size=(r, g, hd)), jnp.float32)
    kl = jnp.zeros((r,), jnp.int32)
    acc, m, l = ad.attn_decode_q8_pallas(
        q, kc, ks, kc, ks, kl, sm_scale=hd ** -0.5, tt=64, interpret=True)
    assert np.all(np.asarray(acc) == 0.0)
    assert np.all(np.asarray(l) == 0.0)
    assert np.all(np.asarray(m) == ad.NEG_INF)


# ---------------------------------------------------------------------------
# Attention tile autotuning: (tq, tt) in the shared autotune cache (PR 6)
# ---------------------------------------------------------------------------

def test_attn_tiles_roundtrip_and_interpret_defaults(tmp_path, monkeypatch):
    from repro.kernels import autotune as at

    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "tune.json"))
    at.clear_memory_cache()
    # miss -> deterministic defaults (the interpret-mode contract)
    assert at.get_attn_tiles(4096, 64, 8, interpret=True) == (
        ad.DEFAULT_TQ, ad.DEFAULT_TT)
    key = at.record_attn(4096, 64, 8, 64, 512, interpret=True, us=12.5)
    assert "attn" in key and "hd64" in key and "h8" in key
    assert at.get_attn_tiles(4096, 64, 8, interpret=True) == (64, 512)
    # T buckets to the next power of two: 3000 shares 4096's entry
    assert at.get_attn_tiles(3000, 64, 8, interpret=True) == (64, 512)
    # distinct head count = distinct entry
    assert at.get_attn_tiles(4096, 64, 4, interpret=True) == (
        ad.DEFAULT_TQ, ad.DEFAULT_TT)
    at.clear_memory_cache()


def test_autotune_attn_sweeps_and_records(tmp_path, monkeypatch):
    """Forced interpret-mode sweep on a tiny shape: every candidate runs,
    a winner lands in the cache, and the lookup the kernels use finds it."""
    from repro.kernels import autotune as at

    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "tune.json"))
    at.clear_memory_cache()
    best = at.autotune_attn(64, 32, 2, batch=1, decode=True, interpret=True,
                            iters=1, force_interpret_bench=True)
    assert best[0] == 1  # decode sweeps the TQ=1 specialization only
    assert at.get_attn_tiles(64, 32, 2, interpret=True) == best
    # without the force flag, interpret mode never benchmarks
    assert at.autotune_attn(128, 32, 2, interpret=True) == (
        ad.DEFAULT_TQ, ad.DEFAULT_TT)
    at.clear_memory_cache()


def test_decode_uses_tuned_tt(rng, tmp_path, monkeypatch):
    """decode_attn_q8(tt=None) resolves the key-tile width through the
    autotune cache (spied at the pallas entry)."""
    import repro.kernels.attn_decode as ad_mod
    from repro.kernels import autotune as at

    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "tune.json"))
    at.clear_memory_cache()
    b, kv, g, hd, t = 1, 2, 2, 32, 64
    at.record_attn(t, hd, kv, 1, 16, interpret=True)
    cache, _, _ = _quant_cache(rng, b, kv, t, hd)
    q = jnp.asarray(rng.normal(size=(b, kv, g, 1, hd)), jnp.float32)
    ktok = kv_quant.kv_encode(
        jnp.asarray(rng.normal(size=(b, kv, 1, hd)), jnp.float32))
    vtok = kv_quant.kv_encode(
        jnp.asarray(rng.normal(size=(b, kv, 1, hd)), jnp.float32))
    kl = jnp.asarray([t], jnp.int32)
    seen = []
    real = ad_mod.attn_decode_q8_pallas

    def spy(*a, **kw):
        seen.append(kw.get("tt"))
        return real(*a, **kw)

    monkeypatch.setattr(ad_mod, "attn_decode_q8_pallas", spy)
    out_tuned = ad.decode_attn_q8(q, cache, ktok, vtok, kl,
                                  backend="pallas", interpret=True)
    assert seen == [16]  # the recorded winner, not DEFAULT_TT
    out_default = ad.decode_attn_q8(q, cache, ktok, vtok, kl,
                                    backend="pallas", interpret=True,
                                    tt=ad_mod.DEFAULT_TT)
    np.testing.assert_allclose(np.asarray(out_tuned),
                               np.asarray(out_default), atol=1e-6)
    at.clear_memory_cache()


# ---------------------------------------------------------------------------
# Narrow-q-width tile family: speculative K+1 verify windows (PR 10)
# ---------------------------------------------------------------------------

def test_qwidth_key_family_and_fallback(tmp_path, monkeypatch):
    """Narrow verify spans get their own |q{bucket} autotune entries;
    lookup falls back to the base (wide-prefill) key, then to defaults."""
    from repro.kernels import autotune as at

    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "tune.json"))
    at.clear_memory_cache()
    # bucketing: pow2 round-up, distinct buckets = distinct keys
    assert at._bucket_q(1) == 1 and at._bucket_q(5) == 8
    assert at._attn_key(4096, 64, 8, interpret=True, q_width=5) \
        == at._attn_key(4096, 64, 8, interpret=True, q_width=8)
    assert at._attn_key(4096, 64, 8, interpret=True, q_width=5) \
        != at._attn_key(4096, 64, 8, interpret=True)
    # no entries at all -> defaults
    assert at.get_attn_tiles(4096, 64, 8, interpret=True, q_width=5) == (
        ad.DEFAULT_TQ, ad.DEFAULT_TT)
    # base (wide) winner recorded -> narrow lookup falls back to it
    at.record_attn(4096, 64, 8, 64, 512, interpret=True)
    assert at.get_attn_tiles(4096, 64, 8, interpret=True, q_width=5) == (
        64, 512)
    # dedicated narrow winner shadows the base entry for its bucket only
    at.record_attn(4096, 64, 8, 4, 128, interpret=True, q_width=5)
    assert at.get_attn_tiles(4096, 64, 8, interpret=True, q_width=5) == (
        4, 128)
    assert at.get_attn_tiles(4096, 64, 8, interpret=True, q_width=3) == (
        64, 512)  # different bucket: still the base entry
    assert at.get_attn_tiles(4096, 64, 8, interpret=True) == (64, 512)
    at.clear_memory_cache()


def test_qwidth_candidates_capped_at_bucket():
    from repro.kernels import autotune as at

    for qw in (1, 5, 8, 16):
        for tq, tt in at.attn_candidates(1024, 64, q_width=qw):
            assert tq <= at._bucket_q(qw)
    # wide prefill sweep is unchanged by the family's existence
    wide = at.attn_candidates(1024, 64)
    assert any(tq > at.SPEC_QWIDTH_MAX for tq, _ in wide)


def test_prefill_narrow_span_uses_qwidth_entry(rng, tmp_path, monkeypatch):
    """prefill_attn_q8 with a speculative-width span resolves tiles
    through the q-width key (spied at the pallas entry) and matches the
    default-tile output bitwise."""
    import repro.kernels.attn_decode as ad_mod
    from repro.kernels import autotune as at

    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "tune.json"))
    at.clear_memory_cache()
    b, kv, g, hd, t, span = 1, 2, 2, 32, 64, 5
    at.record_attn(t, hd, kv, 2, 16, interpret=True, q_width=span)
    cache, _, _ = _quant_cache(rng, b, kv, t, hd)
    q = jnp.asarray(rng.normal(size=(b, kv, g, span, hd)), jnp.float32)
    kl = jnp.asarray([t], jnp.int32)
    off = jnp.asarray([t - span], jnp.int32)
    seen = []
    real = ad_mod.attn_q8_pallas

    def spy(*a, **kw):
        seen.append((kw.get("tq"), kw.get("tt")))
        return real(*a, **kw)

    monkeypatch.setattr(ad_mod, "attn_q8_pallas", spy)
    out_tuned = ad.prefill_attn_q8(q, cache, kl, off, backend="pallas",
                                   interpret=True)
    assert seen == [(2, 16)]  # the narrow-span winner, not DEFAULT_TQ
    out_default = ad.prefill_attn_q8(q, cache, kl, off, backend="pallas",
                                     interpret=True, tq=ad_mod.DEFAULT_TQ,
                                     tt=ad_mod.DEFAULT_TT)
    np.testing.assert_allclose(np.asarray(out_tuned),
                               np.asarray(out_default), atol=1e-5)
    at.clear_memory_cache()
