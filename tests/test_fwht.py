"""FWHT invariants: involution, isometry, equivalence of butterfly and
matmul forms (Theorem 2's epsilon_FWHT is what bounds the tolerances)."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import fwht as F


@pytest.mark.parametrize("n", [2, 8, 32, 256])
def test_involution(rng, n):
    x = jnp.asarray(rng.normal(size=(4, n)), jnp.float32)
    assert np.allclose(F.fwht(F.fwht(x)), x, atol=1e-4)


def test_isometry(rng):
    x = jnp.asarray(rng.normal(size=(8, 256)), jnp.float32)
    y = F.fwht(x)
    assert np.allclose(np.linalg.norm(np.asarray(y), axis=-1),
                       np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-5)


def test_matches_matmul_form(rng):
    x = jnp.asarray(rng.normal(size=(5, 256)), jnp.float32)
    h = F.hadamard_matrix(256)
    assert np.allclose(F.fwht(x), x @ h, atol=1e-4)


def test_hadamard_symmetric_involutory():
    h = np.asarray(F.hadamard_matrix(64, dtype=jnp.float64))
    assert np.allclose(h, h.T)
    assert np.allclose(h @ h, np.eye(64), atol=1e-12)


def test_blocked_independent_blocks(rng):
    x = jnp.asarray(rng.normal(size=(3, 512)), jnp.float32)
    y = F.blocked_fwht(x, 256)
    y0 = F.fwht(x[:, :256])
    assert np.allclose(y[:, :256], y0, atol=1e-5)


def test_outlier_energy_spreading(rng):
    """Corollary 1: a single outlier M contributes M/sqrt(n) per coefficient."""
    x = np.zeros((1, 256), np.float32)
    x[0, 17] = 160.0
    y = np.asarray(F.fwht(jnp.asarray(x)))
    assert np.allclose(np.abs(y), 10.0, atol=1e-4)  # 160/sqrt(256)


def test_rejects_non_pow2():
    with pytest.raises(ValueError):
        F.fwht(jnp.zeros((2, 100)))
    with pytest.raises(ValueError):
        F.blocked_fwht(jnp.zeros((2, 100)), 256)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**32 - 1), st.sampled_from([16, 64, 256]))
def test_property_involution_isometry(seed, n):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(size=(2, n)) * r.uniform(0.1, 100), jnp.float32)
    y = F.fwht(x)
    assert np.allclose(F.fwht(y), x, atol=1e-3 * float(jnp.max(jnp.abs(x)) + 1))
    assert np.allclose(np.sum(np.square(np.asarray(y))),
                       np.sum(np.square(np.asarray(x))), rtol=1e-4)
