"""W3A8 integer compute path (rotation-domain activations, PR 8).

Covers the activation codec (isometry, scale safety), the int8 Pallas
kernels vs the integer reference, int-vs-float parity across every fused
format, the dispatch/policy plumbing, and the two contracts the PR must
not break: ``act_quant=False`` token streams stay bit-identical to PR 7
HEAD, and the restructured ref path materializes no full-weight-size f32
tensor before the contraction.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.core import formats
from repro.core.act_quant import ACT_QMAX, act_decode, act_encode
from repro.core.fwht import blocked_fwht
from repro.core.qlinear import qmatmul
from repro.core.quantize import QMeta
from repro.kernels import ref
from repro.kernels.itq3_matmul import itq3_matmul_int8_pallas
from repro.kernels.itq3_matvec import MATVEC_MAX_M, itq3_matvec_int8_pallas
from repro.models import lm
from repro.models.layers import Runtime
from repro.serve.engine import Request, ServeEngine
from repro.serve.quantized import (MATMUL_LEAVES, QuantPolicy, QuantRule,
                                   quantize_params)

KEY = jax.random.PRNGKey(0)
FUSED_FMTS = ["itq3_s", "itq3_s_sub", "itq3_x", "iq3_s", "quip3"]


def _rel_l2(a, b):
    a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
    return np.linalg.norm(a - b) / max(np.linalg.norm(a), 1e-12)


def _encode_for(qt, x):
    """Activation codes matching a QTensor's rotation convention."""
    m = qt.meta
    return act_encode(x, block=m.block, rotate=m.rotate,
                      dsign=qt.data.get("dsign"))


# ---------------------------------------------------------------------------
# Codec: FWHT isometry + scale safety
# ---------------------------------------------------------------------------

def test_codec_isometry_roundtrip(rng):
    """encode rotates into the Hadamard domain; decode + one more (self-
    inverse) FWHT lands back on x within int8 quantization error."""
    x = jnp.asarray(rng.normal(size=(4, 512)), jnp.float32)
    codes, scale = act_encode(x, rotate=True)
    assert codes.dtype == jnp.int8 and scale.shape == (4, 1)
    back = blocked_fwht(act_decode(codes, scale), 256)
    assert _rel_l2(x, back) < 2e-2
    # rotate=False is the identity codec (plain per-row absmax int8)
    codes0, scale0 = act_encode(x, rotate=False)
    assert _rel_l2(x, act_decode(codes0, scale0)) < 2e-2


def test_codec_dot_isometry(rng):
    """The load-bearing identity: x . Hw == (Hx) . w per block."""
    x = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
    lhs = jnp.dot(x, blocked_fwht(w[None], 256)[0])
    rhs = jnp.dot(blocked_fwht(x[None], 256)[0], w)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), atol=1e-3)


def test_codec_scale_extremes(rng):
    """Rows at 1e6/1e7/1e-7 magnitudes and an all-zero row: codes stay in
    the int8 grid, scales stay finite, zero rows produce zero codes and a
    zero scale (no 0/0 NaN), and nonzero rows use the full grid."""
    base = rng.normal(size=(4, 512)).astype(np.float32)
    base[3] = 0.0
    mags = np.asarray([1e6, 1e7, 1e-7, 1.0], np.float32)[:, None]
    x = jnp.asarray(base * mags)
    codes, scale = act_encode(x, rotate=True)
    c, s = np.asarray(codes), np.asarray(scale)
    assert np.all(np.isfinite(s)) and np.all(np.abs(c) <= ACT_QMAX)
    assert np.all(c[3] == 0) and s[3, 0] == 0.0
    for row in range(3):  # absmax rule pins the largest element to +-127
        assert np.max(np.abs(c[row])) == ACT_QMAX
    assert np.all(np.isfinite(np.asarray(act_decode(codes, scale))))


def test_codec_dsign_matches_manual_fold(rng):
    """quip3 convention: dsign multiplies x per block before the FWHT."""
    x = jnp.asarray(rng.normal(size=(3, 512)), jnp.float32)
    dsign = jnp.asarray(rng.choice([-1.0, 1.0], size=(2, 256)), jnp.float32)
    got_c, got_s = act_encode(x, rotate=True, dsign=dsign)
    folded = (x.reshape(3, 2, 256) * dsign).reshape(3, 512)
    want_c, want_s = act_encode(folded, rotate=True)
    assert np.array_equal(np.asarray(got_c), np.asarray(want_c))
    assert np.array_equal(np.asarray(got_s), np.asarray(want_s))


# ---------------------------------------------------------------------------
# Kernels: int8 Pallas variants vs the integer reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", FUSED_FMTS)
@pytest.mark.parametrize("hoist", [False, True])
def test_int8_kernel_matches_int8_ref(rng, fmt, hoist):
    w = jnp.asarray(rng.standard_t(df=4, size=(512, 320)) * 0.02, jnp.float32)
    x = jnp.asarray(rng.normal(size=(24, 512)), jnp.float32)
    qt = formats.quantize(w, fmt)
    xq, xs = _encode_for(qt, x)
    m = qt.meta
    args = (xq, xs, qt.data["plane2"], qt.data["plane1"],
            qt.data["scales"], qt.data["zps"])
    kw = dict(fivelevel=m.fivelevel, sub_blocks=m.sub_blocks)
    want = np.asarray(ref.itq3_matmul_int8_ref(*args, **kw))
    got = np.asarray(itq3_matmul_int8_pallas(
        *args, **kw, tm=8, tn=128, interpret=True, hoist=hoist))
    np.testing.assert_allclose(got, want, atol=1e-4)


@pytest.mark.parametrize("fmt", FUSED_FMTS)
@pytest.mark.parametrize("m", [1, MATVEC_MAX_M])
def test_int8_matvec_matches_int8_ref(rng, fmt, m):
    w = jnp.asarray(rng.normal(size=(512, 192)) * 0.05, jnp.float32)
    x = jnp.asarray(rng.normal(size=(m, 512)), jnp.float32)
    qt = formats.quantize(w, fmt)
    xq, xs = _encode_for(qt, x)
    meta = qt.meta
    args = (xq, xs, qt.data["plane2"], qt.data["plane1"],
            qt.data["scales"], qt.data["zps"])
    kw = dict(fivelevel=meta.fivelevel, sub_blocks=meta.sub_blocks)
    want = np.asarray(ref.itq3_matmul_int8_ref(*args, **kw))
    got = np.asarray(itq3_matvec_int8_pallas(*args, **kw, tn=64,
                                             interpret=True))
    np.testing.assert_allclose(got, want, atol=1e-4)


# ---------------------------------------------------------------------------
# Parity: integer path vs float path, both backends, ragged shapes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", FUSED_FMTS)
@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_int_vs_float_parity_formats(rng, fmt, backend):
    """qmatmul(act_quant=True) tracks the float contraction within the
    int8 activation-quantization error on every registered fused format."""
    w = jnp.asarray(rng.standard_t(df=4, size=(512, 320)) * 0.02, jnp.float32)
    x = jnp.asarray(rng.normal(size=(6, 512)), jnp.float32)
    qt = formats.quantize(w, fmt)
    kw = dict(mode="activations", backend=backend, compute_dtype=jnp.float32,
              interpret=True)
    y_float = np.asarray(qmatmul(x, qt, **kw))
    y_int = np.asarray(qmatmul(x, qt, act_quant=True, **kw))
    assert _rel_l2(y_float, y_int) < 5e-2
    # and both track the dequantized oracle
    y0 = np.asarray(jnp.matmul(x, formats.dequantize(qt, jnp.float32)))
    assert _rel_l2(y0, y_int) < 5e-2


@pytest.mark.parametrize("m,n,k", [
    (1, 128, 300),     # decode-shaped matvec dispatch, ragged K -> pad 512
    (4, 192, 576),     # matvec dispatch, ragged K -> pad 768
    (130, 320, 576),   # tiled dispatch, ragged M/N/K vs tiles
    (256, 256, 512),   # tile-aligned
])
def test_act_quant_dispatch_shapes(rng, m, n, k):
    """Backend parity through the public entrypoint: the pallas dispatch
    (matvec for m <= MATVEC_MAX_M, tiled above) matches the ref integer
    contraction on ragged non-multiple-of-256 K."""
    w = jnp.asarray(rng.normal(size=(k, n)) * 0.05, jnp.float32)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    qt = formats.quantize(w, "itq3_s")
    kw = dict(mode="activations", compute_dtype=jnp.float32,
              act_quant=True, interpret=True)
    y_ref = np.asarray(qmatmul(x, qt, backend="ref", **kw))
    y_pal = np.asarray(qmatmul(x, qt, backend="pallas", **kw))
    np.testing.assert_allclose(y_pal, y_ref, atol=2e-3)
    y0 = np.asarray(jnp.matmul(x, formats.dequantize(qt, jnp.float32)))
    assert _rel_l2(y0, y_pal) < 5e-2


# ---------------------------------------------------------------------------
# Satellite (a): ref-path cast traffic — codes stay int8 until the MAC
# ---------------------------------------------------------------------------

def _big_f32_eqns(jaxpr, thresh):
    hits = []

    def walk(j):
        for eqn in j.eqns:
            for sub in jax.core.jaxprs_in_params(eqn.params):
                walk(sub)
            for v in eqn.outvars:
                aval = v.aval
                if (getattr(aval, "dtype", None) == jnp.float32
                        and np.prod(aval.shape, dtype=int) >= thresh):
                    hits.append((eqn.primitive.name, tuple(aval.shape)))

    walk(jaxpr.jaxpr)
    return hits


def test_ref_cast_traffic_budget(rng):
    """The PR 5 leftover, fixed. Integer path: codes stay int8 until the
    MAC — ZERO weight-size f32 tensors anywhere in the jaxpr (the mixed
    f32 x int8 dot converts inside the GEMM). Float path: the exact
    integer zero-point fold removed the decode -> subtract -> correction
    chain, leaving one fused scale-and-cast (convert + mul, a single
    elementwise fusion for XLA) feeding one full-K GEMM — at most two
    weight-size f32 equations, and the self-contained ref oracle
    (kernels/ref.py) also carries none."""
    K, N = 512, 768
    w = jnp.asarray(rng.normal(size=(K, N)) * 0.05, jnp.float32)
    x = jnp.asarray(rng.normal(size=(4, K)), jnp.float32)
    qt = formats.quantize(w, "itq3_s")
    thresh = N * K

    int8_jaxpr = jax.make_jaxpr(lambda a: qmatmul(
        a, qt, mode="activations", backend="ref", act_quant=True,
        compute_dtype=jnp.float32))(x)
    assert _big_f32_eqns(int8_jaxpr, thresh) == []

    oracle_jaxpr = jax.make_jaxpr(lambda a: ref.itq3_matmul_ref(
        a, qt.data["plane2"], qt.data["plane1"], qt.data["scales"],
        qt.data["zps"], rotate_weights=False))(x)
    assert _big_f32_eqns(oracle_jaxpr, thresh) == []

    float_jaxpr = jax.make_jaxpr(lambda a: qmatmul(
        a, qt, mode="activations", backend="ref",
        compute_dtype=jnp.float32))(x)
    hits = _big_f32_eqns(float_jaxpr, thresh)
    assert len(hits) <= 2, hits


# ---------------------------------------------------------------------------
# Policy + meta plumbing
# ---------------------------------------------------------------------------

def test_qmeta_act_quant_backcompat(rng):
    qt = formats.quantize(
        jnp.asarray(rng.normal(size=(256, 64)), jnp.float32), "itq3_s")
    assert qt.meta.act_quant is True  # checkpoints predating the field opt in
    d = qt.meta.to_dict()
    d.pop("act_quant")
    assert QMeta.from_dict(d).act_quant is True


def test_policy_act_quant_opt_out(rng):
    """QuantRule(act_quant=False) pins matching paths to the float
    contraction even when the runtime knob is on — bit-identical to the
    act_quant=False call — while opted-in paths take the integer path."""
    policy = QuantPolicy((
        QuantRule(r"(^|\.)lm_head$", "itq3_s", act_quant=False),
        QuantRule(MATMUL_LEAVES, "itq3_s"),
    ))
    params = {"lm_head": jnp.asarray(rng.normal(size=(256, 64)), jnp.float32),
              "wq": jnp.asarray(rng.normal(size=(256, 64)), jnp.float32)}
    qp = quantize_params(params, policy)
    assert qp["lm_head"].meta.act_quant is False
    assert qp["wq"].meta.act_quant is True
    # round-trips through the policy serialization
    rt = QuantPolicy.from_dict(policy.to_dict())
    assert rt.rules[0].act_quant is False and rt.rules[1].act_quant is None

    x = jnp.asarray(rng.normal(size=(3, 256)), jnp.float32)
    kw = dict(mode="activations", backend="ref", compute_dtype=jnp.float32)
    y_off = np.asarray(qmatmul(x, qp["lm_head"], **kw))
    y_on = np.asarray(qmatmul(x, qp["lm_head"], act_quant=True, **kw))
    assert np.array_equal(y_off, y_on)  # opted out: knob is a no-op
    z_off = np.asarray(qmatmul(x, qp["wq"], **kw))
    z_on = np.asarray(qmatmul(x, qp["wq"], act_quant=True, **kw))
    assert not np.array_equal(z_off, z_on)  # opted in: integer path taken
    assert _rel_l2(z_off, z_on) < 5e-2


def test_autotune_int8_key_family(tmp_path, monkeypatch):
    """int8-path winners live under their own key component; float-path
    entries are untouched and lookups never cross over."""
    from repro.kernels import autotune as at

    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "tune.json"))
    at.clear_memory_cache()
    key = at.record(8, 320, 512, "itq3_s", 16, 64, interpret=True,
                    act_quant=True, us=3.0)
    assert "|int8|" in key
    fkey = at.record(8, 320, 512, "itq3_s", 32, 128, interpret=True, us=5.0)
    assert "int8" not in fkey and key != fkey
    assert at.get_tiles(8, 320, 512, "itq3_s", interpret=True,
                        act_quant=True) == (16, 64)
    assert at.get_tiles(8, 320, 512, "itq3_s", interpret=True) == (32, 128)
    # untuned int8 shape -> deterministic defaults (interpret contract)
    assert at.get_tiles(8, 320, 1024, "itq3_s", interpret=True,
                        act_quant=True) == (at.DEFAULT_TM, at.DEFAULT_TN)
    at.clear_memory_cache()


# ---------------------------------------------------------------------------
# Engine: act_quant=False streams bit-identical to PR 7 HEAD (goldens
# captured on this CPU image before any PR 8 code change), act_quant=True
# passes tolerance-based quality parity, stats() reports the knob.
# ---------------------------------------------------------------------------

GOLDEN_PR7 = {
    ("smollm-135m", "itq3_s", True): [[227, 227, 227, 227, 198, 198],
                                      [227, 227, 227, 227, 51, 51]],
    ("smollm-135m", "itq3_x", False): [[291, 242, 83, 83, 370, 83],
                                       [242, 344, 344, 344, 173, 173]],
    ("zamba2-7b", "itq3_s_sub", True): [[148, 153, 186, 222, 153, 223],
                                        [147, 432, 224, 432, 448, 431]],
}


def _run_engine(arch, fmt, kv_quant, act_quant):
    cfg = reduced(get_config(arch))
    params = quantize_params(lm.init_params(KEY, cfg), fmt)
    rt = Runtime(compute_dtype=jnp.float32, kv_quant=kv_quant,
                 capacity_factor=8.0, act_quant=act_quant)
    eng = ServeEngine(params, cfg, slots=2, max_len=48, rt=rt)
    reqs = [Request(rid=i, prompt=(np.arange(6 + 3 * i) + 1) % cfg.vocab_size,
                    max_new=6) for i in range(2)]
    eng.run(reqs)
    return eng, [list(map(int, r.out)) for r in reqs]


@pytest.mark.parametrize("arch,fmt,kvq", sorted(GOLDEN_PR7, key=str))
def test_engine_streams_bit_identical_to_pr7_head(arch, fmt, kvq):
    eng, streams = _run_engine(arch, fmt, kvq, act_quant=False)
    assert streams == GOLDEN_PR7[(arch, fmt, kvq)]
    assert eng.stats()["act_quant"] is False


def test_engine_act_quant_stream_quality_parity():
    """Greedy streams under the integer path: tolerance-based parity (the
    int8 codec perturbs logits ~1-2% rel L2, so near-total token
    agreement, not bitwise equality, is the contract)."""
    eng, streams = _run_engine("smollm-135m", "itq3_s", True, act_quant=True)
    golden = GOLDEN_PR7[("smollm-135m", "itq3_s", True)]
    agree = sum(a == b for s, g in zip(streams, golden)
                for a, b in zip(s, g))
    total = sum(len(g) for g in golden)
    assert agree >= total - 2, (streams, golden)
    st = eng.stats()
    assert st["act_quant"] is True and "kv_quant" in st and "backend" in st


def test_model_logits_parity_act_quant():
    """Full-model logits under the integer path stay within the measured
    codec error envelope (1.4% smollm / 2.2% zamba on this image)."""
    cfg = reduced(get_config("smollm-135m"))
    params = quantize_params(lm.init_params(KEY, cfg), "itq3_s")
    toks = jnp.asarray((np.arange(24) + 1) % cfg.vocab_size)[None, :]
    outs = {}
    for aq in (False, True):
        rt = Runtime(compute_dtype=jnp.float32, act_quant=aq)
        outs[aq] = np.asarray(lm.forward(params, toks, rt, cfg)[0])
    assert _rel_l2(outs[False], outs[True]) < 6e-2
    agree = np.mean(outs[False].argmax(-1) == outs[True].argmax(-1))
    assert agree >= 0.8
