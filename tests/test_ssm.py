"""SSM blocks: Mamba2 chunked-scan vs stepwise equivalence, RWKV6 state
continuity (prefill-then-decode == one pass)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduced
from repro.models import ssm
from repro.models.layers import Runtime

RT = Runtime(compute_dtype=jnp.float32)
KEY = jax.random.PRNGKey(1)


def test_mamba2_chunked_equals_stepwise():
    cfg = reduced(get_config("zamba2-7b"))
    p = ssm.mamba2_init(KEY, cfg)
    B, T = 2, 9  # not a multiple of chunk => exercises padding
    x = jax.random.normal(KEY, (B, T, cfg.d_model), jnp.float32) * 0.3
    st0 = ssm.mamba2_empty_state(cfg, B)
    y_full, st_full = ssm.mamba2_apply(p, x, RT, cfg, state=st0)
    # stepwise decode
    st = ssm.mamba2_empty_state(cfg, B)
    ys = []
    for t in range(T):
        y, st = ssm.mamba2_apply(p, x[:, t:t+1], RT, cfg, state=st, decode=True)
        ys.append(y)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_full),
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(st["ssm"]), np.asarray(st_full["ssm"]),
                               atol=2e-4)


def test_mamba2_long_chunking(rng):
    """T spanning multiple chunks agrees with single-chunk reference."""
    cfg = reduced(get_config("zamba2-7b"))
    p = ssm.mamba2_init(KEY, cfg)
    B, T = 1, 300  # > CHUNK=128 => 3 chunks
    x = jax.random.normal(KEY, (B, T, cfg.d_model), jnp.float32) * 0.2
    st0 = ssm.mamba2_empty_state(cfg, B)
    y_full, _ = ssm.mamba2_apply(p, x, RT, cfg, state=st0)
    # split into two calls (state carry across call boundary)
    st = ssm.mamba2_empty_state(cfg, B)
    y1, st = ssm.mamba2_apply(p, x[:, :150], RT, cfg, state=st)
    y2, st = ssm.mamba2_apply(p, x[:, 150:], RT, cfg, state=st)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=3e-4)


def test_rwkv6_state_continuity():
    cfg = reduced(get_config("rwkv6-3b"))
    p = ssm.rwkv6_init(KEY, cfg)
    B, T = 2, 10
    x = jax.random.normal(KEY, (B, T, cfg.d_model), jnp.float32) * 0.3
    st0 = ssm.rwkv6_empty_state(cfg, B)
    y_full, st_full = ssm.rwkv6_apply(p, x, RT, cfg, state=st0)
    st = ssm.rwkv6_empty_state(cfg, B)
    y1, st = ssm.rwkv6_apply(p, x[:, :6], RT, cfg, state=st)
    ys = [y1]
    for t in range(6, T):
        y, st = ssm.rwkv6_apply(p, x[:, t:t+1], RT, cfg, state=st, decode=True)
        ys.append(y)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_full), atol=2e-4)
    np.testing.assert_allclose(np.asarray(st["wkv"]), np.asarray(st_full["wkv"]),
                               atol=2e-4)


def test_rwkv6_decay_in_range():
    cfg = reduced(get_config("rwkv6-3b"))
    p = ssm.rwkv6_init(KEY, cfg)
    x = jax.random.normal(KEY, (1, 4, cfg.d_model), jnp.float32)
    # decay w = exp(-exp(...)) must be in (0, 1): probe via state norm decay
    st = ssm.rwkv6_empty_state(cfg, 1)
    _, st1 = ssm.rwkv6_apply(p, x, RT, cfg, state=st)
    assert np.all(np.isfinite(np.asarray(st1["wkv"])))


def test_segsum_stability():
    """all exponentiated quantities <= 0 (DESIGN: stable for any chunk len)."""
    logd = -jnp.abs(jax.random.normal(KEY, (4, 128)))
    seg = ssm._segsum(logd)
    finite = np.asarray(jnp.where(jnp.isfinite(seg), seg, 0.0))
    assert np.all(finite <= 1e-6)
