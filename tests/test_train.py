"""Training substrate: loss goes down, grad-accumulation equivalence,
optimizer math, lr schedule, gradient compression error-feedback."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.data.pipeline import SyntheticCorpus
from repro.models.layers import Runtime
from repro.train import loop as tl, optim

KEY = jax.random.PRNGKey(0)


def test_loss_decreases():
    cfg = reduced(get_config("smollm-135m"))
    rt = Runtime(compute_dtype=jnp.float32)
    step = jax.jit(tl.make_train_step(cfg, rt, warmup=5, total_steps=120,
                                      lr_peak=3e-3))
    state = tl.init_train_state(KEY, cfg)
    corpus = SyntheticCorpus(cfg.vocab_size, seed=3)
    losses = []
    for s in range(120):
        b = corpus.batch(s, 16, 64)
        state, m = step(state, {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(m["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.5, (
        losses[:3], losses[-3:])


def test_grad_accumulation_equivalence():
    cfg = reduced(get_config("qwen1.5-0.5b"))
    rt = Runtime(compute_dtype=jnp.float32)
    s1 = jax.jit(tl.make_train_step(cfg, rt, num_micro=1, total_steps=10))
    s4 = jax.jit(tl.make_train_step(cfg, rt, num_micro=4, total_steps=10))
    state = tl.init_train_state(KEY, cfg)
    corpus = SyntheticCorpus(cfg.vocab_size, seed=1)
    batch = {k: jnp.asarray(v) for k, v in corpus.batch(0, 8, 32).items()}
    st1, m1 = s1(jax.tree.map(jnp.copy, state), batch)
    st4, m4 = s4(jax.tree.map(jnp.copy, state), batch)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-4
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                     st1.params, st4.params)
    assert max(jax.tree.leaves(d)) < 1e-4


def test_adamw_vs_reference():
    params = {"w": jnp.asarray([1.0, -2.0, 3.0], jnp.float32)}
    grads = {"w": jnp.asarray([0.1, 0.2, -0.3], jnp.float32)}
    st = optim.adamw_init(params)
    new_p, st2, gnorm = optim.adamw_update(grads, st, params, lr=1e-2,
                                           weight_decay=0.0, grad_clip=1e9)
    # hand-rolled first step: m=0.1g, v=0.05g^2, mhat=g, vhat=g^2
    g = np.asarray([0.1, 0.2, -0.3])
    want = np.asarray(params["w"]) - 1e-2 * g / (np.abs(g) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, atol=1e-5)
    assert abs(float(gnorm) - np.linalg.norm(g)) < 1e-6


def test_grad_clip():
    params = {"w": jnp.ones((4,), jnp.float32)}
    grads = {"w": jnp.full((4,), 100.0, jnp.float32)}
    st = optim.adamw_init(params)
    _, _, gnorm = optim.adamw_update(grads, st, params, lr=0.0, grad_clip=1.0)
    assert float(gnorm) == 200.0  # reported pre-clip


def test_cosine_lr():
    lr0 = float(optim.cosine_lr(jnp.int32(0), peak=1.0, warmup=10, total=100))
    lr_peak = float(optim.cosine_lr(jnp.int32(10), peak=1.0, warmup=10, total=100))
    lr_end = float(optim.cosine_lr(jnp.int32(100), peak=1.0, warmup=10, total=100))
    assert lr0 == 0.0 and abs(lr_peak - 1.0) < 0.01 and lr_end <= 0.11


def test_compressed_allreduce_error_feedback():
    """int8 + error feedback: mean of quantized exchanges converges to the
    true mean across steps (residual replay)."""
    rng = np.random.default_rng(0)
    g_pods = [rng.normal(size=(64,)).astype(np.float32) for _ in range(2)]
    true_mean = np.mean(g_pods, axis=0)
    errs = [np.zeros(64, np.float32) for _ in range(2)]
    acc = np.zeros(64, np.float64)
    for step in range(8):
        xs = [g + e for g, e in zip(g_pods, errs)]
        amax = max(np.abs(x).max() for x in xs)
        scale = max(amax, 1e-12) / 127.0
        qs = [np.clip(np.round(x / scale), -127, 127) for x in xs]
        deqs = [q * scale for q in qs]
        errs = [x - d for x, d in zip(xs, deqs)]
        out = sum(qs) * scale / 2
        acc += out
    # time-averaged compressed mean ~= true mean (error feedback property)
    np.testing.assert_allclose(acc / 8, true_mean, atol=scale)


SHARDMAP_COMPRESS = """
import jax, jax.numpy as jnp, numpy as np
from repro.train.grad import compressed_pod_allreduce, zeros_error_buf

mesh = jax.make_mesh((2, 4), ("pod", "data"))
rng = np.random.default_rng(0)
g = {"w": jnp.asarray(rng.normal(size=(2, 64)), jnp.float32)}  # per-pod partials
e = {"w": jnp.zeros((2, 64), jnp.float32)}
true_mean = np.mean(np.asarray(g["w"]), axis=0)

with mesh:
    acc = np.zeros(64)
    for step in range(6):
        red, e = jax.jit(lambda a, b: compressed_pod_allreduce(a, b, mesh))(g, e)
        acc += np.asarray(red["w"][0])
    # both pods see identical reduced values
    assert np.allclose(np.asarray(red["w"][0]), np.asarray(red["w"][1]))
    # error feedback: time-average converges to the true mean
    err = np.max(np.abs(acc / 6 - true_mean))
assert err < 0.02, err
print("COMPRESS_OK", err)
"""


def test_compressed_pod_allreduce_shardmap():
    """8-device shard_map execution of the compressed allreduce. The
    historical "hang" here (skip-on-expiry quarantine since PR 3) was never
    the shard_map: the stripped subprocess env dropped JAX_PLATFORMS, so the
    child's ``import jax`` went platform-probing for minutes. With the env
    inherited (tests/_subproc.py) the same test passes in ~1s, so the
    quarantine is gone — a timeout now fails loudly like any regression."""
    from _subproc import run_py
    res = run_py(SHARDMAP_COMPRESS, devices=8, timeout=120)
    assert "COMPRESS_OK" in res.stdout, res.stdout + res.stderr
