"""MoE dispatch: capacity semantics, gate normalization, dropless limit."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduced
from repro.models import moe
from repro.models.layers import Runtime

KEY = jax.random.PRNGKey(0)


def setup(capacity=64.0):
    cfg = reduced(get_config("olmoe-1b-7b"))
    p = moe.moe_init(KEY, cfg.d_model, cfg.d_ff, cfg.num_experts, cfg.activation)
    rt = Runtime(compute_dtype=jnp.float32, capacity_factor=capacity)
    return cfg, p, rt


def test_output_shape_and_aux():
    cfg, p, rt = setup()
    x = jax.random.normal(KEY, (2, 8, cfg.d_model), jnp.float32)
    y, aux = moe.moe_apply(p, x, rt, cfg)
    assert y.shape == x.shape
    assert float(aux) >= 1.0  # Switch aux is >= 1 at init (E * sum ~ 1)


def test_dropless_is_linear_in_gates():
    """With huge capacity, output == sum_k gate_k * expert_k(x) computed
    densely."""
    cfg, p, rt = setup(capacity=64.0)
    x = jax.random.normal(KEY, (1, 4, cfg.d_model), jnp.float32)
    y, _ = moe.moe_apply(p, x, rt, cfg)

    # dense reference
    logits = x @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    g, idx = jax.lax.top_k(probs, cfg.experts_per_token)
    g = g / jnp.sum(g, -1, keepdims=True)
    ref = jnp.zeros_like(x)
    for e in range(cfg.num_experts):
        h = jax.nn.silu(x @ p["gate"][e]) * (x @ p["up"][e])
        o = h @ p["down"][e]
        w = jnp.sum(jnp.where(idx == e, g, 0.0), axis=-1)[..., None]
        ref = ref + w * o
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-4)


def test_capacity_drops_tokens():
    """cap=1 forces drops; output energy strictly below dropless."""
    cfg, p, _ = setup()
    x = jax.random.normal(KEY, (1, 16, cfg.d_model), jnp.float32)
    y_drop, _ = moe.moe_apply(p, x, Runtime(compute_dtype=jnp.float32,
                                            capacity_factor=0.05), cfg)
    y_full, _ = moe.moe_apply(p, x, Runtime(compute_dtype=jnp.float32,
                                            capacity_factor=64.0), cfg)
    assert float(jnp.linalg.norm(y_drop)) < float(jnp.linalg.norm(y_full))
