"""Checkpoints: atomic roundtrip, async, GC, resume, restore-with-sharding."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt


def tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nest": {"b": jnp.ones((4,), jnp.int32)},
            "state": {"step": jnp.int32(7)}}


def test_roundtrip(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 3, tree())
    restored, step = ckpt.restore(d, tree())
    assert step == 3
    assert np.array_equal(restored["a"], tree()["a"])
    assert np.array_equal(restored["nest"]["b"], tree()["nest"]["b"])


def test_async_and_latest(tmp_path):
    d = str(tmp_path)
    th = ckpt.save_async(d, 1, tree())
    th.join()
    ckpt.save(d, 5, tree())
    assert ckpt.latest_step(d) == 5


def test_gc_keeps_last(tmp_path):
    d = str(tmp_path)
    for s in range(6):
        ckpt.save(d, s, tree(), keep=2)
    steps = sorted(int(n[5:]) for n in os.listdir(d) if n.startswith("step_"))
    assert steps == [4, 5]


def test_uncommitted_ignored(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 2, tree())
    # fake a torn save
    os.makedirs(os.path.join(d, "step_00000009"))
    assert ckpt.latest_step(d) == 2


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ckpt.restore(str(tmp_path), tree())


def test_elastic_restore_with_shardings(tmp_path):
    """Restore onto explicit (single-device) shardings — the elastic path."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    d = str(tmp_path)
    ckpt.save(d, 1, tree())
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), tree())
    restored, _ = ckpt.restore(d, tree(), shardings=sh)
    assert restored["a"].sharding == NamedSharding(mesh, P())
    assert np.array_equal(restored["a"], tree()["a"])


def test_dtype_cast_on_restore(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 1, {"w": jnp.ones((2,), jnp.float32)})
    template = {"w": jnp.zeros((2,), jnp.bfloat16)}
    restored, _ = ckpt.restore(d, template)
    assert restored["w"].dtype == jnp.bfloat16
