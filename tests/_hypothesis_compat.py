"""Use hypothesis when installed; otherwise a tiny deterministic fallback.

``hypothesis`` is a declared dev dependency (pyproject.toml), but the
property tests should still *run* — not error at collection — on minimal
environments (e.g. the CPU container that only has jax + numpy). The
fallback drives each ``@given`` test with ``max_examples`` seeded draws, so
the same invariants are exercised, just without shrinking or example
databases.

Only the strategy surface this suite uses is implemented:
``st.integers(lo, hi)`` and ``st.sampled_from(seq)``.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import inspect

    import numpy as np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw  # draw(rng) -> value

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: int(r.integers(min_value, max_value,
                                                      endpoint=True)))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda r: elements[int(r.integers(len(elements)))])

    st = _Strategies()

    def settings(*, max_examples: int = 20, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            # strategies fill the TRAILING parameters (by name, so fixtures
            # pytest passes as keywords can't collide with the draws);
            # expose only the leading params so pytest doesn't look for
            # fixtures named like the drawn ones.
            params = list(inspect.signature(fn).parameters.values())
            drawn = [p.name for p in params[len(params) - len(strategies):]]

            @functools.wraps(fn)
            def runner(*args, **kwargs):
                n = getattr(runner, "_max_examples", 20)
                rng = np.random.default_rng(0)
                for _ in range(n):
                    draws = {nm: s.draw(rng)
                             for nm, s in zip(drawn, strategies)}
                    fn(*args, **kwargs, **draws)

            runner.__signature__ = inspect.Signature(
                params[:len(params) - len(strategies)])
            del runner.__wrapped__  # don't let pytest unwrap to fn
            return runner
        return deco

__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
