"""Format-level properties: Theorem 2 reconstruction bound, rotation
benefit on heavy-tailed weights, bpw accounting, qlinear mode agreement."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import formats, grids, qlinear
from repro.core.quantize import QTensor, to_blocks, from_blocks

TERNARY = ["iq3_s", "quip3", "itq3_s", "itq3_s_sub", "itq3_x"]


def heavy_tailed(rng, k=1024, n=64, scale=0.02):
    return jnp.asarray(rng.standard_t(df=4, size=(k, n)) * scale, jnp.float32)


def test_theorem2_bound(rng):
    """Theorem 2, as stated: |Q_T(x) - x| <= d_k/2 for x *within the
    representable range* (|x - z d| <= 3d/2); outside it the clamp
    dominates — which is exactly why the rotation (making blocks Gaussian
    so the tails are light) is the paper's whole point."""
    from repro.core.fwht import fwht
    from repro.core.quantize import quantize_blocks_ternary, dequantize_blocks_ternary
    w = jnp.asarray(np.random.default_rng(0).standard_t(df=4, size=(64, 256)), jnp.float32)
    data = quantize_blocks_ternary(w, rotate=True, rule="paper")
    wh = dequantize_blocks_ternary(data, rotate=True)
    # rotated-domain elementwise error
    rot_w = np.asarray(fwht(w))
    rot_wh = np.asarray(fwht(wh))
    d = np.asarray(data["scales"], np.float32)[:, None]
    z = np.asarray(data["zps"], np.float32)[:, None]
    err = np.abs(rot_w - rot_wh)
    in_range = np.abs(rot_w - z * d) <= 1.5 * d + 1e-6
    assert in_range.mean() > 0.75  # rotation Gaussianizes: most in range
    assert np.all(err[in_range] <= d.repeat(256, 1)[in_range] / 2 + 1e-4)


def test_isometry_of_error(rng):
    """Theorem 2 core: rotated-domain quant error equals weight-domain error."""
    from repro.core.quantize import quantize_blocks_ternary, dequantize_blocks_ternary
    from repro.core.fwht import fwht
    w = jnp.asarray(rng.normal(size=(8, 256)) * 0.1, jnp.float32)
    data = quantize_blocks_ternary(w, rotate=True)
    wh = dequantize_blocks_ternary(data, rotate=True)
    rot_err = np.asarray(fwht(w) - fwht(wh))
    dom_err = np.asarray(w - wh)
    assert np.allclose(np.linalg.norm(rot_err, axis=-1),
                       np.linalg.norm(dom_err, axis=-1), rtol=1e-4)


def test_rotation_beats_no_rotation_on_heavy_tails(rng):
    w = heavy_tailed(rng)
    errs = {}
    for f in ["iq3_s", "itq3_s"]:
        wh = formats.dequantize(formats.quantize(w, f), jnp.float32)
        errs[f] = float(jnp.mean((wh - w) ** 2))
    assert errs["itq3_s"] < errs["iq3_s"]


def test_quality_ladder(rng):
    w = heavy_tailed(rng)
    errs = {}
    for f in ["q8_0", "q4_0", "itq3_x", "itq3_s_sub", "itq3_s"]:
        wh = formats.dequantize(formats.quantize(w, f), jnp.float32)
        errs[f] = float(jnp.mean((wh - w) ** 2))
    assert errs["q8_0"] < errs["q4_0"] < errs["itq3_x"]
    assert errs["itq3_s_sub"] <= errs["itq3_s"]


def test_lloyd_rule_beats_paper_rule(rng):
    """The documented scale-rule discrepancy, measurably."""
    w = jnp.asarray(rng.normal(size=(2048, 32)) * 0.05, jnp.float32)
    e = {}
    for rule in ("paper", "lloyd"):
        wh = formats.dequantize(formats.quantize(w, "itq3_s", rule=rule), jnp.float32)
        e[rule] = float(jnp.mean((wh - w) ** 2))
    assert e["lloyd"] < e["paper"] * 0.85


def test_bits_per_weight_storage(rng):
    w = heavy_tailed(rng, 1024, 64)
    for f, bpw in [("itq3_s", 3.125), ("itq3_s_sub", 3.625), ("q8_0", 8.5),
                   ("q4_0", 4.5)]:
        qt = formats.quantize(w, f)
        actual = qt.nbytes() * 8 / (1024 * 64)
        assert actual <= bpw + 0.05, (f, actual)


def test_padding_path(rng):
    w = jnp.asarray(rng.normal(size=(576, 48)) * 0.05, jnp.float32)  # smollm dims
    qt = formats.quantize(w, "itq3_s")
    wh = formats.dequantize(qt, jnp.float32)
    assert wh.shape == w.shape
    rel = float(jnp.linalg.norm(wh - w) / jnp.linalg.norm(w))
    assert rel < 0.8


@pytest.mark.parametrize("fmt", TERNARY + ["q8_0", "q4_0", "bf16"])
def test_qlinear_modes_agree(rng, fmt):
    w = heavy_tailed(rng, 512, 96)
    x = jnp.asarray(rng.normal(size=(3, 512)), jnp.float32)
    qt = formats.quantize(w, fmt)
    y0 = qlinear.qmatmul(x, qt, mode="dequant", compute_dtype=jnp.float32)
    for mode in ("weights", "activations"):
        y = qlinear.qmatmul(x, qt, mode=mode, compute_dtype=jnp.float32)
        assert np.allclose(y, y0, atol=2e-3), (fmt, mode)


def test_qtensor_pytree(rng):
    import jax
    qt = formats.quantize(heavy_tailed(rng, 256, 8), "itq3_s")
    leaves, treedef = jax.tree_util.tree_flatten(qt)
    qt2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert qt2.meta == qt.meta


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from(["itq3_s", "itq3_x", "iq3_s"]))
def test_property_roundtrip_error_bounded(seed, fmt):
    r = np.random.default_rng(seed)
    w = jnp.asarray(r.normal(size=(256, 16)) * r.uniform(1e-3, 10), jnp.float32)
    qt = formats.quantize(w, fmt)
    wh = formats.dequantize(qt, jnp.float32)
    rel = float(jnp.linalg.norm(wh - w) / (jnp.linalg.norm(w) + 1e-9))
    assert rel < 1.0  # quantization never increases energy beyond the signal
