"""Planar 3-bit packing (96 B / 256 weights, the paper's storage budget)."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import packing


def test_sizes(rng):
    codes = jnp.asarray(rng.integers(0, 8, size=(256,)), jnp.uint8)
    p2, p1 = packing.pack_codes(codes)
    assert p2.shape == (64,) and p1.shape == (32,)
    assert p2.nbytes + p1.nbytes == 96  # exactly 3 bits/weight


def test_roundtrip_batched(rng):
    codes = jnp.asarray(rng.integers(0, 8, size=(5, 3, 256)), jnp.uint8)
    assert np.array_equal(packing.unpack_codes(*packing.pack_codes(codes)), codes)


def test_nibble_reference_roundtrip(rng):
    codes = jnp.asarray(rng.integers(0, 8, size=(4, 256)), jnp.uint8)
    words = packing.pack_nibbles_reference(codes)
    assert np.array_equal(packing.unpack_nibbles_reference(words), codes)


def test_interleave_layout(rng):
    """byte i of plane2 holds elements {i, 64+i, 128+i, 192+i} (VREG-lane
    interleave, DESIGN.md §2)."""
    codes = np.zeros(256, np.uint8)
    codes[64 + 7] = 3  # element 71 -> byte 7, bit-pair 1
    p2, _ = packing.pack_codes(jnp.asarray(codes))
    assert int(p2[7]) == 3 << 2


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_property_roundtrip(seed):
    r = np.random.default_rng(seed)
    codes = jnp.asarray(r.integers(0, 8, size=(2, 256)), jnp.uint8)
    assert np.array_equal(packing.unpack_codes(*packing.pack_codes(codes)), codes)
    w = packing.pack_nibbles_reference(codes)
    assert np.array_equal(packing.unpack_nibbles_reference(w), codes)
