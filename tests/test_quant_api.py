"""Unified quantization API: format registry, QuantPolicy, backend-dispatching
qmatmul, and QTensor checkpointing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import formats, qlinear
from repro.core.quantize import QMeta, QTensor
from repro.checkpoint import ckpt
from repro.serve.quantized import (
    MATMUL_LEAVES, QuantPolicy, QuantRule, describe_quantized, quantize_params,
)

TERNARY = ["iq3_s", "quip3", "itq3_s", "itq3_s_sub", "itq3_x"]


def heavy_tailed(rng, k=512, n=96, scale=0.02):
    return jnp.asarray(rng.standard_t(df=4, size=(k, n)) * scale, jnp.float32)


# ---------------------------------------------------------------------------
# Format registry
# ---------------------------------------------------------------------------

def test_registry_contents():
    for name in TERNARY + ["fp16", "bf16", "q8_0", "q4_0"]:
        spec = formats.get_format(name)
        assert spec.name == name
        assert spec.supports_fused == (name in TERNARY)
    with pytest.raises(ValueError):
        formats.get_format("no_such_fmt")


def test_register_custom_format(rng):
    """A third-party format plugs in via @register_format and flows through
    quantize/dequantize/qmatmul with zero changes elsewhere."""

    @formats.register_format
    class Demo(formats.TernaryFormat):
        def __init__(self):
            super().__init__("itq3_demo", rotate=True, sub_blocks=4)

    try:
        w = heavy_tailed(rng)
        qt = formats.quantize(w, "itq3_demo")
        assert qt.meta.fmt == "itq3_demo" and qt.meta.sub_blocks == 4
        wh = formats.dequantize(qt, jnp.float32)
        assert wh.shape == w.shape
        x = jnp.asarray(rng.normal(size=(3, 512)), jnp.float32)
        y0 = qlinear.qmatmul(x, qt, mode="dequant", compute_dtype=jnp.float32)
        ya = qlinear.qmatmul(x, qt, mode="activations", backend="ref",
                             compute_dtype=jnp.float32)
        yp = qlinear.qmatmul(x, qt, mode="weights", backend="pallas",
                             interpret=True, tm=8, tn=32,
                             compute_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(ya), np.asarray(y0), atol=2e-3)
        np.testing.assert_allclose(np.asarray(yp), np.asarray(y0), atol=2e-3)
    finally:
        del formats.FORMATS["itq3_demo"]


def test_sub_blocks_override(rng):
    w = heavy_tailed(rng)
    qt = formats.quantize(w, "itq3_s", sub_blocks=4)
    assert qt.meta.sub_blocks == 4
    assert qt.data["scales"].shape[-1] == 4
    wh = formats.dequantize(qt, jnp.float32)
    rel = float(jnp.linalg.norm(wh - w) / jnp.linalg.norm(w))
    assert rel < 0.8


# ---------------------------------------------------------------------------
# Unified qmatmul backend dispatch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", TERNARY)
@pytest.mark.parametrize("mode", ["weights", "activations", "auto"])
def test_backend_parity(rng, fmt, mode):
    """ref and pallas backends agree for every registered ternary format."""
    w = heavy_tailed(rng)
    x = jnp.asarray(rng.normal(size=(6, 512)), jnp.float32)
    qt = formats.quantize(w, fmt)
    yr = qlinear.qmatmul(x, qt, mode=mode, backend="ref",
                         compute_dtype=jnp.float32)
    yp = qlinear.qmatmul(x, qt, mode=mode, backend="pallas", interpret=True,
                         tm=8, tn=32, compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(yp), np.asarray(yr), atol=2e-3)


def test_backend_pallas_falls_back_for_dense_formats(rng):
    """Non-fused formats (and mode="dequant") serve through the ref path even
    under backend="pallas" — one code path for mixed-precision trees."""
    w = heavy_tailed(rng)
    x = jnp.asarray(rng.normal(size=(2, 512)), jnp.float32)
    for fmt in ("q8_0", "bf16"):
        qt = formats.quantize(w, fmt)
        y0 = qlinear.qmatmul(x, qt, mode="dequant", compute_dtype=jnp.float32)
        yp = qlinear.qmatmul(x, qt, mode="activations", backend="pallas",
                             compute_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(yp), np.asarray(y0), atol=1e-5)


def test_backend_validation(rng):
    qt = formats.quantize(heavy_tailed(rng), "itq3_s")
    x = jnp.ones((2, 512), jnp.float32)
    with pytest.raises(ValueError):
        qlinear.qmatmul(x, qt, backend="cuda")


# ---------------------------------------------------------------------------
# QuantPolicy
# ---------------------------------------------------------------------------

def fake_params(rng):
    arr = lambda *s: jnp.asarray(rng.normal(size=s) * 0.05, jnp.float32)
    return {
        "embed": arr(300, 128),
        "layers": {
            "attn": {"wq": arr(128, 128), "wo": arr(128, 128)},
            "mlp": {"gate": arr(128, 256), "up": arr(128, 256),
                    "down": arr(256, 128)},
            "ln1": {"scale": jnp.ones((128,), jnp.float32)},
            "moe": {"router": arr(128, 8)},
        },
        "lm_head": arr(128, 300),
    }


def test_policy_precedence_first_match_wins(rng):
    policy = QuantPolicy((
        (r"(^|\.)lm_head$", "q8_0"),
        (r"(^|\.)(gate|up|down)$", "itq3_s_sub"),
        (MATMUL_LEAVES, "itq3_s"),
    ))
    q = quantize_params(fake_params(rng), policy)
    got = describe_quantized(q)
    assert got["lm_head"] == "q8_0"
    assert got["layers.mlp.down"] == "itq3_s_sub"
    assert got["layers.attn.wq"] == "itq3_s"
    # safety rails: norms / tiny router / unmatched embed stay fp
    assert "layers.ln1.scale" not in got
    assert "layers.moe.router" not in got
    assert "embed" not in got


def test_policy_none_fmt_pins_fp(rng):
    policy = QuantPolicy((
        (r"(^|\.)wq$", None),  # explicit fp pin beats the catch-all below
        (MATMUL_LEAVES, "itq3_s"),
    ))
    got = describe_quantized(quantize_params(fake_params(rng), policy))
    assert "layers.attn.wq" not in got
    assert got["layers.attn.wo"] == "itq3_s"


def test_policy_full_path_rules(rng):
    """Rules see the whole dotted path, not just the leaf name."""
    policy = QuantPolicy((
        (r"^layers\.mlp\.", "itq3_x"),
        (MATMUL_LEAVES, "itq3_s"),
    ))
    got = describe_quantized(quantize_params(fake_params(rng), policy))
    assert got["layers.mlp.up"] == "itq3_x"
    assert got["layers.attn.wq"] == "itq3_s"


def test_policy_per_rule_overrides(rng):
    policy = QuantPolicy(
        (QuantRule(r"(^|\.)wq$", "itq3_s", rule="lloyd", seed=7, sub_blocks=4),
         QuantRule(MATMUL_LEAVES, "itq3_s")),
        rule="paper")
    q = quantize_params(fake_params(rng), policy)
    wq = q["layers"]["attn"]["wq"]
    assert wq.meta.rule == "lloyd" and wq.meta.sub_blocks == 4
    assert q["layers"]["attn"]["wo"].meta.rule == "paper"
    assert q["layers"]["attn"]["wo"].meta.sub_blocks == 0


def test_policy_embed_rule_quantizes_transposed(rng):
    policy = QuantPolicy(((r"(^|\.)embed$", "q8_0"),))
    q = quantize_params(fake_params(rng), policy)
    assert isinstance(q["embed"], QTensor)
    assert q["embed"].meta.shape == (128, 300)  # stored (D, V) for tied head


def test_policy_dict_roundtrip():
    policy = QuantPolicy(
        (QuantRule(r"(^|\.)lm_head$", "q8_0"),
         QuantRule(r"(^|\.)wq$", None),
         QuantRule(MATMUL_LEAVES, "itq3_s", rule="lloyd", sub_blocks=8)),
        rule="paper", seed=3)
    d = policy.to_dict()
    import json
    assert QuantPolicy.from_dict(json.loads(json.dumps(d))) == policy


def test_policy_rejects_unknown_format():
    with pytest.raises(ValueError):
        QuantRule("wq$", "nope_fmt")


def test_policy_rejects_sub_blocks_on_dense_format():
    with pytest.raises(ValueError):
        QuantRule("wq$", "q8_0", sub_blocks=4)


def test_policy_accepts_tuple_and_dict_rules(rng):
    a = QuantPolicy(((r"(^|\.)wq$", "q8_0"),))
    b = QuantPolicy(({"pattern": r"(^|\.)wq$", "fmt": "q8_0"},))
    assert a == b
    got = describe_quantized(quantize_params(fake_params(rng), b))
    assert got == {"layers.attn.wq": "q8_0"}


def test_uniform_policy_matches_legacy_call(rng):
    params = fake_params(rng)
    a = describe_quantized(quantize_params(params, "itq3_s"))
    b = describe_quantized(
        quantize_params(params, QuantPolicy.uniform("itq3_s")))
    assert a == b and "layers.attn.wq" in a


# ---------------------------------------------------------------------------
# QTensor checkpointing
# ---------------------------------------------------------------------------

def test_ckpt_qtensor_roundtrip(tmp_path, rng):
    d = str(tmp_path)
    w = heavy_tailed(rng)
    tree = {"layer": {"wq": formats.quantize(w, "itq3_s_sub"),
                      "scale": jnp.ones((4,), jnp.float32)}}
    ckpt.save(d, 1, tree)
    restored, step = ckpt.restore(d, tree)
    assert step == 1
    qt0, qt1 = tree["layer"]["wq"], restored["layer"]["wq"]
    assert qt1.meta == qt0.meta
    for k in qt0.data:
        np.testing.assert_array_equal(np.asarray(qt1.data[k]),
                                      np.asarray(qt0.data[k]))


def test_ckpt_restore_qtensor_into_fp_template(tmp_path, rng):
    """The serve-from-disk path: the template holds fp weights, the
    checkpoint holds packed planes — restore yields the quantized tree."""
    d = str(tmp_path)
    w = heavy_tailed(rng)
    ckpt.save(d, 0, {"wq": formats.quantize(w, "itq3_s")})
    restored, _ = ckpt.restore(d, {"wq": w})
    assert isinstance(restored["wq"], QTensor)
    np.testing.assert_array_equal(
        np.asarray(restored["wq"].data["plane2"]),
        np.asarray(formats.quantize(w, "itq3_s").data["plane2"]))


def test_ckpt_restore_tree_without_template(tmp_path, rng):
    d = str(tmp_path)
    tree = {"a": {"b": formats.quantize(heavy_tailed(rng), "itq3_x"),
                  "c": jnp.arange(4, dtype=jnp.int32)}}
    ckpt.save(d, 2, tree)
    restored, step = ckpt.restore_tree(d)
    assert step == 2
    assert isinstance(restored["a"]["b"], QTensor)
    assert restored["a"]["b"].meta == tree["a"]["b"].meta
    np.testing.assert_array_equal(restored["a"]["c"], np.arange(4))


def test_ckpt_restore_shardings_align_past_qtensor(tmp_path, rng):
    """Shardings stay paired with their template leaves even when an
    earlier leaf is a QTensor (whose data dict spans several arrays)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    d = str(tmp_path)
    tree = {"a_q": formats.quantize(heavy_tailed(rng), "itq3_s"),
            "b": jnp.arange(6, dtype=jnp.float32)}
    ckpt.save(d, 1, tree)
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
    restored, _ = ckpt.restore(d, tree, shardings=sh)
    assert isinstance(restored["a_q"], QTensor)
    assert restored["b"].sharding == NamedSharding(mesh, P())
    np.testing.assert_array_equal(np.asarray(restored["b"]), np.arange(6))
    # the QTensor's packed planes land in the prescribed layout too
    for arr in restored["a_q"].data.values():
        assert arr.sharding == NamedSharding(mesh, P())


def test_ckpt_async_with_qtensors(tmp_path, rng):
    d = str(tmp_path)
    tree = {"wq": formats.quantize(heavy_tailed(rng), "quip3")}
    ckpt.save_async(d, 4, tree).join()
    restored, _ = ckpt.restore_tree(d)
    assert restored["wq"].meta == tree["wq"].meta
    np.testing.assert_array_equal(np.asarray(restored["wq"].data["dsign"]),
                                  np.asarray(tree["wq"].data["dsign"]))


# ---------------------------------------------------------------------------
# End to end: policy -> checkpoint -> ServeEngine, bit-identical logits
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_e2e_policy_ckpt_serve_identical(tmp_path):
    from repro.configs.base import get_config, mixed_precision_recipe, reduced
    from repro.models import lm
    from repro.models.layers import Runtime
    from repro.serve.engine import Request, ServeEngine

    cfg = reduced(get_config("smollm-135m"))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    policy = QuantPolicy.from_dict(mixed_precision_recipe(cfg))
    q = quantize_params(params, policy)
    fmts = set(describe_quantized(q).values())
    assert {"q8_0", "itq3_s_sub", "itq3_s"} <= fmts

    d = str(tmp_path)
    ckpt.save(d, 0, q)

    rt = Runtime(compute_dtype=jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
    l_live, _, _ = lm.forward(q, toks, rt, cfg)
    restored, _ = ckpt.restore_tree(d)
    l_disk, _, _ = lm.forward(restored, toks, rt, cfg)
    assert bool(jnp.all(l_live == l_disk))  # bit-identical logits

    mk = lambda: [Request(rid=i, prompt=np.arange(4 + i) % cfg.vocab_size,
                          max_new=4) for i in range(3)]
    out_live = [r.out for r in
                ServeEngine(q, cfg, slots=2, max_len=32, rt=rt).run(mk())]
    out_disk = [r.out for r in
                ServeEngine.from_checkpoint(d, cfg, slots=2, max_len=32,
                                            rt=rt).run(mk())]
    assert out_live == out_disk
