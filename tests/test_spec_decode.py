"""Speculative decoding: the propose/verify/commit refactor of the decode
tick, judged against the committed pre-refactor goldens
(tests/goldens/spec_decode_streams.json, captured on the one-token engine
BEFORE speculation existed).

The contract, per layout (dense fp32, dense rotated-int8, paged pool):

* spec OFF  -> streams byte-identical to the goldens (the refactor is a
  structural no-op when no draft model is configured);
* spec ON, greedy slots -> committed streams byte-identical to the SAME
  goldens (lossless verification: acceptance only reorders work, never
  tokens);
* spec ON, per-request opt-out (``draft=False`` / ``draft_tokens=0``)
  -> byte-identical for EVERY request, sampled ones included (the kvec=0
  window reuses the non-speculative PRNG stream);
* paged runs drain the block pool to zero with allocator invariants
  intact (no leaked lookahead blocks).
"""
import dataclasses
import importlib.util
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.models import lm
from repro.models.layers import Runtime
from repro.serve import spec
from repro.serve.engine import Request, ServeEngine
from repro.serve.faults import Fault, FaultPlan
from repro.serve.paged import blocks_needed
from repro.serve.sampling import (
    FINISH_CANCELLED, FINISH_DEADLINE, FINISH_LENGTH, FINISH_REASONS,
    FINISH_STOP, SamplingParams,
)

KEY = jax.random.PRNGKey(0)
_GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "goldens")

# The three layouts the goldens were captured with — engine kwargs must
# match tests/goldens/capture_spec_goldens.py exactly for bit-identity.
LAYOUTS = {
    "dense_fp": dict(rt=Runtime(compute_dtype=jnp.float32)),
    "dense_q8": dict(rt=Runtime(compute_dtype=jnp.float32, kv_quant=True)),
    "paged_q8": dict(rt=Runtime(compute_dtype=jnp.float32, kv_quant=True),
                     paged=True, block_size=16),
}
GREEDY_RIDS = [str(i) for i in range(7)] + ["203"]   # 203: greedy + stop
SAMPLED_RIDS = ["200", "201", "202"]


def _load_golden_module():
    s = importlib.util.spec_from_file_location(
        "capture_spec_goldens",
        os.path.join(_GOLDEN_DIR, "capture_spec_goldens.py"))
    mod = importlib.util.module_from_spec(s)
    s.loader.exec_module(mod)
    return mod


golden_requests = _load_golden_module().golden_requests

with open(os.path.join(_GOLDEN_DIR, "spec_decode_streams.json")) as _f:
    GOLDENS = json.load(_f)


@pytest.fixture(scope="module")
def model():
    cfg = reduced(get_config("smollm-135m"))
    return cfg, lm.init_params(KEY, cfg)


@pytest.fixture(scope="module")
def draft(model):
    cfg, params = model
    return spec.draft_from_params(params, cfg, 1)


def _engine(model, layout, **kw):
    cfg, params = model
    base = dict(LAYOUTS[layout])
    base.update(kw)
    return ServeEngine(params, cfg, slots=4, max_len=64, prompt_pad=16,
                      **base)


def _streams(done):
    return {str(r.rid): [int(t) for t in r.out] for r in done}


def _check_drained(eng):
    if eng.paged:
        assert eng.pool.used() == 0, "leaked pool blocks after drain"
        eng.pool.check(eng._table)


# ---------------------------------------------------------------------------
# Parity vs the pre-refactor goldens
# ---------------------------------------------------------------------------

@pytest.mark.timeout(600)
@pytest.mark.parametrize("layout", sorted(LAYOUTS))
def test_spec_off_byte_identical_to_goldens(model, layout):
    """No draft model configured: the refactored engine must reproduce the
    pre-refactor goldens byte-for-byte — every rid, sampled included."""
    cfg, _ = model
    eng = _engine(model, layout)
    got = _streams(eng.run(golden_requests(cfg.vocab_size)))
    assert got == GOLDENS[layout]
    assert not eng.stats().get("speculative", False)
    _check_drained(eng)


@pytest.mark.timeout(600)
@pytest.mark.parametrize("layout", sorted(LAYOUTS))
def test_greedy_spec_bit_identical_lossless(model, draft, layout):
    """Greedy speculative streams equal the non-speculative goldens
    regardless of draft quality (here: a 1-layer self-draft whose
    proposals are mostly wrong). Sampled slots use a different PRNG
    stream by design — checked for sanity, not parity."""
    cfg, _ = model
    dparams, dcfg = draft
    eng = _engine(model, layout, draft_params=dparams, draft_cfg=dcfg,
                  num_draft_tokens=4)
    got = _streams(eng.run(golden_requests(cfg.vocab_size)))
    for rid in GREEDY_RIDS:
        assert got[rid] == GOLDENS[layout][rid], (
            f"greedy rid {rid} diverged under speculation ({layout})")
    for rid in SAMPLED_RIDS:
        want = GOLDENS[layout][rid]
        assert len(got[rid]) == len(want)  # same max_new budget honored
        assert all(0 <= t < cfg.vocab_size for t in got[rid])
    st = eng.stats()
    assert st["speculative"] and st["spec_steps"] >= 1
    assert st["draft_proposed"] > 0
    # one transfer per window + one per admission wave, nothing else
    assert st["decode_steps"] < st["host_syncs"] <= st["decode_steps"] + 11
    assert st["cache_donated"]
    _check_drained(eng)


@pytest.mark.timeout(600)
@pytest.mark.parametrize("optout", ["draft", "draft_tokens"])
def test_spec_optout_bitwise_for_all_rids(model, draft, optout):
    """draft=False (or draft_tokens=0) routes a slot through the kvec=0
    window: one token per step on the natural PRNG stream — bit-identical
    to the non-speculative engine for sampled requests too."""
    cfg, _ = model
    dparams, dcfg = draft
    eng = _engine(model, "dense_q8", draft_params=dparams, draft_cfg=dcfg,
                  num_draft_tokens=4)
    off = (dict(draft=False) if optout == "draft"
           else dict(draft_tokens=0))
    reqs = []
    for r in golden_requests(cfg.vocab_size):
        sp = (dataclasses.replace(r.sampling, **off) if r.sampling
              else SamplingParams(**off))
        reqs.append(Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new,
                            sampling=sp))
    got = _streams(eng.run(reqs))
    assert got == GOLDENS["dense_q8"]
    assert eng.stats()["draft_accepted"] == 0  # nothing was ever proposed


@pytest.mark.timeout(600)
def test_perfect_draft_full_acceptance_accounting(model):
    """A full-depth self-draft is the target model: every greedy proposal
    verifies, so acceptance is exactly 100% and each window commits K+1
    tokens (modulo stream-end truncation). Pins the accounting split
    between engine stats and per-request stats."""
    cfg, params = model
    dparams, dcfg = spec.draft_from_params(params, cfg, cfg.num_layers)
    k = 4
    eng = _engine(model, "dense_q8", draft_params=dparams, draft_cfg=dcfg,
                  num_draft_tokens=k)
    reqs = [Request(rid=i, prompt=(np.arange(5 + 3 * i) % cfg.vocab_size
                                   ).astype(np.int32), max_new=12)
            for i in range(3)]
    done = eng.run(reqs)
    st = eng.stats()
    assert st["acceptance_rate"] == pytest.approx(1.0)
    assert st["draft_accepted"] == st["draft_proposed"] > 0
    assert st["tokens_per_step"] > 2.0
    # with everything accepted each slot needs ceil(12 / (k+1)) windows
    assert all(r.spec_windows == -(-r.max_new // (k + 1)) for r in reqs)
    for r in done:
        assert r.finish_reason == FINISH_LENGTH
        rs = r.stats()
        assert rs["draft_accepted"] == rs["draft_proposed"] == r.drafted
        assert rs["acceptance_rate"] == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# Chaos mid-window: cancel / deadline / preempt
# ---------------------------------------------------------------------------

@pytest.mark.timeout(600)
def test_chaos_midwindow_cancel_preempt_deadline(model, draft):
    """Faults landing between speculative windows on a PAGED spec engine:
    a cancel, a forced preempt (with later resume), and a decode-timeout
    expiry. Every request ends in exactly one terminal StreamEvent, event
    indices stay dense per rid, and the pool drains with no block leaked
    by the lookahead allocation."""
    cfg, _ = model
    dparams, dcfg = draft
    plan = FaultPlan([Fault("cancel", step=3, rid=0),
                      Fault("preempt", step=4, rid=1)])
    reqs = [Request(rid=0, prompt=np.arange(6, dtype=np.int32), max_new=30),
            Request(rid=1, prompt=np.arange(9, dtype=np.int32), max_new=20),
            Request(rid=2, prompt=np.arange(4, dtype=np.int32), max_new=20,
                    decode_timeout_ms=0.0),
            Request(rid=3, prompt=np.arange(7, dtype=np.int32), max_new=6)]
    eng = _engine(model, "paged_q8", draft_params=dparams, draft_cfg=dcfg,
                  num_draft_tokens=4, faults=plan)
    events = list(eng.generate(reqs))
    assert reqs[0].finish_reason == FINISH_CANCELLED
    assert reqs[1].finish_reason == FINISH_LENGTH and reqs[1].preemptions >= 1
    assert reqs[2].finish_reason == FINISH_DEADLINE
    assert 1 <= len(reqs[2].out) < reqs[2].max_new
    assert reqs[3].finish_reason in (FINISH_LENGTH, FINISH_STOP)
    for r in reqs:
        assert r.finish_reason in FINISH_REASONS
        term = [e for e in events if e.rid == r.rid and e.finished]
        assert len(term) == 1, f"rid {r.rid}: {len(term)} terminal events"
        idx = [e.index for e in events if e.rid == r.rid]
        assert idx == sorted(set(idx)), f"rid {r.rid} event indices not dense"
    assert len({(e.rid, e.index) for e in events}) == len(events)
    assert all(r is None for r in eng.active)
    assert (eng._slot_draft_k == 0).all()
    _check_drained(eng)


# ---------------------------------------------------------------------------
# Paged lookahead sizing
# ---------------------------------------------------------------------------

def test_blocks_needed_lookahead():
    assert blocks_needed(0, 16) == 1
    assert blocks_needed(15, 16) == 1
    assert blocks_needed(16, 16) == 2
    # a K=4 window starting at pos 13 can commit through pos 17: 2 blocks
    assert blocks_needed(13, 16, lookahead=4) == 2
    assert blocks_needed(11, 16, lookahead=4) == 1
    assert blocks_needed(31, 16, lookahead=1) == 3


@pytest.mark.timeout(600)
def test_paged_tiny_pool_spec_preempts_and_stays_lossless(model, draft):
    """A starved pool must preempt/resume around speculative windows and
    still commit greedy streams identical to the goldens."""
    cfg, _ = model
    dparams, dcfg = draft
    eng = _engine(model, "paged_q8", num_blocks=8, draft_params=dparams,
                  draft_cfg=dcfg, num_draft_tokens=4)
    got = _streams(eng.run(golden_requests(cfg.vocab_size)))
    for rid in GREEDY_RIDS:
        assert got[rid] == GOLDENS["paged_q8"][rid]
    _check_drained(eng)


# ---------------------------------------------------------------------------
# Constructor gates
# ---------------------------------------------------------------------------

def test_spec_constructor_validation(model, draft):
    cfg, params = model
    dparams, dcfg = draft
    with pytest.raises(ValueError, match="draft_cfg"):
        ServeEngine(params, cfg, slots=2, max_len=48,
                    rt=Runtime(compute_dtype=jnp.float32),
                    draft_params=dparams)
    with pytest.raises(ValueError, match="sample_on_host"):
        ServeEngine(params, cfg, slots=2, max_len=48,
                    rt=Runtime(compute_dtype=jnp.float32),
                    draft_params=dparams, draft_cfg=dcfg,
                    sample_on_host=True)
    with pytest.raises(ValueError, match="num_draft_tokens"):
        ServeEngine(params, cfg, slots=2, max_len=48,
                    rt=Runtime(compute_dtype=jnp.float32),
                    draft_params=dparams, draft_cfg=dcfg,
                    num_draft_tokens=0)
    with pytest.raises(ValueError, match="vocab"):
        ServeEngine(params, cfg, slots=2, max_len=48,
                    rt=Runtime(compute_dtype=jnp.float32),
                    draft_params=dparams,
                    draft_cfg=dataclasses.replace(
                        dcfg, vocab_size=cfg.vocab_size + 1))
    with pytest.raises(ValueError, match="famil"):
        ServeEngine(params, cfg, slots=2, max_len=48,
                    rt=Runtime(compute_dtype=jnp.float32),
                    draft_params=dparams,
                    draft_cfg=dataclasses.replace(dcfg, family="ssm"))


def test_draft_from_params_gates(model):
    cfg, params = model
    with pytest.raises(ValueError, match="depth"):
        spec.draft_from_params(params, cfg, cfg.num_layers + 1)
    with pytest.raises(ValueError, match="famil"):
        spec.draft_from_params(params, dataclasses.replace(cfg,
                                                           family="ssm"), 1)
    dparams, dcfg = spec.draft_from_params(params, cfg, 1)
    assert dcfg.num_layers == 1
    # embedding / head leaves shared by reference, layers sliced
    assert dparams["embed"] is params["embed"]
    lead = jax.tree.leaves(dparams["layers"])[0]
    assert lead.shape[0] == 1


def test_sampling_params_spec_knob_validation():
    with pytest.raises(ValueError, match="draft_tokens"):
        SamplingParams(draft_tokens=-1)
    sp = SamplingParams(draft=False)
    assert sp.draft is False and sp.draft_tokens is None
