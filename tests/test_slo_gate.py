"""Serving-SLO regression gate: the helper that fails CI when a fresh
bench run's scheduler lifecycle numbers (TTFT / queue wait / tok_s)
regress beyond tolerance against the committed BENCH_serve.json."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.common import (
    BENCH_SCHEMA, CALIBRATION_RECORD, assert_no_slo_regression,
    calibration_ratio, calibration_wall_ms, slo_regressions,
)
from benchmarks.serve_bench import _run_scheduler
from repro.configs.base import get_config, reduced
from repro.models import lm
from repro.models.layers import Runtime


def _rec(name, **metrics):
    return {"name": name, "metrics": metrics}


def _sched(name, ttft=100.0, wait=50.0, tok_s=1000.0):
    return _rec(name, policy=name.split("_")[-1], ttft_ms=ttft,
                queue_wait_ms=wait, tok_s=tok_s, tokens=192)


COMMITTED = [_sched("serve/sched_fifo"),
             _sched("serve/sched_priority", ttft=120.0),
             _rec("serve/cache_donation", donated=True, bytes_moved=0,
                  decode_steps=50)]


def test_gate_passes_within_tolerance():
    fresh = [_sched("serve/sched_fifo", ttft=150.0, wait=80.0, tok_s=700.0)]
    assert slo_regressions(COMMITTED, fresh, max_ratio=2.0) == []


def test_gate_flags_each_slo_metric_with_its_sense():
    # ttft/queue_wait regress UP, tok_s regresses DOWN — and an
    # IMPROVEMENT in any of them never trips the gate
    fresh = [_sched("serve/sched_fifo", ttft=500.0, wait=10.0, tok_s=5000.0)]
    probs = slo_regressions(COMMITTED, fresh, max_ratio=2.0)
    assert len(probs) == 1 and "ttft_ms" in probs[0] \
        and "serve/sched_fifo" in probs[0]
    fresh = [_sched("serve/sched_fifo", tok_s=100.0)]
    probs = slo_regressions(COMMITTED, fresh, max_ratio=2.0)
    assert len(probs) == 1 and "tok_s" in probs[0]
    fresh = [_sched("serve/sched_fifo", wait=500.0)]
    assert any("queue_wait_ms" in p
               for p in slo_regressions(COMMITTED, fresh, max_ratio=2.0))


def test_gate_only_compares_sched_records_and_shared_names():
    # non-sched records and names absent from one side are ignored...
    fresh = [_sched("serve/sched_sjf", ttft=9e9),
             _rec("serve/cache_donation", donated=False, bytes_moved=1e12,
                  decode_steps=1)]
    assert slo_regressions(COMMITTED, fresh, max_ratio=2.0) == []
    # ...unless require_all, where a DROPPED committed record is itself
    # a regression (a silently deleted policy must not pass the gate)
    probs = slo_regressions(COMMITTED, fresh, max_ratio=2.0,
                            require_all=True)
    assert sorted("fifo" in p or "priority" in p for p in probs) == [
        True, True]


def test_gate_skips_non_numeric_and_missing_metrics():
    fresh = [_rec("serve/sched_fifo", policy="fifo", ttft_ms="broken",
                  queue_wait_ms=None, tokens=192)]
    assert slo_regressions(COMMITTED, fresh, max_ratio=2.0) == []


def _committed_doc(tmp_path, records):
    doc = {"schema": BENCH_SCHEMA, "suite": "serve", "smoke": False,
           "device": "cpu", "records": records}
    p = tmp_path / "BENCH_serve.json"
    p.write_text(json.dumps(doc))
    return p


def test_assert_no_slo_regression_env_tolerance(tmp_path, monkeypatch):
    p = _committed_doc(tmp_path, COMMITTED)
    bad = [_sched("serve/sched_fifo", ttft=500.0)]
    with pytest.raises(AssertionError, match="ttft_ms"):
        assert_no_slo_regression(p, bad, max_ratio=2.0)
    # env knob loosens the gate (known machine mismatch escape hatch)
    monkeypatch.setenv("SERVE_SLO_MAX_RATIO", "10.0")
    assert_no_slo_regression(p, bad)  # 5x worse < 10x tolerance


def test_calibration_ratio_and_fallback():
    # both stamps present -> fresh/committed slowdown; either missing -> 1
    old = COMMITTED + [_rec(CALIBRATION_RECORD, wall_ms=10.0)]
    new = [_rec(CALIBRATION_RECORD, wall_ms=30.0)]
    assert calibration_ratio(old, new) == pytest.approx(3.0)
    assert calibration_ratio(COMMITTED, new) == 1.0
    assert calibration_ratio(old, []) == 1.0
    # non-numeric / nonpositive stamps are ignored, not crashed on
    assert calibration_ratio(
        old, [_rec(CALIBRATION_RECORD, wall_ms=0.0)]) == 1.0


def test_calibration_widens_gate_on_slower_machine(tmp_path):
    """A 3x-slower checker gets 3x more wall-clock headroom; a FASTER
    checker keeps the raw tolerance (speed never hides a regression)."""
    old = COMMITTED + [_rec(CALIBRATION_RECORD, wall_ms=10.0)]
    p = _committed_doc(tmp_path, old)
    # 2.5x-worse ttft: trips the raw 2x gate, passes once the machine is
    # measured to be 3x slower (effective tolerance 6x)
    slow = [_sched("serve/sched_fifo", ttft=250.0),
            _rec(CALIBRATION_RECORD, wall_ms=30.0)]
    assert_no_slo_regression(p, slow, max_ratio=2.0)
    # same metrics from an EQUAL-speed machine: still a regression
    same = [_sched("serve/sched_fifo", ttft=250.0),
            _rec(CALIBRATION_RECORD, wall_ms=10.0)]
    with pytest.raises(AssertionError, match="ttft_ms"):
        assert_no_slo_regression(p, same, max_ratio=2.0)
    # a 10x FASTER machine does not shrink the tolerance below max_ratio
    fast = [_sched("serve/sched_fifo", ttft=150.0),
            _rec(CALIBRATION_RECORD, wall_ms=1.0)]
    assert_no_slo_regression(p, fast, max_ratio=2.0)


def test_calibration_workload_is_measurable():
    w = calibration_wall_ms(iters=2)
    assert 0 < w < 60_000


def test_assert_no_slo_regression_refuses_smoke_committed(tmp_path):
    doc = {"schema": BENCH_SCHEMA, "suite": "serve", "smoke": True,
           "device": "cpu", "records": COMMITTED}
    p = tmp_path / "BENCH_serve.json"
    p.write_text(json.dumps(doc))
    with pytest.raises(ValueError, match="smoke"):
        assert_no_slo_regression(p, COMMITTED, max_ratio=2.0)


@pytest.mark.timeout(300)
def test_live_mini_run_aligns_with_committed_trajectory():
    """End-to-end plumbing check: a tiny fifo run produces a record whose
    name and metric keys line up with the committed trajectory, and the
    gate runs over the REAL file. The tolerance is huge — this guards the
    gate's wiring (renamed metrics, dropped records), not wall-clock."""
    from benchmarks.common import load_and_validate, repo_root
    committed = repo_root() / "BENCH_serve.json"
    if not committed.exists():
        pytest.skip("no committed serve trajectory")
    cfg = reduced(get_config("smollm-135m"))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    r = _run_scheduler(params, cfg, policy="fifo", slots=2, n_requests=4,
                       max_new=4, max_len=48)
    fresh = [{"name": "serve/sched_fifo",
              "metrics": {"policy": "fifo", "ttft_ms": r["ttft_ms"],
                          "queue_wait_ms": r["queue_wait_ms"],
                          "tok_s": r["tok_s"], "tokens": r["tokens"]}}]
    doc = load_and_validate(committed, forbid_smoke=True)
    names = {rec["name"] for rec in doc["records"]}
    assert "serve/sched_fifo" in names  # the record the gate anchors on
    # a mini CPU run differs from the committed full run by workload size
    # and machine — gate with a plumbing-only tolerance
    assert_no_slo_regression(committed, fresh, max_ratio=1e6)
