"""Sharding rules + multi-device execution (subprocess with 8 host devices;
this process keeps seeing 1 device per the dry-run isolation rule)."""
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from _subproc import run_py

from repro.configs.base import get_config
from repro.launch.mesh import make_host_mesh
from repro.sharding import rules as R


def test_adaptive_kv_rules():
    mesh = make_host_mesh(1, 1)  # axis sizes 1: divisibility trivially true
    cfg = get_config("nemotron-4-15b")
    r = R.make_rules(mesh, cfg)
    assert r.assignments["batch"] in ("data", ("data",), None)


def test_rules_on_fake_mesh():
    """Check the adaptive choices against the production-mesh sizes without
    building the mesh (pure dict math)."""
    import dataclasses
    from unittest import mock
    cfg = get_config("nemotron-4-15b")  # kv=8 not divisible by 16

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")
    r = R.make_rules(FakeMesh(), cfg)
    assert r.assignments["kv_heads"] is None
    assert r.assignments["kv_seq"] == "model"  # flash-decode fallback
    assert r.assignments["ffn"] == "model"

    cfg2 = get_config("olmoe-1b-7b")  # kv=16 divisible
    r2 = R.make_rules(FakeMesh(), cfg2)
    assert r2.assignments["kv_heads"] == "model"
    assert r2.assignments["kv_seq"] is None
    assert r2.assignments["experts"] == "model"

    cfg3 = get_config("smollm-135m")  # kv 3: seq-sharded KV fallback
    r3 = R.make_rules(FakeMesh(), cfg3)
    # heads shard by the flat H*HD projection width (9*64=576 % 16 == 0)
    assert r3.assignments["heads"] == "model"
    assert r3.assignments["kv_seq"] == "model"
    assert r3.assignments["ffn"] == "model"  # 1536 % 16 == 0


def test_param_pspecs_cover_tree():
    class FakeMesh:
        shape = {"data": 4, "model": 2}
        axis_names = ("data", "model")
    import jax.numpy as jnp
    from repro.models import lm
    cfg = get_config("smollm-135m")
    import functools
    sds = jax.eval_shape(functools.partial(lm.init_params, cfg=cfg),
                         jax.random.PRNGKey(0))
    r = R.make_rules(FakeMesh(), cfg)
    specs = R.param_pspecs(sds, cfg, r)
    flat_s = jax.tree_util.tree_leaves(specs, is_leaf=lambda x: isinstance(x, P))
    flat_l = jax.tree_util.tree_leaves(sds)
    assert len(flat_s) == len(flat_l)
    for leaf, spec in zip(flat_l, flat_s):
        assert isinstance(spec, P)
        # every sharded dim must divide
        for dim, ax in enumerate(spec):
            if ax is None:
                continue
            size = {"data": 4, "model": 2}[ax if isinstance(ax, str) else ax[0]]
            assert leaf.shape[dim] % size == 0, (leaf.shape, spec)


MULTIDEV_SCRIPT = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import get_config, reduced
    from repro.launch.train import build_trainer
    from repro.train import loop as tl
    from repro.data.pipeline import SyntheticCorpus

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = reduced(get_config("qwen1.5-0.5b"))
    jitted, shardings, rules = build_trainer(cfg, mesh, total_steps=4)
    with mesh:
        state = tl.init_train_state(jax.random.PRNGKey(0), cfg)
        state = jax.device_put(state, shardings)
        corpus = SyntheticCorpus(cfg.vocab_size, seed=5)
        losses = []
        for s in range(4):
            b = corpus.batch(s, 8, 32)
            state, m = jitted(state, {k: jnp.asarray(v) for k, v in b.items()})
            losses.append(float(m["loss"]))
    assert all(np.isfinite(losses)), losses
    print("MULTIDEV_OK", losses[0], losses[-1])
""")


@pytest.mark.slow
def test_multidevice_train_subprocess():
    """Real 8-device SPMD execution of the sharded train step."""
    res = run_py(MULTIDEV_SCRIPT, devices=8, timeout=600)
    assert "MULTIDEV_OK" in res.stdout, res.stdout + res.stderr


SINGLE_VS_MULTI = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import get_config, reduced
    from repro.models import lm
    from repro.models.layers import Runtime
    from repro.sharding import rules as R
    from jax.sharding import NamedSharding, PartitionSpec as P

    cfg = reduced(get_config("olmoe-1b-7b"))
    key = jax.random.PRNGKey(0)
    params = lm.init_params(key, cfg)
    toks = jax.random.randint(key, (4, 16), 0, cfg.vocab_size)
    rt0 = Runtime(compute_dtype=jnp.float32, capacity_factor=8.0)
    base, _, _ = lm.forward(params, toks, rt0, cfg)

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    rules = R.make_rules(mesh, cfg)
    rt = Runtime(compute_dtype=jnp.float32, capacity_factor=8.0,
                 rules=rules, mesh=mesh)
    specs = R.param_pspecs(params, cfg, rules)
    with mesh:
        sp = jax.device_put(params, jax.tree.map(
            lambda s: NamedSharding(mesh, s), specs,
            is_leaf=lambda x: isinstance(x, P)))
        st = jax.device_put(toks, NamedSharding(mesh, P("data", None)))
        out, _, _ = jax.jit(lambda p, t: lm.forward(p, t, rt, cfg))(sp, st)
    err = float(jnp.max(jnp.abs(out - base)))
    print("SPMD_MATCH", err)
    assert err < 1e-3, err
""")


@pytest.mark.slow
def test_sharded_forward_matches_single_device():
    """SPMD-sharded forward == single-device forward (numerics)."""
    res = run_py(SINGLE_VS_MULTI, devices=8, timeout=600)
    assert "SPMD_MATCH" in res.stdout, res.stdout + res.stderr
