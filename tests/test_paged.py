"""Paged rotated-int8 KV cache: BlockPool allocator invariants, block-table
kernel parity, and engine-level bit-identity against the committed dense
goldens (tests/goldens/paged_dense_streams.json, captured on the dense
engine BEFORE paging existed — the acceptance bar for the subsystem)."""
import importlib.util
import json
import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.serve import kv_quant
from repro.kernels import attn_decode as ad
from repro.models import lm
from repro.models.layers import Runtime
from repro.serve.engine import Request, ServeEngine
from repro.serve.faults import Fault, FaultPlan, burst
from repro.serve.paged import (
    NULL_BLOCK, BlockPool, PoolExhausted, init_paged_cache, zero_blocks,
)
from repro.serve.sampling import FINISH_ERROR, FINISH_LENGTH, FINISH_REASONS

from _hypothesis_compat import given, settings, st

KEY = jax.random.PRNGKey(0)
# Matches tests/goldens/capture_paged_goldens.py exactly — bit-identity
# requires the identical Runtime the goldens were captured with.
RTQ = Runtime(compute_dtype=jnp.float32, kv_quant=True)

_GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "goldens")


def _load_golden_module():
    spec = importlib.util.spec_from_file_location(
        "capture_paged_goldens",
        os.path.join(_GOLDEN_DIR, "capture_paged_goldens.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


golden_requests = _load_golden_module().golden_requests

with open(os.path.join(_GOLDEN_DIR, "paged_dense_streams.json")) as _f:
    GOLDEN_STREAMS = json.load(_f)


@pytest.fixture(scope="module")
def model():
    cfg = reduced(get_config("smollm-135m"))
    return cfg, lm.init_params(KEY, cfg)


def _paged_engine(model, **kw):
    cfg, params = model
    kw.setdefault("slots", 4)
    kw.setdefault("max_len", 64)
    kw.setdefault("prompt_pad", 16)
    kw.setdefault("rt", RTQ)
    return ServeEngine(params, cfg, paged=True, block_size=16, **kw)


# ---------------------------------------------------------------------------
# BlockPool allocator
# ---------------------------------------------------------------------------

def test_blockpool_validation():
    with pytest.raises(ValueError, match="blocks"):
        BlockPool(1, 16)
    with pytest.raises(ValueError, match="block_size"):
        BlockPool(4, 0)
    pool = BlockPool(5, 16)
    assert pool.capacity == 4 and pool.available() == 4
    assert pool.ref[NULL_BLOCK] == 1  # pinned


def test_blockpool_alloc_free_refcount_cycle():
    pool = BlockPool(4, 8)
    a, b, c = pool.alloc(), pool.alloc(), pool.alloc()
    assert sorted((a, b, c)) == [1, 2, 3]
    with pytest.raises(PoolExhausted):
        pool.alloc()
    pool.incref(b)
    assert not pool.decref(b)   # still shared
    assert pool.decref(b)       # now freed
    assert pool.available() == 1
    with pytest.raises(AssertionError, match="double free"):
        pool.decref(b)
    assert pool.decref(a) and pool.decref(c)
    assert pool.available() == pool.capacity
    pool.check()


def test_blockpool_chain_hash_is_context_sensitive():
    """hash(block i) must fold in the whole prefix: identical block CONTENT
    under different contexts must not alias (causal K/V differ)."""
    a = np.arange(32, dtype=np.int32)
    b = np.concatenate([a[:16] + 1, a[16:]])  # same 2nd block, new context
    ha = BlockPool.chain_hashes(a, 16)
    hb = BlockPool.chain_hashes(b, 16)
    assert len(ha) == len(hb) == 2
    assert ha[0] != hb[0] and ha[1] != hb[1]
    # true shared prefix DOES collide (that's the sharing condition)
    c = np.concatenate([a[:16], a[16:] + 5])
    hc = BlockPool.chain_hashes(c, 16)
    assert hc[0] == ha[0] and hc[1] != ha[1]
    # partial tail contributes no hash
    assert BlockPool.chain_hashes(a[:20], 16) == [ha[0]]


def test_blockpool_alloc_prompt_shares_full_prefix_blocks():
    pool = BlockPool(8, 4)
    p = np.arange(10, dtype=np.int32)  # 2 full blocks + partial tail
    first = pool.alloc_prompt(p)
    second = pool.alloc_prompt(p)
    assert first[:2] == second[:2]      # full blocks shared
    assert first[2] != second[2]        # partial tail always private
    assert pool.prefix_hits == 2
    assert pool.used() == 4             # 3 + 1, not 6
    pool.check([first, second])
    # all-or-nothing: a prompt that cannot fully fit leaves no residue
    with pytest.raises(PoolExhausted):
        pool.alloc_prompt(np.arange(40, dtype=np.int32))
    assert pool.used() == 4
    pool.check([first, second])


@settings(max_examples=30)
@given(st.integers(0, 10_000))
def test_blockpool_invariants_under_random_op_sequences(seed):
    """Property test: any interleaving of admit/grow/finish/preempt/resume
    keeps the allocator consistent — no double free, no leaked block, free
    list disjoint from referenced blocks, prefix map never points at a
    freed block. pool.check() asserts all of it after every op."""
    rng = np.random.default_rng(seed)
    pool = BlockPool(int(rng.integers(3, 12)), int(rng.integers(1, 6)))
    tables: dict[int, list[int]] = {}   # live slot -> block chain
    swapped: dict[int, int] = {}        # preempted rid -> chain length
    next_id = 0
    for _ in range(40):
        op = rng.choice(["admit", "grow", "finish", "preempt", "resume"])
        if op == "admit":
            prompt = rng.integers(0, 50, size=int(rng.integers(1, 20)))
            try:
                tables[next_id] = pool.alloc_prompt(prompt.astype(np.int32))
                next_id += 1
            except PoolExhausted:
                pass
        elif op == "grow" and tables:
            sid = int(rng.choice(list(tables)))
            try:
                tables[sid].append(pool.alloc())
            except PoolExhausted:
                pass
        elif op == "finish" and tables:
            sid = int(rng.choice(list(tables)))
            for blk in tables.pop(sid):
                pool.decref(blk)
        elif op == "preempt" and tables:
            sid = int(rng.choice(list(tables)))
            chain = tables.pop(sid)
            swapped[sid] = len(chain)
            for blk in chain:
                pool.decref(blk)
        elif op == "resume" and swapped:
            sid = int(rng.choice(list(swapped)))
            n = swapped[sid]
            got: list[int] = []
            try:
                for _ in range(n):
                    got.append(pool.alloc())
                tables[sid] = got
                del swapped[sid]
            except PoolExhausted:
                for blk in got:  # all-or-nothing, like the engine
                    pool.decref(blk)
        pool.check(tables.values())
    # drain everything: the pool must return to pristine
    for chain in tables.values():
        for blk in chain:
            pool.decref(blk)
    assert pool.available() == pool.capacity
    pool.check()


# ---------------------------------------------------------------------------
# Paged cache planes + kernel parity
# ---------------------------------------------------------------------------

def test_init_paged_cache_shapes_and_guards(model):
    cfg, _ = model
    cache = init_paged_cache(cfg, num_blocks=6, block_size=8)
    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    assert cache["attn"]["k"].shape == (cfg.num_layers, 6, kvh, 8, hd)
    assert cache["attn"]["k"].dtype == jnp.int8
    assert cache["attn"]["k_scale"].shape == (cfg.num_layers, 6, kvh, 8, 1)
    assert cache["attn"]["k_scale"].dtype == jnp.float16
    import dataclasses
    bad = dataclasses.replace(cfg, family="ssm")
    with pytest.raises(ValueError, match="famil"):
        init_paged_cache(bad, num_blocks=6, block_size=8)


def test_zero_blocks_zeroes_only_targets(model):
    cfg, _ = model
    cache = init_paged_cache(cfg, num_blocks=4, block_size=4)
    attn = {k: v + 1 for k, v in cache["attn"].items()}
    out = zero_blocks({"attn": attn}, [2])["attn"]
    for leaf in out.values():
        assert float(jnp.abs(leaf[:, 2]).max()) == 0.0
        assert float(jnp.abs(leaf[:, 1]).min()) == 1.0


def _dense_and_paged_caches(rng, b=2, kvh=2, bs=8, maxb=3, hd=128):
    """A random quantized dense cache and its paged twin: pool blocks hold
    the same rows, scattered through a shuffled block table."""
    t = maxb * bs
    kc, ks = kv_quant.kv_encode(
        jnp.asarray(rng.normal(size=(b, kvh, t, hd)), jnp.float32))
    vc, vs = kv_quant.kv_encode(
        jnp.asarray(rng.normal(size=(b, kvh, t, hd)), jnp.float32))
    dense = {"k": kc, "k_scale": ks, "v": vc, "v_scale": vs}
    nb = b * maxb + 1
    table = jnp.asarray(
        1 + rng.permutation(b * maxb).reshape(b, maxb), jnp.int32)
    paged = {"table": table}
    for key, leaf in dense.items():
        x = leaf.reshape(b, kvh, maxb, bs, -1)       # cut T into blocks
        x = jnp.swapaxes(x, 1, 2).reshape(b * maxb, kvh, bs, -1)
        pool = jnp.zeros((nb,) + x.shape[1:], leaf.dtype)
        paged[key] = pool.at[table.reshape(-1)].set(x)
    return dense, paged


def test_paged_to_dense_gather_matches(rng):
    dense, paged = _dense_and_paged_caches(rng)
    out = ad.paged_to_dense(paged)
    for key in dense:
        np.testing.assert_array_equal(np.asarray(out[key]),
                                      np.asarray(dense[key]))


def test_paged_decode_ref_bitwise_vs_dense(rng):
    dense, paged = _dense_and_paged_caches(rng)
    b, kvh, t, hd = dense["k"].shape
    q = jnp.asarray(rng.normal(size=(b, kvh, 2, 1, hd)), jnp.float32)
    ktok = kv_quant.kv_encode(
        jnp.asarray(rng.normal(size=(b, kvh, 1, hd)), jnp.float32))
    vtok = kv_quant.kv_encode(
        jnp.asarray(rng.normal(size=(b, kvh, 1, hd)), jnp.float32))
    kl = jnp.asarray([t - 3, 5], jnp.int32)  # ragged, mid-block lengths
    want = ad.decode_attn_q8(q, dense, ktok, vtok, kl, backend="ref")
    got = ad.decode_attn_q8(q, paged, ktok, vtok, kl, backend="ref")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_paged_prefill_ref_bitwise_vs_dense(rng):
    dense, paged = _dense_and_paged_caches(rng)
    b, kvh, t, hd = dense["k"].shape
    span = 4
    q = jnp.asarray(rng.normal(size=(b, kvh, 2, span, hd)), jnp.float32)
    kl = jnp.asarray([t, t - 7], jnp.int32)
    pos = kl - span
    want = ad.prefill_attn_q8(q, dense, kl, pos, backend="ref")
    got = ad.prefill_attn_q8(q, paged, kl, pos, backend="ref")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.slow
@pytest.mark.timeout(600)
def test_paged_decode_kernel_interpret_bitwise_vs_dense(rng):
    """Kernel path (interpret mode): paged and dense agree bitwise when the
    effective key-tile matches (tt divides block_size, so both run the
    identical flash-attention accumulation order)."""
    dense, paged = _dense_and_paged_caches(rng, bs=8, maxb=2)
    b, kvh, t, hd = dense["k"].shape
    q = jnp.asarray(rng.normal(size=(b, kvh, 2, 1, hd)), jnp.float32)
    ktok = kv_quant.kv_encode(
        jnp.asarray(rng.normal(size=(b, kvh, 1, hd)), jnp.float32))
    vtok = kv_quant.kv_encode(
        jnp.asarray(rng.normal(size=(b, kvh, 1, hd)), jnp.float32))
    kl = jnp.asarray([t, t - 5], jnp.int32)
    for tt in (4, 8):
        want = ad.decode_attn_q8(q, dense, ktok, vtok, kl,
                                 backend="pallas", interpret=True, tt=tt)
        got = ad.decode_attn_q8(q, paged, ktok, vtok, kl,
                                backend="pallas", interpret=True, tt=tt)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# Engine: bit-identity against the pre-paging dense goldens
# ---------------------------------------------------------------------------

def _assert_matches_goldens(done):
    streams = {str(r.rid): [int(tok) for tok in r.out] for r in done}
    assert set(streams) == set(GOLDEN_STREAMS)
    for rid, want in GOLDEN_STREAMS.items():
        assert streams[rid] == want, f"rid {rid} diverged from dense golden"


@pytest.mark.timeout(600)
def test_paged_engine_bit_identical_to_dense_goldens(model):
    cfg, _ = model
    eng = _paged_engine(model)
    done = eng.run(golden_requests(cfg.vocab_size))
    _assert_matches_goldens(done)
    st_ = eng.stats()
    assert st_["paged"] and st_["prefix_hits"] >= 1  # rid 100/101 shared
    assert st_["pool_blocks_used"] == 0              # fully drained
    eng.pool.check(eng._table)


@pytest.mark.timeout(600)
def test_paged_tiny_pool_preempts_swaps_and_stays_bit_identical(model):
    """4 usable blocks for an 11-request burst: the engine must preempt,
    host-swap block sets, and resume — with every stream still bit-equal
    to the dense goldens."""
    cfg, _ = model
    eng = _paged_engine(model, num_blocks=5)
    done = eng.run(golden_requests(cfg.vocab_size))
    _assert_matches_goldens(done)
    st_ = eng.stats()
    assert st_["preemptions"] >= 1 and st_["resumes"] >= 1
    assert st_["blocks_swapped"] >= 1
    assert st_["pool_blocks_used"] == 0
    eng.pool.check(eng._table)


@pytest.mark.timeout(300)
def test_paged_prefix_sharing_dedups_pool_blocks(model):
    """Two live requests over the same 32-token prefix must hold the full
    prefix blocks ONCE (refcount 2), not twice."""
    cfg, _ = model
    eng = _paged_engine(model, slots=2)
    shared = (np.arange(32) % cfg.vocab_size).astype(np.int32)
    reqs = [Request(rid=0, prompt=shared.copy(), max_new=8),
            Request(rid=1, prompt=np.concatenate(
                [shared, np.asarray([7], np.int32)]), max_new=8)]
    it = eng.generate(reqs)
    next(it)
    assert eng.pool.prefix_hits == 2        # both 16-token prefix blocks
    shared_blocks = set(eng._slot_blocks[0]) & set(eng._slot_blocks[1])
    assert len(shared_blocks) == 2
    assert all(eng.pool.ref[b] == 2 for b in shared_blocks)
    eng.pool.check(eng._table)
    list(it)
    assert eng.pool.used() == 0


@pytest.mark.timeout(300)
def test_paged_oversize_prompt_finishes_error_not_crash(model):
    cfg, _ = model
    eng = _paged_engine(model, num_blocks=3)  # 2 usable blocks = 32 tokens
    big = Request(rid=0, prompt=(np.arange(40) % cfg.vocab_size
                                 ).astype(np.int32), max_new=4)
    ok = Request(rid=1, prompt=np.arange(4, dtype=np.int32), max_new=3)
    list(eng.generate([big, ok]))
    assert big.finish_reason == FINISH_ERROR and big.out == []
    assert ok.finish_reason == FINISH_LENGTH
    assert eng.stats()["pool_exhausted"] >= 1
    assert eng.pool.used() == 0


def test_paged_requires_kv_quant(model):
    cfg, params = model
    with pytest.raises(ValueError, match="kv_quant"):
        ServeEngine(params, cfg, slots=2, max_len=48, paged=True,
                    rt=Runtime(compute_dtype=jnp.float32))


# ---------------------------------------------------------------------------
# Satellites: stats split, mesh guard
# ---------------------------------------------------------------------------

@pytest.mark.timeout(300)
def test_stats_reserved_vs_live_split(model):
    """cache_bytes_reserved counts allocation (blocks / dense planes);
    cache_bytes_live is position-weighted — live <= reserved always, and
    both exist on dense AND paged engines."""
    cfg, params = model
    dense = ServeEngine(params, cfg, slots=2, max_len=48, rt=RTQ)
    reqs = [Request(rid=0, prompt=np.arange(6, dtype=np.int32), max_new=4)]
    it = dense.generate(reqs)
    next(it)
    st_ = dense.stats()
    assert st_["cache_bytes_reserved"] == dense.cache_bytes
    assert 0 < st_["cache_bytes_live"] <= st_["cache_bytes_reserved"]
    list(it)
    assert dense.stats()["cache_bytes_live"] == 0

    eng = _paged_engine(model, slots=2)
    reqs = [Request(rid=0, prompt=np.arange(18, dtype=np.int32), max_new=4)]
    it = eng.generate(reqs)
    next(it)
    st_ = eng.stats()
    # 18 tokens -> 2 blocks reserved (32 token-slots), 19+ live tokens
    assert st_["cache_bytes_reserved"] > st_["cache_bytes_live"] > 0
    assert st_["pool_utilization"] > 0
    assert st_["max_concurrent"] == 1
    list(it)
    assert eng.stats()["cache_bytes_live"] == 0
    assert eng.stats()["pool_utilization"] == 0


def test_mesh_with_data_axis_raises_clear_error(model):
    cfg, params = model
    class _FakeMesh:
        shape = {"data": 2, "model": 1}
    with pytest.raises(ValueError, match="data"):
        ServeEngine(params, cfg, slots=2, max_len=48, rt=RTQ,
                    mesh=_FakeMesh())


# ---------------------------------------------------------------------------
# Chaos under paging
# ---------------------------------------------------------------------------

@pytest.mark.timeout(600)
def test_paged_kv_nan_quarantine_zeroes_blocks_healthy_stream_intact(model):
    """The fault router must follow the block table: poisoning slot 0 (a)
    errors that stream, (b) leaves the neighbor bit-identical to a
    fault-free paged run, (c) returns ZEROED blocks to the pool so the
    next tenant decodes as in a fresh engine."""
    cfg, _ = model
    def reqs():
        return [Request(rid=i, prompt=(np.arange(4 + i) % cfg.vocab_size
                                       ).astype(np.int32), max_new=6)
                for i in range(2)]
    clean = reqs()
    _paged_engine(model, slots=2).run(clean)

    plan = FaultPlan([Fault("kv_nan", step=2, slot=0, plane="k_scale",
                            value=math.nan)])
    eng = _paged_engine(model, slots=2, faults=plan)
    faulted = reqs()
    list(eng.generate(faulted))
    poisoned, healthy = faulted
    assert poisoned.finish_reason == FINISH_ERROR
    assert healthy.finish_reason == FINISH_LENGTH
    assert healthy.out == clean[1].out
    assert eng.quarantined == 1
    assert eng.pool.used() == 0
    eng.pool.check(eng._table)
    # poisoned blocks were zeroed before returning to the free list: a new
    # tenant reusing them decodes exactly as in a fresh engine
    again = [Request(rid=10, prompt=np.arange(4, dtype=np.int32), max_new=4)]
    list(eng.generate(again))
    ref = [Request(rid=10, prompt=np.arange(4, dtype=np.int32), max_new=4)]
    _paged_engine(model, slots=2).run(ref)
    assert again[0].out == ref[0].out


@pytest.mark.timeout(600)
def test_paged_chaos_burst_everything_terminates(model):
    """Full chaos plan over a paged engine with a tight pool: every request
    reaches a terminal finish_reason from the closed vocabulary and the
    pool drains to zero — no leaked or wedged blocks."""
    cfg, _ = model
    plan = FaultPlan([
        Fault("kv_nan", step=3, slot=0),
        Fault("clock_skip", step=5, dt=1.0),
        Fault("stall", step=5, dt=2.0),
    ])
    eng = _paged_engine(model, slots=2, num_blocks=7, max_queue=4,
                        shed_policy="shed_lowest", scheduler="priority",
                        watchdog_timeout_s=0.5, faults=plan)
    reqs = burst(8, cfg.vocab_size, max_new=6)
    for i, r in enumerate(reqs):
        r.priority = i % 3
        if i % 2:
            r.deadline_ms = 400.0
    for r in reqs:
        eng.submit_request(r)
    list(eng.generate())
    assert all(r.done for r in reqs)
    assert all(r.finish_reason in FINISH_REASONS for r in reqs)
    assert all(r is None for r in eng.active)
    assert len(eng.scheduler) == 0 and eng.stats()["swapped"] == 0
    assert eng.pool.used() == 0
    eng.pool.check(eng._table)
