"""Run one forward + one quantized decode step for EVERY assigned
architecture (reduced configs) — the whole zoo through the public API.

    PYTHONPATH=src python examples/multiarch_smoke.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ARCH_IDS, get_config, reduced
from repro.models import lm
from repro.models.layers import Runtime
from repro.serve.quantized import quantize_params

rt = Runtime(compute_dtype=jnp.float32, capacity_factor=4.0)
key = jax.random.PRNGKey(0)

for arch in ARCH_IDS:
    cfg = reduced(get_config(arch))
    t0 = time.time()
    params = lm.init_params(key, cfg)
    toks = jax.random.randint(key, (2, 12), 0, cfg.vocab_size)
    ff = (jax.random.normal(key, (2, cfg.frontend_len, cfg.frontend_dim))
          if cfg.frontend else None)
    logits, _, _ = lm.forward(params, toks, rt, cfg, frontend_feats=ff)

    q = quantize_params(params, "itq3_s")
    cache = lm.init_cache(cfg, 2, 24, dtype=jnp.float32)
    _, cache, _ = lm.forward(q, toks, rt, cfg, frontend_feats=ff,
                             cache=cache, pos=0)
    dpos = 12 + (cfg.frontend_len if (cfg.frontend and cfg.family != "audio") else 0)
    dl, _ = lm.decode_step(q, toks[:, :1], cache, jnp.int32(dpos), rt, cfg)
    print(f"{arch:24s} [{cfg.family:6s}] fp-fwd + itq3-decode OK "
          f"({time.time()-t0:.1f}s)  logits {tuple(dl.shape)}")
print("\nall 10 architectures OK")
