"""Fault-tolerant training demo: heartbeats, a simulated host failure,
elastic re-mesh, checkpoint restore, deterministic data replay.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/fault_tolerant_train.py

Phase 1 trains on a (4 data x 2 model) mesh with async checkpoints. At a
scripted step a "host" dies (we simulate the fleet losing 2 of 8 devices).
The monitor detects the failure, plan_remesh keeps TP=2 and shrinks data
4->3, and training resumes from the last committed checkpoint on the NEW
mesh — the elastic-restore path (same weights, different sharding) — with
the data pipeline replaying deterministically from the restored step.
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.configs.base import get_config, reduced
from repro.data.pipeline import SyntheticCorpus
from repro.ft.monitor import HeartbeatMonitor, plan_remesh
from repro.launch.train import build_trainer
from repro.train import loop as tl

CKPT = "/tmp/repro_ft_demo"
cfg = reduced(get_config("qwen1.5-0.5b"))
corpus = SyntheticCorpus(cfg.vocab_size, seed=11)
FAIL_AT = 6

print("== phase 1: (data=4, model=2) mesh ==")
mesh = jax.make_mesh((4, 2), ("data", "model"))
jitted, shardings, _ = build_trainer(cfg, mesh, total_steps=20)
monitor = HeartbeatMonitor(num_hosts=4, timeout_s=5.0)
with mesh:
    state = jax.device_put(tl.init_train_state(jax.random.PRNGKey(0), cfg),
                           shardings)
    losses = []
    for step in range(20):
        if step == FAIL_AT:
            print(f"!! simulated failure of host 3 at step {step}")
            monitor.exclude([3])  # heartbeat timeout would do this for real
            break
        b = corpus.batch(step, 8, 32)
        state, m = jitted(state, {k: jnp.asarray(v) for k, v in b.items()})
        monitor.beat(0, step); monitor.beat(1, step); monitor.beat(2, step)
        monitor.beat(3, step)
        losses.append(float(m["loss"]))
        if (step + 1) % 3 == 0:
            ckpt.save(CKPT, step + 1, state)
            print(f"  step {step} loss {losses[-1]:.4f} [checkpoint]")

last = ckpt.latest_step(CKPT)
alive = len(monitor.alive()) * 2  # 2 devices per simulated host
plan = plan_remesh(alive, model=2)
print(f"\n== elastic re-mesh: {alive} devices alive -> "
      f"(data={plan.data}, model={plan.model}); resume from step {last} ==")

mesh2 = jax.make_mesh((plan.data, plan.model), ("data", "model"))
jitted2, shardings2, _ = build_trainer(cfg, mesh2, total_steps=20)
with mesh2:
    template = tl.init_train_state(jax.random.PRNGKey(0), cfg)
    state2, start = ckpt.restore(CKPT, template, shardings=shardings2)
    for step in range(start, start + 6):
        b = corpus.batch(step, 6, 32)  # batch divisible by new data axis
        state2, m = jitted2(state2, {k: jnp.asarray(v) for k, v in b.items()})
        print(f"  step {step} loss {float(m['loss']):.4f} (on new mesh)")
print("\nOK: training continued across failure with deterministic replay.")
