"""Speculative decoding on the quantized engine: the decode tick as a
propose/verify/commit pipeline.

    PYTHONPATH=src python examples/speculative_decode.py

A cheap draft model proposes K tokens per slot from its own KV cache; ONE
batched target pass scores all K+1 window positions against the
rotated-int8 cache (``lm.score_tokens`` -> the PR 5 q-tile kernel); the
accepted prefix plus one corrected token folds back into each slot's
stream. Greedy verification is lossless — the committed stream is the
target's argmax sequence no matter what the draft proposes — which this
example asserts token-for-token against the non-speculative engine.

Two self-draft pairs (a draft that is a layer-prefix of the target,
sharing embedding/head weights by reference):

* an HONEST 1-layer draft of the full target — realistic low acceptance,
  streams still bit-identical;
* an acceptance-friendly target whose layers >= 1 are exact no-ops
  (zeroed residual projections) — the 1-layer draft IS the target, so
  ~every proposal verifies and tokens-per-step approaches K+1.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduced
from repro.models import lm
from repro.models.layers import Runtime
from repro.serve import spec
from repro.serve.engine import Request, ServeEngine

cfg = reduced(get_config("smollm-135m"))
rt = Runtime(compute_dtype=jnp.float32, kv_quant=True)
rng = np.random.default_rng(7)
prompts = [rng.integers(1, cfg.vocab_size, size=5 + 2 * i) for i in range(4)]


def serve(params, draft_depth=None, k=4):
    kw = {}
    if draft_depth:
        dparams, dcfg = spec.draft_from_params(params, cfg, draft_depth)
        kw = dict(draft_params=dparams, draft_cfg=dcfg, num_draft_tokens=k)
    eng = ServeEngine(params, cfg, slots=4, max_len=64, rt=rt, **kw)
    done = eng.run([Request(rid=i, prompt=p, max_new=24)
                    for i, p in enumerate(prompts)])
    return [r.out for r in done], eng.stats()


params = lm.init_params(jax.random.PRNGKey(0), cfg)
base, _ = serve(params)
tok, st = serve(params, draft_depth=1)
assert tok == base, "greedy speculative streams must match non-speculative"
print(f"honest 1/{cfg.num_layers}-layer self-draft: token parity OK, "
      f"acceptance {st['acceptance_rate']:.1%}, "
      f"{st['tokens_per_step']:.2f} tokens/step")

# acceptance-friendly target: layers >= 1 get zero residual projections
# (exact passthroughs), so the 1-layer draft computes the target's logits
layers = {kk: dict(v) if isinstance(v, dict) else v
          for kk, v in params["layers"].items()}
layers["attn"]["wo"] = layers["attn"]["wo"].at[1:].set(0.0)
layers["mlp"]["down"] = layers["mlp"]["down"].at[1:].set(0.0)
noop = dict(params, layers=layers)
base, _ = serve(noop)
tok, st = serve(noop, draft_depth=1)
assert tok == base, "greedy speculative streams must match non-speculative"
assert st["acceptance_rate"] > 0.9, st["acceptance_rate"]
assert st["tokens_per_step"] > 2.0, st["tokens_per_step"]
print(f"no-op-tail self-draft:        token parity OK, "
      f"acceptance {st['acceptance_rate']:.1%}, "
      f"{st['tokens_per_step']:.2f} tokens/step "
      f"({st['spec_steps']} windows for "
      f"{sum(len(t) for t in tok)} tokens)")
