"""End-to-end driver: train a ~100M-class (reduced) model a few hundred
steps, checkpoint it, quantize with a mixed-precision QuantPolicy, and
serve batched requests — straight from the quantized checkpoint.

    PYTHONPATH=src python examples/train_then_serve_quantized.py \
        [--arch smollm-135m] [--steps 300]

This is the paper's deployment story in one script: full-precision
training -> Algorithm 1 offline quantization (policy-controlled per
layer) -> packed-plane checkpoint -> serving from disk, with eval-loss
measured before/after quantization for every 3-bit format.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.configs.base import get_config, mixed_precision_recipe, reduced
from repro.data.pipeline import SyntheticCorpus
from repro.models import lm
from repro.models.layers import Runtime
from repro.serve.engine import Request, ServeEngine
from repro.serve.quantized import (
    QuantPolicy, describe_quantized, quantize_params, quantized_bytes,
)
from repro.train import loop as tl

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="smollm-135m")
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--ckpt", default="/tmp/repro_e2e_ckpt")
args = ap.parse_args()

cfg = reduced(get_config(args.arch))
rt = Runtime(compute_dtype=jnp.float32)
corpus = SyntheticCorpus(cfg.vocab_size, seed=3)

print(f"== training {cfg.name} (reduced) for {args.steps} steps ==")
step = jax.jit(tl.make_train_step(cfg, rt, warmup=10, total_steps=args.steps,
                                  lr_peak=3e-3))
state = tl.init_train_state(jax.random.PRNGKey(0), cfg)
t0 = time.time()
for s in range(args.steps):
    b = corpus.batch(s, 16, 64)
    state, m = step(state, {k: jnp.asarray(v) for k, v in b.items()})
    if s % 50 == 0 or s == args.steps - 1:
        print(f"  step {s:4d} loss {float(m['loss']):.4f}")
print(f"trained in {time.time()-t0:.1f}s; checkpointing to {args.ckpt}")
ckpt.save(args.ckpt, args.steps, state)


def eval_loss(params):
    tot = 0.0
    for b in corpus.eval_batches(4, 8, 64):
        l, _ = lm.forward_xent(params, jnp.asarray(b["tokens"]),
                               jnp.asarray(b["labels"]), rt, cfg)
        tot += float(l)
    return tot / 4


base = eval_loss(state.params)
print(f"\n== quantization quality (eval loss; fp={base:.4f}) ==")
for fmt in ("q8_0", "iq3_s", "itq3_s", "itq3_x"):
    q = quantize_params(state.params, fmt)
    dl = eval_loss(q) - base
    print(f"  {fmt:8s} delta={dl:+.4f}  bytes={quantized_bytes(q)/1e6:.1f}MB")

print("\n== mixed-precision policy (head 8-bit, MLP sub-block, rest itq3_s) ==")
policy = QuantPolicy.from_dict(mixed_precision_recipe(cfg))
qparams = quantize_params(state.params, policy)
for path, fmt in sorted(describe_quantized(qparams).items()):
    print(f"  {path:24s} -> {fmt}")
print(f"  eval delta={eval_loss(qparams)-base:+.4f}  "
      f"bytes={quantized_bytes(qparams)/1e6:.1f}MB")

qdir = args.ckpt + "_quantized"
ckpt.save(qdir, args.steps, qparams)
print(f"saved packed-plane checkpoint to {qdir}")

print("\n== serving the policy-quantized model from disk ==")
eng = ServeEngine.from_checkpoint(qdir, cfg, slots=4, max_len=96, rt=rt)
rng = np.random.default_rng(1)
reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 6 + i % 4),
                max_new=12) for i in range(10)]
t0 = time.time()
done = eng.run(reqs)
toks = sum(len(r.out) for r in done)
print(f"served {len(done)} requests / {toks} tokens in {time.time()-t0:.1f}s")
print("sample:", done[0].out)
