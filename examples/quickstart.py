"""Quickstart: the ITQ3_S pipeline end to end on one weight matrix.

    PYTHONPATH=src python examples/quickstart.py

1. Make a heavy-tailed weight matrix (transformer-like outliers).
2. Rotate + ternary-quantize it (paper Algorithm 1) into 3.125 bits/weight.
3. Reconstruct and compare against the no-rotation 3-bit baseline.
4. Run a matmul through all three execution paths (dequant / fused
   weight-rotation / dual-domain activation-rotation) on both qmatmul
   backends (ref and the Pallas kernel in interpret mode), showing they
   agree — one entrypoint, ``qlinear.qmatmul(..., backend=...)``.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import formats, qlinear
from repro.core.fwht import fwht

rng = np.random.default_rng(0)
W = jnp.asarray(rng.standard_t(df=4, size=(1024, 256)) * 0.02, jnp.float32)
x = jnp.asarray(rng.normal(size=(4, 1024)), jnp.float32)

print("== distribution smoothing (Theorem 1) ==")
blocks = np.asarray(W.T.reshape(-1, 256))
rot = np.asarray(fwht(jnp.asarray(blocks)))
kurt = lambda a: float(np.mean(((a - a.mean()) / a.std()) ** 4) - 3)
print(f"excess kurtosis: raw={kurt(blocks):+.2f}  rotated={kurt(rot):+.2f} (0 = gaussian)")

print("\n== quantize (Algorithm 1) ==")
for fmt in ("iq3_s", "itq3_s", "itq3_x"):
    qt = formats.quantize(W, fmt)
    Wh = formats.dequantize(qt, jnp.float32)
    rel = float(jnp.linalg.norm(Wh - W) / jnp.linalg.norm(W))
    bpw = qt.nbytes() * 8 / W.size
    print(f"{fmt:8s} rel-err={rel:.4f}  {bpw:.3f} bits/weight "
          f"({'with' if qt.meta.rotate else 'no'} rotation)")

print("\n== execution paths agree (one qmatmul, two backends) ==")
qt = formats.quantize(W, "itq3_s")
y0 = qlinear.qmatmul(x, qt, mode="dequant", compute_dtype=jnp.float32)
for mode in ("weights", "activations"):
    yj = qlinear.qmatmul(x, qt, mode=mode, backend="ref",
                         compute_dtype=jnp.float32)
    yk = qlinear.qmatmul(x, qt, mode=mode, backend="pallas", interpret=True,
                         tm=4, tn=128, compute_dtype=jnp.float32)
    print(f"mode={mode:12s} |ref-dequant|={float(jnp.max(jnp.abs(yj-y0))):.2e} "
          f"|pallas-dequant|={float(jnp.max(jnp.abs(yk-y0))):.2e}")
print("\nOK — see examples/train_then_serve_quantized.py for the full lifecycle.")
