"""Rotated int8 KV-cache (paper §7.2 future work, implemented): halve the
long-context cache with the same FWHT smoothing the weights get.

    PYTHONPATH=src python examples/kv_cache_quant.py

Shows: (1) per-vector rotated-int8 roundtrip error vs plain int8 on keys
with channel outliers, (2) dequantize-free attention scores via the
isometry q.k == (Hq).(Hk), (3) end-to-end decode logits with a quantized
cache vs exact cache, (4) bytes saved at the long_500k shape.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduced
from repro.core.fwht import fwht
from repro.models import lm
from repro.models.layers import Runtime
from repro.serve import kv_quant

rt = Runtime(compute_dtype=jnp.float32)
key = jax.random.PRNGKey(0)
cfg = reduced(get_config("stablelm-3b"))
params = lm.init_params(key, cfg)

T, B = 24, 2
toks = jax.random.randint(key, (B, T + 1), 0, cfg.vocab_size)
cache = lm.init_cache(cfg, B, 32, dtype=jnp.float32)
_, cache, _ = lm.forward(params, toks[:, :T], rt, cfg, cache=cache, pos=0)

# exact decode
d_exact, _ = lm.decode_step(params, toks[:, T:T+1], cache, jnp.int32(T), rt, cfg)

# quantize the written part of the cache through the rotated-int8 codec
def roundtrip(a):
    codes, scale = kv_quant.kv_encode(a)
    return kv_quant.kv_decode(codes, scale, dtype=a.dtype)

qcache = jax.tree.map(roundtrip, cache)
d_q, _ = lm.decode_step(params, toks[:, T:T+1], qcache, jnp.int32(T), rt, cfg)

err = float(jnp.max(jnp.abs(d_q - d_exact)))
scale = float(jnp.max(jnp.abs(d_exact)))
print(f"decode logits with int8-rotated cache: max err {err:.4f} "
      f"(logit scale {scale:.2f}) -> {100*err/scale:.2f}% relative")

hd = cfg.resolved_head_dim
ratio = kv_quant.cache_bytes_ratio(hd)
full = get_config("zamba2-7b")
bytes_bf16 = 14 * 1 * full.num_kv_heads * 524288 * full.resolved_head_dim * 2 * 2
print(f"\ncache bytes ratio at head_dim {hd}: {ratio:.3f} of bf16")
print(f"zamba2-7b long_500k attention cache: {bytes_bf16/1e9:.1f} GB bf16 -> "
      f"{bytes_bf16*kv_quant.cache_bytes_ratio(full.resolved_head_dim)/1e9:.1f} GB rotated-int8")
