"""Rotated int8 KV-cache serving (paper §7.2, productionized): the engine
decodes straight off an int8+fp16-scale cache — dequantize-free scores via
the isometry q.k == (Hq).(Hk), one inverse FWHT per step on the V side —
at ~0.52x the bf16 cache bytes.

    PYTHONPATH=src python examples/kv_cache_quant.py

Drives the REAL serving path (``Runtime.kv_quant=True``, the same engine
``launch/serve.py --kv-quant`` boots), not the standalone codec: greedy
rollouts through ``ServeEngine`` with the quantized cache are compared
token-for-token against the fp32-cache engine, and the cache shrink is read
off the engine's ``cache_bytes`` counter.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (
    SHAPES, get_config, kv_cache_bytes_per_token, reduced,
)
from repro.models import lm
from repro.models.layers import Runtime
from repro.serve import kv_quant
from repro.serve.engine import Request, ServeEngine

cfg = reduced(get_config("stablelm-3b"))
params = lm.init_params(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(7)
prompts = [rng.integers(1, cfg.vocab_size, size=6 + i) for i in range(3)]

outs, engines = {}, {}
for kv_q in (False, True):
    rt = Runtime(compute_dtype=jnp.float32, kv_quant=kv_q)
    eng = ServeEngine(params, cfg, slots=3, max_len=48, rt=rt)
    done = eng.run([Request(rid=i, prompt=p, max_new=8)
                    for i, p in enumerate(prompts)])
    outs[kv_q] = [r.out for r in done]
    engines[kv_q] = eng
    label = "rotated-int8" if kv_q else "fp32"
    print(f"{label:>12} cache: {eng.cache_bytes:6d} B, "
          f"{eng.stats()['syncs_per_token']:.2f} syncs/token, "
          f"tokens {done[0].out}")

# greedy rollouts are token-identical: rotation spreads the per-vector
# outliers (Theorem 1) before the int8 grid, so the cache quantization
# error never flips an argmax on this model
assert outs[False] == outs[True], (outs[False], outs[True])
shrink = engines[True].cache_bytes / engines[False].cache_bytes
print(f"\ntoken parity: OK; engine cache shrink vs fp32: {shrink:.3f}x")

hd = cfg.resolved_head_dim
print(f"bytes/element ratio vs bf16 at head_dim {hd}: "
      f"{kv_quant.cache_bytes_ratio(hd):.3f}  "
      f"((HD + 2 scale bytes) / 2*HD)")

full = get_config("zamba2-7b")
bpt_fp = kv_cache_bytes_per_token(full)            # bf16 deployment layout
bpt_q8 = kv_cache_bytes_per_token(full, kv_quant=True)
T = 524288  # the long_500k shape
print(f"zamba2-7b long_500k attention cache: "
      f"{bpt_fp * T / 1e9:.1f} GB bf16 -> {bpt_q8 * T / 1e9:.1f} GB "
      f"rotated-int8 ({bpt_q8 / bpt_fp:.3f}x)")

# --- long_500k hybrid-serving dry run (reduced zamba2, REAL 524288-slot
# cache) -------------------------------------------------------------------
# The int8 layout is what makes this cell allocatable at all: the reduced
# hybrid's rotated-int8 cache at 524288 positions is ~0.4 GB where the fp32
# layout would be ~1.6 GB. Boots the real engine, admits one prompt through
# the chunk ladder, and decodes a few tokens off the full-length cache —
# proving the long_500k serving path end to end, not just the arithmetic.
# Skip with REPRO_LONG500K=0 (it adds ~1 min on CPU).
import os
import time

if os.environ.get("REPRO_LONG500K", "1") != "0":
    long_T = SHAPES["long_500k"].seq_len
    cfg_h = reduced(full)
    params_h = lm.init_params(jax.random.PRNGKey(1), cfg_h)
    rt_h = Runtime(compute_dtype=jnp.float32, kv_quant=True)
    t0 = time.time()
    eng_h = ServeEngine(params_h, cfg_h, slots=1, max_len=long_T, rt=rt_h)
    boot_s = time.time() - t0
    t0 = time.time()
    [r] = eng_h.run([Request(rid=0, prompt=rng.integers(
        1, cfg_h.vocab_size, size=9), max_new=3)])
    assert len(r.out) == 3 and r.finish_reason == "length", (
        r.out, r.finish_reason)
    print(f"\nlong_500k dry run (reduced zamba2-7b, {long_T} positions): "
          f"cache {eng_h.cache_bytes / 1e6:.0f} MB rotated-int8, "
          f"boot {boot_s:.0f}s, 3 tokens in {time.time() - t0:.0f}s, "
          f"tokens {r.out}")

    # --- the same long_500k window through the PAGED pool ------------------
    # The dense engine above must ALLOCATE all 524288 positions to open the
    # window; the paged engine opens the identical window with a block table
    # 32768 entries wide but only allocates pool blocks for live tokens —
    # here 64 blocks (1024 token-slots), ~512x less cache memory resident
    # for the same max_len. (On CPU the einsum reference still gathers a
    # dense view per step, so this cell demonstrates ALLOCATION, not CPU
    # walltime; the TPU kernel reads blocks through the table directly.)
    t0 = time.time()
    eng_p = ServeEngine(params, cfg, slots=1, max_len=long_T,
                        rt=Runtime(compute_dtype=jnp.float32, kv_quant=True),
                        paged=True, num_blocks=65, block_size=16)
    boot_s = time.time() - t0
    t0 = time.time()
    [rp] = eng_p.run([Request(rid=0, prompt=rng.integers(
        1, cfg.vocab_size, size=9), max_new=3)])
    assert len(rp.out) == 3 and rp.finish_reason == "length", (
        rp.out, rp.finish_reason)
    st = eng_p.stats()
    print(f"long_500k paged dry run (reduced {cfg.name}, {long_T}-position "
          f"window): pool {eng_p.cache_bytes / 1e6:.1f} MB vs "
          f"{kv_cache_bytes_per_token(cfg, kv_quant=True) * long_T / 1e6:.0f}"
          f" MB dense reservation, boot {boot_s:.0f}s, 3 tokens in "
          f"{time.time() - t0:.0f}s, tokens {rp.out}")
